//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this workspace vendors
//! the small slice of anyhow it actually uses: a context-chain [`Error`],
//! the [`Result`] alias, the [`Context`] extension trait (on both `Result`
//! and `Option`), and the `anyhow!` / `bail!` / `ensure!` macros.  Error
//! sources are flattened into display strings at conversion time — no
//! downcasting, backtraces, or `std::error::Error` impl (matching anyhow's
//! own deliberate lack of the latter, which is also what makes the blanket
//! `From` impl below coherent).

use std::fmt;

/// A context-chain error: the most recently attached context first, the
/// root cause last, rendered `ctx: ctx: root`.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach a layer of context (used by the [`Context`] trait).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints the Debug form; render the chain
        // one cause per line like anyhow does.
        match self.chain.split_first() {
            None => write!(f, "(empty error)"),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for c in rest {
                        write!(f, "\n    {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`
// (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Drop-in alias for `std::result::Result` with [`Error`] as the default
/// error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        "nope".parse::<u32>().context("parsing the answer")?;
        Ok(0)
    }

    #[test]
    fn context_chains_render_outermost_first() {
        let err = fails().unwrap_err();
        let text = err.to_string();
        assert!(text.starts_with("parsing the answer: "), "{text}");
    }

    #[test]
    fn option_context_works() {
        let v: Option<u32> = None;
        let err = v.context("missing value").unwrap_err();
        assert_eq!(err.to_string(), "missing value");
    }

    #[test]
    fn macros_compile_and_fire() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Err(anyhow!("fell through with {}", x))
        }
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
        assert!(inner(5).unwrap_err().to_string().contains("right out"));
        assert!(inner(1).unwrap_err().to_string().contains("fell through"));
    }

    #[test]
    fn io_error_sources_flatten() {
        let err: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(err.to_string().contains("boom"));
        assert_eq!(err.root_cause(), "boom");
    }
}
