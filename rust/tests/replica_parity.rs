//! Replica-plane parity: the copy-on-write shared parameter store
//! (`coordinator::replica`) must be **bit-identical** to the dense
//! layout it replaced — K per-client buffers, each applying every
//! delivered update itself.  The tests maintain exactly that dense
//! K-replica mirror on the side (incremental `zo::apply_update` per
//! client per delivered round, the old memory layout's arithmetic) and
//! compare bit patterns against the store's logical replicas:
//!
//! * FeedSign / DP-FeedSign / ZO-FedSGD under partial participation,
//!   BER impairment and deadline stragglers (`catchup = "off"`: every
//!   committed round reaches every client, and the orbit records the
//!   *delivered* aggregate, so the mirror is exact);
//! * replay catch-up with an injected offline schedule: stale logical
//!   replicas read back (through the snapshot cache or the
//!   init-plus-orbit reconstruction) as the dense straggler buffers,
//!   mid-run and after `catch_up_all`;
//! * a proptest-lite case randomizing the participation schedule;
//! * the memory contract itself: an all-synced pool holds one `d`-float
//!   buffer regardless of K, with exactly one canonical apply per
//!   committed round.
//!
//! Replicas are compared as `u32` bit patterns throughout — BER can
//! drive weights non-finite, where f32 equality would lie.

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::session::RoundPlan;
use feedsign::coordinator::{Algorithm, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, NetCfg};
use feedsign::orbit::OrbitEntry;
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::zo;
use feedsign::util::proptest_lite::{check, Gen};

fn bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|v| v.to_bits()).collect()
}

fn build_session(algo: Algorithm, k: usize, cfg_mut: impl FnOnce(&mut SessionCfg)) -> Session {
    let train = generate(&SYNTH_CIFAR10, 400, 0);
    let test = generate(&SYNTH_CIFAR10, 150, 1);
    let shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 17)
        })
        .collect();
    let mut cfg = SessionCfg {
        algorithm: algo,
        rounds: 0,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        seed: 17,
        ..Default::default()
    };
    cfg_mut(&mut cfg);
    Session::new(cfg, clients, train, test)
}

/// The dense baseline the replica plane replaced: K independent
/// parameter buffers, each applying every round it *hears* itself.
/// `applied[id]` is the first round client `id` has not applied — the
/// dense twin of the store's watermark.
struct DenseMirror {
    w: Vec<Vec<f32>>,
    applied: Vec<usize>,
}

impl DenseMirror {
    fn new(s: &Session) -> Self {
        let k = s.clients.len();
        let init = s.replica(0).into_owned();
        DenseMirror { w: vec![init; k], applied: vec![0; k] }
    }

    /// Apply orbit entries `[applied[id], upto)` to client `id`'s dense
    /// buffer — the per-client AXPY loop the old layout ran eagerly.
    fn sync_to(&mut self, s: &Session, id: usize, upto: usize) {
        let eta = s.orbit.eta;
        for t in self.applied[id]..upto {
            match &s.orbit.entries[t] {
                OrbitEntry::Sign(sign) => {
                    zo::apply_update(&mut self.w[id], t as u32, *sign as f32 * eta);
                }
                OrbitEntry::Pairs(pairs) => {
                    let k = pairs.len().max(1) as f32;
                    for &(seed, p) in pairs {
                        zo::apply_update(&mut self.w[id], seed, eta * p / k);
                    }
                }
            }
        }
        self.applied[id] = self.applied[id].max(upto);
    }

    /// Broadcast delivery (`catchup = "off"`): every client applies the
    /// round that just committed.
    fn sync_all(&mut self, s: &Session) {
        for id in 0..self.w.len() {
            self.sync_to(s, id, s.orbit.len());
        }
    }
}

#[test]
fn broadcast_runs_match_the_dense_mirror_bit_for_bit() {
    // every synchronized engine, under partial participation, BER
    // corruption and deadline stragglers — all catchup-off, where the
    // broadcast reaches the whole pool and the orbit is the delivered
    // update stream
    type CfgMutator = Box<dyn Fn(&mut SessionCfg)>;
    let scenarios: Vec<(&str, CfgMutator)> = vec![
        ("partial", Box::new(|cfg: &mut SessionCfg| {
            cfg.participation = ParticipationCfg::Fraction(0.4);
        })),
        ("ber", Box::new(|cfg: &mut SessionCfg| {
            cfg.net = NetCfg {
                channel: ChannelModel::BitFlip { ber: 0.05 },
                links: LinkAssignment::parse("mixed").unwrap(),
                deadline_s: 0.0,
                channel_seed: 3,
            };
        })),
        ("deadline", Box::new(|cfg: &mut SessionCfg| {
            cfg.net = NetCfg {
                channel: ChannelModel::Ideal,
                links: LinkAssignment::parse("mixed").unwrap(),
                deadline_s: 0.1,
                channel_seed: 3,
            };
        })),
        ("drop", Box::new(|cfg: &mut SessionCfg| {
            cfg.net = NetCfg {
                channel: ChannelModel::Erasure { p: 0.3 },
                links: LinkAssignment::parse("mixed").unwrap(),
                deadline_s: 0.0,
                channel_seed: 3,
            };
        })),
    ];
    for algo in [Algorithm::FeedSign, Algorithm::DpFeedSign { epsilon: 4.0 }, Algorithm::ZoFedSgd] {
        for (label, mutate) in &scenarios {
            let mut s = build_session(algo, 5, |cfg| mutate(cfg));
            let mut mirror = DenseMirror::new(&s);
            for t in 0..60 {
                s.step(t);
                mirror.sync_all(&s);
                if t % 20 == 19 {
                    for id in 0..5 {
                        assert_eq!(
                            bits(&mirror.w[id]),
                            bits(&s.replica(id)),
                            "{}/{label}: client {id} diverged from the dense mirror at round {t}",
                            algo.name()
                        );
                    }
                }
            }
            for id in 0..5 {
                assert_eq!(
                    bits(&mirror.w[id]),
                    bits(&s.replica(id)),
                    "{}/{label}: final client {id} diverged from the dense mirror",
                    algo.name()
                );
            }
            assert!(s.replicas_synchronized(), "{}/{label}", algo.name());
            // the memory contract: a broadcast pool shares one buffer
            let st = s.replica_stats();
            assert_eq!(st.owned_clients, 0, "{}/{label}", algo.name());
            assert_eq!(
                st.peak_bytes,
                4 * st.d,
                "{}/{label}: all-synced pool must cost O(d), not K·d",
                algo.name()
            );
        }
    }
}

#[test]
fn replay_catchup_stale_reads_match_the_dense_straggler() {
    // injected offline schedule: client 3 disappears for a span; its
    // *stale* logical replica must read back (cache or reconstruction)
    // as the dense buffer that stopped applying rounds — mid-run, for
    // both the cached and the cache-disabled store
    for (label, cache) in [("cached", 8usize), ("cold", 0)] {
        for algo in [Algorithm::FeedSign, Algorithm::ZoFedSgd] {
            let mut s = build_session(algo, 4, |cfg| {
                cfg.catchup = CatchupCfg::Replay;
                cfg.replica_cache = cache;
                // injected plans bypass the sampler; declare a config that
                // can strand clients so snapshot admission stays open and
                // the "cached" arm really exercises the cache path
                cfg.participation = ParticipationCfg::Fraction(0.75);
            });
            let mut mirror = DenseMirror::new(&s);
            let all = |t: u64| RoundPlan { round: t, participants: vec![0, 1, 2, 3] };
            let without3 = |t: u64| RoundPlan { round: t, participants: vec![0, 1, 2] };
            for t in 0..5 {
                s.step_with_plan(all(t));
                for id in 0..4 {
                    mirror.sync_to(&s, id, s.orbit.len());
                }
            }
            for t in 5..25 {
                s.step_with_plan(without3(t));
                for id in 0..3 {
                    mirror.sync_to(&s, id, s.orbit.len());
                }
                // client 3's dense buffer is frozen at round 5; the
                // store's stale logical replica must read identically
                assert_eq!(
                    bits(&mirror.w[3]),
                    bits(&s.replica(3)),
                    "{}/{label}: stale read diverged at round {t}",
                    algo.name()
                );
            }
            // rejoin: replay brings the dense straggler and the logical
            // replica to the same bits
            s.step_with_plan(all(25));
            for id in 0..4 {
                mirror.sync_to(&s, id, s.orbit.len());
            }
            for id in 0..4 {
                assert_eq!(
                    bits(&mirror.w[id]),
                    bits(&s.replica(id)),
                    "{}/{label}: client {id} diverged after rejoin",
                    algo.name()
                );
            }
            s.catch_up_all();
            assert!(s.replicas_synchronized(), "{}/{label}", algo.name());
        }
    }
}

#[test]
fn randomized_participation_schedules_stay_bit_identical() {
    // proptest-lite: arbitrary participation schedules (including empty
    // rounds and long per-client gaps) — after the run every logical
    // replica equals its dense mirror, and catch_up_all restores pool
    // equality
    check("replica plane vs dense mirror", |g: &mut Gen| {
        let k = g.usize_in(2, 5);
        let rounds = g.usize_in(5, 25);
        let cache = g.usize_in(0, 3);
        let mut s = build_session(Algorithm::FeedSign, k, |cfg| {
            cfg.catchup = CatchupCfg::Replay;
            cfg.replica_cache = cache;
            cfg.participation = ParticipationCfg::Fraction(0.75);
        });
        let mut mirror = DenseMirror::new(&s);
        for t in 0..rounds {
            let participants: Vec<usize> = (0..k).filter(|_| g.usize_in(0, 2) > 0).collect();
            // stale participants replay their missed span before probing
            for &id in &participants {
                mirror.sync_to(&s, id, t);
            }
            s.step_with_plan(RoundPlan { round: t as u64, participants: participants.clone() });
            // ...and hear the round they voted in (when it committed)
            for &id in &participants {
                mirror.sync_to(&s, id, s.orbit.len());
            }
            // spot-check a random client's logical replica, stale or not
            let probe = g.usize_in(0, k);
            mirror.sync_to(&s, probe, s.tracker().last_synced(probe) as usize);
            assert_eq!(
                bits(&mirror.w[probe]),
                bits(&s.replica(probe)),
                "client {probe} diverged at round {t} (k={k}, cache={cache})"
            );
        }
        s.catch_up_all();
        for id in 0..k {
            mirror.sync_to(&s, id, s.orbit.len());
            assert_eq!(
                bits(&mirror.w[id]),
                bits(&s.replica(id)),
                "client {id} diverged after catch_up_all (k={k})"
            );
        }
        assert!(s.replicas_synchronized());
    });
}

#[test]
fn large_pool_memory_is_flat_in_k() {
    // the table8-style pool: K = 200 clients, full participation — the
    // replica plane must hold one canonical buffer (4·d bytes), where
    // the dense layout would hold 200 of them
    let mut s = build_session(Algorithm::FeedSign, 200, |_| {});
    for t in 0..5 {
        s.step(t);
    }
    let st = s.replica_stats();
    assert_eq!(st.clients, 200);
    assert_eq!(st.peak_bytes, 4 * st.d);
    assert!(st.peak_bytes <= 2 * 4 * st.d, "acceptance bound: <= 2·d floats");
    assert_eq!(st.dense_bytes, 200 * 4 * st.d);
    assert_eq!(st.canonical_commits, 5, "exactly one canonical AXPY per round");
    assert!(s.replicas_synchronized());
    assert_eq!(s.ledger.uplink_bits, 5 * 200, "1-bit votes from the whole pool");
}
