//! Cross-module integration tests: config -> session -> metrics -> orbit
//! pipelines, algorithm behaviour contrasts, and protocol invariants that
//! only show up when the whole coordinator runs.

use feedsign::config::{quickstart, ExperimentConfig, ModelSpec, TaskSpec};
use feedsign::coordinator::{Algorithm, Attack};
use feedsign::orbit;

fn vision_cfg(algorithm: &str, rounds: u64) -> ExperimentConfig {
    let mut cfg = quickstart();
    cfg.algorithm = algorithm.into();
    cfg.rounds = rounds;
    cfg.eval_every = 0;
    cfg.verbose = false;
    if algorithm == "mezo" {
        cfg.clients = 1;
    }
    cfg
}

fn lm_cfg(algorithm: &str, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: "itest-lm".into(),
        model: ModelSpec::Transformer { vocab: 48, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 12 },
        task: TaskSpec::SynthLm { name: "synth-sst2".into(), train: 256, test: 128 },
        algorithm: algorithm.into(),
        clients: if algorithm == "mezo" { 1 } else { 3 },
        rounds,
        eta: 1e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: 0,
        eval_batches: 2,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 1,
        verbose: false,
    }
}

#[test]
fn every_algorithm_runs_and_learns_vision() {
    for algo in ["feedsign", "zo-fedsgd", "mezo", "dp-feedsign:20.0"] {
        let mut session = vision_cfg(algo, 800).build_session().unwrap();
        let (l0, _) = session.evaluate();
        let result = session.run();
        assert!(
            result.final_loss < l0,
            "{algo} failed to learn: {l0} -> {}",
            result.final_loss
        );
        assert!(session.replicas_synchronized(), "{algo} desynchronized replicas");
    }
}

#[test]
fn fedsgd_baseline_dominates_zo_in_few_rounds() {
    // FO moves much faster per round (its comm budget is 32d bits/step)
    let mut fo = vision_cfg("fedsgd", 150).build_session().unwrap();
    fo.cfg.eta = 0.1;
    let fo_result = fo.run();
    let mut zo = vision_cfg("feedsign", 150).build_session().unwrap();
    let zo_result = zo.run();
    assert!(fo_result.final_acc > zo_result.final_acc, "FO should win at equal (tiny) round budget");
}

#[test]
fn lm_pipeline_learns_task() {
    let mut session = lm_cfg("feedsign", 1200).build_session().unwrap();
    session.cfg.eta = 1e-3;
    let (l0, a0) = session.evaluate();
    let result = session.run();
    assert!(result.final_loss < l0, "LM loss {l0} -> {}", result.final_loss);
    let _ = a0;
}

#[test]
fn comm_ledger_eq5_accounting_across_algorithms() {
    // Eq. 5: FeedSign 1 bit, ZO-FedSGD 64 bits per client-step uplink
    for (algo, per_step_up) in [("feedsign", 1u64), ("zo-fedsgd", 64u64)] {
        let mut session = vision_cfg(algo, 50).build_session().unwrap();
        for t in 0..50 {
            session.step(t);
        }
        assert_eq!(session.ledger.uplink_bits, 50 * 5 * per_step_up, "{algo}");
    }
}

#[test]
fn orbit_roundtrips_through_disk_format_and_replays() {
    let mut session = vision_cfg("feedsign", 300).build_session().unwrap();
    let result = session.run();
    let bytes = orbit::encode(&session.orbit);
    // 300 signs bit-packed: well under 100 bytes + header
    assert!(bytes.len() < 100, "orbit {} bytes", bytes.len());
    let decoded = orbit::decode(&bytes).unwrap();
    let mut w = session.clients[0].engine.init_params(session.cfg.seed);
    decoded.replay(&mut w);
    assert_eq!(w.as_slice(), &*session.replica(0), "disk-roundtripped orbit must replay exactly");
    let _ = result;
}

#[test]
fn zo_fedsgd_orbit_replays_exactly_too() {
    let mut session = vision_cfg("zo-fedsgd", 200).build_session().unwrap();
    session.run();
    let decoded = orbit::decode(&orbit::encode(&session.orbit)).unwrap();
    let mut w = session.clients[0].engine.init_params(session.cfg.seed);
    decoded.replay(&mut w);
    assert_eq!(w.as_slice(), &*session.replica(0));
}

#[test]
fn byzantine_minority_cannot_stop_feedsign() {
    // 2 of 5 sign-flippers: majority still honest, learning proceeds
    let mut cfg = vision_cfg("feedsign", 1200);
    cfg.byzantine_count = 2;
    cfg.attack = Some("sign-flip".into());
    let mut session = cfg.build_session().unwrap();
    let (l0, _) = session.evaluate();
    let result = session.run();
    assert!(result.final_loss < l0, "2/5 byzantine should not stop FeedSign");
}

#[test]
fn byzantine_majority_stops_feedsign() {
    // 3 of 5 sign-flippers: p_t > 1/2, the model must NOT learn (Prop D.5)
    let mut cfg = vision_cfg("feedsign", 800);
    cfg.byzantine_count = 3;
    cfg.attack = Some("sign-flip".into());
    let mut session = cfg.build_session().unwrap();
    let (l0, _) = session.evaluate();
    let result = session.run();
    assert!(
        result.final_loss >= l0 - 0.05,
        "adversarial majority should reverse/stall: {l0} -> {}",
        result.final_loss
    );
}

#[test]
fn random_projection_attack_hurts_zo_more_than_sign_flip_hurts_feedsign() {
    let rounds = 1500;
    let run = |algo: &str, attack: Option<&str>| {
        let mut cfg = vision_cfg(algo, rounds);
        cfg.byzantine_count = usize::from(attack.is_some());
        cfg.attack = attack.map(Into::into);
        cfg.build_session().unwrap().run().final_acc
    };
    let zo_clean = run("zo-fedsgd", None);
    let zo_attacked = run("zo-fedsgd", Some("random-projection:20.0"));
    let fs_clean = run("feedsign", None);
    let fs_attacked = run("feedsign", Some("sign-flip"));
    let zo_drop = zo_clean - zo_attacked;
    let fs_drop = fs_clean - fs_attacked;
    assert!(
        zo_drop > fs_drop,
        "zo drop {zo_drop} should exceed feedsign drop {fs_drop}"
    );
}

#[test]
fn dp_epsilon_orders_convergence() {
    // Remark D.3: smaller eps -> slower convergence (noisier votes)
    let run = |eps: f32| {
        let mut cfg = vision_cfg(&format!("dp-feedsign:{eps}"), 1000);
        cfg.seed = 3;
        cfg.build_session().unwrap().run().final_loss
    };
    let tight = run(0.05); // nearly a fair coin
    let loose = run(20.0); // nearly the plain majority
    assert!(loose < tight - 0.1, "eps=20 loss {loose} should beat eps=0.05 loss {tight}");
}

#[test]
fn heterogeneity_degrades_zo_fedsgd() {
    let run = |beta: Option<f32>, noise: f32| {
        let mut cfg = vision_cfg("zo-fedsgd", 1200);
        cfg.dirichlet_beta = beta;
        cfg.c_g_noise = noise;
        cfg.build_session().unwrap().run().final_loss
    };
    let iid = run(None, 0.0);
    let skewed = run(Some(0.1), 2.0);
    assert!(skewed > iid - 0.02, "high skew + projection noise should not improve ZO: {iid} vs {skewed}");
}

#[test]
fn config_file_roundtrip_drives_identical_run() {
    let cfg = vision_cfg("feedsign", 60);
    let text = cfg.to_toml();
    let parsed = ExperimentConfig::from_toml(&text).unwrap();
    assert_eq!(parsed.algorithm(), Algorithm::FeedSign);
    let r1 = cfg.build_session().unwrap().run();
    let r2 = parsed.build_session().unwrap().run();
    assert_eq!(r1.final_loss, r2.final_loss, "TOML roundtrip changed the run");
    assert_eq!(r1.ledger.uplink_bits, r2.ledger.uplink_bits);
}

#[test]
fn attack_parse_matrix() {
    for (s, expect) in [
        ("sign-flip", Attack::SignFlip),
        ("random-projection:2.5", Attack::RandomProjection { scale: 2.5 }),
        ("label-flip", Attack::LabelFlip),
    ] {
        assert_eq!(Attack::parse(s), Some(expect));
    }
}

#[test]
fn mezo_equals_k1_feedsign_with_projection_scaling() {
    // structural check: a K=1 FeedSign vote is just Sign(p); the two runs
    // differ only in step magnitude (eta vs eta*|p|), so both must learn.
    let mut fs = vision_cfg("feedsign", 600);
    fs.clients = 1;
    let fs_result = fs.build_session().unwrap().run();
    let mezo_result = vision_cfg("mezo", 600).build_session().unwrap().run();
    let (init_loss, _) = vision_cfg("mezo", 1).build_session().unwrap().evaluate();
    assert!(fs_result.final_loss < init_loss);
    assert!(mezo_result.final_loss < init_loss);
}
