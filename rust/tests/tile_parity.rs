//! Tiled-parameter-plane parity: the fused commit+probe sweep and the
//! tiered canonical store must be pure execution strategies — never a
//! protocol change.
//!
//! The contracts, per `coordinator::tile` / `simkit::zo`'s fused kernel:
//!
//! 1. **Tile parity** — for every engine (FeedSign, DP-FeedSign,
//!    ZO-FedSGD), every tile size in {1, 61, 4096, d, d+1} (including
//!    non-divisors of the SIMD lane block), every worker/shard count, a
//!    fused-sweep session is **bit-identical** to the legacy multi-pass
//!    closure-verb engine (`fuse_commits: false`) — under partial
//!    participation, a `ber:P` bit-flip channel, and deadline stragglers
//!    all at once.
//! 2. **Spill parity** — a session whose canonical store pages through a
//!    resident window smaller than `d` lands on the in-RAM bits, while
//!    its peak resident bytes hold to the byte budget (flat memory).
//! 3. **Cross-topology parity** — the threaded distributed topology and
//!    the tiled synchronous session agree bit-for-bit, whatever the tile.
//! 4. **Staging parity** — the restricted seed space (FedKSeed) pre-draws
//!    round t+1's pool index at commit time; the staged probe views must
//!    not change the stream.
//!
//! Replicas are compared as `u32` bit patterns (flips can push weights
//! non-finite; NaN-blind f32 equality must not hide a divergence).

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::distributed::{run_feedsign, DistClient, DistCfg};
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::{Algorithm, Attack, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::data::Dataset;
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, NetCfg};
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::prng::Rng;

const ROUNDS: u64 = 30;
/// LinearProbe(128, 10) parameter count — the `d` the tile sizes bracket.
const D: usize = 128 * 10 + 10;

fn bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|v| v.to_bits()).collect()
}

/// The impaired regime every parity case below runs under: partial
/// participation, a bit-flip channel over heterogeneous links, and a
/// round deadline that cuts iot-class stragglers at plan time.
fn impaired_net() -> NetCfg {
    NetCfg {
        channel: ChannelModel::BitFlip { ber: 0.05 },
        links: LinkAssignment::parse("mixed").unwrap(),
        deadline_s: 0.1,
        channel_seed: 5,
    }
}

/// Execution-strategy knobs under test; everything protocol-level is
/// held fixed across a comparison.
#[derive(Clone, Copy)]
struct Knobs {
    shards: usize,
    threads: usize,
    tile: usize,
    tile_budget: usize,
    fuse: bool,
}

impl Knobs {
    /// The legacy multi-pass closure-verb engine: the parity reference.
    fn legacy() -> Self {
        Knobs { shards: 0, threads: 1, tile: 0, tile_budget: 0, fuse: false }
    }

    fn fused(tile: usize, threads: usize, shards: usize) -> Self {
        Knobs { shards, threads, tile, tile_budget: 0, fuse: true }
    }
}

/// Session with every tiling knob pinned at construction — explicit
/// values are env-proof, so the `FEEDSIGN_TILE` / `FEEDSIGN_TILE_BUDGET`
/// CI legs cannot change what these tests compare.
fn build(algo: Algorithm, k: usize, knobs: Knobs) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
    let data_shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = data_shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: algo,
        rounds: ROUNDS,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        participation: ParticipationCfg::Fraction(0.6),
        catchup: CatchupCfg::Replay,
        net: impaired_net(),
        threads: knobs.threads,
        shards: knobs.shards,
        tile: knobs.tile,
        tile_budget: knobs.tile_budget,
        fuse_commits: knobs.fuse,
        seed: 11,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

fn run_to_end(mut s: Session) -> Session {
    for t in 0..ROUNDS {
        s.step(t);
    }
    s.catch_up_all();
    s
}

fn assert_session_parity(label: &str, base: &Session, s: &Session) {
    for id in 0..base.clients.len() {
        assert_eq!(
            bits(&base.replica(id)),
            bits(&s.replica(id)),
            "{label}: client {id} replica diverged"
        );
    }
    assert_eq!(base.ledger.uplink_bits, s.ledger.uplink_bits, "{label}: uplink bits");
    assert_eq!(base.ledger.downlink_bits, s.ledger.downlink_bits, "{label}: downlink bits");
    assert_eq!(base.net.stats, s.net.stats, "{label}: impairment trace diverged");
    assert_eq!(
        feedsign::orbit::encode(&base.orbit),
        feedsign::orbit::encode(&s.orbit),
        "{label}: orbit bytes diverged"
    );
}

#[test]
fn fused_sweep_is_bit_identical_for_every_tile_thread_and_shard_count() {
    for algo in [
        Algorithm::FeedSign,
        Algorithm::DpFeedSign { epsilon: 2.0 },
        Algorithm::ZoFedSgd,
    ] {
        // legacy multi-pass closure-verb baseline (fuse_commits: false)
        let base = run_to_end(build(algo, 5, Knobs::legacy()));
        assert_eq!(base.probe_stats.staged_probes, 0, "legacy engine must not stage");
        // tile sizes bracket d and include 1 and a SIMD-lane non-divisor
        for tile in [1usize, 61, 4096, D, D + 1] {
            for threads in [1usize, 8] {
                for shards in [0usize, 3] {
                    let s = run_to_end(build(algo, 5, Knobs::fused(tile, threads, shards)));
                    let label = format!("{algo:?}/tile={tile}/threads={threads}/shards={shards}");
                    assert_session_parity(&label, &base, &s);
                }
            }
        }
    }
}

#[test]
fn fused_feedsign_serves_staged_probe_views() {
    // the fused sweep renders round t+1's probe views during the commit
    // of round t; after round 0 every canonical probe must be served
    // from the staged buffers on the batched-probe engine
    let s = run_to_end(build(Algorithm::FeedSign, 5, Knobs::fused(0, 1, 0)));
    assert!(s.probe_stats.staged_probes > 0, "no probe was served from staging");
    // stragglers own replicas and fall back to classic probes, so only
    // canonical passes — not per-probe counts — have a hard bound: at
    // most one pass for round 0 plus one per post-straggler round
    assert!(
        s.probe_stats.canonical_passes < s.probe_stats.unbatched_passes(),
        "staging saved no canonical passes"
    );
}

#[test]
fn spill_mode_lands_on_the_in_ram_bits_with_flat_memory() {
    let base = run_to_end(build(Algorithm::FeedSign, 5, Knobs::fused(0, 2, 0)));
    assert_eq!(base.replica_stats().tile.spills, 0, "in-RAM run must not spill");
    // resident windows of 2-3 pages, all far below d = 1290 floats
    for (tile, pages) in [(64usize, 2usize), (61, 3), (256, 1)] {
        let budget = 4 * tile * pages;
        let knobs = Knobs { shards: 0, threads: 2, tile, tile_budget: budget, fuse: true };
        let s = run_to_end(build(Algorithm::FeedSign, 5, knobs));
        let label = format!("spill tile={tile} budget={budget}");
        assert_session_parity(&label, &base, &s);
        let ts = s.replica_stats().tile;
        assert!(ts.spills > 0, "{label}: d exceeds the window, the sweep must spill");
        assert!(
            ts.peak_resident_bytes <= budget,
            "{label}: peak resident {} B broke the budget",
            ts.peak_resident_bytes
        );
    }
}

#[test]
fn restricted_seed_pool_staging_stays_bit_identical() {
    // FedKSeed staging pre-draws round t+1's pool index at commit time —
    // legal only because the draw is a pure function of the accumulated
    // scalars; this pins that purity end to end, with pool catch-up on
    let build_pool = |knobs: Knobs| {
        let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
        let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
        let data_shards = split(&train, 5, Partition::Iid, 0);
        let clients: Vec<Client> = data_shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: Algorithm::FeedSign,
            rounds: ROUNDS,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            participation: ParticipationCfg::Fraction(0.6),
            catchup: CatchupCfg::PoolScalars,
            seed_pool: 16,
            net: impaired_net(),
            threads: knobs.threads,
            shards: knobs.shards,
            tile: knobs.tile,
            tile_budget: knobs.tile_budget,
            fuse_commits: knobs.fuse,
            seed: 11,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    };
    let base = run_to_end(build_pool(Knobs::legacy()));
    for tile in [1usize, 61, D + 1] {
        let s = run_to_end(build_pool(Knobs::fused(tile, 2, 0)));
        assert_session_parity(&format!("pool/tile={tile}"), &base, &s);
    }
    let fused = run_to_end(build_pool(Knobs::fused(0, 1, 0)));
    assert!(fused.probe_stats.staged_probes > 0, "pool staging never engaged");
    assert_session_parity("pool/auto-tile", &base, &fused);
}

fn dist_clients(k: usize, train: &Dataset) -> Vec<DistClient> {
    let shards = split(train, k, Partition::Iid, 0);
    shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            let engine: Box<dyn feedsign::engine::Engine> =
                Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
            let w = engine.init_params(11);
            DistClient {
                engine,
                w,
                shard,
                attack: Attack::None,
                rng: Rng::new(11 ^ 0xC11E_17, id as u32 + 1),
            }
        })
        .collect()
}

#[test]
fn both_topologies_agree_under_tiling() {
    // threaded distributed topology vs fused tiled sync sessions vs the
    // legacy engine: one impaired configuration, one set of bits
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let dcfg = DistCfg {
        rounds: ROUNDS,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        participation: ParticipationCfg::Fraction(0.6),
        catchup: CatchupCfg::Replay,
        net: impaired_net(),
        seed: 11,
        seed_pool: 0,
        shards: 0,
    };
    let dist = run_feedsign(dist_clients(5, &train), train.clone(), dcfg);
    let legacy = run_to_end(build(Algorithm::FeedSign, 5, Knobs::legacy()));
    for tile in [1usize, D + 1] {
        let s = run_to_end(build(Algorithm::FeedSign, 5, Knobs::fused(tile, 2, 3)));
        for (id, w) in dist.finals.iter().enumerate() {
            assert_eq!(bits(w), bits(&s.replica(id)), "tile={tile} client {id}: topologies diverged");
            assert_eq!(bits(w), bits(&legacy.replica(id)), "client {id}: legacy engine drifted");
        }
    }
}
