//! Parallel round-engine parity: for every synchronized algorithm, a
//! session fanned out over N worker threads must be **bit-identical** to
//! the sequential baseline (`threads = 1`) — final replicas, ledger
//! totals, orbit entries and orbit replay.  This is the determinism
//! contract of the plan/execute/commit engine (commit order = client id);
//! if any of these assertions ever loosens to a tolerance, the protocol's
//! replica-synchronization story is broken.

use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::{Algorithm, Attack, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::data::Dataset;
use feedsign::engine::NativeEngine;
use feedsign::simkit::nn::LinearProbe;

fn build_session(
    algo: Algorithm,
    k: usize,
    threads: usize,
    participation: ParticipationCfg,
    byzantine: usize,
) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
    let shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            let c = Client::new(
                id,
                Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                shard,
                11,
            );
            if id < byzantine {
                c.with_attack(Attack::SignFlip)
            } else {
                c
            }
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: algo,
        rounds: 0,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        participation,
        threads,
        seed: 11,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

/// Step both sessions `rounds` times and assert complete bitwise parity.
fn assert_parity(mut seq: Session, mut par: Session, rounds: u64, label: &str) {
    for t in 0..rounds {
        seq.step(t);
        par.step(t);
    }
    // 1. final replicas: every client, bit-identical
    assert_eq!(seq.clients.len(), par.clients.len());
    for id in 0..seq.clients.len() {
        assert_eq!(seq.replica(id), par.replica(id), "{label}: replica {id} diverged");
    }
    assert!(seq.replicas_synchronized(), "{label}: sequential replicas desynced");
    assert!(par.replicas_synchronized(), "{label}: parallel replicas desynced");
    // 2. ledger: bit counts AND message counts
    assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits, "{label}: uplink bits");
    assert_eq!(seq.ledger.downlink_bits, par.ledger.downlink_bits, "{label}: downlink bits");
    assert_eq!(seq.ledger.uplink_msgs, par.ledger.uplink_msgs, "{label}: uplink msgs");
    assert_eq!(seq.ledger.downlink_msgs, par.ledger.downlink_msgs, "{label}: downlink msgs");
    // 3. orbit: identical entries, and replay reconstructs the parallel
    //    session's final replica exactly from the shared init
    assert_eq!(seq.orbit.entries, par.orbit.entries, "{label}: orbit entries");
    let mut w = par.clients[0].engine.init_params(11);
    par.orbit.replay(&mut w);
    assert_eq!(w.as_slice(), &*par.replica(0), "{label}: orbit replay must reconstruct exactly");
}

#[test]
fn feedsign_parallel_matches_sequential() {
    let seq = build_session(Algorithm::FeedSign, 5, 1, ParticipationCfg::Full, 0);
    let par = build_session(Algorithm::FeedSign, 5, 4, ParticipationCfg::Full, 0);
    assert_parity(seq, par, 120, "feedsign");
}

#[test]
fn dp_feedsign_parallel_matches_sequential() {
    let algo = Algorithm::DpFeedSign { epsilon: 4.0 };
    let seq = build_session(algo, 5, 1, ParticipationCfg::Full, 0);
    let par = build_session(algo, 5, 4, ParticipationCfg::Full, 0);
    assert_parity(seq, par, 120, "dp-feedsign");
}

#[test]
fn zo_fedsgd_parallel_matches_sequential() {
    let seq = build_session(Algorithm::ZoFedSgd, 4, 1, ParticipationCfg::Full, 0);
    let par = build_session(Algorithm::ZoFedSgd, 4, 4, ParticipationCfg::Full, 0);
    assert_parity(seq, par, 80, "zo-fedsgd");
}

#[test]
fn parity_holds_under_byzantine_attack() {
    // attack mutations draw from per-client RNG streams; fan-out must not
    // perturb them
    let seq = build_session(Algorithm::FeedSign, 5, 1, ParticipationCfg::Full, 2);
    let par = build_session(Algorithm::FeedSign, 5, 4, ParticipationCfg::Full, 2);
    assert_parity(seq, par, 100, "feedsign+byzantine");
}

#[test]
fn parity_holds_under_partial_participation() {
    for participation in [ParticipationCfg::Fraction(0.4), ParticipationCfg::Bernoulli(0.5)] {
        let seq = build_session(Algorithm::FeedSign, 5, 1, participation, 0);
        let par = build_session(Algorithm::FeedSign, 5, 4, participation, 0);
        assert_parity(seq, par, 100, &format!("feedsign+{}", participation.render()));
        let seq = build_session(Algorithm::ZoFedSgd, 5, 1, participation, 0);
        let par = build_session(Algorithm::ZoFedSgd, 5, 4, participation, 0);
        assert_parity(seq, par, 60, &format!("zo-fedsgd+{}", participation.render()));
    }
}

#[test]
fn parity_across_many_thread_counts() {
    // odd worker counts exercise ragged chunking of the participant list
    let mut reference = build_session(Algorithm::FeedSign, 7, 1, ParticipationCfg::Full, 0);
    for t in 0..60 {
        reference.step(t);
    }
    for threads in [2usize, 3, 5, 8, 16] {
        let mut s = build_session(Algorithm::FeedSign, 7, threads, ParticipationCfg::Full, 0);
        for t in 0..60 {
            s.step(t);
        }
        assert_eq!(
            s.replica(0),
            reference.replica(0),
            "threads={threads} diverged from sequential"
        );
        assert_eq!(s.ledger.uplink_bits, reference.ledger.uplink_bits);
        assert_eq!(s.orbit.entries, reference.orbit.entries);
    }
}

#[test]
fn auto_threads_matches_sequential_run_results() {
    // cfg.threads = 0 (auto) goes through whatever parallelism the machine
    // has; the run-level metrics must still be identical
    let mut seq = build_session(Algorithm::FeedSign, 5, 1, ParticipationCfg::Full, 0);
    seq.cfg.rounds = 50;
    seq.cfg.eval_every = 10;
    let mut auto = build_session(Algorithm::FeedSign, 5, 0, ParticipationCfg::Full, 0);
    auto.cfg.rounds = 50;
    auto.cfg.eval_every = 10;
    let r_seq = seq.run();
    let r_auto = auto.run();
    assert_eq!(r_seq.final_loss, r_auto.final_loss);
    assert_eq!(r_seq.final_acc, r_auto.final_acc);
    assert_eq!(r_seq.ledger.uplink_bits, r_auto.ledger.uplink_bits);
    for (a, b) in r_seq.records.iter().zip(&r_auto.records) {
        assert_eq!(a.eval_loss, b.eval_loss);
        assert_eq!(a.eval_acc, b.eval_acc);
    }
}
