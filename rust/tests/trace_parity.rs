//! Deterministic-tracing parity: the observability plane must observe,
//! never steer.
//!
//! The contracts, per `obs::trace`'s determinism rules:
//!
//! 1. **Logical-sequence invariance** — the timestamp-free logical event
//!    sequence (every [`Phase::is_logical`] phase, sorted) is identical
//!    across worker thread counts {1, 3, 8}, for both the flat and the
//!    sharded engine, under partial participation, a bit-flip channel
//!    and deadline stragglers all at once.  Wall-clock attribution
//!    (`RoundGate` / `Overlap`) and worker binning (`ProbeBatch`) are
//!    excluded by construction.
//! 2. **Zero observer effect** — a traced run is bit-identical to an
//!    untraced run: replicas, ledger, impairment trace, orbit, votes.
//!    Timing is recorded but never fed back into control flow.
//! 3. **Cross-topology agreement** — the synchronous session and the
//!    threaded distributed topology emit the same round-level sequence
//!    (`Plan` / `NetAdmit` / `Commit`) for the same configured run.
//! 4. **Straggler attribution** — a sharded impaired run names the
//!    gating shard and link class, measures lookahead overlap, exports
//!    a parseable Chrome trace, and rolls up into the registry.

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::distributed::{run_feedsign_with, DistClient, DistCfg};
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::{Algorithm, Attack, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::data::Dataset;
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, NetCfg};
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::prng::Rng;
#[cfg(feature = "obs")]
use feedsign::obs::{Phase, Registry};
#[cfg(feature = "obs")]
use feedsign::util::json::Json;

const ROUNDS: u64 = 30;
const K: usize = 7;

fn bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|v| v.to_bits()).collect()
}

/// The impaired regime every case runs under: partial participation, a
/// bit-flip channel over heterogeneous links, and a round deadline that
/// cuts stragglers at plan time — the setting where tracing has the
/// most state to observe and the most ways to perturb it.
fn impaired_net() -> NetCfg {
    NetCfg {
        channel: ChannelModel::BitFlip { ber: 0.05 },
        links: LinkAssignment::parse("mixed").unwrap(),
        deadline_s: 0.1,
        channel_seed: 5,
    }
}

/// Session with `shards` and `threads` pinned at construction — explicit
/// values are env-proof, so the `FEEDSIGN_SHARDS` CI leg cannot change
/// what these tests compare.
fn build(algo: Algorithm, shards: usize, threads: usize) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
    let data_shards = split(&train, K, Partition::Iid, 0);
    let clients: Vec<Client> = data_shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: algo,
        rounds: ROUNDS,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        participation: ParticipationCfg::Fraction(0.6),
        catchup: CatchupCfg::Replay,
        net: impaired_net(),
        threads,
        shards,
        seed: 11,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

/// Enable tracing (before the first round — admission logging follows
/// the tracer), run to completion, rejoin stragglers.
fn traced(mut s: Session) -> Session {
    s.enable_tracing();
    run_to_end(s)
}

fn run_to_end(mut s: Session) -> Session {
    for t in 0..ROUNDS {
        s.step(t);
    }
    s.catch_up_all();
    s
}

fn dist_clients(train: &Dataset) -> Vec<DistClient> {
    let shards = split(train, K, Partition::Iid, 0);
    shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            let engine: Box<dyn feedsign::engine::Engine> =
                Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
            let w = engine.init_params(11);
            DistClient {
                engine,
                w,
                shard,
                attack: Attack::None,
                rng: Rng::new(11 ^ 0xC11E_17, id as u32 + 1),
            }
        })
        .collect()
}

fn dist_cfg(shards: usize) -> DistCfg {
    DistCfg {
        rounds: ROUNDS,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        participation: ParticipationCfg::Fraction(0.6),
        catchup: CatchupCfg::Replay,
        net: impaired_net(),
        seed: 11,
        seed_pool: 0,
        shards,
    }
}

#[test]
#[cfg(feature = "obs")]
fn logical_sequence_is_thread_count_invariant() {
    for algo in [Algorithm::FeedSign, Algorithm::ZoFedSgd] {
        for shards in [0usize, 4] {
            let base = traced(build(algo, shards, 1));
            let base_seq = base.tracer.logical_sequence();
            assert!(!base_seq.is_empty(), "{algo:?}/shards={shards}: no logical events");
            // spot-check the taxonomy the sequence must carry
            assert!(base_seq.iter().any(|l| l.contains(" plan ")), "plans traced");
            assert!(base_seq.iter().any(|l| l.contains(" probe ")), "probes traced");
            assert!(base_seq.iter().any(|l| l.contains(" commit ")), "commits traced");
            assert!(base_seq.iter().any(|l| l.contains(" net_admit ")), "admissions traced");
            if shards > 0 {
                assert!(base_seq.iter().any(|l| l.contains(" shard_merge ")), "merges traced");
            }
            for threads in [3usize, 8] {
                let s = traced(build(algo, shards, threads));
                assert_eq!(
                    base_seq,
                    s.tracer.logical_sequence(),
                    "{algo:?}/shards={shards}/threads={threads}: logical sequence diverged"
                );
            }
        }
    }
}

#[test]
fn tracing_never_changes_the_bits() {
    // sync topology: a traced session vs an untraced session of the same
    // impaired sharded run — the engine must not read what was recorded
    let plain = run_to_end(build(Algorithm::FeedSign, 4, 3));
    let tr = traced(build(Algorithm::FeedSign, 4, 3));
    if !feedsign::obs::trace_env() {
        // (under the FEEDSIGN_TRACE=1 CI leg both sessions trace)
        assert!(plain.tracer.is_empty(), "untraced session must record nothing");
    }
    #[cfg(feature = "obs")]
    assert!(!tr.tracer.is_empty(), "traced session must record");
    for id in 0..K {
        assert_eq!(
            bits(&plain.replica(id)),
            bits(&tr.replica(id)),
            "client {id}: replica diverged under tracing"
        );
    }
    assert_eq!(plain.ledger.uplink_bits, tr.ledger.uplink_bits, "uplink bits");
    assert_eq!(plain.ledger.downlink_bits, tr.ledger.downlink_bits, "downlink bits");
    assert_eq!(plain.net.stats, tr.net.stats, "impairment trace diverged under tracing");
    assert_eq!(
        feedsign::orbit::encode(&plain.orbit),
        feedsign::orbit::encode(&tr.orbit),
        "orbit bytes diverged under tracing"
    );

    // distributed topology: tracing chosen by parameter, same contract
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let off = run_feedsign_with(dist_clients(&train), train.clone(), dist_cfg(4), false);
    let on = run_feedsign_with(dist_clients(&train), train.clone(), dist_cfg(4), true);
    assert!(off.trace.is_empty(), "trace=false must record nothing");
    for (id, w) in off.finals.iter().enumerate() {
        assert_eq!(bits(w), bits(&on.finals[id]), "dist client {id}: tracing drifted");
    }
    assert_eq!(off.ledger.uplink_bits, on.ledger.uplink_bits);
    assert_eq!(off.ledger.downlink_bits, on.ledger.downlink_bits);
    assert_eq!(off.net, on.net, "dist impairment trace diverged under tracing");
    assert_eq!(off.votes_per_round, on.votes_per_round, "delivered votes diverged");
}

#[test]
#[cfg(feature = "obs")]
fn both_topologies_emit_identical_round_level_sequences() {
    // the phases both topologies define identically: the plan fixed, the
    // deadline admission, the delivered per-voter commits and the
    // round's canonical commit
    let round_level = |p: Phase| matches!(p, Phase::Plan | Phase::NetAdmit | Phase::Commit);
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    for shards in [0usize, 4] {
        let threads = if shards == 0 { 1 } else { 4 };
        let sync = traced(build(Algorithm::FeedSign, shards, threads));
        let dist = run_feedsign_with(dist_clients(&train), train.clone(), dist_cfg(shards), true);
        let a = sync.tracer.logical_sequence_of(round_level);
        let b = dist.trace.logical_sequence_of(round_level);
        assert!(!a.is_empty(), "shards={shards}: no round-level events");
        assert_eq!(a, b, "shards={shards}: topologies disagree on round-level phases");
    }
}

#[test]
#[cfg(feature = "obs")]
fn trace_export_and_registry_attribute_stragglers() {
    let s = traced(build(Algorithm::FeedSign, 4, 4));
    let events = s.tracer.events();
    let gate = events
        .iter()
        .find(|e| e.phase == Phase::RoundGate)
        .expect("sharded run records round gates");
    assert!(gate.shard >= 0, "the gating shard is named");
    assert!(events.iter().any(|e| e.phase == Phase::Overlap), "lookahead overlap is measured");
    assert!(events.iter().any(|e| e.phase == Phase::LinkGate), "link-class attribution recorded");

    // chrome trace parses back and carries the named gate
    let text = feedsign::obs::export::chrome_trace(events);
    let v = Json::parse(&text).expect("chrome trace parses");
    let rows = v.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert_eq!(rows.len(), events.len());
    let name = format!("round_gate shard={}", gate.shard);
    assert!(
        rows.iter().any(|r| r.get("name").and_then(Json::as_str) == Some(name.as_str())),
        "gate track present in the chrome trace"
    );

    // registry rollups: per-shard gating and per-link-class counters
    let mut reg = Registry::default();
    reg.absorb_events(events);
    let prom = reg.to_prometheus();
    assert!(prom.contains("feedsign_round_gated_total{shard=\""), "per-shard gating rollup");
    assert!(
        prom.contains("feedsign_round_gated_by_link_total{class=\""),
        "per-link-class gating rollup"
    );
    assert!(prom.contains("feedsign_net_round_virtual_us_count"), "virtual latency histogram");
    assert!(prom.contains("feedsign_execute_duration_us_count"), "execute duration histogram");
}
