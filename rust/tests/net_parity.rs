//! Impaired-channel determinism: the three contracts `crate::net` ships
//! with.
//!
//! 1. **Ideal parity** — a session configured with `--channel ideal`
//!    (even with exotic link profiles and a nonzero channel seed) is
//!    **bit-identical** to a session that never heard of the simulator:
//!    replicas, ledger, orbit.
//! 2. **Thread parity** — an *impaired* run (flips, drops, deadline
//!    stragglers) produces an identical impairment trace, replicas and
//!    ledger for every worker-thread count, because draws are keyed by
//!    `(channel_seed, round, client, direction)` rather than sequenced.
//! 3. **Cross-topology parity** — the threaded distributed topology
//!    observes the same trace as the synchronous session for the same
//!    configuration, with impairments in flight.
//!
//! Replicas are compared as `u32` bit patterns: corruption can push
//! weights non-finite, and NaN-blind f32 equality must not hide a
//! divergence.

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::distributed::{run_feedsign, DistClient, DistCfg};
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::{Algorithm, Attack, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::data::Dataset;
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, LinkProfile, NetCfg};
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::prng::Rng;

fn bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|v| v.to_bits()).collect()
}

fn build_session(algo: Algorithm, k: usize, cfg_mut: impl FnOnce(&mut SessionCfg)) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
    let shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
        })
        .collect();
    let mut cfg = SessionCfg {
        algorithm: algo,
        rounds: 0,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        seed: 11,
        ..Default::default()
    };
    cfg_mut(&mut cfg);
    Session::new(cfg, clients, train, test)
}

fn dist_clients(k: usize, train: &Dataset) -> Vec<DistClient> {
    let shards = split(train, k, Partition::Iid, 0);
    shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            let engine: Box<dyn feedsign::engine::Engine> =
                Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
            let w = engine.init_params(11);
            DistClient {
                engine,
                w,
                shard,
                attack: Attack::None,
                rng: Rng::new(11 ^ 0xC11E_17, id as u32 + 1),
            }
        })
        .collect()
}

#[test]
fn ideal_channel_is_bit_identical_to_the_no_net_baseline() {
    let mut baseline = build_session(Algorithm::FeedSign, 5, |_| {});
    // plain `--channel ideal` with the default link: fully inactive,
    // zero draws, zero stats
    let mut ideal = build_session(Algorithm::FeedSign, 5, |cfg| {
        cfg.net = NetCfg { channel_seed: 99, ..NetCfg::ideal() };
    });
    // ideal channel but exotic links: the virtual clock engages (the
    // knob must not be silently ignored), yet every message still
    // arrives untouched — replicas and ledgers may not drift a bit
    let mut clocked = build_session(Algorithm::FeedSign, 5, |cfg| {
        cfg.net = NetCfg {
            channel: ChannelModel::Ideal,
            links: LinkAssignment::Uniform(LinkProfile::iot()),
            deadline_s: 0.0,
            channel_seed: 99,
        };
    });
    for t in 0..80 {
        baseline.step(t);
        ideal.step(t);
        clocked.step(t);
    }
    for s in [&ideal, &clocked] {
        for id in 0..baseline.clients.len() {
            assert_eq!(
                bits(&baseline.replica(id)),
                bits(&s.replica(id)),
                "client {id} replica drifted"
            );
        }
        assert_eq!(baseline.ledger.uplink_bits, s.ledger.uplink_bits);
        assert_eq!(baseline.ledger.downlink_bits, s.ledger.downlink_bits);
        assert_eq!(baseline.ledger.uplink_msgs, s.ledger.uplink_msgs);
        assert_eq!(baseline.orbit.len(), s.orbit.len());
    }
    assert_eq!(ideal.net.stats, Default::default(), "ideal runs draw nothing");
    assert_eq!(clocked.net.stats.rounds, 80, "exotic links tick the clock");
    assert!(clocked.net.stats.virtual_s > 0.0);
    assert_eq!(clocked.net.stats.stragglers, 0);
    assert_eq!(clocked.net.stats.dropped_msgs, 0);
    assert_eq!(clocked.net.stats.flipped_bits, 0);
}

fn impaired_net(channel: ChannelModel, deadline_s: f64) -> NetCfg {
    NetCfg {
        channel,
        links: LinkAssignment::parse("mixed").unwrap(),
        deadline_s,
        channel_seed: 5,
    }
}

#[test]
fn impaired_runs_are_identical_across_worker_thread_counts() {
    for (channel, catchup) in [
        (ChannelModel::BitFlip { ber: 0.05 }, CatchupCfg::Off),
        (ChannelModel::Erasure { p: 0.3 }, CatchupCfg::Replay),
    ] {
        let build = |threads: usize| {
            build_session(Algorithm::FeedSign, 5, |cfg| {
                cfg.threads = threads;
                cfg.participation = ParticipationCfg::Fraction(0.6);
                cfg.catchup = catchup;
                cfg.net = impaired_net(channel, 0.0);
            })
        };
        let mut seq = build(1);
        let mut par = build(4);
        for t in 0..100 {
            seq.step(t);
            par.step(t);
        }
        seq.catch_up_all();
        par.catch_up_all();
        for id in 0..seq.clients.len() {
            assert_eq!(
                bits(&seq.replica(id)),
                bits(&par.replica(id)),
                "{channel:?}: client {id} diverged"
            );
        }
        assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits, "{channel:?}");
        assert_eq!(seq.ledger.downlink_bits, par.ledger.downlink_bits, "{channel:?}");
        assert_eq!(seq.net.stats, par.net.stats, "{channel:?}: impairment trace diverged");
    }
}

#[test]
fn impaired_zo_runs_are_identical_across_worker_thread_counts() {
    // ZO pairs corrupt semantically (seed and coefficient bits); even if
    // a blown coefficient drives replicas non-finite, the bit patterns
    // must match across thread counts
    let build = |threads: usize| {
        build_session(Algorithm::ZoFedSgd, 4, |cfg| {
            cfg.threads = threads;
            cfg.net = impaired_net(ChannelModel::BitFlip { ber: 0.01 }, 0.0);
        })
    };
    let mut seq = build(1);
    let mut par = build(3);
    for t in 0..60 {
        seq.step(t);
        par.step(t);
    }
    for id in 0..seq.clients.len() {
        assert_eq!(bits(&seq.replica(id)), bits(&par.replica(id)), "client {id} diverged");
    }
    assert_eq!(seq.net.stats, par.net.stats);
}

#[test]
fn same_channel_seed_reproduces_different_channel_seed_diverges() {
    let build = |channel_seed: u32| {
        let mut s = build_session(Algorithm::FeedSign, 5, |cfg| {
            cfg.participation = ParticipationCfg::Fraction(0.6);
            cfg.catchup = CatchupCfg::Replay;
            cfg.net = NetCfg {
                channel: ChannelModel::Erasure { p: 0.5 },
                links: LinkAssignment::Uniform(LinkProfile::mobile()),
                deadline_s: 0.0,
                channel_seed,
            };
        });
        for t in 0..200 {
            s.step(t);
        }
        s.catch_up_all();
        s
    };
    let a = build(5);
    let b = build(5);
    assert_eq!(bits(&a.replica(0)), bits(&b.replica(0)), "same seed must reproduce");
    assert_eq!(a.net.stats, b.net.stats);
    let c = build(6);
    assert_ne!(
        bits(&a.replica(0)),
        bits(&c.replica(0)),
        "a different channel seed draws a different drop pattern"
    );
}

#[test]
fn deadline_stragglers_resync_through_replay() {
    let mut s = build_session(Algorithm::FeedSign, 6, |cfg| {
        cfg.catchup = CatchupCfg::Replay;
        cfg.net = impaired_net(ChannelModel::Ideal, 0.1);
    });
    for t in 0..60 {
        s.step(t);
    }
    // mixed cycle: ids 2 and 5 are iot-class (0.4 s RTT > 0.1 s deadline)
    assert_eq!(s.net.stats.stragglers, 2 * 60, "iot clients miss every deadline");
    assert!(!s.replicas_synchronized(), "stragglers are stale mid-run");
    s.catch_up_all();
    assert!(s.replicas_synchronized(), "replay brings stragglers current");
}

#[test]
fn impaired_cross_topology_parity() {
    // the distributed PS and the synchronous session must observe the
    // same keyed impairment trace: identical finals, ledgers and stats —
    // under flips, drops, and deadline stragglers, for both catch-up
    // modes the threaded topology supports
    let cases = [
        (ChannelModel::BitFlip { ber: 0.2 }, 0.0, CatchupCfg::Off),
        (ChannelModel::BitFlip { ber: 0.2 }, 0.0, CatchupCfg::Replay),
        (ChannelModel::Erasure { p: 0.3 }, 0.0, CatchupCfg::Off),
        (ChannelModel::Erasure { p: 0.3 }, 0.1, CatchupCfg::Replay),
    ];
    for (channel, deadline_s, catchup) in cases {
        let label = format!("{channel:?}/deadline={deadline_s}/{catchup:?}");
        let net = impaired_net(channel, deadline_s);
        let train: Dataset = generate(&SYNTH_CIFAR10, 300, 0);
        let test: Dataset = generate(&SYNTH_CIFAR10, 100, 1);
        let shards = split(&train, 4, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(
                    id,
                    Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                    shard,
                    11,
                )
            })
            .collect();
        let cfg = SessionCfg {
            rounds: 60,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            participation: ParticipationCfg::Fraction(0.5),
            catchup,
            net: net.clone(),
            seed: 11,
            ..Default::default()
        };
        let mut sync = Session::new(cfg, clients, train.clone(), test);
        for t in 0..60 {
            sync.step(t);
        }
        sync.catch_up_all();

        let dcfg = DistCfg {
            rounds: 60,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            participation: ParticipationCfg::Fraction(0.5),
            catchup,
            net,
            seed: 11,
            seed_pool: 0,
            shards: 0,
        };
        let res = run_feedsign(dist_clients(4, &train), train, dcfg);
        for (id, w) in res.finals.iter().enumerate() {
            assert_eq!(
                bits(w),
                bits(&sync.replica(id)),
                "{label}: client {id} diverged across topologies"
            );
        }
        assert_eq!(res.ledger.uplink_bits, sync.ledger.uplink_bits, "{label}");
        assert_eq!(res.ledger.downlink_bits, sync.ledger.downlink_bits, "{label}");
        assert_eq!(res.ledger.uplink_msgs, sync.ledger.uplink_msgs, "{label}");
        assert_eq!(res.ledger.downlink_msgs, sync.ledger.downlink_msgs, "{label}");
        assert_eq!(res.net, sync.net.stats, "{label}: impairment trace diverged");
    }
}

#[test]
fn ber_zero_bitflip_channel_matches_ideal_replicas() {
    // `ber:0` engages the simulator (stats tick) but flips nothing: the
    // learning trajectory must equal the ideal channel's exactly — the
    // property that makes the BER-sweep bench's 0 column a true baseline
    let mut ideal = build_session(Algorithm::FeedSign, 5, |_| {});
    let mut zero = build_session(Algorithm::FeedSign, 5, |cfg| {
        cfg.net = NetCfg {
            channel: ChannelModel::BitFlip { ber: 0.0 },
            links: LinkAssignment::Uniform(LinkProfile::mobile()),
            deadline_s: 0.0,
            channel_seed: 3,
        };
    });
    for t in 0..80 {
        ideal.step(t);
        zero.step(t);
    }
    assert_eq!(bits(&ideal.replica(0)), bits(&zero.replica(0)));
    assert_eq!(ideal.ledger.uplink_bits, zero.ledger.uplink_bits);
    assert_eq!(zero.net.stats.flipped_bits, 0);
    assert_eq!(zero.net.stats.rounds, 80, "the virtual clock still observed the run");
}
