//! Seed-history catch-up parity: a client excluded from k rounds under
//! `catchup = "replay"` must rejoin with a replica **bit-identical** to an
//! always-participating client's, for every synchronized engine — this is
//! what removes the correctness asterisk from partial participation
//! (`fraction:F` / `bernoulli:P`).  The tests pin:
//!
//! * rejoin parity for k ∈ {1, 7, 50} missed rounds, for FeedSign,
//!   DP-FeedSign and ZO-FedSGD;
//! * exact replay-bit accounting (1 bit per missed FeedSign round) and the
//!   dense-rebroadcast cost baseline (32·d bits);
//! * the bill-each-pair-once invariant: a full replay run spends exactly
//!   the downlink bits of the broadcast-to-everyone baseline, in fewer
//!   messages;
//! * ledger compaction never drops a record the slowest tracked client
//!   still needs, however small the ring's soft capacity;
//! * seed-pool interop (FedKSeed restricted seed space): rejoin parity
//!   for both index-record replay and the constant-size K-scalar
//!   download (`catchup = "pool"`), index-record pricing at
//!   `ceil(log2 K) + 1` bits, and compaction over index records.
//!
//! `FEEDSIGN_SEED_POOL=K` reruns the whole FeedSign portion of the suite
//! over a K-seed pool (the CI seed-pool leg); exact-bit accounting tests
//! that assume 1-bit records pin `seed_pool = 0` explicitly.

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::session::RoundPlan;
use feedsign::coordinator::{Algorithm, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::engine::NativeEngine;
use feedsign::simkit::nn::LinearProbe;

/// Pool size the FeedSign tests run with: `FEEDSIGN_SEED_POOL=K` opts
/// the suite into the restricted seed space (0 = unrestricted).  The
/// non-FeedSign engines always run unrestricted — the pool applies to
/// the sign-vote algorithms only.
fn env_seed_pool(algo: Algorithm) -> usize {
    match algo {
        Algorithm::FeedSign | Algorithm::DpFeedSign { .. } => std::env::var("FEEDSIGN_SEED_POOL")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0),
        _ => 0,
    }
}

fn build_session(algo: Algorithm, k: usize, catchup: CatchupCfg) -> Session {
    build_pool_session(algo, k, catchup, env_seed_pool(algo))
}

fn build_pool_session(
    algo: Algorithm,
    k: usize,
    catchup: CatchupCfg,
    seed_pool: usize,
) -> Session {
    let train = generate(&SYNTH_CIFAR10, 400, 0);
    let test = generate(&SYNTH_CIFAR10, 150, 1);
    let shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 13)
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: algo,
        rounds: 0,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        catchup,
        seed_pool,
        seed: 13,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

fn plan_full(t: u64, k: usize) -> RoundPlan {
    RoundPlan { round: t, participants: (0..k).collect() }
}

fn plan_without(t: u64, k: usize, skip: usize) -> RoundPlan {
    RoundPlan { round: t, participants: (0..k).filter(|&i| i != skip).collect() }
}

#[test]
fn rejoin_is_bit_identical_for_every_engine_and_gap() {
    let engines =
        [Algorithm::FeedSign, Algorithm::DpFeedSign { epsilon: 4.0 }, Algorithm::ZoFedSgd];
    for algo in engines {
        for gap in [1usize, 7, 50] {
            let mut s = build_session(algo, 4, CatchupCfg::Replay);
            let mut t = 0u64;
            for _ in 0..3 {
                s.step_with_plan(plan_full(t, 4));
                t += 1;
            }
            // client 2 goes offline for `gap` rounds
            for _ in 0..gap {
                s.step_with_plan(plan_without(t, 4, 2));
                t += 1;
            }
            // rejoin: the engine replays the missed span before client 2
            // probes, then two more full rounds run
            for _ in 0..2 {
                s.step_with_plan(plan_full(t, 4));
                t += 1;
            }
            assert_eq!(
                s.replica(2),
                s.replica(0),
                "{}: client offline for {gap} rounds rejoined with a drifted replica",
                algo.name()
            );
            s.catch_up_all();
            assert!(
                s.replicas_synchronized(),
                "{}: pool not synchronized after catch_up_all (gap {gap})",
                algo.name()
            );
        }
    }
}

#[test]
fn replay_bits_are_one_per_missed_feedsign_round() {
    // pinned to the unrestricted space: the 1-bit-per-round arithmetic
    // below is exactly what seed_pool mode replaces with log2(K)+1
    let mut s = build_pool_session(Algorithm::FeedSign, 4, CatchupCfg::Replay, 0);
    let mut t = 0u64;
    for _ in 0..2 {
        s.step_with_plan(plan_full(t, 4));
        t += 1;
    }
    for _ in 0..3 {
        s.step_with_plan(plan_without(t, 4, 3));
        t += 1;
    }
    s.step_with_plan(plan_full(t, 4));
    // uplink: every participant votes 1 bit
    assert_eq!(s.ledger.uplink_bits, 2 * 4 + 3 * 3 + 4);
    // downlink: participants hear 1 bit per round; the rejoin replays the
    // 3 missed rounds at 1 bit each (seed = round is derivable, §I.1)
    assert_eq!(s.ledger.downlink_bits, (2 * 4 + 3 * 3 + 4) + 3);
}

#[test]
fn rebroadcast_pays_dense_checkpoint_and_stays_exact() {
    let schedule = |catchup: CatchupCfg| {
        // pinned unrestricted: the 32·d − 3 delta assumes 1-bit records
        let mut s = build_pool_session(Algorithm::FeedSign, 4, catchup, 0);
        let mut t = 0u64;
        for _ in 0..2 {
            s.step_with_plan(plan_full(t, 4));
            t += 1;
        }
        for _ in 0..3 {
            s.step_with_plan(plan_without(t, 4, 3));
            t += 1;
        }
        s.step_with_plan(plan_full(t, 4));
        s
    };
    let replay = schedule(CatchupCfg::Replay);
    let rebroadcast = schedule(CatchupCfg::Rebroadcast);
    // both rejoin exactly...
    assert_eq!(replay.replica(3), replay.replica(0));
    assert_eq!(rebroadcast.replica(3), rebroadcast.replica(0));
    assert_eq!(rebroadcast.replica(3), replay.replica(3), "policies must agree on bits");
    // ...but the dense fallback pays 32·d where replay paid 3 bits
    let d = replay.clients[0].engine.n_params() as u64;
    assert_eq!(
        rebroadcast.ledger.downlink_bits - replay.ledger.downlink_bits,
        32 * d - 3,
        "rebroadcast must cost a dense checkpoint where replay cost 3 bits"
    );
}

#[test]
fn full_replay_run_matches_broadcast_run_bit_for_bit() {
    // The bill-each-(client, round)-pair-once invariant: with replay, a
    // pair is billed either as the round's live broadcast or as a replay
    // record later — never both, never neither — so total downlink bits
    // equal the broadcast-to-everyone baseline while message count drops,
    // and the final replicas are identical because stale participants are
    // caught up *before* they probe.
    for algo in [Algorithm::FeedSign, Algorithm::ZoFedSgd] {
        let mut off = build_session(algo, 5, CatchupCfg::Off);
        off.cfg.participation = ParticipationCfg::Fraction(0.4);
        let mut rep = build_session(algo, 5, CatchupCfg::Replay);
        rep.cfg.participation = ParticipationCfg::Fraction(0.4);
        for t in 0..80 {
            off.step(t);
            rep.step(t);
        }
        rep.catch_up_all();
        for id in 0..off.clients.len() {
            assert_eq!(
                off.replica(id),
                rep.replica(id),
                "{}: replica {id} diverged across catch-up modes",
                algo.name()
            );
        }
        assert_eq!(off.ledger.uplink_bits, rep.ledger.uplink_bits, "{}", algo.name());
        assert_eq!(
            off.ledger.downlink_bits,
            rep.ledger.downlink_bits,
            "{}: replay must bill each (client, round) pair exactly once",
            algo.name()
        );
        assert!(
            rep.ledger.downlink_msgs < off.ledger.downlink_msgs,
            "{}: replay batches missed rounds into fewer messages",
            algo.name()
        );
    }
}

#[test]
fn pool_rejoin_is_bit_identical_for_both_pool_catchup_modes() {
    // The seed-pool twin of `rejoin_is_bit_identical_...`: the missed
    // span is repaired either by replaying the index records or by
    // downloading the K accumulated scalars — both must land the
    // rejoining client on the always-on clients' bits exactly.
    for catchup in [CatchupCfg::Replay, CatchupCfg::PoolScalars] {
        for gap in [1usize, 7, 50] {
            let mut s = build_pool_session(Algorithm::FeedSign, 4, catchup, 32);
            let mut t = 0u64;
            for _ in 0..3 {
                s.step_with_plan(plan_full(t, 4));
                t += 1;
            }
            for _ in 0..gap {
                s.step_with_plan(plan_without(t, 4, 2));
                t += 1;
            }
            for _ in 0..2 {
                s.step_with_plan(plan_full(t, 4));
                t += 1;
            }
            assert_eq!(
                s.replica(2),
                s.replica(0),
                "{catchup:?}: pool client offline {gap} rounds rejoined with drifted bits"
            );
            s.catch_up_all();
            assert!(s.replicas_synchronized(), "{catchup:?}: pool not synchronized (gap {gap})");
        }
    }
}

#[test]
fn pool_catchup_pricing_replay_scales_with_gap_scalar_download_does_not() {
    // K = 32 pool seeds: every record prices at ceil(log2 32) + 1 = 6
    // bits, and the FedKSeed scalar download prices at 32·K bits no
    // matter how long the client was away.
    let run = |catchup: CatchupCfg, gap: u64| {
        let mut s = build_pool_session(Algorithm::FeedSign, 4, catchup, 32);
        let mut t = 0u64;
        for _ in 0..2 {
            s.step_with_plan(plan_full(t, 4));
            t += 1;
        }
        for _ in 0..gap {
            s.step_with_plan(plan_without(t, 4, 3));
            t += 1;
        }
        let before = s.ledger.downlink_bits;
        s.step_with_plan(plan_full(t, 4)); // rejoin + one live round
        s.ledger.downlink_bits - before
    };
    // rejoin round: 4 live (index + sign) broadcasts at 6 bits each,
    // plus the catch-up payload
    let live = 4 * 6;
    assert_eq!(run(CatchupCfg::Replay, 7), live + 7 * 6);
    assert_eq!(run(CatchupCfg::Replay, 50), live + 50 * 6);
    let scalar_7 = run(CatchupCfg::PoolScalars, 7);
    let scalar_50 = run(CatchupCfg::PoolScalars, 50);
    assert_eq!(scalar_7, live + 32 * 32, "32-bit scalar per pool seed");
    assert_eq!(scalar_7, scalar_50, "the scalar download is constant in the gap");
}

#[test]
fn compaction_retains_index_records_for_the_slowest_client() {
    // the compaction floor logic must hold when the pinned records are
    // pool-index records (and their replay must bill at 5 bits each:
    // ceil(log2 16) + 1)
    let mut s = build_pool_session(Algorithm::FeedSign, 3, CatchupCfg::Replay, 16);
    s.history.set_capacity(4);
    let mut t = 0u64;
    for _ in 0..2 {
        s.step_with_plan(plan_full(t, 3));
        t += 1;
    }
    for _ in 0..50 {
        s.step_with_plan(plan_without(t, 3, 2));
        t += 1;
    }
    assert_eq!(s.tracker().last_synced(2), 2);
    assert_eq!(s.history.records_len(), 50, "client 2 pins rounds 2..52");
    let before = s.ledger.downlink_bits;
    s.step_with_plan(plan_full(t, 3));
    assert_eq!(s.replica(2), s.replica(0), "index-record rejoin must be bit-identical");
    assert_eq!(
        s.ledger.downlink_bits - before,
        3 * 5 + 50 * 5,
        "3 live broadcasts + 50 replayed index records, 5 bits each"
    );
    assert!(
        s.history.records_len() <= 4,
        "ring must shrink to capacity once the watermark advances ({} records)",
        s.history.records_len()
    );
}

#[test]
fn compaction_never_drops_records_the_slowest_client_needs() {
    let mut s = build_session(Algorithm::FeedSign, 3, CatchupCfg::Replay);
    s.history.set_capacity(4);
    let mut t = 0u64;
    for _ in 0..2 {
        s.step_with_plan(plan_full(t, 3));
        t += 1;
    }
    // client 2 offline for 50 rounds: the ring must blow straight past
    // its soft capacity rather than drop a record client 2 still needs
    for _ in 0..50 {
        s.step_with_plan(plan_without(t, 3, 2));
        t += 1;
    }
    assert_eq!(s.tracker().last_synced(2), 2);
    assert_eq!(
        s.history.records_len(),
        50,
        "rounds 2..52 are pinned by client 2's watermark (rounds 0..2 compacted)"
    );
    // rejoin: the span must be fully servable and exact
    s.step_with_plan(plan_full(t, 3));
    assert_eq!(s.replica(2), s.replica(0), "rejoin after 50 rounds must be bit-identical");
    // with everyone synced, the very next compaction trims to capacity
    assert!(
        s.history.records_len() <= 4,
        "ring must shrink to its soft capacity once the watermark advances ({} records)",
        s.history.records_len()
    );
}
