//! Randomized property tests (proptest_lite harness) over the protocol
//! invariants the paper's guarantees rest on.

use feedsign::comm::{index_bits_for, Ledger, Message};
use feedsign::coordinator::aggregation::{dp_vote, majority_sign, mean_projection};
use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::shard::VoteAcc;
use feedsign::coordinator::{aggregation, Algorithm, Client, Session, SessionCfg, ShardMap};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, NetCfg};
use feedsign::orbit::{decode, encode, Orbit, OrbitEntry};
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::ops;
use feedsign::simkit::prng::{normals_vec, philox4x32, Rng};
use feedsign::simkit::zo;
use feedsign::util::proptest_lite::{check, Gen};

#[test]
fn prop_majority_vote_permutation_invariant() {
    check("vote permutation invariance", |g: &mut Gen| {
        let k = g.usize_in(1, 30);
        let mut signs = g.signs(k);
        let before = majority_sign(&signs);
        g.rng.shuffle(&mut signs);
        assert_eq!(majority_sign(&signs), before);
    });
}

#[test]
fn prop_majority_vote_antisymmetric_on_odd_pools() {
    check("vote antisymmetry", |g: &mut Gen| {
        // odd K only: even K has the tie convention
        let k = g.usize_in(0, 15) * 2 + 1;
        let signs = g.signs(k);
        let flipped: Vec<i8> = signs.iter().map(|s| -s).collect();
        assert_eq!(majority_sign(&signs), -majority_sign(&flipped));
    });
}

#[test]
fn prop_mean_projection_linear_in_scale() {
    check("mean projection scaling", |g: &mut Gen| {
        let n = g.usize_in(1, 20);
        let ps = g.vec_f32(n, -5.0, 5.0);
        let scaled: Vec<f32> = ps.iter().map(|p| 2.0 * p).collect();
        assert!((mean_projection(&scaled) - 2.0 * mean_projection(&ps)).abs() < 1e-4);
    });
}

#[test]
fn prop_orbit_encode_decode_roundtrip() {
    check("orbit roundtrip", |g: &mut Gen| {
        let mut orbit = Orbit::new(
            if g.bool() { "feedsign" } else { "zo-fedsgd" },
            g.u32(),
            g.f32_in(1e-6, 1e-1),
        );
        let n = g.usize_in(0, 200);
        let homogeneous = g.bool();
        for _ in 0..n {
            if homogeneous || g.bool() {
                orbit.push_sign(if g.bool() { 1 } else { -1 });
            } else {
                let pairs = (0..g.usize_in(1, 6))
                    .map(|_| (g.u32() & 0x7FFF_FFFF, g.f32_in(-3.0, 3.0)))
                    .collect();
                orbit.push_pairs(pairs);
            }
        }
        let back = decode(&encode(&orbit)).expect("roundtrip");
        assert_eq!(back.entries, orbit.entries);
        assert_eq!(back.init_seed, orbit.init_seed);
        assert_eq!(back.eta, orbit.eta);
        assert_eq!(back.algorithm, orbit.algorithm);
    });
}

#[test]
fn prop_orbit_sign_entries_cost_one_bit() {
    check("orbit 1 bit/step", |g: &mut Gen| {
        let n = g.usize_in(1, 4000);
        let mut orbit = Orbit::new("feedsign", 0, 1e-3);
        for _ in 0..n {
            orbit.push_sign(if g.bool() { 1 } else { -1 });
        }
        let bytes = encode(&orbit).len();
        let header = 32; // magic+version+name+seed+eta+count+flag upper bound
        assert!(bytes <= n.div_ceil(8) + header, "{n} steps -> {bytes} bytes");
    });
}

#[test]
fn prop_replay_matches_incremental_updates() {
    check("orbit replay == live updates", |g: &mut Gen| {
        let d = g.usize_in(8, 256) & !3;
        let eta = g.f32_in(1e-4, 1e-2);
        let mut w = g.vec_normal(d);
        let w0 = w.clone();
        let mut orbit = Orbit::new("feedsign", 0, eta);
        for t in 0..g.usize_in(1, 60) {
            let s = if g.bool() { 1i8 } else { -1 };
            zo::apply_update(&mut w, t as u32, s as f32 * eta);
            orbit.push_sign(s);
        }
        let mut replayed = w0;
        orbit.replay(&mut replayed);
        assert_eq!(replayed, w);
    });
}

#[test]
fn prop_orbit_mixed_replay_matches() {
    check("mixed orbit replay", |g: &mut Gen| {
        let d = 64usize;
        let eta = 1e-3f32;
        let mut w = g.vec_normal(d);
        let w0 = w.clone();
        let mut orbit = Orbit::new("zo-fedsgd", 0, eta);
        for t in 0..20u32 {
            if g.bool() {
                let s = if g.bool() { 1i8 } else { -1 };
                // NOTE: replay uses the entry *index* as the seed for signs
                zo::apply_update(&mut w, orbit.entries.len() as u32, s as f32 * eta);
                orbit.push_sign(s);
            } else {
                let pairs: Vec<(u32, f32)> = (0..g.usize_in(1, 4))
                    .map(|_| (g.u32() & 0x7FFF_FFFF, g.f32_in(-2.0, 2.0)))
                    .collect();
                let k = pairs.len() as f32;
                for &(seed, p) in &pairs {
                    zo::apply_update(&mut w, seed, eta * p / k);
                }
                orbit.push_pairs(pairs);
            }
            let _ = t;
        }
        let mut replayed = w0;
        orbit.replay(&mut replayed);
        assert_eq!(replayed, w);
    });
}

#[test]
fn prop_dirichlet_split_is_partition() {
    let data = generate(&SYNTH_CIFAR10, 400, 0);
    check("dirichlet partition", |g: &mut Gen| {
        let k = g.usize_in(2, 30);
        let beta = g.f32_in(0.05, 20.0);
        let shards = split(&data, k, Partition::Dirichlet { beta }, g.u32());
        let mut seen = vec![false; data.len()];
        for s in &shards {
            assert!(!s.is_empty());
            for &i in &s.indices {
                assert!(!seen[i], "duplicate assignment");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "unassigned sample");
    });
}

#[test]
fn prop_philox_streams_reproducible_and_distinct() {
    check("philox reproducibility", |g: &mut Gen| {
        let seed = g.u32();
        let ctr = g.u32();
        assert_eq!(philox4x32(seed, ctr), philox4x32(seed, ctr));
        assert_ne!(philox4x32(seed, ctr), philox4x32(seed ^ 1, ctr));
    });
}

#[test]
fn prop_axpy_into_matches_scalar_reference() {
    check("axpy reference", |g: &mut Gen| {
        let n = g.usize_in(4, 300);
        let w = g.vec_normal(n);
        let seed = g.u32() & 0x7FFF_FFFF;
        let scale = g.f32_in(-2.0, 2.0);
        let mut out = vec![0.0; n];
        zo::axpy_into(&w, &mut out, seed, scale);
        let z = normals_vec(seed, n);
        for i in 0..n {
            assert_eq!(out[i], w[i] + scale * z[i], "elem {i}");
        }
    });
}

#[test]
fn prop_chunk_parallel_axpy_matches_reference_for_any_split() {
    // the chunk-parallel engine's soundness claim: splitting the Philox
    // counter space at ANY point — arbitrary span offsets or any worker
    // count — reproduces w + scale * z(seed) bit-exactly
    check("chunk-parallel axpy", |g: &mut Gen| {
        let n = g.usize_in(5, 600);
        let w = g.vec_normal(n);
        let seed = g.u32() & 0x7FFF_FFFF;
        let scale = g.f32_in(-3.0, 3.0);
        let z = normals_vec(seed, n);
        let expect: Vec<f32> = w.iter().zip(&z).map(|(wi, zi)| wi + scale * zi).collect();
        // arbitrary split point, including mid-lane
        let cut = g.usize_in(0, n + 1).min(n);
        let mut out = vec![0.0f32; n];
        zo::axpy_span(&w[..cut], &mut out[..cut], seed, scale, 0);
        zo::axpy_span(&w[cut..], &mut out[cut..], seed, scale, cut);
        assert_eq!(out, expect, "split at {cut}");
        // explicit worker counts, ragged chunking included
        let threads = g.usize_in(1, 9);
        let mut out_par = vec![0.0f32; n];
        zo::axpy_into_threads(&w, &mut out_par, seed, scale, threads);
        assert_eq!(out_par, expect, "{threads} workers");
    });
}

#[test]
fn prop_ledger_additive_over_message_sequences() {
    check("ledger additivity", |g: &mut Gen| {
        let msgs: Vec<Message> = (0..g.usize_in(0, 40))
            .map(|_| match g.usize_in(0, 4) {
                0 => Message::SignVote { sign: 1 },
                1 => Message::GlobalSign { sign: -1 },
                2 => Message::Projection { seed: g.u32(), p: 0.5 },
                _ => Message::GlobalProjections {
                    pairs: (0..g.usize_in(1, 5)).map(|_| (g.u32(), 1.0f32)).collect(),
                },
            })
            .collect();
        let mut whole = Ledger::default();
        for m in &msgs {
            whole.record(m);
        }
        let cut = g.usize_in(0, msgs.len() + 1).min(msgs.len());
        let (a_msgs, b_msgs) = msgs.split_at(cut);
        let mut a = Ledger::default();
        let mut b = Ledger::default();
        for m in a_msgs {
            a.record(m);
        }
        for m in b_msgs {
            b.record(m);
        }
        a.merge(&b);
        assert_eq!(a.uplink_bits, whole.uplink_bits);
        assert_eq!(a.downlink_bits, whole.downlink_bits);
        assert_eq!(a.uplink_msgs, whole.uplink_msgs);
    });
}

#[test]
fn prop_dp_vote_respects_unanimity_at_high_eps() {
    check("dp vote unanimity", |g: &mut Gen| {
        let k = g.usize_in(1, 20);
        let sign = if g.bool() { 1i8 } else { -1 };
        let signs = vec![sign; k];
        let mut rng = Rng::new(g.u32(), 0);
        assert_eq!(dp_vote(&signs, 500.0, &mut rng), sign);
    });
}

#[test]
fn prop_matmul_transpose_identities() {
    check("matmul identities", |g: &mut Gen| {
        let (m, k, n) = (g.usize_in(1, 8), g.usize_in(1, 8), g.usize_in(1, 8));
        let a = g.vec_normal(m * k);
        let b = g.vec_normal(k * n);
        // c = a@b via matmul
        let mut c1 = vec![0.0; m * n];
        ops::matmul(&a, &b, &mut c1, m, k, n);
        // c = a@(b^T)^T via matmul_bt on bt = b^T ([n,k])
        let mut bt = vec![0.0; n * k];
        for p in 0..k {
            for j in 0..n {
                bt[j * k + p] = b[p * n + j];
            }
        }
        let mut c2 = vec![0.0; m * n];
        ops::matmul_bt_acc(&a, &bt, &mut c2, m, k, n);
        for i in 0..m * n {
            assert!((c1[i] - c2[i]).abs() < 1e-4);
        }
    });
}

#[test]
fn prop_shard_vote_merge_conserves_counts_and_payload_bits() {
    // the sharded coordinator's arithmetic contract, fuzzed at the
    // message level: for ANY pool size, shard count and participant
    // subset, the hierarchical (sum, voters) merge reconstructs the flat
    // tally exactly, the majority/DP thresholds agree bit-for-bit with
    // the flat forms, and every ShardVotes pair prices by the
    // log2-domain formula
    check("shard merge conservation", |g: &mut Gen| {
        let k = g.usize_in(1, 400);
        let n = g.usize_in(1, 13);
        let map = ShardMap::new(k, n);
        assert_eq!(map.shards(), n.min(k));
        assert_eq!(map.clients(), k);
        let voters: Vec<usize> = (0..k).filter(|_| g.bool()).collect();
        let signs = g.signs(voters.len());
        let mut tally = vec![VoteAcc::default(); map.shards()];
        for (&id, &s) in voters.iter().zip(&signs) {
            tally[map.shard_of(id)].push(s);
        }
        let mut total = VoteAcc::default();
        for s in 0..map.shards() {
            let acc = tally[s];
            let shard_size = map.range(s).len();
            assert!(acc.voters <= shard_size, "a shard cannot out-vote its population");
            let msg = Message::ShardVotes {
                sum: acc.sum,
                voters: acc.voters,
                shard_size,
                dense_pairs: false,
            };
            assert_eq!(
                msg.payload_bits(),
                index_bits_for(2 * acc.voters + 1) as u64
                    + index_bits_for(shard_size + 1) as u64,
                "sparse pair pricing"
            );
            let dense = Message::ShardVotes {
                sum: acc.sum,
                voters: acc.voters,
                shard_size,
                dense_pairs: true,
            };
            assert_eq!(dense.payload_bits(), 64 * acc.voters as u64, "dense pair pricing");
            total.merge(acc);
        }
        // conservation: the merged pair IS the flat tally
        assert_eq!(total.sum, signs.iter().map(|&s| s as i32).sum::<i32>());
        assert_eq!(total.voters, signs.len());
        assert_eq!(aggregation::majority_from_sum(total.sum), majority_sign(&signs));
        // DP path: counts form consumes the same single uniform draw
        if !signs.is_empty() {
            let eps = g.f32_in(0.1, 10.0);
            let seed = g.u32();
            let flat = dp_vote(&signs, eps, &mut Rng::new(seed, 7));
            let sharded =
                aggregation::dp_vote_counts(total.q_plus(), total.voters, eps, &mut Rng::new(seed, 7));
            assert_eq!(flat, sharded, "DP exponential mechanism must not see the topology");
        }
    });
}

#[test]
fn prop_sharded_session_parity_under_random_schedules() {
    // end-to-end schedule fuzzer: random (algorithm, participation,
    // channel, deadline, catch-up, seed pool, shard count, thread count)
    // configurations, each run flat and sharded — replicas, the
    // client-facing ledger (payload-bit conservation), the impairment
    // trace and the orbit must all be bit-identical
    let train = generate(&SYNTH_CIFAR10, 64, 0);
    let test = generate(&SYNTH_CIFAR10, 32, 1);
    check("sharded schedule parity", |g: &mut Gen| {
        let k = g.usize_in(3, 9);
        let rounds = g.usize_in(4, 11) as u64;
        let algo = match g.usize_in(0, 3) {
            0 => Algorithm::FeedSign,
            1 => Algorithm::DpFeedSign { epsilon: g.f32_in(0.5, 8.0) },
            _ => Algorithm::ZoFedSgd,
        };
        let seed_pool = if matches!(algo, Algorithm::ZoFedSgd) || g.bool() {
            0
        } else {
            g.usize_in(2, 9)
        };
        let participation = match g.usize_in(0, 3) {
            0 => ParticipationCfg::Full,
            1 => ParticipationCfg::Fraction(g.f32_in(0.3, 0.9)),
            _ => ParticipationCfg::Bernoulli(g.f32_in(0.4, 0.9)),
        };
        let catchup = match g.usize_in(0, 3) {
            0 => CatchupCfg::Off,
            1 => CatchupCfg::Replay,
            _ if seed_pool >= 2 => CatchupCfg::PoolScalars,
            _ => CatchupCfg::Rebroadcast,
        };
        let net = NetCfg {
            channel: match g.usize_in(0, 3) {
                0 => ChannelModel::Ideal,
                1 => ChannelModel::BitFlip { ber: g.f32_in(0.001, 0.1) as f64 },
                _ => ChannelModel::Erasure { p: g.f32_in(0.01, 0.3) as f64 },
            },
            links: LinkAssignment::parse(if g.bool() { "mixed" } else { "mobile" }).unwrap(),
            deadline_s: if g.bool() { 0.0 } else { g.f32_in(0.05, 0.3) as f64 },
            channel_seed: g.u32(),
        };
        let shards = g.usize_in(1, 6);
        let threads = g.usize_in(1, 5);
        let seed = g.u32();
        let run = |shards: usize, threads: usize| {
            let data_shards = split(&train, k, Partition::Iid, 0);
            let clients: Vec<Client> = data_shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    Client::new(
                        id,
                        Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                        shard,
                        seed,
                    )
                })
                .collect();
            let cfg = SessionCfg {
                algorithm: algo,
                rounds,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 8,
                eval_every: 0,
                participation,
                catchup,
                seed_pool,
                net: net.clone(),
                threads,
                shards,
                seed,
                ..Default::default()
            };
            let mut s = Session::new(cfg, clients, train.clone(), test.clone());
            for t in 0..rounds {
                s.step(t);
            }
            s.catch_up_all();
            s
        };
        let flat = run(0, 1);
        let sharded = run(shards, threads);
        for id in 0..k {
            assert_eq!(
                flat.replica(id).iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                sharded.replica(id).iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "client {id} replica diverged (shards={shards}, threads={threads})"
            );
        }
        // RunResult payload-bit conservation: the client-facing ledger
        // must not know the coordinator is sharded
        assert_eq!(flat.ledger, sharded.ledger, "ledger diverged under sharding");
        assert_eq!(flat.net.stats, sharded.net.stats, "impairment trace diverged");
        assert_eq!(encode(&flat.orbit), encode(&sharded.orbit), "orbit diverged");
        let stats = sharded.shard_stats();
        assert_eq!(stats.shards, shards.min(k));
        assert_eq!(flat.shard_stats().shards, 0);
        assert_eq!(flat.shard_stats().merge_bits, 0);
    });
}

#[test]
fn prop_fused_tile_sweep_parity_under_random_schedules() {
    // tiled-parameter-plane fuzzer: random (algorithm, participation,
    // channel, deadline, catch-up, seed pool, shard count, thread count)
    // configurations plus a random fused-sweep tile size — including 1,
    // d, d+1 and odd non-divisors of the SIMD lane block — and an
    // optional spill budget.  The fused single-sweep engine must
    // reproduce the legacy multi-pass closure-verb engine's f32 stream
    // bitwise: replicas, ledger, impairment trace and orbit.
    let train = generate(&SYNTH_CIFAR10, 64, 0);
    let test = generate(&SYNTH_CIFAR10, 32, 1);
    const D: usize = 128 * 10 + 10; // LinearProbe(128, 10)
    check("fused tile sweep parity", |g: &mut Gen| {
        let k = g.usize_in(3, 7);
        let rounds = g.usize_in(4, 9) as u64;
        let algo = match g.usize_in(0, 3) {
            0 => Algorithm::FeedSign,
            1 => Algorithm::DpFeedSign { epsilon: g.f32_in(0.5, 8.0) },
            _ => Algorithm::ZoFedSgd,
        };
        let seed_pool = if matches!(algo, Algorithm::ZoFedSgd) || g.bool() {
            0
        } else {
            g.usize_in(2, 9)
        };
        let participation = match g.usize_in(0, 3) {
            0 => ParticipationCfg::Full,
            1 => ParticipationCfg::Fraction(g.f32_in(0.3, 0.9)),
            _ => ParticipationCfg::Bernoulli(g.f32_in(0.4, 0.9)),
        };
        let catchup = match g.usize_in(0, 3) {
            0 => CatchupCfg::Off,
            1 => CatchupCfg::Replay,
            _ if seed_pool >= 2 => CatchupCfg::PoolScalars,
            _ => CatchupCfg::Rebroadcast,
        };
        let net = NetCfg {
            channel: match g.usize_in(0, 3) {
                0 => ChannelModel::Ideal,
                1 => ChannelModel::BitFlip { ber: g.f32_in(0.001, 0.1) as f64 },
                _ => ChannelModel::Erasure { p: g.f32_in(0.01, 0.3) as f64 },
            },
            links: LinkAssignment::parse(if g.bool() { "mixed" } else { "mobile" }).unwrap(),
            deadline_s: if g.bool() { 0.0 } else { g.f32_in(0.05, 0.3) as f64 },
            channel_seed: g.u32(),
        };
        let tile = match g.usize_in(0, 5) {
            0 => 1,
            1 => D,
            2 => D + 1,
            // odd tiles never divide the 4-lane SIMD block
            3 => g.usize_in(1, 64) * 2 + 1,
            _ => g.usize_in(1, 2 * D + 2),
        };
        // pages >= 1 keeps peak_resident <= budget well-defined; budget 0
        // exercises the in-RAM store
        let tile_budget = if g.bool() { 0 } else { 4 * tile * g.usize_in(1, 4) };
        let shards = g.usize_in(0, 4);
        let threads = g.usize_in(1, 5);
        let seed = g.u32();
        let run = |fuse: bool, tile: usize, budget: usize, shards: usize, threads: usize| {
            let data_shards = split(&train, k, Partition::Iid, 0);
            let clients: Vec<Client> = data_shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    Client::new(
                        id,
                        Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                        shard,
                        seed,
                    )
                })
                .collect();
            let cfg = SessionCfg {
                algorithm: algo,
                rounds,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 8,
                eval_every: 0,
                participation,
                catchup,
                seed_pool,
                net: net.clone(),
                threads,
                shards,
                tile,
                tile_budget: budget,
                fuse_commits: fuse,
                seed,
                ..Default::default()
            };
            let mut s = Session::new(cfg, clients, train.clone(), test.clone());
            for t in 0..rounds {
                s.step(t);
            }
            s.catch_up_all();
            s
        };
        let legacy = run(false, 0, 0, 0, 1);
        let fused = run(true, tile, tile_budget, shards, threads);
        for id in 0..k {
            assert_eq!(
                legacy.replica(id).iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                fused.replica(id).iter().map(|v| v.to_bits()).collect::<Vec<u32>>(),
                "client {id} replica diverged (tile={tile}, budget={tile_budget}, \
                 shards={shards}, threads={threads})"
            );
        }
        assert_eq!(legacy.ledger, fused.ledger, "ledger diverged under fused sweep");
        assert_eq!(legacy.net.stats, fused.net.stats, "impairment trace diverged");
        assert_eq!(encode(&legacy.orbit), encode(&fused.orbit), "orbit diverged");
        assert_eq!(legacy.probe_stats.staged_probes, 0, "legacy engine must not stage");
        if tile_budget > 0 {
            let ts = fused.replica_stats().tile;
            assert!(
                ts.peak_resident_bytes <= tile_budget,
                "peak resident {} B broke the {tile_budget} B budget (tile={tile})",
                ts.peak_resident_bytes
            );
        }
    });
}

#[test]
fn prop_probe_never_mutates_params() {
    check("probe purity", |g: &mut Gen| {
        use feedsign::data::Batch;
        use feedsign::simkit::nn::{LinearProbe, Model};
        let dim = g.usize_in(2, 16);
        let classes = g.usize_in(2, 5);
        let mut model = LinearProbe::new(dim, classes);
        let w = model.init(g.u32());
        let rows = g.usize_in(1, 8);
        let batch = Batch::Features {
            x: g.vec_normal(rows * dim),
            y: (0..rows).map(|_| g.usize_in(0, classes) as u32).collect(),
            rows,
            dim,
        };
        let mut w_probe = w.clone();
        let mut scratch = Vec::new();
        zo::spsa_probe_scratch(&mut model, &w_probe, &mut scratch, &batch, g.u32() & 0x7FFF_FFFF, 1e-3);
        assert_eq!(w_probe, w);
    });
}
