//! Sharded-coordinator parity: `--shards N` must be a pure execution
//! strategy, never a protocol change.
//!
//! The contracts, per `coordinator::shard`'s three invariants:
//!
//! 1. **Shard-count parity** — for every engine (FeedSign, DP-FeedSign,
//!    ZO-FedSGD), every shard count N in {1, 2, 4, 7} and every worker
//!    thread count, a sharded session is **bit-identical** to the
//!    unsharded baseline: replicas, client-facing ledger, orbit, and the
//!    impairment trace — under partial participation, a `ber:P` bit-flip
//!    channel, and deadline stragglers all at once.
//! 2. **Cross-topology parity** — the threaded distributed topology with
//!    a sharded PS lands on the same bits as the sharded synchronous
//!    session (and both on the flat engines' bits).
//! 3. **Merge-traffic containment** — the hierarchical `ShardVotes`
//!    merge is coordinator-internal: it shows up in `ShardStats`, never
//!    in the client-facing `Ledger`.
//!
//! Replicas are compared as `u32` bit patterns (flips can push weights
//! non-finite; NaN-blind f32 equality must not hide a divergence).

use feedsign::coordinator::catchup::CatchupCfg;
use feedsign::coordinator::distributed::{run_feedsign, DistClient, DistCfg};
use feedsign::coordinator::participation::ParticipationCfg;
use feedsign::coordinator::{Algorithm, Attack, Client, Session, SessionCfg};
use feedsign::data::partition::{split, Partition};
use feedsign::data::vision::{generate, SYNTH_CIFAR10};
use feedsign::data::Dataset;
use feedsign::engine::NativeEngine;
use feedsign::net::{ChannelModel, LinkAssignment, NetCfg};
use feedsign::simkit::nn::LinearProbe;
use feedsign::simkit::prng::Rng;

fn bits(w: &[f32]) -> Vec<u32> {
    w.iter().map(|v| v.to_bits()).collect()
}

/// The impaired regime every parity case below runs under: partial
/// participation, a bit-flip channel over heterogeneous links, and a
/// round deadline that cuts iot-class stragglers at plan time.
fn impaired_net() -> NetCfg {
    NetCfg {
        channel: ChannelModel::BitFlip { ber: 0.05 },
        links: LinkAssignment::parse("mixed").unwrap(),
        deadline_s: 0.1,
        channel_seed: 5,
    }
}

/// Session with `shards` and `threads` pinned at construction — explicit
/// values are env-proof, so the `FEEDSIGN_SHARDS` CI leg cannot change
/// what these tests compare.
fn build(algo: Algorithm, k: usize, shards: usize, threads: usize) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 150, 1);
    let data_shards = split(&train, k, Partition::Iid, 0);
    let clients: Vec<Client> = data_shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: algo,
        rounds: 50,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        participation: ParticipationCfg::Fraction(0.6),
        catchup: CatchupCfg::Replay,
        net: impaired_net(),
        threads,
        shards,
        seed: 11,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

fn run_to_end(mut s: Session) -> Session {
    for t in 0..50 {
        s.step(t);
    }
    s.catch_up_all();
    s
}

fn assert_session_parity(label: &str, base: &Session, s: &Session) {
    for id in 0..base.clients.len() {
        assert_eq!(
            bits(&base.replica(id)),
            bits(&s.replica(id)),
            "{label}: client {id} replica diverged"
        );
    }
    assert_eq!(base.ledger.uplink_bits, s.ledger.uplink_bits, "{label}: uplink bits");
    assert_eq!(base.ledger.downlink_bits, s.ledger.downlink_bits, "{label}: downlink bits");
    assert_eq!(base.ledger.uplink_msgs, s.ledger.uplink_msgs, "{label}: uplink msgs");
    assert_eq!(base.ledger.downlink_msgs, s.ledger.downlink_msgs, "{label}: downlink msgs");
    assert_eq!(base.net.stats, s.net.stats, "{label}: impairment trace diverged");
    assert_eq!(
        feedsign::orbit::encode(&base.orbit),
        feedsign::orbit::encode(&s.orbit),
        "{label}: orbit bytes diverged"
    );
}

#[test]
fn every_engine_is_bit_identical_for_all_shard_and_thread_counts() {
    for algo in [
        Algorithm::FeedSign,
        Algorithm::DpFeedSign { epsilon: 2.0 },
        Algorithm::ZoFedSgd,
    ] {
        // unsharded sequential baseline
        let base = run_to_end(build(algo, 7, 0, 1));
        assert_eq!(base.shard_stats().shards, 0, "flat baseline must not shard");
        for n in [1usize, 2, 4, 7] {
            for threads in [1usize, 3, 8] {
                let s = run_to_end(build(algo, 7, n, threads));
                let label = format!("{algo:?}/shards={n}/threads={threads}");
                assert_session_parity(&label, &base, &s);
                let stats = s.shard_stats();
                assert_eq!(stats.shards, n.min(7), "{label}: shard count");
                assert!(stats.merges > 0, "{label}: merge traffic must be metered");
            }
        }
    }
}

#[test]
fn merge_traffic_is_coordinator_internal() {
    // the hierarchical merge must price its ShardVotes pairs somewhere —
    // but never in the client-facing ledger the flat run produces
    let flat = run_to_end(build(Algorithm::FeedSign, 7, 0, 1));
    let sharded = run_to_end(build(Algorithm::FeedSign, 7, 4, 4));
    assert_eq!(flat.ledger.uplink_bits, sharded.ledger.uplink_bits);
    assert_eq!(flat.ledger.uplink_msgs, sharded.ledger.uplink_msgs);
    let stats = sharded.shard_stats();
    assert!(stats.merge_bits > 0, "pairs carry information");
    assert!(
        stats.merges >= stats.merge_bits / 64,
        "each pair prices at most the dense 64-bit bound"
    );
    assert_eq!(flat.shard_stats().merges, 0);
}

fn dist_clients(k: usize, train: &Dataset) -> Vec<DistClient> {
    let shards = split(train, k, Partition::Iid, 0);
    shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            let engine: Box<dyn feedsign::engine::Engine> =
                Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
            let w = engine.init_params(11);
            DistClient {
                engine,
                w,
                shard,
                attack: Attack::None,
                rng: Rng::new(11 ^ 0xC11E_17, id as u32 + 1),
            }
        })
        .collect()
}

#[test]
fn both_topologies_agree_under_sharding() {
    // sync sharded vs threaded-distributed sharded vs both flat: four
    // runs of the same impaired configuration, one set of bits
    let train: Dataset = generate(&SYNTH_CIFAR10, 400, 0);
    let dist = |shards: usize| {
        let dcfg = DistCfg {
            rounds: 50,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            participation: ParticipationCfg::Fraction(0.6),
            catchup: CatchupCfg::Replay,
            net: impaired_net(),
            seed: 11,
            seed_pool: 0,
            shards,
        };
        run_feedsign(dist_clients(7, &train), train.clone(), dcfg)
    };
    let sync_flat = run_to_end(build(Algorithm::FeedSign, 7, 0, 1));
    let sync_sharded = run_to_end(build(Algorithm::FeedSign, 7, 4, 4));
    let dist_flat = dist(0);
    let dist_sharded = dist(4);

    for (id, w) in dist_sharded.finals.iter().enumerate() {
        assert_eq!(bits(w), bits(&dist_flat.finals[id]), "dist client {id}: sharding drifted");
        assert_eq!(bits(w), bits(&sync_sharded.replica(id)), "client {id}: topologies diverged");
        assert_eq!(bits(w), bits(&sync_flat.replica(id)), "client {id}: sharded vs flat sync");
    }
    for d in [&dist_flat, &dist_sharded] {
        assert_eq!(d.ledger.uplink_bits, sync_flat.ledger.uplink_bits);
        assert_eq!(d.ledger.downlink_bits, sync_flat.ledger.downlink_bits);
        assert_eq!(d.net, sync_flat.net.stats, "impairment trace diverged");
    }
    assert_eq!(dist_sharded.shard.shards, 4);
    assert!(dist_sharded.shard.merges > 0);
    assert_eq!(dist_flat.shard.shards, 0);
}

#[test]
fn oversubscribed_shard_count_degrades_to_singletons() {
    // --shards 7 over a 3-client pool: the map clamps to 3 singleton
    // shards and the run stays bit-identical to flat
    let base = run_to_end_small(build_small(0));
    let s = run_to_end_small(build_small(7));
    for id in 0..3 {
        assert_eq!(bits(&base.replica(id)), bits(&s.replica(id)), "client {id}");
    }
    assert_eq!(s.shard_stats().shards, 3, "clamped to one shard per client");
}

fn build_small(shards: usize) -> Session {
    let train: Dataset = generate(&SYNTH_CIFAR10, 300, 0);
    let test: Dataset = generate(&SYNTH_CIFAR10, 100, 1);
    let data_shards = split(&train, 3, Partition::Iid, 0);
    let clients: Vec<Client> = data_shards
        .into_iter()
        .enumerate()
        .map(|(id, shard)| {
            Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 11)
        })
        .collect();
    let cfg = SessionCfg {
        algorithm: Algorithm::FeedSign,
        rounds: 30,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        threads: 2,
        shards,
        seed: 11,
        ..Default::default()
    };
    Session::new(cfg, clients, train, test)
}

fn run_to_end_small(mut s: Session) -> Session {
    for t in 0..30 {
        s.step(t);
    }
    s
}
