//! PJRT <-> native-substrate parity: the two engines must agree on the
//! protocol-critical quantities.  These tests exercise the full AOT
//! artifact path (HLO text -> compile -> execute) and are skipped when
//! `artifacts/` has not been built (`make artifacts`).

use feedsign::data::{corpus, Batch};
use feedsign::runtime::{artifacts_available, artifacts_dir, PjrtModel};
use feedsign::simkit::nn::{Model, ModelCfg, TransformerSim};
use feedsign::simkit::prng;

fn load_tiny() -> Option<PjrtModel> {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(PjrtModel::load(&artifacts_dir(), "tiny").expect("load tiny"))
}

fn token_batch(model: &PjrtModel, rows: usize, seed: u32) -> Batch {
    let cols = model.entry.seq_len + 1;
    let d = corpus::generate(
        &corpus::GrammarSpec::default(),
        model.entry.vocab,
        model.entry.seq_len,
        rows,
        seed,
    );
    d.gather(&(0..rows).collect::<Vec<_>>())
}

#[test]
fn zvec_matches_rust_philox() {
    let Some(model) = load_tiny() else { return };
    for seed in [0u32, 1, 42, 9999] {
        let z_pjrt = model.zvec(seed).expect("zvec");
        let z_rust = prng::normals_vec(seed, model.entry.padded_size);
        assert_eq!(z_pjrt.len(), z_rust.len());
        let max_dev = z_pjrt
            .iter()
            .zip(&z_rust)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dev < 1e-5, "seed {seed}: kernel z deviates by {max_dev}");
    }
}

#[test]
fn init_params_match_python_reference_stats() {
    let Some(model) = load_tiny() else { return };
    let w = model.init_params(0);
    assert_eq!(w.len(), model.entry.padded_size);
    // embedding block: std 0.02 normals
    let embed = &w[..model.entry.vocab * model.entry.d_model];
    let mean: f32 = embed.iter().sum::<f32>() / embed.len() as f32;
    let var: f32 = embed.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / embed.len() as f32;
    assert!(mean.abs() < 2e-3, "embed mean {mean}");
    assert!((var.sqrt() - 0.02).abs() < 2e-3, "embed std {}", var.sqrt());
    // pad tail zeros
    assert!(w[model.entry.n_params..].iter().all(|&v| v == 0.0));
}

#[test]
fn loss_matches_native_transformer() {
    let Some(model) = load_tiny() else { return };
    let e = &model.entry;
    let cfg = ModelCfg::new(e.vocab, e.d_model, e.n_layers, e.n_heads, e.seq_len);
    let mut native = TransformerSim::new(cfg);
    let w = model.init_params(3);
    let batch = token_batch(&model, e.batch_eval, 5);
    let l_pjrt = model.loss(&w, &batch).expect("loss");
    let l_native = native.loss(&w, &batch);
    assert!(
        (l_pjrt - l_native).abs() < 2e-3,
        "loss mismatch: pjrt {l_pjrt} vs native {l_native}"
    );
}

#[test]
fn eval_accuracy_matches_native() {
    let Some(model) = load_tiny() else { return };
    let e = &model.entry;
    let cfg = ModelCfg::new(e.vocab, e.d_model, e.n_layers, e.n_heads, e.seq_len);
    let mut native = TransformerSim::new(cfg);
    let w = model.init_params(1);
    let batch = token_batch(&model, e.batch_eval, 6);
    let (_, c_pjrt) = model.eval(&w, &batch).expect("eval");
    let (_, c_native) = native.eval(&w, &batch);
    assert_eq!(c_pjrt, c_native, "argmax accuracy must agree");
}

#[test]
fn probe_sign_agrees_with_native() {
    // The 1-bit vote is the protocol payload: both engines must produce
    // the same sign for the same (w, batch, seed, mu) whenever the
    // projection is not borderline.
    let Some(model) = load_tiny() else { return };
    let e = &model.entry;
    let cfg = ModelCfg::new(e.vocab, e.d_model, e.n_layers, e.n_heads, e.seq_len);
    let mut native = TransformerSim::new(cfg);
    let w = model.init_params(2);
    let batch = token_batch(&model, e.batch_probe, 7);
    let mut agree = 0;
    let mut checked = 0;
    for seed in 0..12u32 {
        let p_pjrt = model.spsa_probe(&w, &batch, seed, 1e-3).expect("probe");
        let p_native = feedsign::simkit::zo::spsa_probe(&mut native, &w, &batch, seed, 1e-3);
        // relative agreement on the value...
        assert!(
            (p_pjrt - p_native).abs() < 0.05 * p_native.abs().max(0.5),
            "seed {seed}: pjrt {p_pjrt} vs native {p_native}"
        );
        // ...and on the vote when not borderline
        if p_native.abs() > 0.02 {
            checked += 1;
            if (p_pjrt >= 0.0) == (p_native >= 0.0) {
                agree += 1;
            }
        }
    }
    assert!(checked >= 6, "too few decisive probes");
    assert_eq!(agree, checked, "vote disagreement between engines");
}

#[test]
fn update_matches_native_axpy() {
    let Some(model) = load_tiny() else { return };
    let mut w_pjrt = model.init_params(4);
    let mut w_native = w_pjrt.clone();
    model.update(&mut w_pjrt, 11, 5e-3).expect("update");
    feedsign::simkit::zo::apply_update(&mut w_native, 11, 5e-3);
    let max_dev = w_pjrt
        .iter()
        .zip(&w_native)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 1e-6, "update deviates by {max_dev}");
}

#[test]
fn fo_step_reduces_loss_through_artifacts() {
    let Some(model) = load_tiny() else { return };
    let mut w = model.init_params(5);
    let batch = token_batch(&model, model.entry.batch_probe, 8);
    let l0 = model.fo_step(&mut w, &batch, 0.25).expect("fo");
    for _ in 0..5 {
        model.fo_step(&mut w, &batch, 0.25).expect("fo");
    }
    let l1 = model.fo_step(&mut w, &batch, 0.0).expect("fo");
    assert!(l1 < l0, "FO through artifacts must descend: {l0} -> {l1}");
}

#[test]
fn grad_proj_close_to_spsa_probe() {
    // Lemma 3.9 territory: the probe converges to the jvp as mu -> 0
    let Some(model) = load_tiny() else { return };
    let w = model.init_params(6);
    let batch = token_batch(&model, model.entry.batch_probe, 9);
    for seed in [0u32, 3, 8] {
        let exact = model.grad_proj(&w, &batch, seed).expect("jvp");
        let probe = model.spsa_probe(&w, &batch, seed, 1e-4).expect("probe");
        assert!(
            (exact - probe).abs() < 0.05 * exact.abs().max(0.5),
            "seed {seed}: jvp {exact} vs probe {probe}"
        );
    }
}
