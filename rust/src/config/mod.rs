//! Experiment configuration: TOML-serializable description of a federated
//! run, validated and buildable into a live [`Session`].
//!
//! The CLI (`feedsign run --config exp.toml`) and the bench harnesses both
//! construct sessions through this module, so every experiment in
//! EXPERIMENTS.md is reproducible from a checked-in config.

use crate::coordinator::{
    Algorithm, Attack, CatchupCfg, Client, ParticipationCfg, Session, SessionCfg,
};
use crate::data::partition::{split, Partition};
use crate::data::{corpus, tasks, vision, Dataset};
use crate::engine::{Engine, NativeEngine};
use crate::net::{ChannelModel, LinkAssignment, NetCfg};
use crate::simkit::nn::{LinearProbe, ModelCfg, TransformerSim};
use crate::util::toml_lite::{Doc, Value};
use anyhow::{bail, Context, Result};

/// Model selection for the native engine.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSpec {
    /// Decoder-only transformer LM (simkit).
    Transformer {
        vocab: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        seq_len: usize,
    },
    /// Linear probe over frozen features (vision last-layer FFT).
    LinearProbe { dim: usize, classes: usize },
}

impl ModelSpec {
    /// A small LM the LM tables use by default.
    pub fn lm_small() -> Self {
        ModelSpec::Transformer { vocab: 64, d_model: 32, n_layers: 2, n_heads: 4, seq_len: 16 }
    }

    pub fn build(&self) -> Box<dyn Engine> {
        match *self {
            ModelSpec::Transformer { vocab, d_model, n_layers, n_heads, seq_len } => {
                Box::new(NativeEngine::new(TransformerSim::new(ModelCfg::new(
                    vocab, d_model, n_layers, n_heads, seq_len,
                ))))
            }
            ModelSpec::LinearProbe { dim, classes } => {
                Box::new(NativeEngine::new(LinearProbe::new(dim, classes)))
            }
        }
    }
}

/// Task / dataset selection.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskSpec {
    /// One of the synthetic LM classification tasks (`synth-sst2`, …).
    SynthLm { name: String, train: usize, test: usize },
    /// The template-grammar pretraining corpus.
    Corpus { train: usize, test: usize },
    /// Synthetic vision (`synth-cifar10` / `synth-cifar100`).
    SynthVision { name: String, train: usize, test: usize },
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub model: ModelSpec,
    pub task: TaskSpec,
    /// algorithm string: `feedsign | zo-fedsgd | fedsgd | mezo | dp-feedsign:EPS`
    pub algorithm: String,
    pub clients: usize,
    pub rounds: u64,
    pub eta: f32,
    pub mu: f32,
    pub batch_size: usize,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub eval_batch_size: usize,
    /// `iid` or Dirichlet concentration (`beta > 0`)
    pub dirichlet_beta: Option<f32>,
    pub byzantine_count: usize,
    /// attack string: `sign-flip | random-projection[:s] | gauss-noise[:s] | label-flip`
    pub attack: Option<String>,
    pub c_g_noise: f32,
    /// per-round client sampling: `full | fraction:F | bernoulli:P`
    /// (synchronized ZO algorithms only)
    pub participation: String,
    /// offline-client catch-up policy: `off | replay | rebroadcast | pool`
    /// (synchronized ZO algorithms only; see `coordinator::catchup`)
    pub catchup: String,
    /// restricted seed space (FedKSeed): size K of the candidate
    /// direction pool, or 0 for the unrestricted per-round derivation.
    /// K ≥ 2 prices each round announcement at `ceil(log2 K)` index bits
    /// instead of an implicit 64-bit round counter (FeedSign algorithms
    /// only; see `comm::SeedPool`)
    pub seed_pool: usize,
    /// impaired-channel model: `ideal | ber:P | drop:P` (see `net`)
    pub channel: String,
    /// per-client link profiles: `mobile | wifi | iot | mixed`
    pub link: String,
    /// round deadline in virtual seconds (0 = no straggler cut;
    /// synchronized ZO algorithms only)
    pub deadline: f64,
    /// seed of the impairment draw streams (keyed with
    /// `(round, client, direction)`; independent of the run seed so
    /// channel sweeps can hold the learning trajectory fixed)
    pub channel_seed: u32,
    /// round-engine worker threads (0 = auto, 1 = sequential baseline)
    pub threads: usize,
    /// replica-plane snapshot cache capacity (`coordinator::replica`):
    /// how many pre-commit canonical buffers the coordinator retains so
    /// stale logical replicas stay readable without a history
    /// reconstruction.  Memory bound `replica_cache · d` floats, spent
    /// only while stragglers exist; 0 disables the cache.  Never
    /// affects the computed bits.
    pub replica_cache: usize,
    /// coordinator shards (`--shards N`; see `coordinator::shard`):
    /// `>= 1` partitions the client pool into that many contiguous-id
    /// shards, each owning its clients' probe fan-out and a local
    /// sign-vote accumulator merged hierarchically — bit-identical to
    /// the unsharded engine by construction.  0 keeps the flat path
    /// (synchronized ZO algorithms only).
    pub shards: usize,
    /// fused-sweep tile in f32 elements (`--tile N`; see
    /// `coordinator::tile` and `simkit::zo::fused_commit_probe_span`):
    /// the canonical walk granularity of the single-sweep commit+probe
    /// kernel.  0 = auto (the `FEEDSIGN_TILE` env override or the
    /// L2-sized default).  Never affects the computed bits — counter-mode
    /// noise makes every tiling bit-identical by construction.
    pub tile: usize,
    /// tiered canonical store budget in **bytes** (`--tile-budget N`):
    /// `> 0` caps the resident tile window of the canonical buffer and
    /// spills cold tiles to an unlinked temp file, so `d` larger than
    /// the budget runs with flat peak memory; 0 keeps the canonical
    /// fully in RAM.  Never affects the computed bits.
    pub tile_budget: usize,
    /// Central FO pretraining steps on a *format-matched but
    /// label-uninformative* dataset before federation begins.  This
    /// manufactures the "pretrained checkpoint" the paper's fine-tuning
    /// experiments assume (Assumption 3.5): the model learns the sequence
    /// format (emit a label token after SEP) without learning the target
    /// mapping, which is what makes ZO fine-tuning move in few rounds.
    pub pretrain_rounds: u64,
    pub seed: u32,
    pub verbose: bool,
}

impl ExperimentConfig {
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = Doc::parse(text).context("parsing experiment TOML")?;
        let req_str = |sec: &str, key: &str| -> Result<String> {
            doc.str(sec, key)
                .with_context(|| format!("missing string key {sec}.{key}"))
        };
        let model = match req_str("model", "kind")?.as_str() {
            "transformer" => ModelSpec::Transformer {
                vocab: doc.int("model", "vocab").context("model.vocab")? as usize,
                d_model: doc.int("model", "d_model").context("model.d_model")? as usize,
                n_layers: doc.int("model", "n_layers").context("model.n_layers")? as usize,
                n_heads: doc.int("model", "n_heads").context("model.n_heads")? as usize,
                seq_len: doc.int("model", "seq_len").context("model.seq_len")? as usize,
            },
            "linear-probe" => ModelSpec::LinearProbe {
                dim: doc.int("model", "dim").context("model.dim")? as usize,
                classes: doc.int("model", "classes").context("model.classes")? as usize,
            },
            k => bail!("unknown model kind {k:?}"),
        };
        let train = doc.int("task", "train").context("task.train")? as usize;
        let test = doc.int("task", "test").context("task.test")? as usize;
        let task = match req_str("task", "kind")?.as_str() {
            "synth-lm" => TaskSpec::SynthLm { name: req_str("task", "name")?, train, test },
            "corpus" => TaskSpec::Corpus { train, test },
            "synth-vision" => TaskSpec::SynthVision { name: req_str("task", "name")?, train, test },
            k => bail!("unknown task kind {k:?}"),
        };
        let cfg = ExperimentConfig {
            name: req_str("", "name")?,
            model,
            task,
            algorithm: req_str("", "algorithm")?,
            clients: doc.int("", "clients").context("clients")? as usize,
            rounds: doc.int("", "rounds").context("rounds")? as u64,
            eta: doc.float("", "eta").context("eta")? as f32,
            mu: doc.float("", "mu").context("mu")? as f32,
            batch_size: doc.int("", "batch_size").context("batch_size")? as usize,
            eval_every: doc.int("", "eval_every").unwrap_or(0) as u64,
            eval_batches: doc.int("", "eval_batches").unwrap_or(4) as usize,
            eval_batch_size: doc.int("", "eval_batch_size").unwrap_or(32) as usize,
            pretrain_rounds: doc.int("", "pretrain_rounds").unwrap_or(0) as u64,
            dirichlet_beta: doc.float("", "dirichlet_beta").map(|b| b as f32),
            byzantine_count: doc.int("", "byzantine_count").unwrap_or(0) as usize,
            attack: doc.str("", "attack"),
            c_g_noise: doc.float("", "c_g_noise").unwrap_or(0.0) as f32,
            participation: doc.str("", "participation").unwrap_or_else(|| "full".into()),
            catchup: doc.str("", "catchup").unwrap_or_else(|| "off".into()),
            seed_pool: doc.int("", "seed_pool").unwrap_or(0) as usize,
            channel: doc.str("", "channel").unwrap_or_else(|| "ideal".into()),
            link: doc.str("", "link").unwrap_or_else(|| "mobile".into()),
            deadline: doc.float("", "deadline").unwrap_or(0.0),
            channel_seed: doc.int("", "channel_seed").unwrap_or(0) as u32,
            threads: doc.int("", "threads").unwrap_or(0) as usize,
            replica_cache: doc.int("", "replica_cache").unwrap_or(4) as usize,
            shards: doc.int("", "shards").unwrap_or(0) as usize,
            tile: doc.int("", "tile").unwrap_or(0) as usize,
            tile_budget: doc.int("", "tile_budget").unwrap_or(0) as usize,
            seed: doc.int("", "seed").unwrap_or(0) as u32,
            verbose: doc.bool("", "verbose").unwrap_or(false),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&text)
    }

    pub fn to_toml(&self) -> String {
        let mut d = Doc::default();
        let s = |v: &str| Value::Str(v.to_string());
        d.set("", "name", s(&self.name));
        d.set("", "algorithm", s(&self.algorithm));
        d.set("", "clients", Value::Int(self.clients as i64));
        d.set("", "rounds", Value::Int(self.rounds as i64));
        d.set("", "eta", Value::Float(self.eta as f64));
        d.set("", "mu", Value::Float(self.mu as f64));
        d.set("", "batch_size", Value::Int(self.batch_size as i64));
        d.set("", "eval_every", Value::Int(self.eval_every as i64));
        d.set("", "eval_batches", Value::Int(self.eval_batches as i64));
        d.set("", "eval_batch_size", Value::Int(self.eval_batch_size as i64));
        if let Some(beta) = self.dirichlet_beta {
            d.set("", "dirichlet_beta", Value::Float(beta as f64));
        }
        d.set("", "byzantine_count", Value::Int(self.byzantine_count as i64));
        if let Some(a) = &self.attack {
            d.set("", "attack", s(a));
        }
        d.set("", "c_g_noise", Value::Float(self.c_g_noise as f64));
        d.set("", "participation", s(&self.participation));
        d.set("", "catchup", s(&self.catchup));
        d.set("", "seed_pool", Value::Int(self.seed_pool as i64));
        d.set("", "channel", s(&self.channel));
        d.set("", "link", s(&self.link));
        d.set("", "deadline", Value::Float(self.deadline));
        d.set("", "channel_seed", Value::Int(self.channel_seed as i64));
        d.set("", "threads", Value::Int(self.threads as i64));
        d.set("", "replica_cache", Value::Int(self.replica_cache as i64));
        d.set("", "shards", Value::Int(self.shards as i64));
        d.set("", "tile", Value::Int(self.tile as i64));
        d.set("", "tile_budget", Value::Int(self.tile_budget as i64));
        d.set("", "pretrain_rounds", Value::Int(self.pretrain_rounds as i64));
        d.set("", "seed", Value::Int(self.seed as i64));
        d.set("", "verbose", Value::Bool(self.verbose));
        match &self.model {
            ModelSpec::Transformer { vocab, d_model, n_layers, n_heads, seq_len } => {
                d.set("model", "kind", s("transformer"));
                d.set("model", "vocab", Value::Int(*vocab as i64));
                d.set("model", "d_model", Value::Int(*d_model as i64));
                d.set("model", "n_layers", Value::Int(*n_layers as i64));
                d.set("model", "n_heads", Value::Int(*n_heads as i64));
                d.set("model", "seq_len", Value::Int(*seq_len as i64));
            }
            ModelSpec::LinearProbe { dim, classes } => {
                d.set("model", "kind", s("linear-probe"));
                d.set("model", "dim", Value::Int(*dim as i64));
                d.set("model", "classes", Value::Int(*classes as i64));
            }
        }
        match &self.task {
            TaskSpec::SynthLm { name, train, test } => {
                d.set("task", "kind", s("synth-lm"));
                d.set("task", "name", s(name));
                d.set("task", "train", Value::Int(*train as i64));
                d.set("task", "test", Value::Int(*test as i64));
            }
            TaskSpec::Corpus { train, test } => {
                d.set("task", "kind", s("corpus"));
                d.set("task", "train", Value::Int(*train as i64));
                d.set("task", "test", Value::Int(*test as i64));
            }
            TaskSpec::SynthVision { name, train, test } => {
                d.set("task", "kind", s("synth-vision"));
                d.set("task", "name", s(name));
                d.set("task", "train", Value::Int(*train as i64));
                d.set("task", "test", Value::Int(*test as i64));
            }
        }
        d.render()
    }

    pub fn validate(&self) -> Result<()> {
        let Some(algo) = Algorithm::parse(&self.algorithm) else {
            bail!("unknown algorithm {:?}", self.algorithm);
        };
        if matches!(algo, Algorithm::Mezo) && self.clients != 1 {
            bail!("mezo is centralized: clients must be 1");
        }
        if self.clients == 0 || self.rounds == 0 || self.batch_size == 0 {
            bail!("clients, rounds and batch_size must be positive");
        }
        if self.byzantine_count >= self.clients && self.byzantine_count > 0 {
            bail!("byzantine_count must be < clients");
        }
        if let Some(beta) = self.dirichlet_beta {
            if beta <= 0.0 {
                bail!("dirichlet beta must be > 0");
            }
        }
        if self.eta <= 0.0 || self.mu <= 0.0 {
            bail!("eta and mu must be positive");
        }
        if let Some(a) = &self.attack {
            if Attack::parse(a).is_none() {
                bail!("unknown attack {a:?}");
            }
        }
        let Some(participation) = ParticipationCfg::parse(&self.participation) else {
            bail!("unknown participation {:?} (full | fraction:F | bernoulli:P)", self.participation);
        };
        if participation != ParticipationCfg::Full
            && matches!(algo, Algorithm::FedSgd | Algorithm::Mezo)
        {
            bail!("partial participation applies to feedsign/dp-feedsign/zo-fedsgd only");
        }
        let Some(catchup) = CatchupCfg::parse(&self.catchup) else {
            bail!("unknown catchup {:?} (off | replay | rebroadcast | pool)", self.catchup);
        };
        if catchup.is_on() && matches!(algo, Algorithm::FedSgd | Algorithm::Mezo) {
            bail!("catch-up applies to feedsign/dp-feedsign/zo-fedsgd only");
        }
        if self.seed_pool == 1 {
            bail!("seed_pool = 1 would fix a single direction for the whole run; use K >= 2 (or 0 for the unrestricted space)");
        }
        if self.seed_pool > 0
            && !matches!(algo, Algorithm::FeedSign | Algorithm::DpFeedSign { .. })
        {
            bail!("the restricted seed space (seed_pool) applies to feedsign/dp-feedsign only");
        }
        if catchup == CatchupCfg::PoolScalars && self.seed_pool == 0 {
            bail!("catchup = \"pool\" downloads the K accumulated pool scalars and so requires seed_pool >= 2");
        }
        let Some(channel) = ChannelModel::parse(&self.channel) else {
            bail!("unknown channel {:?} (ideal | ber:P | drop:P)", self.channel);
        };
        let Some(link) = LinkAssignment::parse(&self.link) else {
            bail!("unknown link profile {:?} (mobile | wifi | iot | mixed)", self.link);
        };
        if !self.deadline.is_finite() || self.deadline < 0.0 {
            bail!("deadline must be a non-negative number of virtual seconds");
        }
        if self.deadline > 0.0 && matches!(algo, Algorithm::FedSgd | Algorithm::Mezo) {
            bail!("the round deadline applies to feedsign/dp-feedsign/zo-fedsgd only");
        }
        if self.shards > 0 && matches!(algo, Algorithm::FedSgd | Algorithm::Mezo) {
            bail!("coordinator shards apply to feedsign/dp-feedsign/zo-fedsgd only");
        }
        if matches!(algo, Algorithm::Mezo) && !channel.is_ideal() {
            bail!("mezo is centralized: there is no channel to impair");
        }
        if matches!(algo, Algorithm::Mezo) && !link.is_default() {
            bail!("mezo is centralized: there is no client link to simulate");
        }
        // model/task compatibility
        match (&self.model, &self.task) {
            (ModelSpec::Transformer { vocab, seq_len, .. }, TaskSpec::SynthLm { name, .. }) => {
                if tasks::find_task(name).is_none() {
                    bail!("unknown synth task {name:?}");
                }
                let spec = tasks::find_task(name).unwrap();
                if *vocab <= spec.n_classes + 8 {
                    bail!("vocab too small for task {name}");
                }
                let _ = seq_len;
            }
            (ModelSpec::Transformer { .. }, TaskSpec::Corpus { .. }) => {}
            (ModelSpec::LinearProbe { dim, classes }, TaskSpec::SynthVision { name, .. }) => {
                let spec = vision_spec(name)?;
                if *dim != spec.feat_dim || *classes != spec.n_classes {
                    bail!(
                        "probe dims ({dim}, {classes}) mismatch task {name} ({}, {})",
                        spec.feat_dim,
                        spec.n_classes
                    );
                }
            }
            _ => bail!("model/task kind mismatch"),
        }
        Ok(())
    }

    pub fn algorithm(&self) -> Algorithm {
        Algorithm::parse(&self.algorithm).expect("validated")
    }

    pub fn participation_cfg(&self) -> ParticipationCfg {
        ParticipationCfg::parse(&self.participation).expect("validated")
    }

    pub fn catchup_cfg(&self) -> CatchupCfg {
        CatchupCfg::parse(&self.catchup).expect("validated")
    }

    pub fn net_cfg(&self) -> NetCfg {
        NetCfg {
            channel: ChannelModel::parse(&self.channel).expect("validated"),
            links: LinkAssignment::parse(&self.link).expect("validated"),
            deadline_s: self.deadline,
            channel_seed: self.channel_seed,
        }
    }

    /// Generate the train/test datasets.
    pub fn datasets(&self) -> Result<(Dataset, Dataset)> {
        Ok(match (&self.model, &self.task) {
            (
                ModelSpec::Transformer { vocab, seq_len, .. },
                TaskSpec::SynthLm { name, train, test },
            ) => {
                let spec = tasks::find_task(name).context("task")?;
                (
                    tasks::generate(spec, *vocab, *seq_len, *train, self.seed.wrapping_mul(2) + 100),
                    tasks::generate(spec, *vocab, *seq_len, *test, self.seed.wrapping_mul(2) + 101),
                )
            }
            (ModelSpec::Transformer { vocab, seq_len, .. }, TaskSpec::Corpus { train, test }) => {
                let g = corpus::GrammarSpec::default();
                (
                    corpus::generate(&g, *vocab, *seq_len, *train, self.seed + 200),
                    corpus::generate(&g, *vocab, *seq_len, *test, self.seed + 201),
                )
            }
            (ModelSpec::LinearProbe { .. }, TaskSpec::SynthVision { name, train, test }) => {
                let spec = vision_spec(name)?;
                (
                    vision::generate(&spec, *train, self.seed + 300),
                    vision::generate(&spec, *test, self.seed + 301),
                )
            }
            _ => bail!("model/task kind mismatch"),
        })
    }

    /// Build a ready-to-run session (native engines).
    pub fn build_session(&self) -> Result<Session> {
        self.validate()?;
        let (train, test) = self.datasets()?;
        let partition = match self.dirichlet_beta {
            None => Partition::Iid,
            Some(beta) => Partition::Dirichlet { beta },
        };
        let shards = split(&train, self.clients, partition, self.seed);
        let attack = self
            .attack
            .as_deref()
            .map(|a| Attack::parse(a).expect("validated"))
            .unwrap_or(Attack::SignFlip);
        // optional centralized FO pretraining -> shared checkpoint
        let checkpoint: Option<Vec<f32>> = if self.pretrain_rounds > 0 {
            let pre = self.pretrain_dataset()?;
            let mut engine = self.model.build();
            let mut w = engine.init_params(self.seed);
            let mut rng = crate::simkit::prng::Rng::new(self.seed ^ 0x9E7, 0);
            let mut shard = crate::data::Shard::new((0..pre.len()).collect());
            for _ in 0..self.pretrain_rounds {
                let batch = shard.next_batch(&pre, self.batch_size, &mut rng);
                engine.fo_step(&mut w, &batch, 0.2);
            }
            Some(w)
        } else {
            None
        };
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                let mut c = Client::new(id, self.model.build(), shard, self.seed);
                if let Some(w) = &checkpoint {
                    // the pool shares one pretrained start: client 0
                    // carries the dense buffer, everyone else declares
                    // bit-equality to it — the replica plane then holds a
                    // single canonical copy instead of K
                    c = if id == 0 { c.with_checkpoint(w) } else { c.with_session_checkpoint() };
                }
                if id < self.byzantine_count {
                    c.with_attack(attack)
                } else {
                    c
                }
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: self.algorithm(),
            rounds: self.rounds,
            eta: self.eta,
            mu: self.mu,
            batch_size: self.batch_size,
            eval_every: self.eval_every,
            eval_batches: self.eval_batches,
            eval_batch_size: self.eval_batch_size,
            c_g_noise: self.c_g_noise,
            participation: self.participation_cfg(),
            catchup: self.catchup_cfg(),
            seed_pool: self.seed_pool,
            threads: self.threads,
            net: self.net_cfg(),
            replica_cache: self.replica_cache,
            shards: self.shards,
            tile: self.tile,
            // config 0 = "unset": fall through to the SessionCfg default,
            // which honours the FEEDSIGN_TILE_BUDGET env override (the CI
            // spill leg reroutes every session through the tiered store)
            tile_budget: match self.tile_budget {
                0 => SessionCfg::default().tile_budget,
                b => b,
            },
            fuse_commits: true,
            seed: self.seed,
            verbose: self.verbose,
        };
        Ok(Session::new(cfg, clients, train, test))
    }
}

impl ExperimentConfig {
    /// Format-matched, label-uninformative pretraining data: the same
    /// generator as the target task but keyed to a disjoint signal-set
    /// name, so the planted signals carry no information about the target
    /// mapping while sequence structure (SEP + label slot) is identical.
    fn pretrain_dataset(&self) -> Result<Dataset> {
        match (&self.model, &self.task) {
            (ModelSpec::Transformer { vocab, seq_len, .. }, TaskSpec::SynthLm { name, train, .. }) => {
                let target = tasks::find_task(name).context("task")?;
                let spec = tasks::TaskSpec::new("pretrain-format", target.n_classes, target.signal_rate, target.signal_width);
                Ok(tasks::generate(&spec, *vocab, *seq_len, (*train).max(512), self.seed + 777))
            }
            (ModelSpec::Transformer { vocab, seq_len, .. }, TaskSpec::Corpus { train, .. }) => {
                Ok(corpus::generate(&corpus::GrammarSpec::default(), *vocab, *seq_len, (*train).max(512), self.seed + 778))
            }
            (ModelSpec::LinearProbe { .. }, TaskSpec::SynthVision { name, train, .. }) => {
                // vision probes have no pretraining stage (the featurizer IS
                // the pretrained backbone); return an unrelated mixture so a
                // configured pretrain still runs without informing the task
                let spec = vision_spec(name)?;
                Ok(vision::generate(&spec, (*train).max(256), self.seed + 779))
            }
            _ => anyhow::bail!("model/task kind mismatch"),
        }
    }
}

fn vision_spec(name: &str) -> Result<vision::VisionSpec> {
    match name {
        "synth-cifar10" => Ok(vision::SYNTH_CIFAR10.clone()),
        "synth-cifar100" => Ok(vision::SYNTH_CIFAR100.clone()),
        _ => bail!("unknown vision task {name:?}"),
    }
}

/// Built-in quickstart config (also written by `feedsign init-config`).
pub fn quickstart() -> ExperimentConfig {
    ExperimentConfig {
        name: "quickstart".into(),
        model: ModelSpec::LinearProbe { dim: 128, classes: 10 },
        task: TaskSpec::SynthVision { name: "synth-cifar10".into(), train: 2000, test: 500 },
        algorithm: "feedsign".into(),
        clients: 5,
        rounds: 2000,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 200,
        eval_batches: 4,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        tile: 0,
        tile_budget: 0,
        pretrain_rounds: 0,
        seed: 0,
        verbose: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_validates_and_builds() {
        let cfg = quickstart();
        cfg.validate().unwrap();
        let s = cfg.build_session().unwrap();
        assert_eq!(s.clients.len(), 5);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = quickstart();
        let text = cfg.to_toml();
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(back.name, cfg.name);
        assert_eq!(back.model, cfg.model);
        assert_eq!(back.clients, 5);
    }

    #[test]
    fn rejects_bad_algorithm() {
        let mut cfg = quickstart();
        cfg.algorithm = "sgd9000".into();
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_mezo_with_many_clients() {
        let mut cfg = quickstart();
        cfg.algorithm = "mezo".into();
        assert!(cfg.validate().is_err());
        cfg.clients = 1;
        cfg.validate().unwrap();
    }

    #[test]
    fn rejects_probe_dim_mismatch() {
        let mut cfg = quickstart();
        cfg.model = ModelSpec::LinearProbe { dim: 64, classes: 10 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_all_byzantine() {
        let mut cfg = quickstart();
        cfg.byzantine_count = 5;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn lm_config_builds() {
        let cfg = ExperimentConfig {
            name: "lm".into(),
            model: ModelSpec::lm_small(),
            task: TaskSpec::SynthLm { name: "synth-sst2".into(), train: 200, test: 100 },
            algorithm: "zo-fedsgd".into(),
            clients: 3,
            rounds: 10,
            eta: 1e-4,
            mu: 1e-3,
            batch_size: 8,
            eval_every: 0,
            eval_batches: 2,
            eval_batch_size: 16,
            dirichlet_beta: Some(1.0),
            byzantine_count: 1,
            attack: Some("random-projection".into()),
            c_g_noise: 0.0,
            participation: "full".into(),
            catchup: "off".into(),
            seed_pool: 0,
            channel: "ideal".into(),
            link: "mobile".into(),
            deadline: 0.0,
            channel_seed: 0,
            threads: 0,
            replica_cache: 4,
            shards: 0,
            tile: 0,
            tile_budget: 0,
            pretrain_rounds: 0,
            seed: 1,
            verbose: false,
        };
        let mut s = cfg.build_session().unwrap();
        s.step(0); // smoke: one LM round with an attacker
        assert!(s.ledger.uplink_bits > 0);
    }

    #[test]
    fn participation_parses_and_roundtrips() {
        let mut cfg = quickstart();
        cfg.participation = "fraction:0.4".into();
        cfg.validate().unwrap();
        assert_eq!(cfg.participation_cfg(), ParticipationCfg::Fraction(0.4));
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.participation, "fraction:0.4");
        let mut s = cfg.build_session().unwrap();
        s.step(0);
        assert_eq!(s.ledger.uplink_bits, 2, "2 of 5 participants vote");
    }

    #[test]
    fn rejects_bad_participation_and_fo_partial() {
        let mut cfg = quickstart();
        cfg.participation = "sometimes".into();
        assert!(cfg.validate().is_err());
        cfg.participation = "fraction:0.5".into();
        cfg.algorithm = "fedsgd".into();
        assert!(cfg.validate().is_err(), "FO baseline is full-participation only");
    }

    #[test]
    fn catchup_parses_roundtrips_and_gates() {
        let mut cfg = quickstart();
        cfg.participation = "fraction:0.4".into();
        cfg.catchup = "replay".into();
        cfg.validate().unwrap();
        assert_eq!(cfg.catchup_cfg(), CatchupCfg::Replay);
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.catchup, "replay");
        let mut s = cfg.build_session().unwrap();
        s.step(0);
        // 2 of 5 participate: with catch-up on, only they hear the bit
        assert_eq!(s.ledger.downlink_bits, 2);
        // bad spec and FO/MeZO gating
        cfg.catchup = "resend".into();
        assert!(cfg.validate().is_err());
        cfg.catchup = "replay".into();
        cfg.participation = "full".into();
        cfg.algorithm = "fedsgd".into();
        assert!(cfg.validate().is_err(), "catch-up is a seed-protocol feature");
    }

    #[test]
    fn omitted_catchup_defaults_off() {
        let cfg = quickstart();
        let mut text = cfg.to_toml();
        text = text
            .lines()
            .filter(|l| !l.starts_with("catchup"))
            .collect::<Vec<_>>()
            .join("\n");
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(back.catchup_cfg(), CatchupCfg::Off);
    }

    #[test]
    fn channel_parses_roundtrips_and_gates() {
        let mut cfg = quickstart();
        cfg.channel = "ber:0.001".into();
        cfg.link = "mixed".into();
        cfg.deadline = 0.5;
        cfg.channel_seed = 7;
        cfg.validate().unwrap();
        let net = cfg.net_cfg();
        assert_eq!(net.channel, crate::net::ChannelModel::BitFlip { ber: 0.001 });
        assert!((net.deadline_s - 0.5).abs() < 1e-12);
        assert_eq!(net.channel_seed, 7);
        assert!(net.is_active());
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.channel, "ber:0.001");
        assert_eq!(back.link, "mixed");
        assert!((back.deadline - 0.5).abs() < 1e-12);
        assert_eq!(back.channel_seed, 7);
        // bad specs
        cfg.channel = "lossy".into();
        assert!(cfg.validate().is_err());
        cfg.channel = "drop:0.1".into();
        cfg.link = "carrier-pigeon".into();
        assert!(cfg.validate().is_err());
        cfg.link = "mobile".into();
        cfg.deadline = -1.0;
        assert!(cfg.validate().is_err());
        // gating: FO has no plan phase to cut, MeZO has no channel
        cfg.deadline = 0.5;
        cfg.algorithm = "fedsgd".into();
        assert!(cfg.validate().is_err(), "deadline is a synchronized-round feature");
        cfg.deadline = 0.0;
        cfg.validate().unwrap();
        cfg.algorithm = "mezo".into();
        cfg.clients = 1;
        assert!(cfg.validate().is_err(), "mezo has no channel to impair");
        cfg.channel = "ideal".into();
        cfg.link = "mixed".into();
        assert!(cfg.validate().is_err(), "mezo has no client links to simulate");
        cfg.link = "mobile".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn omitted_channel_defaults_ideal_and_inactive() {
        let cfg = quickstart();
        let text: String = cfg
            .to_toml()
            .lines()
            .filter(|l| {
                !l.starts_with("channel")
                    && !l.starts_with("link")
                    && !l.starts_with("deadline")
            })
            .collect::<Vec<_>>()
            .join("\n");
        let back = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(back.channel, "ideal");
        assert_eq!(back.link, "mobile");
        assert!(!back.net_cfg().is_active());
    }

    #[test]
    fn ber_channel_session_builds_and_steps() {
        let mut cfg = quickstart();
        cfg.channel = "ber:0.5".into();
        cfg.rounds = 5;
        let mut s = cfg.build_session().unwrap();
        for t in 0..5 {
            s.step(t);
        }
        assert!(s.net.stats.flipped_bits > 0, "half the votes should flip");
        assert!(s.replicas_synchronized(), "flips corrupt votes, not replicas");
    }

    #[test]
    fn threads_roundtrip_through_toml() {
        let mut cfg = quickstart();
        cfg.threads = 3;
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.threads, 3);
    }

    #[test]
    fn replica_cache_roundtrips_and_defaults() {
        let mut cfg = quickstart();
        cfg.replica_cache = 9;
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.replica_cache, 9);
        // omitted key falls back to the default capacity
        let text: String = cfg
            .to_toml()
            .lines()
            .filter(|l| !l.starts_with("replica_cache"))
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(ExperimentConfig::from_toml(&text).unwrap().replica_cache, 4);
        // and the knob reaches the session
        cfg.replica_cache = 0;
        let s = cfg.build_session().unwrap();
        assert_eq!(s.cfg.replica_cache, 0);
    }

    #[test]
    fn shards_roundtrip_gate_and_reach_the_session() {
        let mut cfg = quickstart();
        cfg.shards = 2;
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.shards, 2);
        // omitted key defaults to the flat path
        let text: String = cfg
            .to_toml()
            .lines()
            .filter(|l| !l.starts_with("shards"))
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(ExperimentConfig::from_toml(&text).unwrap().shards, 0);
        // the knob reaches the session's sharded plane
        cfg.rounds = 3;
        let mut s = cfg.build_session().unwrap();
        s.step(0);
        assert_eq!(s.shard_stats().shards, 2);
        assert_eq!(s.shard_stats().merges, 2, "one merge per shard per round");
        // gating: FO/MeZO have no vote to shard
        cfg.algorithm = "fedsgd".into();
        assert!(cfg.validate().is_err(), "shards are a sign-vote feature");
    }

    #[test]
    fn tile_knobs_roundtrip_and_reach_the_session() {
        let mut cfg = quickstart();
        cfg.tile = 64;
        // 2-page resident window: d = 1290 floats needs ~21 tiles of 64,
        // so a full round must spill
        cfg.tile_budget = 4 * 64 * 2;
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.tile, 64);
        assert_eq!(back.tile_budget, 4 * 64 * 2);
        // omitted keys default to auto tiling with the in-RAM store
        let text: String = cfg
            .to_toml()
            .lines()
            .filter(|l| !l.starts_with("tile"))
            .collect::<Vec<_>>()
            .join("\n");
        let plain = ExperimentConfig::from_toml(&text).unwrap();
        assert_eq!(plain.tile, 0);
        assert_eq!(plain.tile_budget, 0);
        // the knobs reach the session: a spill-mode round stays
        // synchronized and holds the resident window to the budget
        cfg.rounds = 3;
        let mut s = cfg.build_session().unwrap();
        assert_eq!(s.cfg.tile, 64);
        assert_eq!(s.cfg.tile_budget, 4 * 64 * 2);
        s.step(0);
        assert!(s.replicas_synchronized());
        let ts = s.replica_stats().tile;
        assert!(ts.spills > 0, "d exceeds the window: the sweep must spill");
        assert!(ts.peak_resident_bytes <= 4 * 64 * 2);
    }

    #[test]
    fn pretrained_pool_shares_one_checkpoint_buffer() {
        let mut cfg = quickstart();
        cfg.rounds = 5;
        cfg.pretrain_rounds = 10;
        let mut s = cfg.build_session().unwrap();
        // all K clients start bit-identical to the pretrained canonical:
        // nobody is promoted to an owned replica, so the coordinator
        // holds one d-float buffer, not K
        assert_eq!(s.replica_stats().owned_clients, 0);
        assert_eq!(s.replica_stats().peak_bytes, 4 * s.replicas.d());
        s.step(0);
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn seed_pool_roundtrips_gates_and_reaches_session() {
        let mut cfg = quickstart();
        cfg.seed_pool = 64;
        cfg.validate().unwrap();
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.seed_pool, 64);
        // omitted key defaults to the unrestricted space
        let text: String = cfg
            .to_toml()
            .lines()
            .filter(|l| !l.starts_with("seed_pool"))
            .collect::<Vec<_>>()
            .join("\n");
        assert_eq!(ExperimentConfig::from_toml(&text).unwrap().seed_pool, 0);
        // the knob reaches the session and prices the downlink at
        // ceil(log2 64) + 1 = 7 bits per client per round
        cfg.rounds = 3;
        let mut s = cfg.build_session().unwrap();
        assert_eq!(s.cfg.seed_pool, 64);
        s.step(0);
        assert_eq!(s.ledger.downlink_bits, 5 * 7);
        // gating: K = 1 is degenerate; FO/MeZO have no seed to restrict
        cfg.seed_pool = 1;
        assert!(cfg.validate().is_err(), "a single-direction pool cannot learn");
        cfg.seed_pool = 64;
        cfg.algorithm = "zo-fedsgd".into();
        assert!(cfg.validate().is_err(), "projection uplinks are not index-coded");
        cfg.algorithm = "dp-feedsign:2.0".into();
        cfg.validate().unwrap();
    }

    #[test]
    fn pool_catchup_requires_seed_pool() {
        let mut cfg = quickstart();
        cfg.participation = "fraction:0.4".into();
        cfg.catchup = "pool".into();
        assert!(cfg.validate().is_err(), "no pool to download scalars for");
        cfg.seed_pool = 16;
        cfg.validate().unwrap();
        assert_eq!(cfg.catchup_cfg(), CatchupCfg::PoolScalars);
        let back = ExperimentConfig::from_toml(&cfg.to_toml()).unwrap();
        assert_eq!(back.catchup, "pool");
        cfg.rounds = 4;
        let mut s = cfg.build_session().unwrap();
        for t in 0..4 {
            s.step(t);
        }
        s.catch_up_all();
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn dp_algorithm_parses_through_config() {
        let mut cfg = quickstart();
        cfg.algorithm = "dp-feedsign:2.0".into();
        cfg.validate().unwrap();
        assert_eq!(cfg.algorithm(), Algorithm::DpFeedSign { epsilon: 2.0 });
    }
}
