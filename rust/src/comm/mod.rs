//! Communication substrate: typed PS<->client messages with *exact* bit
//! accounting, plus an analytic bandwidth/latency model for projecting
//! wall-clock communication cost.
//!
//! The paper's headline (Table 1, Eq. 5) is a bits-per-step claim:
//!
//! | method     | uplink/step/client | downlink/step/client |
//! |------------|--------------------|----------------------|
//! | FedSGD     | 32·d               | 32·d                 |
//! | ZO-FedSGD  | 64 (seed+proj)     | 64·K                 |
//! | FeedSign   | **1**              | **1**                |
//!
//! Every message the coordinator sends is constructed here and carries its
//! own payload size; [`Ledger`] accumulates the totals that the Table 1
//! bench and the per-run metrics report.  The in-process transport is a
//! `std::sync::mpsc` pair per client ([`link`]) — the same topology a
//! real deployment would have, with the physical link swapped for a
//! process-local channel.  The link itself is lossless; impairment
//! (bit flips, drops, latency, deadlines) is the job of the
//! [`crate::net`] simulator, which sits between the coordinator and
//! these channels and corrupts messages *semantically*.
//!
//! ## Seed history (offline-client catch-up)
//!
//! Partial participation breaks the broadcast-to-everyone assumption: a
//! client skipped for rounds `t..t+k` can no longer apply round `t+k`'s
//! update, because FeedSign replicas are synchronized *by construction*,
//! one seed-sign pair at a time.  [`SeedHistory`] is the FedKSeed-style
//! fix: the PS appends every committed [`SeedRecord`] in round order, and
//! a returning client downloads just the missed span and replays it
//! locally (see `coordinator::catchup`).  Replay order equals commit
//! order — f32 accumulation is order-sensitive, so this is what keeps a
//! rejoining replica bit-identical to an always-on one.  The history is a
//! bounded ring: a compaction watermark (the slowest tracked client's
//! synced round) gates what the ring may drop, so a record is never
//! discarded while some tracked client still needs it.

/// A protocol message.  Payload bits follow the paper's accounting
/// (Eq. 5): float projections are 32 bits, seeds 32 bits, signs 1 bit.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Client -> PS: FeedSign's 1-bit vote.
    SignVote { sign: i8 },
    /// Client -> PS: ZO-FedSGD's seed-projection pair.
    Projection { seed: u32, p: f32 },
    /// Client -> PS: FedSGD's dense gradient.
    Gradient { g: Vec<f32> },
    /// PS -> client: FeedSign's 1-bit global direction.
    GlobalSign { sign: i8 },
    /// PS -> client: ZO-FedSGD's aggregated seed-projection pairs (one per
    /// participating client).
    GlobalProjections { pairs: Vec<(u32, f32)> },
    /// PS -> client: FedSGD's averaged dense gradient.
    GlobalGradient { g: Vec<f32> },
    /// PS -> client: round kick-off (seed is derivable from the round
    /// index in FeedSign — `seed = t` — so this carries zero payload bits;
    /// it models the same round-trigger a deployment piggybacks on the
    /// previous downlink).
    RoundStart { round: u64 },
    /// PS -> client: the committed-update span a rejoining client missed
    /// (`catchup = "replay"`).  Each record prices itself: 1 bit when the
    /// seed is derivable from the round (FeedSign / DP-FeedSign), 64 bits
    /// for an explicit seed-coefficient pair (ZO-FedSGD).
    ReplayHistory { records: Vec<SeedRecord> },
    /// PS -> client: dense-checkpoint rebroadcast for a rejoining client
    /// (`catchup = "rebroadcast"` — the cost baseline replay is compared
    /// against; 32·d bits).
    Rebroadcast { n_params: usize },
}

impl Message {
    /// Paper-accounting payload size in bits.
    pub fn payload_bits(&self) -> u64 {
        match self {
            Message::SignVote { .. } | Message::GlobalSign { .. } => 1,
            Message::Projection { .. } => 64,
            Message::Gradient { g } | Message::GlobalGradient { g } => 32 * g.len() as u64,
            Message::GlobalProjections { pairs } => 64 * pairs.len() as u64,
            Message::RoundStart { .. } => 0,
            Message::ReplayHistory { records } => {
                records.iter().map(SeedRecord::payload_bits).sum()
            }
            Message::Rebroadcast { n_params } => 32 * *n_params as u64,
        }
    }

    pub fn is_uplink(&self) -> bool {
        matches!(
            self,
            Message::SignVote { .. } | Message::Projection { .. } | Message::Gradient { .. }
        )
    }
}

/// One committed global update, as the PS remembers it for offline-client
/// catch-up: replaying the record applies `w -= sign · lr_scale · z(seed)`
/// — exactly the update every participant applied when round `round`
/// committed.  FeedSign/DP-FeedSign rounds commit one record with
/// `seed = round` and `lr_scale = eta`; a ZO-FedSGD round commits one
/// record per participant pair with the mean-projection coefficient
/// folded into `(sign, lr_scale)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeedRecord {
    /// Round index this update committed at (replay order = round order).
    pub round: u64,
    /// Philox direction seed of the update.
    pub seed: u32,
    /// Global direction sign (0 marks a zero-participant no-op round).
    pub sign: i8,
    /// Non-negative step magnitude; the applied step is `sign · lr_scale`.
    pub lr_scale: f32,
    /// Whether the protocol derives `seed` from `round` (the FeedSign /
    /// DP-FeedSign schedule `seed = t`, §I.1), set at commit time by the
    /// engine that knows the protocol — pricing must not be inferred from
    /// a `seed == round` coincidence, which a randomly sampled ZO seed
    /// can produce.
    pub seed_from_round: bool,
}

impl SeedRecord {
    /// A FeedSign/DP-FeedSign round commit: `seed = round`, derivable.
    pub fn sign_step(round: u64, sign: i8, lr_scale: f32) -> SeedRecord {
        SeedRecord { round, seed: round as u32, sign, lr_scale, seed_from_round: true }
    }

    /// A ZO-FedSGD pair commit: explicit seed, coefficient folded into
    /// `(sign, lr_scale)` so replay applies `sign · lr_scale` bit-exactly.
    pub fn pair_step(round: u64, seed: u32, coeff: f32) -> SeedRecord {
        SeedRecord {
            round,
            seed,
            sign: if coeff < 0.0 { -1 } else { 1 },
            lr_scale: coeff.abs(),
            seed_from_round: false,
        }
    }

    /// Step coefficient for `zo::apply_update` / `Engine::update`.  Built
    /// as `sign · |coefficient|`, it reproduces the committed coefficient
    /// bit-exactly (a `±0.0` coefficient is a no-op either way).
    pub fn step(&self) -> f32 {
        self.sign as f32 * self.lr_scale
    }

    /// Paper-accounting bits to ship this record to a rejoining client:
    /// 1 bit when the seed is derivable from the round index (only the
    /// sign travels), else 32-bit seed + 32-bit coefficient (the
    /// ZO-FedSGD pair format).
    pub fn payload_bits(&self) -> u64 {
        if self.seed_from_round {
            1
        } else {
            64
        }
    }
}

/// Default soft bound on retained history records (a FeedSign record is
/// 16 bytes, so the default ring is well under a memory page per client
/// pool even before compaction).
pub const DEFAULT_HISTORY_CAPACITY: usize = 4096;

/// Append-only per-round history of committed updates, stored as a
/// bounded ring with checkpoint-watermark compaction.
///
/// Invariants:
/// * rounds commit **in order** ([`SeedHistory::commit_round`] asserts
///   `round == head_round`), mirroring the session's deterministic commit
///   phase — replay order must equal commit order for bit-exactness;
/// * a round may commit zero records (a zero-participant no-op round);
///   round indices stay dense either way;
/// * compaction ([`SeedHistory::compact_to`]) only drops *whole rounds*
///   strictly below the caller's watermark, and only while the ring is
///   over capacity — a record still needed by the slowest tracked client
///   (watermark = min synced round) is never dropped, even if that holds
///   the ring above its soft capacity.
#[derive(Debug, Clone)]
pub struct SeedHistory {
    records: std::collections::VecDeque<SeedRecord>,
    /// Oldest round still fully retained (rounds below are compacted).
    tail_round: u64,
    /// Next round to commit (== number of rounds committed so far).
    head_round: u64,
    /// Soft record-count bound; see [`SeedHistory::compact_to`].
    capacity: usize,
}

impl Default for SeedHistory {
    fn default() -> Self {
        SeedHistory::new(DEFAULT_HISTORY_CAPACITY)
    }
}

impl SeedHistory {
    pub fn new(capacity: usize) -> Self {
        SeedHistory {
            records: std::collections::VecDeque::new(),
            tail_round: 0,
            head_round: 0,
            capacity,
        }
    }

    /// Adjust the soft capacity (tests pin tiny rings to exercise the
    /// watermark guarantee).
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
    }

    /// Next round to be committed.
    pub fn head_round(&self) -> u64 {
        self.head_round
    }

    /// Oldest round a replay span may start at.
    pub fn tail_round(&self) -> u64 {
        self.tail_round
    }

    /// Retained record count (≥ the soft capacity only while pinned by a
    /// slow client's watermark).
    pub fn records_len(&self) -> usize {
        self.records.len()
    }

    /// Commit round `round`'s records (possibly none).  Must be called in
    /// round order; every record must carry the committing round.
    pub fn commit_round<I: IntoIterator<Item = SeedRecord>>(&mut self, round: u64, records: I) {
        assert_eq!(
            round, self.head_round,
            "seed history must be committed in round order (commit order = replay order)"
        );
        for r in records {
            assert_eq!(r.round, round, "record round must match the committing round");
            self.records.push_back(r);
        }
        self.head_round = round + 1;
    }

    /// The records a client synced through round `from` (exclusive of
    /// `to`) must replay, in commit order.  `None` when the span reaches
    /// below the compaction tail (the caller must fall back to a dense
    /// rebroadcast) or beyond the committed head.
    pub fn replay_span(&self, from: u64, to: u64) -> Option<Vec<SeedRecord>> {
        if from < self.tail_round || to > self.head_round || from > to {
            return None;
        }
        // records are stored in ascending round order, so the span is a
        // contiguous range locatable by binary search (rejoins after long
        // gaps must not pay a full-ring scan)
        let lo = self.records.partition_point(|r| r.round < from);
        let hi = self.records.partition_point(|r| r.round < to);
        Some(self.records.range(lo..hi).copied().collect())
    }

    /// Ring compaction: drop whole rounds from the tail while the ring is
    /// over its soft capacity **and** the tail round is strictly below
    /// `watermark` (the slowest tracked client's synced round).  Records
    /// at or above the watermark are never dropped, whatever the
    /// capacity — the guarantee `rust/tests/catchup_parity.rs` pins.
    pub fn compact_to(&mut self, watermark: u64) {
        let wm = watermark.min(self.head_round);
        while self.records.len() > self.capacity && self.tail_round < wm {
            let r = self.tail_round;
            while matches!(self.records.front(), Some(rec) if rec.round == r) {
                self.records.pop_front();
            }
            self.tail_round += 1;
        }
    }
}

/// Cumulative communication ledger for one run.
#[derive(Debug, Default, Clone)]
pub struct Ledger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    pub downlink_msgs: u64,
}

impl Ledger {
    pub fn record(&mut self, msg: &Message) {
        // zero-payload round triggers (RoundStart) piggyback on the
        // previous downlink in a deployment, so they cost neither bits nor
        // a message slot.
        if msg.payload_bits() == 0 {
            return;
        }
        if msg.is_uplink() {
            self.uplink_bits += msg.payload_bits();
            self.uplink_msgs += 1;
        } else {
            self.downlink_bits += msg.payload_bits();
            self.downlink_msgs += 1;
        }
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.uplink_bits += other.uplink_bits;
        self.downlink_bits += other.downlink_bits;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
    }

    /// Record a whole message sequence (sub-ledger building block for
    /// parallel workers).
    pub fn record_all<'a, I: IntoIterator<Item = &'a Message>>(&mut self, msgs: I) {
        for m in msgs {
            self.record(m);
        }
    }

    /// Commit per-worker sub-ledgers into this (authoritative) ledger in
    /// the order given.  The parallel round engine meters each client's
    /// messages into a private sub-ledger during the fan-out and commits
    /// them here in client-id order — totals are additive, so the result
    /// is bit-identical to sequential metering (pinned by the
    /// `prop_ledger_additive_over_message_sequences` property and the
    /// cross-topology parity tests).
    pub fn commit<I: IntoIterator<Item = Ledger>>(&mut self, subs: I) {
        for sub in subs {
            self.merge(&sub);
        }
    }
}

/// Analytic link model: projects ledger totals to wall-clock seconds for a
/// given uplink/downlink bandwidth and per-message latency — how the
/// "48 MB ≈ 4 minutes of FHD video per round" style comparisons in §1 are
/// regenerated without a real testbed.
///
/// This is the *closed-form* projection over one global link; its
/// executable counterpart is the [`crate::net`] simulator, which
/// generalizes to heterogeneous per-client [`crate::net::LinkProfile`]s
/// with jitter, impairs messages in flight, and drives a virtual event
/// clock with round deadlines.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// uplink bandwidth, bits/s
    pub up_bps: f64,
    /// downlink bandwidth, bits/s
    pub down_bps: f64,
    /// per-message fixed latency, seconds
    pub rtt_s: f64,
}

impl LinkModel {
    /// A conservative mobile uplink: 20 Mbps up / 100 Mbps down / 30 ms RTT.
    pub fn mobile() -> Self {
        LinkModel { up_bps: 20e6, down_bps: 100e6, rtt_s: 0.03 }
    }

    /// Projected communication seconds for a ledger.
    pub fn seconds(&self, ledger: &Ledger) -> f64 {
        ledger.uplink_bits as f64 / self.up_bps
            + ledger.downlink_bits as f64 / self.down_bps
            + (ledger.uplink_msgs + ledger.downlink_msgs) as f64 * self.rtt_s
    }
}

/// In-process duplex transport between the PS and one client, with both
/// directions metered.  Channels are unbounded: the round protocol is
/// strictly request/response so queue depth is <= 1.
pub struct Duplex {
    pub to_client: std::sync::mpsc::Sender<Message>,
    pub from_client: std::sync::mpsc::Receiver<Message>,
}

/// The client's end of a [`Duplex`].
pub struct ClientPort {
    pub from_ps: std::sync::mpsc::Receiver<Message>,
    pub to_ps: std::sync::mpsc::Sender<Message>,
}

/// Create a metered PS<->client link pair.
pub fn link() -> (Duplex, ClientPort) {
    let (tx_down, rx_down) = std::sync::mpsc::channel();
    let (tx_up, rx_up) = std::sync::mpsc::channel();
    (
        Duplex { to_client: tx_down, from_client: rx_up },
        ClientPort { from_ps: rx_down, to_ps: tx_up },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feedsign_messages_are_one_bit() {
        assert_eq!(Message::SignVote { sign: 1 }.payload_bits(), 1);
        assert_eq!(Message::GlobalSign { sign: -1 }.payload_bits(), 1);
    }

    #[test]
    fn zo_fedsgd_pair_is_64_bits() {
        assert_eq!(Message::Projection { seed: 7, p: 0.5 }.payload_bits(), 64);
        let m = Message::GlobalProjections { pairs: vec![(1, 0.1), (2, 0.2)] };
        assert_eq!(m.payload_bits(), 128);
    }

    #[test]
    fn gradient_scales_with_d() {
        let m = Message::Gradient { g: vec![0.0; 1000] };
        assert_eq!(m.payload_bits(), 32_000);
    }

    #[test]
    fn round_start_free() {
        assert_eq!(Message::RoundStart { round: 3 }.payload_bits(), 0);
        let mut l = Ledger::default();
        l.record(&Message::RoundStart { round: 3 });
        assert_eq!(l.downlink_msgs, 0, "piggybacked trigger costs no message");
    }

    #[test]
    fn ledger_directional_accounting() {
        let mut l = Ledger::default();
        l.record(&Message::SignVote { sign: 1 });
        l.record(&Message::GlobalSign { sign: 1 });
        l.record(&Message::Projection { seed: 0, p: 1.0 });
        assert_eq!(l.uplink_bits, 65);
        assert_eq!(l.downlink_bits, 1);
        assert_eq!(l.uplink_msgs, 2);
        assert_eq!(l.total_bits(), 66);
    }

    #[test]
    fn ledger_commit_matches_sequential_recording() {
        let msgs = [
            Message::SignVote { sign: 1 },
            Message::SignVote { sign: -1 },
            Message::Projection { seed: 3, p: 0.1 },
            Message::GlobalSign { sign: 1 },
        ];
        let mut sequential = Ledger::default();
        sequential.record_all(&msgs);
        // same messages split over two worker sub-ledgers, then committed
        let mut sub_a = Ledger::default();
        sub_a.record_all(&msgs[..2]);
        let mut sub_b = Ledger::default();
        sub_b.record_all(&msgs[2..]);
        let mut committed = Ledger::default();
        committed.commit([sub_a, sub_b]);
        assert_eq!(committed.uplink_bits, sequential.uplink_bits);
        assert_eq!(committed.downlink_bits, sequential.downlink_bits);
        assert_eq!(committed.uplink_msgs, sequential.uplink_msgs);
        assert_eq!(committed.downlink_msgs, sequential.downlink_msgs);
    }

    #[test]
    fn ledger_merge_adds() {
        let mut a = Ledger { uplink_bits: 10, downlink_bits: 5, uplink_msgs: 2, downlink_msgs: 1 };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.uplink_bits, 20);
        assert_eq!(a.downlink_msgs, 2);
    }

    #[test]
    fn link_model_projects_seconds() {
        let lm = LinkModel { up_bps: 1e6, down_bps: 2e6, rtt_s: 0.01 };
        let l = Ledger { uplink_bits: 1_000_000, downlink_bits: 2_000_000, uplink_msgs: 1, downlink_msgs: 1 };
        let s = lm.seconds(&l);
        assert!((s - (1.0 + 1.0 + 0.02)).abs() < 1e-9);
    }

    fn fs_record(round: u64) -> SeedRecord {
        SeedRecord::sign_step(round, if round % 2 == 0 { 1 } else { -1 }, 1e-3)
    }

    #[test]
    fn seed_record_pricing_follows_seed_derivability() {
        // FeedSign schedule: seed derivable from the round -> only the
        // sign travels
        assert_eq!(fs_record(7).payload_bits(), 1);
        // ZO pair: explicit seed + coefficient
        let zo = SeedRecord::pair_step(3, 0x5EED, -0.25);
        assert_eq!(zo.payload_bits(), 64);
        assert_eq!(zo.step(), -0.25);
        // pricing is set by the protocol, NOT by a seed == round
        // coincidence: a random ZO seed that collides with the round
        // index still ships the full 64-bit pair
        let collision = SeedRecord::pair_step(3, 3, 0.5);
        assert_eq!(collision.payload_bits(), 64);
        let m = Message::ReplayHistory { records: vec![fs_record(0), fs_record(1), zo] };
        assert_eq!(m.payload_bits(), 1 + 1 + 64);
        assert!(!m.is_uplink());
    }

    #[test]
    fn rebroadcast_costs_dense_checkpoint() {
        assert_eq!(Message::Rebroadcast { n_params: 1000 }.payload_bits(), 32_000);
    }

    #[test]
    fn history_commits_in_round_order_and_replays_spans() {
        let mut h = SeedHistory::default();
        h.commit_round(0, [fs_record(0)]);
        h.commit_round(1, []); // zero-participant no-op round
        h.commit_round(2, [fs_record(2)]);
        assert_eq!(h.head_round(), 3);
        let span = h.replay_span(0, 3).unwrap();
        assert_eq!(span, vec![fs_record(0), fs_record(2)]);
        assert_eq!(h.replay_span(1, 3).unwrap(), vec![fs_record(2)]);
        assert_eq!(h.replay_span(2, 2).unwrap(), vec![]);
        assert!(h.replay_span(0, 4).is_none(), "beyond the committed head");
    }

    #[test]
    #[should_panic(expected = "round order")]
    fn history_rejects_out_of_order_commits() {
        let mut h = SeedHistory::default();
        h.commit_round(1, [fs_record(1)]);
    }

    #[test]
    fn compaction_respects_capacity_and_watermark() {
        let mut h = SeedHistory::new(4);
        for t in 0..10 {
            h.commit_round(t, [fs_record(t)]);
        }
        // watermark 3: only rounds 0..3 may go, and only down to capacity
        h.compact_to(3);
        assert_eq!(h.tail_round(), 3);
        assert_eq!(h.records_len(), 7, "records >= watermark are pinned");
        assert!(h.replay_span(0, 10).is_none(), "compacted span must refuse");
        assert_eq!(h.replay_span(3, 10).unwrap().len(), 7);
        // watermark 10: free to trim to the soft capacity
        h.compact_to(10);
        assert_eq!(h.records_len(), 4);
        assert_eq!(h.tail_round(), 6);
        assert_eq!(h.replay_span(6, 10).unwrap().len(), 4);
    }

    #[test]
    fn compaction_watermark_exactly_at_ring_capacity() {
        // 8 rounds in a capacity-4 ring, watermark exactly at the round
        // that brings the ring down to capacity: both gates release at
        // the same instant, and neither may overshoot
        let mut h = SeedHistory::new(4);
        for t in 0..8 {
            h.commit_round(t, [fs_record(t)]);
        }
        h.compact_to(4);
        assert_eq!(h.tail_round(), 4);
        assert_eq!(h.records_len(), 4, "trimmed to capacity, not past the watermark");
        assert_eq!(h.replay_span(4, 8).unwrap().len(), 4);
        // raising the watermark to the head changes nothing: the ring is
        // no longer over capacity, so the capacity gate holds the rest
        h.compact_to(8);
        assert_eq!(h.tail_round(), 4);
        assert_eq!(h.records_len(), 4);
    }

    #[test]
    fn untracked_client_joining_after_compaction_is_refused_the_span() {
        // a client the tracker never knew about (it joined the pool
        // after compaction already ran) asks for a span starting below
        // the tail: replay must refuse — `None` is the caller's signal
        // to fall back to a dense rebroadcast, never to replay a
        // silently truncated span
        let mut h = SeedHistory::new(2);
        for t in 0..10 {
            h.commit_round(t, [fs_record(t)]);
        }
        h.compact_to(6);
        assert_eq!(h.tail_round(), 6);
        assert!(h.replay_span(0, 10).is_none(), "fresh-join span reaches below the tail");
        assert!(h.replay_span(5, 10).is_none(), "partially compacted span refuses too");
        assert_eq!(h.replay_span(6, 10).unwrap().len(), 4, "tracked clients unaffected");
    }

    #[test]
    fn zero_capacity_ring_retains_only_watermark_pinned_records() {
        // capacity 0: every record is over-capacity the moment it
        // commits, so retention is governed by the watermark alone
        let mut h = SeedHistory::new(0);
        for t in 0..5 {
            h.commit_round(t, [fs_record(t)]);
            h.compact_to(3); // slowest client stuck at round 3
        }
        assert_eq!(h.tail_round(), 3);
        assert_eq!(h.records_len(), 2, "rounds 3..5 pinned by the watermark");
        assert_eq!(h.replay_span(3, 5).unwrap().len(), 2);
        assert!(h.replay_span(2, 5).is_none());
        // watermark at the head: a zero-capacity ring may drop everything
        h.compact_to(5);
        assert_eq!(h.records_len(), 0);
        assert_eq!(h.tail_round(), 5);
        // ...and still accepts the next in-order commit afterwards
        h.commit_round(5, [fs_record(5)]);
        assert_eq!(h.replay_span(5, 6).unwrap(), vec![fs_record(5)]);
    }

    #[test]
    fn compaction_never_drops_pinned_records_even_over_capacity() {
        let mut h = SeedHistory::new(2);
        for t in 0..50 {
            h.commit_round(t, [fs_record(t)]);
            h.compact_to(5); // slowest client stuck at round 5
        }
        assert!(h.records_len() >= 45, "rounds 5..50 must all be retained");
        assert_eq!(h.replay_span(5, 50).unwrap().len(), 45);
    }

    #[test]
    fn duplex_roundtrip() {
        let (ps, client) = link();
        ps.to_client.send(Message::RoundStart { round: 1 }).unwrap();
        let got = client.from_ps.recv().unwrap();
        assert_eq!(got, Message::RoundStart { round: 1 });
        client.to_ps.send(Message::SignVote { sign: -1 }).unwrap();
        let got = ps.from_client.recv().unwrap();
        assert_eq!(got, Message::SignVote { sign: -1 });
    }
}
