//! Communication substrate: typed PS<->client messages with *exact* bit
//! accounting, plus an analytic bandwidth/latency model for projecting
//! wall-clock communication cost.
//!
//! The paper's headline (Table 1, Eq. 5) is a bits-per-step claim:
//!
//! | method     | uplink/step/client | downlink/step/client |
//! |------------|--------------------|----------------------|
//! | FedSGD     | 32·d               | 32·d                 |
//! | ZO-FedSGD  | 64 (seed+proj)     | 64·K                 |
//! | FeedSign   | **1**              | **1**                |
//!
//! Every message the coordinator sends is constructed here and carries its
//! own payload size; [`Ledger`] accumulates the totals that the Table 1
//! bench and the per-run metrics report.  The in-process transport is a
//! `std::sync::mpsc` pair per client ([`link`]) — the same topology a
//! real deployment would have, with the physical link swapped for a
//! process-local channel.  The link itself is lossless; impairment
//! (bit flips, drops, latency, deadlines) is the job of the
//! [`crate::net`] simulator, which sits between the coordinator and
//! these channels and corrupts messages *semantically*.
//!
//! ## Seed history (offline-client catch-up)
//!
//! Partial participation breaks the broadcast-to-everyone assumption: a
//! client skipped for rounds `t..t+k` can no longer apply round `t+k`'s
//! update, because FeedSign replicas are synchronized *by construction*,
//! one seed-sign pair at a time.  [`SeedHistory`] is the FedKSeed-style
//! fix: the PS appends every committed [`SeedRecord`] in round order, and
//! a returning client downloads just the missed span and replays it
//! locally (see `coordinator::catchup`).  Replay order equals commit
//! order — f32 accumulation is order-sensitive, so this is what keeps a
//! rejoining replica bit-identical to an always-on one.  The history is a
//! bounded ring: a compaction watermark (the slowest tracked client's
//! synced round) gates what the ring may drop, so a record is never
//! discarded while some tracked client still needs it.

use crate::simkit::prng;

/// A protocol message.  Payload bits follow the paper's accounting
/// (Eq. 5): float projections are 32 bits, seeds 32 bits, signs 1 bit.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Client -> PS: FeedSign's 1-bit vote.
    SignVote { sign: i8 },
    /// Client -> PS: ZO-FedSGD's seed-projection pair.
    Projection { seed: u32, p: f32 },
    /// Client -> PS: FedSGD's dense gradient.
    Gradient { g: Vec<f32> },
    /// PS -> client: FeedSign's 1-bit global direction.
    GlobalSign { sign: i8 },
    /// PS -> client: ZO-FedSGD's aggregated seed-projection pairs (one per
    /// participating client).
    GlobalProjections { pairs: Vec<(u32, f32)> },
    /// PS -> client: FedSGD's averaged dense gradient.
    GlobalGradient { g: Vec<f32> },
    /// PS -> client: round kick-off (seed is derivable from the round
    /// index in FeedSign — `seed = t` — so this carries zero payload bits;
    /// it models the same round-trigger a deployment piggybacks on the
    /// previous downlink).
    RoundStart { round: u64 },
    /// PS -> client: the committed-update span a rejoining client missed
    /// (`catchup = "replay"`).  Each record prices itself: 1 bit when the
    /// seed is derivable from the round (FeedSign / DP-FeedSign), 64 bits
    /// for an explicit seed-coefficient pair (ZO-FedSGD).
    ReplayHistory { records: Vec<SeedRecord> },
    /// PS -> client: dense-checkpoint rebroadcast for a rejoining client
    /// (`catchup = "rebroadcast"` — the cost baseline replay is compared
    /// against; 32·d bits).
    Rebroadcast { n_params: usize },
    /// PS -> client: the round's sampled index into the restricted seed
    /// pool (`seed_pool` mode, FedKSeed).  The direction is no longer
    /// derivable from the round alone, so the trigger carries
    /// `ceil(log2 K)` payload bits — the per-round downlink becomes
    /// `ceil(log2 K) + 1` once the 1-bit [`Message::GlobalSign`] lands.
    PoolIndex { round: u64, index: u32, index_bits: u16 },
    /// PS -> client: the K accumulated per-pool-seed step scalars — the
    /// FedKSeed model-delta download, a rejoin cost *constant in the gap
    /// length* (`catchup = "pool"`; 32·K bits) because the whole model
    /// delta is `sum_i scalars[i] · z(pool_seed_i)`.
    PoolScalars { k: usize },
    /// Shard -> global merger (sharded coordinator, `--shards N`): one
    /// shard's pre-reduced vote contribution for a round.  Sign votes are
    /// associative integer sums, so a shard ships only `(sum, voters)` —
    /// the merger folds the sums and reconstructs the exact tally
    /// (`q_+ = (sum + voters) / 2`); only the final majority/DP threshold
    /// is global.  Priced at the pair's information content:
    /// `sum ∈ [-voters, +voters]` costs `ceil(log2(2·voters + 1))` bits
    /// and `voters ∈ [0, shard_size]` costs `ceil(log2(shard_size + 1))`.
    /// ZO-FedSGD shards set `dense_pairs` and forward their voters'
    /// (seed, projection) pairs at 64 bits each — mean aggregation needs
    /// the pairs themselves.  These messages travel coordinator-internally
    /// (shard -> merger), so they are metered in the shard merge ledger
    /// (`coordinator::shard::ShardStats`), never in the client-facing
    /// per-run [`Ledger`].
    ShardVotes { sum: i32, voters: usize, shard_size: usize, dense_pairs: bool },
}

impl Message {
    /// Paper-accounting payload size in bits.
    pub fn payload_bits(&self) -> u64 {
        match self {
            Message::SignVote { .. } | Message::GlobalSign { .. } => 1,
            Message::Projection { .. } => 64,
            Message::Gradient { g } | Message::GlobalGradient { g } => 32 * g.len() as u64,
            Message::GlobalProjections { pairs } => 64 * pairs.len() as u64,
            Message::RoundStart { .. } => 0,
            Message::ReplayHistory { records } => {
                records.iter().map(SeedRecord::payload_bits).sum()
            }
            Message::Rebroadcast { n_params } => 32 * *n_params as u64,
            Message::PoolIndex { index_bits, .. } => *index_bits as u64,
            Message::PoolScalars { k } => 32 * *k as u64,
            Message::ShardVotes { voters, shard_size, dense_pairs, .. } => {
                if *dense_pairs {
                    64 * *voters as u64
                } else {
                    index_bits_for(2 * *voters + 1) as u64 + index_bits_for(*shard_size + 1) as u64
                }
            }
        }
    }

    pub fn is_uplink(&self) -> bool {
        matches!(
            self,
            Message::SignVote { .. }
                | Message::Projection { .. }
                | Message::Gradient { .. }
                | Message::ShardVotes { .. }
        )
    }
}

/// One committed global update, as the PS remembers it for offline-client
/// catch-up: replaying the record applies `w -= sign · lr_scale · z(seed)`
/// — exactly the update every participant applied when round `round`
/// committed.  FeedSign/DP-FeedSign rounds commit one record with
/// `seed = round` and `lr_scale = eta`; a ZO-FedSGD round commits one
/// record per participant pair with the mean-projection coefficient
/// folded into `(sign, lr_scale)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeedRecord {
    /// Round index this update committed at (replay order = round order).
    pub round: u64,
    /// Philox direction seed of the update.
    pub seed: u32,
    /// Global direction sign (0 marks a zero-participant no-op round).
    pub sign: i8,
    /// Non-negative step magnitude; the applied step is `sign · lr_scale`.
    pub lr_scale: f32,
    /// Whether the protocol derives `seed` from `round` (the FeedSign /
    /// DP-FeedSign schedule `seed = t`, §I.1), set at commit time by the
    /// engine that knows the protocol — pricing must not be inferred from
    /// a `seed == round` coincidence, which a randomly sampled ZO seed
    /// can produce.
    pub seed_from_round: bool,
    /// `Some((index, index_bits))` when the update's direction was drawn
    /// from a restricted [`SeedPool`] (`seed_pool` mode): `seed` still
    /// carries the *resolved* pool seed (so every replay path applies the
    /// record without pool context), but on the wire only the
    /// `index_bits = ceil(log2 K)`-bit index travels alongside the sign.
    pub pool_index: Option<(u32, u16)>,
}

impl SeedRecord {
    /// A FeedSign/DP-FeedSign round commit: `seed = round` (masked into
    /// the 31-bit direction space — see
    /// [`crate::simkit::prng::round_direction_seed`]), derivable.
    pub fn sign_step(round: u64, sign: i8, lr_scale: f32) -> SeedRecord {
        SeedRecord {
            round,
            seed: prng::round_direction_seed(round),
            sign,
            lr_scale,
            seed_from_round: true,
            pool_index: None,
        }
    }

    /// A ZO-FedSGD pair commit: explicit seed, coefficient folded into
    /// `(sign, lr_scale)` so replay applies `sign · lr_scale` bit-exactly.
    pub fn pair_step(round: u64, seed: u32, coeff: f32) -> SeedRecord {
        SeedRecord {
            round,
            seed,
            sign: if coeff < 0.0 { -1 } else { 1 },
            lr_scale: coeff.abs(),
            seed_from_round: false,
            pool_index: None,
        }
    }

    /// A restricted-seed-pool round commit (`seed_pool` mode): `seed` is
    /// the resolved pool seed at `index`, and the record prices at
    /// `index_bits + 1` bits (index + sign) instead of the 64-bit
    /// explicit pair.
    pub fn index_step(
        round: u64,
        seed: u32,
        index: u32,
        index_bits: u16,
        sign: i8,
        lr_scale: f32,
    ) -> SeedRecord {
        SeedRecord {
            round,
            seed,
            sign,
            lr_scale,
            seed_from_round: false,
            pool_index: Some((index, index_bits)),
        }
    }

    /// Step coefficient for `zo::apply_update` / `Engine::update`.  Built
    /// as `sign · |coefficient|`, it reproduces the committed coefficient
    /// bit-exactly (a `±0.0` coefficient is a no-op either way).
    pub fn step(&self) -> f32 {
        self.sign as f32 * self.lr_scale
    }

    /// Paper-accounting bits to ship this record to a rejoining client:
    /// 1 bit when the seed is derivable from the round index (only the
    /// sign travels), `ceil(log2 K) + 1` for a restricted-pool index
    /// record (FedKSeed), else 32-bit seed + 32-bit coefficient (the
    /// ZO-FedSGD pair format).
    pub fn payload_bits(&self) -> u64 {
        if let Some((_, bits)) = self.pool_index {
            bits as u64 + 1
        } else if self.seed_from_round {
            1
        } else {
            64
        }
    }
}

/// Bits needed to index a pool of `k` candidates: `ceil(log2 k)`, with a
/// 1-bit floor so a degenerate 1-entry pool still prices a real index.
pub fn index_bits_for(k: usize) -> u16 {
    debug_assert!(k >= 1);
    let bits = usize::BITS - k.saturating_sub(1).leading_zeros();
    bits.max(1) as u16
}

/// The restricted seed space of FedKSeed (arXiv 2312.06353): K candidate
/// Philox direction seeds derived **once** from a pool seed, after which
/// every per-round perturbation is named by a `ceil(log2 K)`-bit *index*
/// instead of a 31-bit seed.  Both topologies (and every rejoining
/// client) derive the identical pool from the run seed, so the pool
/// itself never travels.
///
/// Candidate seeds come from the same Philox-4x32 substrate as the
/// directions themselves (4 candidates per block, counter-indexed) and
/// are masked into the 31-bit [`prng::DIRECTION_MASK`] domain the
/// channel impairment model reserves — the same domain bugfix the
/// round-derived schedule got.
#[derive(Debug, Clone, PartialEq)]
pub struct SeedPool {
    /// The seed the pool was derived from (keys the sampler's draws).
    pub pool_seed: u32,
    seeds: Vec<u32>,
}

/// Key salt separating pool-candidate derivation from every other Philox
/// consumer keyed off the run seed.
const POOL_DERIVE_SALT: u32 = 0x5EED_C0DE;
/// Key salt for the per-round sampler draw.
const POOL_SAMPLE_SALT: u32 = 0xA11C_E5ED;

impl SeedPool {
    /// Derive the K candidate seeds.  Pure function of `(pool_seed, k)`:
    /// every party that knows the run config regenerates the identical
    /// pool.
    pub fn derive(pool_seed: u32, k: usize) -> SeedPool {
        assert!(k >= 2, "a seed pool needs at least 2 candidate directions (got {k})");
        let mut seeds = Vec::with_capacity(k);
        let mut ctr = 0u32;
        while seeds.len() < k {
            for w in prng::philox4x32(pool_seed ^ POOL_DERIVE_SALT, ctr) {
                if seeds.len() < k {
                    seeds.push(w & prng::DIRECTION_MASK);
                }
            }
            ctr = ctr.wrapping_add(1);
        }
        SeedPool { pool_seed, seeds }
    }

    pub fn k(&self) -> usize {
        self.seeds.len()
    }

    /// `ceil(log2 K)` — the bits one ledger index costs on the wire.
    pub fn index_bits(&self) -> u16 {
        index_bits_for(self.seeds.len())
    }

    /// The candidate direction seed at `index`.
    pub fn seed_at(&self, index: u32) -> u32 {
        self.seeds[index as usize]
    }

    /// FedKSeed-Pro's probability-differentiated draw: sample round `t`'s
    /// pool index, biased toward directions with large accumulated
    /// |step-scalar| history (`scalars[i]` is the sum of committed
    /// `sign·lr_scale` steps along candidate `i`).
    ///
    /// Determinism contract: one Philox block keyed
    /// `(pool_seed ^ salt, t)` and a sequential f32 cumulative scan — no
    /// thread-count, topology, or iteration-order dependence, so both
    /// topologies sample the identical index stream (the same discipline
    /// as the participation and channel draws).  Weights are
    /// `1 + K·|h_i|/S` (half uniform mass, half proportional), so the
    /// sampler never collapses onto a single direction and reduces to
    /// uniform while the history is empty.
    pub fn sample_index(&self, scalars: &[f32], t: u64) -> u32 {
        let k = self.seeds.len();
        debug_assert!(scalars.is_empty() || scalars.len() == k);
        let block = prng::philox4x32(self.pool_seed ^ POOL_SAMPLE_SALT, t as u32);
        // fold the high round word in so rounds >= 2^32 keep fresh draws
        let draw = block[0] ^ (t >> 32) as u32;
        let total_h: f64 = scalars.iter().map(|h| h.abs() as f64).sum();
        if total_h <= 0.0 || !total_h.is_finite() {
            // uniform: modulo over a 32-bit draw (bias < K/2^32, and the
            // draw is deterministic, which is the property that matters)
            return draw % k as u32;
        }
        let u = prng::u32_to_unit(draw) as f64;
        let mut weights_total = 0.0f64;
        for h in scalars {
            weights_total += 1.0 + k as f64 * h.abs() as f64 / total_h;
        }
        let target = u * weights_total;
        let mut cum = 0.0f64;
        for (i, h) in scalars.iter().enumerate() {
            cum += 1.0 + k as f64 * h.abs() as f64 / total_h;
            if target <= cum {
                return i as u32;
            }
        }
        (k - 1) as u32
    }
}

/// Default soft bound on retained history records (a FeedSign record is
/// 16 bytes, so the default ring is well under a memory page per client
/// pool even before compaction).
pub const DEFAULT_HISTORY_CAPACITY: usize = 4096;

/// Append-only per-round history of committed updates, stored as a
/// bounded ring with checkpoint-watermark compaction.
///
/// Invariants:
/// * rounds commit **in order** ([`SeedHistory::commit_round`] asserts
///   `round == head_round`), mirroring the session's deterministic commit
///   phase — replay order must equal commit order for bit-exactness;
/// * a round may commit zero records (a zero-participant no-op round);
///   round indices stay dense either way;
/// * compaction ([`SeedHistory::compact_to`]) only drops *whole rounds*
///   strictly below the caller's watermark, and only while the ring is
///   over capacity — a record still needed by the slowest tracked client
///   (watermark = min synced round) is never dropped, even if that holds
///   the ring above its soft capacity.
#[derive(Debug, Clone)]
pub struct SeedHistory {
    records: std::collections::VecDeque<SeedRecord>,
    /// Oldest round still fully retained (rounds below are compacted).
    tail_round: u64,
    /// Next round to commit (== number of rounds committed so far).
    head_round: u64,
    /// Soft record-count bound; see [`SeedHistory::compact_to`].
    capacity: usize,
}

impl Default for SeedHistory {
    fn default() -> Self {
        SeedHistory::new(DEFAULT_HISTORY_CAPACITY)
    }
}

impl SeedHistory {
    pub fn new(capacity: usize) -> Self {
        SeedHistory {
            records: std::collections::VecDeque::new(),
            tail_round: 0,
            head_round: 0,
            capacity,
        }
    }

    /// Adjust the soft capacity (tests pin tiny rings to exercise the
    /// watermark guarantee).
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity;
    }

    /// Next round to be committed.
    pub fn head_round(&self) -> u64 {
        self.head_round
    }

    /// Oldest round a replay span may start at.
    pub fn tail_round(&self) -> u64 {
        self.tail_round
    }

    /// Retained record count (≥ the soft capacity only while pinned by a
    /// slow client's watermark).
    pub fn records_len(&self) -> usize {
        self.records.len()
    }

    /// Commit round `round`'s records (possibly none).  Must be called in
    /// round order; every record must carry the committing round.
    pub fn commit_round<I: IntoIterator<Item = SeedRecord>>(&mut self, round: u64, records: I) {
        assert_eq!(
            round, self.head_round,
            "seed history must be committed in round order (commit order = replay order)"
        );
        for r in records {
            assert_eq!(r.round, round, "record round must match the committing round");
            self.records.push_back(r);
        }
        self.head_round = round + 1;
    }

    /// The records a client synced through round `from` (exclusive of
    /// `to`) must replay, in commit order.  `None` when the span reaches
    /// below the compaction tail (the caller must fall back to a dense
    /// rebroadcast) or beyond the committed head.
    pub fn replay_span(&self, from: u64, to: u64) -> Option<Vec<SeedRecord>> {
        if from < self.tail_round || to > self.head_round || from > to {
            return None;
        }
        // records are stored in ascending round order, so the span is a
        // contiguous range locatable by binary search (rejoins after long
        // gaps must not pay a full-ring scan)
        let lo = self.records.partition_point(|r| r.round < from);
        let hi = self.records.partition_point(|r| r.round < to);
        Some(self.records.range(lo..hi).copied().collect())
    }

    /// Ring compaction: drop whole rounds from the tail while the ring is
    /// over its soft capacity **and** the tail round is strictly below
    /// `watermark` (the slowest tracked client's synced round).  Records
    /// at or above the watermark are never dropped, whatever the
    /// capacity — the guarantee `rust/tests/catchup_parity.rs` pins.
    pub fn compact_to(&mut self, watermark: u64) {
        let wm = watermark.min(self.head_round);
        while self.records.len() > self.capacity && self.tail_round < wm {
            let r = self.tail_round;
            while matches!(self.records.front(), Some(rec) if rec.round == r) {
                self.records.pop_front();
            }
            self.tail_round += 1;
        }
    }
}

/// Cumulative communication ledger for one run.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Ledger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    pub downlink_msgs: u64,
}

impl Ledger {
    pub fn record(&mut self, msg: &Message) {
        // zero-payload round triggers (RoundStart) piggyback on the
        // previous downlink in a deployment, so they cost neither bits nor
        // a message slot.
        if msg.payload_bits() == 0 {
            return;
        }
        if msg.is_uplink() {
            self.uplink_bits += msg.payload_bits();
            self.uplink_msgs += 1;
        } else {
            self.downlink_bits += msg.payload_bits();
            self.downlink_msgs += 1;
        }
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.uplink_bits += other.uplink_bits;
        self.downlink_bits += other.downlink_bits;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
    }

    /// Record a whole message sequence (sub-ledger building block for
    /// parallel workers).
    pub fn record_all<'a, I: IntoIterator<Item = &'a Message>>(&mut self, msgs: I) {
        for m in msgs {
            self.record(m);
        }
    }

    /// Commit per-worker sub-ledgers into this (authoritative) ledger in
    /// the order given.  The parallel round engine meters each client's
    /// messages into a private sub-ledger during the fan-out and commits
    /// them here in client-id order — totals are additive, so the result
    /// is bit-identical to sequential metering (pinned by the
    /// `prop_ledger_additive_over_message_sequences` property and the
    /// cross-topology parity tests).
    pub fn commit<I: IntoIterator<Item = Ledger>>(&mut self, subs: I) {
        for sub in subs {
            self.merge(&sub);
        }
    }
}

/// Analytic link model: projects ledger totals to wall-clock seconds for a
/// given uplink/downlink bandwidth and per-message latency — how the
/// "48 MB ≈ 4 minutes of FHD video per round" style comparisons in §1 are
/// regenerated without a real testbed.
///
/// This is the *closed-form* projection over one global link; its
/// executable counterpart is the [`crate::net`] simulator, which
/// generalizes to heterogeneous per-client [`crate::net::LinkProfile`]s
/// with jitter, impairs messages in flight, and drives a virtual event
/// clock with round deadlines.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// uplink bandwidth, bits/s
    pub up_bps: f64,
    /// downlink bandwidth, bits/s
    pub down_bps: f64,
    /// per-message fixed latency, seconds
    pub rtt_s: f64,
}

impl LinkModel {
    /// A conservative mobile uplink: 20 Mbps up / 100 Mbps down / 30 ms RTT.
    pub fn mobile() -> Self {
        LinkModel { up_bps: 20e6, down_bps: 100e6, rtt_s: 0.03 }
    }

    /// Projected communication seconds for a ledger.  Degenerate link
    /// profiles (zero, negative, or non-finite bandwidth) project to
    /// `+inf` for any non-empty transfer instead of the NaN the naive
    /// `0/0` division produced.
    pub fn seconds(&self, ledger: &Ledger) -> f64 {
        transfer_seconds(ledger.uplink_bits, self.up_bps)
            + transfer_seconds(ledger.downlink_bits, self.down_bps)
            + (ledger.uplink_msgs + ledger.downlink_msgs) as f64 * self.rtt_s
    }
}

/// Seconds to push `bits` through a `bps` link, guarded against
/// degenerate bandwidths: an empty transfer is free on any link, and a
/// non-positive or non-finite bandwidth means a non-empty transfer never
/// completes (`+inf`) — never NaN, which would poison every downstream
/// wall-clock sum and comparison.  Shared by [`LinkModel::seconds`] and
/// the per-client `net::LinkProfile` projections.
pub fn transfer_seconds(bits: u64, bps: f64) -> f64 {
    if bits == 0 {
        0.0
    } else if bps > 0.0 && bps.is_finite() {
        bits as f64 / bps
    } else {
        f64::INFINITY
    }
}

/// In-process duplex transport between the PS and one client, with both
/// directions metered.  Channels are unbounded: the round protocol is
/// strictly request/response so queue depth is <= 1.
pub struct Duplex {
    pub to_client: std::sync::mpsc::Sender<Message>,
    pub from_client: std::sync::mpsc::Receiver<Message>,
}

/// The client's end of a [`Duplex`].
pub struct ClientPort {
    pub from_ps: std::sync::mpsc::Receiver<Message>,
    pub to_ps: std::sync::mpsc::Sender<Message>,
}

/// Create a metered PS<->client link pair.
pub fn link() -> (Duplex, ClientPort) {
    let (tx_down, rx_down) = std::sync::mpsc::channel();
    let (tx_up, rx_up) = std::sync::mpsc::channel();
    (
        Duplex { to_client: tx_down, from_client: rx_up },
        ClientPort { from_ps: rx_down, to_ps: tx_up },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feedsign_messages_are_one_bit() {
        assert_eq!(Message::SignVote { sign: 1 }.payload_bits(), 1);
        assert_eq!(Message::GlobalSign { sign: -1 }.payload_bits(), 1);
    }

    #[test]
    fn zo_fedsgd_pair_is_64_bits() {
        assert_eq!(Message::Projection { seed: 7, p: 0.5 }.payload_bits(), 64);
        let m = Message::GlobalProjections { pairs: vec![(1, 0.1), (2, 0.2)] };
        assert_eq!(m.payload_bits(), 128);
    }

    #[test]
    fn gradient_scales_with_d() {
        let m = Message::Gradient { g: vec![0.0; 1000] };
        assert_eq!(m.payload_bits(), 32_000);
    }

    #[test]
    fn round_start_free() {
        assert_eq!(Message::RoundStart { round: 3 }.payload_bits(), 0);
        let mut l = Ledger::default();
        l.record(&Message::RoundStart { round: 3 });
        assert_eq!(l.downlink_msgs, 0, "piggybacked trigger costs no message");
    }

    #[test]
    fn ledger_directional_accounting() {
        let mut l = Ledger::default();
        l.record(&Message::SignVote { sign: 1 });
        l.record(&Message::GlobalSign { sign: 1 });
        l.record(&Message::Projection { seed: 0, p: 1.0 });
        assert_eq!(l.uplink_bits, 65);
        assert_eq!(l.downlink_bits, 1);
        assert_eq!(l.uplink_msgs, 2);
        assert_eq!(l.total_bits(), 66);
    }

    #[test]
    fn ledger_commit_matches_sequential_recording() {
        let msgs = [
            Message::SignVote { sign: 1 },
            Message::SignVote { sign: -1 },
            Message::Projection { seed: 3, p: 0.1 },
            Message::GlobalSign { sign: 1 },
        ];
        let mut sequential = Ledger::default();
        sequential.record_all(&msgs);
        // same messages split over two worker sub-ledgers, then committed
        let mut sub_a = Ledger::default();
        sub_a.record_all(&msgs[..2]);
        let mut sub_b = Ledger::default();
        sub_b.record_all(&msgs[2..]);
        let mut committed = Ledger::default();
        committed.commit([sub_a, sub_b]);
        assert_eq!(committed.uplink_bits, sequential.uplink_bits);
        assert_eq!(committed.downlink_bits, sequential.downlink_bits);
        assert_eq!(committed.uplink_msgs, sequential.uplink_msgs);
        assert_eq!(committed.downlink_msgs, sequential.downlink_msgs);
    }

    #[test]
    fn ledger_merge_adds() {
        let mut a = Ledger { uplink_bits: 10, downlink_bits: 5, uplink_msgs: 2, downlink_msgs: 1 };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.uplink_bits, 20);
        assert_eq!(a.downlink_msgs, 2);
    }

    #[test]
    fn link_model_projects_seconds() {
        let lm = LinkModel { up_bps: 1e6, down_bps: 2e6, rtt_s: 0.01 };
        let l = Ledger { uplink_bits: 1_000_000, downlink_bits: 2_000_000, uplink_msgs: 1, downlink_msgs: 1 };
        let s = lm.seconds(&l);
        assert!((s - (1.0 + 1.0 + 0.02)).abs() < 1e-9);
    }

    fn fs_record(round: u64) -> SeedRecord {
        SeedRecord::sign_step(round, if round % 2 == 0 { 1 } else { -1 }, 1e-3)
    }

    #[test]
    fn seed_record_pricing_follows_seed_derivability() {
        // FeedSign schedule: seed derivable from the round -> only the
        // sign travels
        assert_eq!(fs_record(7).payload_bits(), 1);
        // ZO pair: explicit seed + coefficient
        let zo = SeedRecord::pair_step(3, 0x5EED, -0.25);
        assert_eq!(zo.payload_bits(), 64);
        assert_eq!(zo.step(), -0.25);
        // pricing is set by the protocol, NOT by a seed == round
        // coincidence: a random ZO seed that collides with the round
        // index still ships the full 64-bit pair
        let collision = SeedRecord::pair_step(3, 3, 0.5);
        assert_eq!(collision.payload_bits(), 64);
        let m = Message::ReplayHistory { records: vec![fs_record(0), fs_record(1), zo] };
        assert_eq!(m.payload_bits(), 1 + 1 + 64);
        assert!(!m.is_uplink());
    }

    #[test]
    fn rebroadcast_costs_dense_checkpoint() {
        assert_eq!(Message::Rebroadcast { n_params: 1000 }.payload_bits(), 32_000);
    }

    #[test]
    fn degenerate_link_profiles_never_project_nan() {
        let l = Ledger { uplink_bits: 100, downlink_bits: 0, uplink_msgs: 1, downlink_msgs: 0 };
        for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
            let lm = LinkModel { up_bps: bad, down_bps: bad, rtt_s: 0.01 };
            let s = lm.seconds(&l);
            assert!(!s.is_nan(), "up_bps={bad} produced NaN");
            assert!(s.is_infinite(), "a non-empty transfer on a dead link never completes");
        }
        // the 0-bit / 0-bps corner is the one that used to be NaN (0/0):
        // an empty transfer is free even on a dead link
        let empty = Ledger::default();
        let lm = LinkModel { up_bps: 0.0, down_bps: 0.0, rtt_s: 0.0 };
        assert_eq!(lm.seconds(&empty), 0.0);
        assert_eq!(transfer_seconds(0, 0.0), 0.0);
        assert_eq!(transfer_seconds(1, 0.0), f64::INFINITY);
        assert_eq!(transfer_seconds(8, 2.0), 4.0);
    }

    #[test]
    fn round_derived_record_seed_is_masked_at_the_boundary() {
        // rounds below 2^31: the masked derivation is the identity
        assert_eq!(SeedRecord::sign_step(7, 1, 1e-3).seed, 7);
        // rounds at/past the MSB boundary: the seed stays in the 31-bit
        // direction space the channel model's corruption masking assumes
        let boundary = SeedRecord::sign_step(1 << 31, 1, 1e-3);
        assert_eq!(boundary.seed, 0);
        let past = SeedRecord::sign_step((1 << 31) + 9, -1, 1e-3);
        assert_eq!(past.seed, 9);
        assert_eq!(past.seed & !crate::simkit::prng::DIRECTION_MASK, 0);
        // pricing is unchanged: the schedule is still round-derivable
        assert_eq!(boundary.payload_bits(), 1);
    }

    #[test]
    fn index_bits_are_ceil_log2() {
        assert_eq!(index_bits_for(1), 1);
        assert_eq!(index_bits_for(2), 1);
        assert_eq!(index_bits_for(3), 2);
        assert_eq!(index_bits_for(4), 2);
        assert_eq!(index_bits_for(5), 3);
        assert_eq!(index_bits_for(1024), 10);
        assert_eq!(index_bits_for(4096), 12);
        assert_eq!(index_bits_for(4097), 13);
    }

    #[test]
    fn seed_pool_derivation_is_deterministic_and_in_domain() {
        let a = SeedPool::derive(29, 4096);
        let b = SeedPool::derive(29, 4096);
        assert_eq!(a, b, "pure function of (pool_seed, k)");
        assert_eq!(a.k(), 4096);
        assert_eq!(a.index_bits(), 12);
        for i in 0..a.k() as u32 {
            assert_eq!(
                a.seed_at(i) & !crate::simkit::prng::DIRECTION_MASK,
                0,
                "candidate {i} left the 31-bit direction space"
            );
        }
        // a different pool seed gives a different pool
        assert_ne!(SeedPool::derive(30, 4096), a);
    }

    #[test]
    fn pool_index_record_prices_at_log2k_plus_one() {
        let pool = SeedPool::derive(7, 4096);
        let r = SeedRecord::index_step(5, pool.seed_at(100), 100, pool.index_bits(), 1, 2e-3);
        assert_eq!(r.payload_bits(), 13, "ceil(log2 4096) + 1 sign bit");
        assert_eq!(r.seed, pool.seed_at(100), "replay needs no pool context");
        // the message variants price consistently
        let m = Message::PoolIndex { round: 5, index: 100, index_bits: pool.index_bits() };
        assert_eq!(m.payload_bits(), 12);
        assert!(!m.is_uplink());
        assert_eq!(Message::PoolScalars { k: 4096 }.payload_bits(), 32 * 4096);
        // the compression claim at K=4096: 64-bit explicit pair vs 13
        assert!(64 >= 4 * r.payload_bits(), ">=4x ledger-record reduction");
    }

    #[test]
    fn shard_votes_price_at_the_pair_information_content() {
        // a 1000-client shard with 600 delivered votes: the sum lives in
        // [-600, 600] (ceil(log2 1201) = 11 bits) and the voter count in
        // [0, 1000] (ceil(log2 1001) = 10 bits) — 21 bits for the whole
        // shard instead of 600 forwarded one-bit votes
        let m = Message::ShardVotes { sum: -42, voters: 600, shard_size: 1000, dense_pairs: false };
        assert_eq!(m.payload_bits(), 11 + 10);
        assert!(m.is_uplink(), "shard votes travel toward the merger");
        // an all-drained shard still reports (0, 0) so the merger can
        // close the round: 1-bit sum floor + the count field
        let drained = Message::ShardVotes { sum: 0, voters: 0, shard_size: 1000, dense_pairs: false };
        assert_eq!(drained.payload_bits(), 1 + 10);
        // ZO shards forward dense pairs — means are not mergeable from
        // (sum, count) without losing each voter's own direction seed
        let zo = Message::ShardVotes { sum: 0, voters: 3, shard_size: 8, dense_pairs: true };
        assert_eq!(zo.payload_bits(), 64 * 3);
    }

    #[test]
    fn pool_sampler_is_uniform_without_history_and_biased_with_it() {
        let pool = SeedPool::derive(11, 64);
        // empty history: a deterministic uniform draw
        let h0 = vec![0.0f32; 64];
        let first = pool.sample_index(&h0, 0);
        assert_eq!(first, pool.sample_index(&h0, 0), "keyed draw reproduces");
        assert!(first < 64);
        let spread: std::collections::BTreeSet<u32> =
            (0..200).map(|t| pool.sample_index(&h0, t)).collect();
        assert!(spread.len() > 16, "uniform draws must spread over the pool");
        // loaded history: the heavy direction is sampled far above 1/K
        let mut h = vec![0.0f32; 64];
        h[17] = 100.0;
        let hits = (0..2000).filter(|t| pool.sample_index(&h, *t) == 17).count();
        assert!(hits > 2000 / 64 * 4, "Pro sampler must bias toward |history| ({hits} hits)");
        // ...but never collapses: other directions still get drawn
        let others = (0..2000).filter(|t| pool.sample_index(&h, *t) != 17).count();
        assert!(others > 200, "uniform floor keeps exploring ({others} non-17 draws)");
    }

    #[test]
    fn history_commits_in_round_order_and_replays_spans() {
        let mut h = SeedHistory::default();
        h.commit_round(0, [fs_record(0)]);
        h.commit_round(1, []); // zero-participant no-op round
        h.commit_round(2, [fs_record(2)]);
        assert_eq!(h.head_round(), 3);
        let span = h.replay_span(0, 3).unwrap();
        assert_eq!(span, vec![fs_record(0), fs_record(2)]);
        assert_eq!(h.replay_span(1, 3).unwrap(), vec![fs_record(2)]);
        assert_eq!(h.replay_span(2, 2).unwrap(), vec![]);
        assert!(h.replay_span(0, 4).is_none(), "beyond the committed head");
    }

    #[test]
    #[should_panic(expected = "round order")]
    fn history_rejects_out_of_order_commits() {
        let mut h = SeedHistory::default();
        h.commit_round(1, [fs_record(1)]);
    }

    #[test]
    fn compaction_respects_capacity_and_watermark() {
        let mut h = SeedHistory::new(4);
        for t in 0..10 {
            h.commit_round(t, [fs_record(t)]);
        }
        // watermark 3: only rounds 0..3 may go, and only down to capacity
        h.compact_to(3);
        assert_eq!(h.tail_round(), 3);
        assert_eq!(h.records_len(), 7, "records >= watermark are pinned");
        assert!(h.replay_span(0, 10).is_none(), "compacted span must refuse");
        assert_eq!(h.replay_span(3, 10).unwrap().len(), 7);
        // watermark 10: free to trim to the soft capacity
        h.compact_to(10);
        assert_eq!(h.records_len(), 4);
        assert_eq!(h.tail_round(), 6);
        assert_eq!(h.replay_span(6, 10).unwrap().len(), 4);
    }

    #[test]
    fn compaction_watermark_exactly_at_ring_capacity() {
        // 8 rounds in a capacity-4 ring, watermark exactly at the round
        // that brings the ring down to capacity: both gates release at
        // the same instant, and neither may overshoot
        let mut h = SeedHistory::new(4);
        for t in 0..8 {
            h.commit_round(t, [fs_record(t)]);
        }
        h.compact_to(4);
        assert_eq!(h.tail_round(), 4);
        assert_eq!(h.records_len(), 4, "trimmed to capacity, not past the watermark");
        assert_eq!(h.replay_span(4, 8).unwrap().len(), 4);
        // raising the watermark to the head changes nothing: the ring is
        // no longer over capacity, so the capacity gate holds the rest
        h.compact_to(8);
        assert_eq!(h.tail_round(), 4);
        assert_eq!(h.records_len(), 4);
    }

    #[test]
    fn untracked_client_joining_after_compaction_is_refused_the_span() {
        // a client the tracker never knew about (it joined the pool
        // after compaction already ran) asks for a span starting below
        // the tail: replay must refuse — `None` is the caller's signal
        // to fall back to a dense rebroadcast, never to replay a
        // silently truncated span
        let mut h = SeedHistory::new(2);
        for t in 0..10 {
            h.commit_round(t, [fs_record(t)]);
        }
        h.compact_to(6);
        assert_eq!(h.tail_round(), 6);
        assert!(h.replay_span(0, 10).is_none(), "fresh-join span reaches below the tail");
        assert!(h.replay_span(5, 10).is_none(), "partially compacted span refuses too");
        assert_eq!(h.replay_span(6, 10).unwrap().len(), 4, "tracked clients unaffected");
    }

    #[test]
    fn zero_capacity_ring_retains_only_watermark_pinned_records() {
        // capacity 0: every record is over-capacity the moment it
        // commits, so retention is governed by the watermark alone
        let mut h = SeedHistory::new(0);
        for t in 0..5 {
            h.commit_round(t, [fs_record(t)]);
            h.compact_to(3); // slowest client stuck at round 3
        }
        assert_eq!(h.tail_round(), 3);
        assert_eq!(h.records_len(), 2, "rounds 3..5 pinned by the watermark");
        assert_eq!(h.replay_span(3, 5).unwrap().len(), 2);
        assert!(h.replay_span(2, 5).is_none());
        // watermark at the head: a zero-capacity ring may drop everything
        h.compact_to(5);
        assert_eq!(h.records_len(), 0);
        assert_eq!(h.tail_round(), 5);
        // ...and still accepts the next in-order commit afterwards
        h.commit_round(5, [fs_record(5)]);
        assert_eq!(h.replay_span(5, 6).unwrap(), vec![fs_record(5)]);
    }

    #[test]
    fn compaction_never_drops_pinned_records_even_over_capacity() {
        let mut h = SeedHistory::new(2);
        for t in 0..50 {
            h.commit_round(t, [fs_record(t)]);
            h.compact_to(5); // slowest client stuck at round 5
        }
        assert!(h.records_len() >= 45, "rounds 5..50 must all be retained");
        assert_eq!(h.replay_span(5, 50).unwrap().len(), 45);
    }

    #[test]
    fn duplex_roundtrip() {
        let (ps, client) = link();
        ps.to_client.send(Message::RoundStart { round: 1 }).unwrap();
        let got = client.from_ps.recv().unwrap();
        assert_eq!(got, Message::RoundStart { round: 1 });
        client.to_ps.send(Message::SignVote { sign: -1 }).unwrap();
        let got = ps.from_client.recv().unwrap();
        assert_eq!(got, Message::SignVote { sign: -1 });
    }
}
