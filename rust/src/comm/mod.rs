//! Communication substrate: typed PS<->client messages with *exact* bit
//! accounting, plus an analytic bandwidth/latency model for projecting
//! wall-clock communication cost.
//!
//! The paper's headline (Table 1, Eq. 5) is a bits-per-step claim:
//!
//! | method     | uplink/step/client | downlink/step/client |
//! |------------|--------------------|----------------------|
//! | FedSGD     | 32·d               | 32·d                 |
//! | ZO-FedSGD  | 64 (seed+proj)     | 64·K                 |
//! | FeedSign   | **1**              | **1**                |
//!
//! Every message the coordinator sends is constructed here and carries its
//! own payload size; [`Ledger`] accumulates the totals that the Table 1
//! bench and the per-run metrics report.  The in-process transport is a
//! tokio mpsc pair per client — the same topology a real deployment would
//! have, with the network link swapped for a channel.

/// A protocol message.  Payload bits follow the paper's accounting
/// (Eq. 5): float projections are 32 bits, seeds 32 bits, signs 1 bit.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Client -> PS: FeedSign's 1-bit vote.
    SignVote { sign: i8 },
    /// Client -> PS: ZO-FedSGD's seed-projection pair.
    Projection { seed: u32, p: f32 },
    /// Client -> PS: FedSGD's dense gradient.
    Gradient { g: Vec<f32> },
    /// PS -> client: FeedSign's 1-bit global direction.
    GlobalSign { sign: i8 },
    /// PS -> client: ZO-FedSGD's aggregated seed-projection pairs (one per
    /// participating client).
    GlobalProjections { pairs: Vec<(u32, f32)> },
    /// PS -> client: FedSGD's averaged dense gradient.
    GlobalGradient { g: Vec<f32> },
    /// PS -> client: round kick-off (seed is derivable from the round
    /// index in FeedSign — `seed = t` — so this carries zero payload bits;
    /// it models the same round-trigger a deployment piggybacks on the
    /// previous downlink).
    RoundStart { round: u64 },
}

impl Message {
    /// Paper-accounting payload size in bits.
    pub fn payload_bits(&self) -> u64 {
        match self {
            Message::SignVote { .. } | Message::GlobalSign { .. } => 1,
            Message::Projection { .. } => 64,
            Message::Gradient { g } | Message::GlobalGradient { g } => 32 * g.len() as u64,
            Message::GlobalProjections { pairs } => 64 * pairs.len() as u64,
            Message::RoundStart { .. } => 0,
        }
    }

    pub fn is_uplink(&self) -> bool {
        matches!(
            self,
            Message::SignVote { .. } | Message::Projection { .. } | Message::Gradient { .. }
        )
    }
}

/// Cumulative communication ledger for one run.
#[derive(Debug, Default, Clone)]
pub struct Ledger {
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    pub uplink_msgs: u64,
    pub downlink_msgs: u64,
}

impl Ledger {
    pub fn record(&mut self, msg: &Message) {
        // zero-payload round triggers (RoundStart) piggyback on the
        // previous downlink in a deployment, so they cost neither bits nor
        // a message slot.
        if msg.payload_bits() == 0 {
            return;
        }
        if msg.is_uplink() {
            self.uplink_bits += msg.payload_bits();
            self.uplink_msgs += 1;
        } else {
            self.downlink_bits += msg.payload_bits();
            self.downlink_msgs += 1;
        }
    }

    pub fn total_bits(&self) -> u64 {
        self.uplink_bits + self.downlink_bits
    }

    pub fn merge(&mut self, other: &Ledger) {
        self.uplink_bits += other.uplink_bits;
        self.downlink_bits += other.downlink_bits;
        self.uplink_msgs += other.uplink_msgs;
        self.downlink_msgs += other.downlink_msgs;
    }

    /// Record a whole message sequence (sub-ledger building block for
    /// parallel workers).
    pub fn record_all<'a, I: IntoIterator<Item = &'a Message>>(&mut self, msgs: I) {
        for m in msgs {
            self.record(m);
        }
    }

    /// Commit per-worker sub-ledgers into this (authoritative) ledger in
    /// the order given.  The parallel round engine meters each client's
    /// messages into a private sub-ledger during the fan-out and commits
    /// them here in client-id order — totals are additive, so the result
    /// is bit-identical to sequential metering (pinned by the
    /// `prop_ledger_additive_over_message_sequences` property and the
    /// cross-topology parity tests).
    pub fn commit<I: IntoIterator<Item = Ledger>>(&mut self, subs: I) {
        for sub in subs {
            self.merge(&sub);
        }
    }
}

/// Analytic link model: projects ledger totals to wall-clock seconds for a
/// given uplink/downlink bandwidth and per-message latency — how the
/// "48 MB ≈ 4 minutes of FHD video per round" style comparisons in §1 are
/// regenerated without a real testbed.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// uplink bandwidth, bits/s
    pub up_bps: f64,
    /// downlink bandwidth, bits/s
    pub down_bps: f64,
    /// per-message fixed latency, seconds
    pub rtt_s: f64,
}

impl LinkModel {
    /// A conservative mobile uplink: 20 Mbps up / 100 Mbps down / 30 ms RTT.
    pub fn mobile() -> Self {
        LinkModel { up_bps: 20e6, down_bps: 100e6, rtt_s: 0.03 }
    }

    /// Projected communication seconds for a ledger.
    pub fn seconds(&self, ledger: &Ledger) -> f64 {
        ledger.uplink_bits as f64 / self.up_bps
            + ledger.downlink_bits as f64 / self.down_bps
            + (ledger.uplink_msgs + ledger.downlink_msgs) as f64 * self.rtt_s
    }
}

/// In-process duplex transport between the PS and one client, with both
/// directions metered.  Channels are unbounded: the round protocol is
/// strictly request/response so queue depth is <= 1.
pub struct Duplex {
    pub to_client: std::sync::mpsc::Sender<Message>,
    pub from_client: std::sync::mpsc::Receiver<Message>,
}

/// The client's end of a [`Duplex`].
pub struct ClientPort {
    pub from_ps: std::sync::mpsc::Receiver<Message>,
    pub to_ps: std::sync::mpsc::Sender<Message>,
}

/// Create a metered PS<->client link pair.
pub fn link() -> (Duplex, ClientPort) {
    let (tx_down, rx_down) = std::sync::mpsc::channel();
    let (tx_up, rx_up) = std::sync::mpsc::channel();
    (
        Duplex { to_client: tx_down, from_client: rx_up },
        ClientPort { from_ps: rx_down, to_ps: tx_up },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feedsign_messages_are_one_bit() {
        assert_eq!(Message::SignVote { sign: 1 }.payload_bits(), 1);
        assert_eq!(Message::GlobalSign { sign: -1 }.payload_bits(), 1);
    }

    #[test]
    fn zo_fedsgd_pair_is_64_bits() {
        assert_eq!(Message::Projection { seed: 7, p: 0.5 }.payload_bits(), 64);
        let m = Message::GlobalProjections { pairs: vec![(1, 0.1), (2, 0.2)] };
        assert_eq!(m.payload_bits(), 128);
    }

    #[test]
    fn gradient_scales_with_d() {
        let m = Message::Gradient { g: vec![0.0; 1000] };
        assert_eq!(m.payload_bits(), 32_000);
    }

    #[test]
    fn round_start_free() {
        assert_eq!(Message::RoundStart { round: 3 }.payload_bits(), 0);
        let mut l = Ledger::default();
        l.record(&Message::RoundStart { round: 3 });
        assert_eq!(l.downlink_msgs, 0, "piggybacked trigger costs no message");
    }

    #[test]
    fn ledger_directional_accounting() {
        let mut l = Ledger::default();
        l.record(&Message::SignVote { sign: 1 });
        l.record(&Message::GlobalSign { sign: 1 });
        l.record(&Message::Projection { seed: 0, p: 1.0 });
        assert_eq!(l.uplink_bits, 65);
        assert_eq!(l.downlink_bits, 1);
        assert_eq!(l.uplink_msgs, 2);
        assert_eq!(l.total_bits(), 66);
    }

    #[test]
    fn ledger_commit_matches_sequential_recording() {
        let msgs = [
            Message::SignVote { sign: 1 },
            Message::SignVote { sign: -1 },
            Message::Projection { seed: 3, p: 0.1 },
            Message::GlobalSign { sign: 1 },
        ];
        let mut sequential = Ledger::default();
        sequential.record_all(&msgs);
        // same messages split over two worker sub-ledgers, then committed
        let mut sub_a = Ledger::default();
        sub_a.record_all(&msgs[..2]);
        let mut sub_b = Ledger::default();
        sub_b.record_all(&msgs[2..]);
        let mut committed = Ledger::default();
        committed.commit([sub_a, sub_b]);
        assert_eq!(committed.uplink_bits, sequential.uplink_bits);
        assert_eq!(committed.downlink_bits, sequential.downlink_bits);
        assert_eq!(committed.uplink_msgs, sequential.uplink_msgs);
        assert_eq!(committed.downlink_msgs, sequential.downlink_msgs);
    }

    #[test]
    fn ledger_merge_adds() {
        let mut a = Ledger { uplink_bits: 10, downlink_bits: 5, uplink_msgs: 2, downlink_msgs: 1 };
        let b = a.clone();
        a.merge(&b);
        assert_eq!(a.uplink_bits, 20);
        assert_eq!(a.downlink_msgs, 2);
    }

    #[test]
    fn link_model_projects_seconds() {
        let lm = LinkModel { up_bps: 1e6, down_bps: 2e6, rtt_s: 0.01 };
        let l = Ledger { uplink_bits: 1_000_000, downlink_bits: 2_000_000, uplink_msgs: 1, downlink_msgs: 1 };
        let s = lm.seconds(&l);
        assert!((s - (1.0 + 1.0 + 0.02)).abs() < 1e-9);
    }

    #[test]
    fn duplex_roundtrip() {
        let (ps, client) = link();
        ps.to_client.send(Message::RoundStart { round: 1 }).unwrap();
        let got = client.from_ps.recv().unwrap();
        assert_eq!(got, Message::RoundStart { round: 1 });
        client.to_ps.send(Message::SignVote { sign: -1 }).unwrap();
        let got = ps.from_client.recv().unwrap();
        assert_eq!(got, Message::SignVote { sign: -1 });
    }
}
