//! Observability plane: deterministic tracing, a counter/histogram
//! registry, and a leveled logger.
//!
//! Three pieces (ROADMAP: the profiling substrate items 3–5 measure
//! against):
//!
//! * [`trace`] — a span/event tracer over the plan → execute → commit
//!   round pipeline.  Workers record into private [`trace::SpanBuf`]s
//!   (the sub-ledger pattern: no locks, no shared state) that the
//!   session absorbs in shard/bin order; exports are Chrome
//!   `trace_event` JSON (`--trace-out trace.json`, load in
//!   `chrome://tracing`) and JSONL (`--trace-out trace.jsonl`).
//! * [`registry`] — monotonic counters + fixed-bucket latency
//!   histograms with Prometheus text exposition (`--metrics-out`),
//!   absorbing the ad-hoc `NetStats` / `ReplicaStats` /
//!   `ProbeBatchStats` / `ShardStats` structs into one naming scheme.
//! * [`log`] — the `FEEDSIGN_LOG=error|warn|info|debug` leveled logger
//!   every former `println!` / `eprintln!` site in library code routes
//!   through.
//!
//! ## Determinism contract
//!
//! Instrumentation **never feeds timing back into control flow**: no
//! branch in the round engine reads a clock or a trace buffer, so every
//! parity suite (parallel, catch-up, net, replica, shard) is
//! bit-identical with tracing on or off.  Events carry two kinds of
//! payload:
//!
//! * **logical keys** (round, phase, shard, client, `n1`/`n2` details)
//!   — pure functions of the run's deterministic state.  Sorted into
//!   [`trace::Tracer::logical_sequence`], they are identical across
//!   thread counts and topologies (pinned by
//!   `rust/tests/trace_parity.rs`).
//! * **wall-clock timestamps** (`start_us`/`dur_us`) and
//!   timing-derived events ([`trace::Phase::RoundGate`],
//!   [`trace::Phase::Overlap`], per-worker
//!   [`trace::Phase::ProbeBatch`] spans) — excluded from the logical
//!   sequence; they exist only for the exports.
//!
//! ## Zero cost when disabled
//!
//! The `obs` cargo feature (default on) compiles the probe sites in;
//! without it [`trace::Tracer::on`] is a compile-time `false` and every
//! recording branch folds away (the [`obs_event!`] macro layer expands
//! to nothing).  With the feature on but tracing not enabled (no
//! `FEEDSIGN_TRACE`, no `--trace-out`), each site is one predictable
//! branch on a bool — CI gates the perf_hotpath round engine at ≤ 1%
//! overhead vs a `--no-default-features` build.

pub mod export;
pub mod log;
pub mod registry;
pub mod trace;

pub use registry::Registry;
pub use trace::{Event, Phase, SpanBuf, Tracer};

/// Whether `FEEDSIGN_TRACE` asks for runtime tracing (`1` / `true` /
/// `on`).  Sessions read this once at construction; the CLI's
/// `--trace-out` enables tracing explicitly regardless.
pub fn trace_env() -> bool {
    match std::env::var("FEEDSIGN_TRACE") {
        Ok(v) => matches!(v.trim(), "1" | "true" | "on"),
        Err(_) => false,
    }
}

/// Microseconds since the process-wide trace epoch (first call wins).
/// Monotonic, shared by every worker thread, so spans recorded in
/// detached [`SpanBuf`]s land on one timeline.
pub fn now_us() -> u64 {
    use std::sync::OnceLock;
    static EPOCH: OnceLock<std::time::Instant> = OnceLock::new();
    let epoch = *EPOCH.get_or_init(std::time::Instant::now);
    epoch.elapsed().as_micros() as u64
}

/// Record one logical event into a [`Tracer`] or [`SpanBuf`] — compiles
/// to nothing without the `obs` feature (arguments are not evaluated).
#[macro_export]
macro_rules! obs_event {
    ($sink:expr, $phase:expr, $round:expr, $shard:expr, $client:expr, $n1:expr, $n2:expr) => {
        #[cfg(feature = "obs")]
        {
            let sink = &mut *$sink;
            if sink.on() {
                sink.push($crate::obs::Event::logical(
                    $phase, $round, $shard, $client, $n1, $n2,
                ));
            }
        }
        #[cfg(not(feature = "obs"))]
        {
            let _ = &$sink;
        }
    };
}

/// Log at error level (stderr; always on unless the level is raised).
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Error, format_args!($($arg)*))
    };
}

/// Log at warn level (stderr; the library default shows these).
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($arg)*))
    };
}

/// Log at info level (stdout; the CLI default shows these, `--quiet`
/// and library consumers do not).
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($arg)*))
    };
}

/// Log at debug level (stdout; `FEEDSIGN_LOG=debug` only).
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_us_is_monotone() {
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
    }

    #[test]
    fn trace_env_parses_common_spellings() {
        // can't mutate the process env safely in a parallel test run;
        // just pin the absent-variable default
        if std::env::var("FEEDSIGN_TRACE").is_err() {
            assert!(!trace_env());
        }
    }

    #[test]
    fn obs_event_macro_records_into_both_sinks() {
        let mut t = Tracer::new(true);
        obs_event!(&mut t, Phase::Plan, 3, -1, -1, 5, 0);
        let mut b = SpanBuf::new(true);
        obs_event!(&mut b, Phase::Probe, 3, -1, 2, 7, 0);
        #[cfg(feature = "obs")]
        {
            assert_eq!(t.events().len(), 1);
            t.absorb(b, 1);
            assert_eq!(t.events().len(), 2);
            assert_eq!(t.events()[1].shard, 1, "absorb stamps the shard");
        }
        #[cfg(not(feature = "obs"))]
        {
            assert!(t.events().is_empty());
            t.absorb(b, 1);
            assert!(t.events().is_empty());
        }
    }
}
