//! Span/event tracer for the round pipeline.
//!
//! The recording topology mirrors the engine's ownership: the session
//! holds one [`Tracer`]; each execute-phase worker records into a
//! private [`SpanBuf`] it returns with its outcomes (the same pattern
//! as the uplink sub-ledgers), and the session absorbs the buffers in
//! shard/bin order.  No locks, no shared mutable state, and — the
//! determinism contract — no engine branch ever reads what was
//! recorded.

use super::now_us;

/// Pipeline phase taxonomy.  The discriminant order is the logical sort
/// rank inside a round: plan before admission before catch-up before
/// execute before commit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Round plan fixed (participation draw + admission consumed).
    /// `n1` = planned participants.
    Plan,
    /// One `NetSim::admit` deadline pass.  `n1` = kept, `n2` = cut.
    NetAdmit,
    /// Virtual-clock straggler attribution for an admitted round:
    /// `client` = the link that gated it, `n1` = link-class index,
    /// `n2` = the round's virtual microseconds.  Deterministic (the
    /// virtual clock is keyed, not wall).
    LinkGate,
    /// One stale client's catch-up replay. `n1` = missed rounds,
    /// `n2` = records applied.
    Catchup,
    /// One shard's execute fan-out (`shard` = -1 on the flat path).
    /// `n1` = shard participants.
    Execute,
    /// One worker's grouped probe-batch pass — schedule-dependent
    /// (worker binning varies with thread count), so excluded from the
    /// logical sequence.  `n1` = probes served, `n2` = canonical passes.
    ProbeBatch,
    /// One client's probe served. `n1` = direction seed.
    Probe,
    /// One delivered contribution committed (`client` >= 0; FeedSign:
    /// `n1` = sign bit; ZO-FedSGD: `n1` = seed, `n2` = projection
    /// bits), or the round's canonical commit (`client` = -1; FeedSign:
    /// `n1` = global sign bit, `n2` = voters; ZO-FedSGD: `n2` =
    /// delivered pairs).
    Commit,
    /// One shard's pre-reduced vote merge. `n1` = voters, `n2` = bits.
    ShardMerge,
    /// Snapshot-cache admissions observed this round (`n1` = taken,
    /// `n2` = declined).
    Snapshot,
    /// A round-boundary evaluation pass.
    Eval,
    /// Wall-clock straggler attribution: `shard` = the shard whose
    /// execute gated the round.  Timing-derived — excluded from the
    /// logical sequence.
    RoundGate,
    /// Lookahead overlap measurement: `n1` = wall microseconds of round
    /// t+1 planning hidden under round t's stragglers.  Timing-derived.
    Overlap,
    /// One fused commit+probe sweep over the canonical store
    /// ([`crate::coordinator::replica::ReplicaStore::advance_fused`]).
    /// `n1` = commits + staged views fused into the pass, `n2` = tile
    /// size in elements.  Wall-duration is the sweep's cost; the tile
    /// size is a schedule/layout knob (never changes the bits), so the
    /// span is excluded from the logical sequence like
    /// [`Phase::ProbeBatch`].  Appended at the enum's end: the
    /// discriminant order of the phases *before* it is the logical sort
    /// rank, which must stay frozen.
    TileSweep,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::NetAdmit => "net_admit",
            Phase::LinkGate => "link_gate",
            Phase::Catchup => "catchup",
            Phase::Execute => "execute",
            Phase::ProbeBatch => "probe_batch",
            Phase::Probe => "probe",
            Phase::Commit => "commit",
            Phase::ShardMerge => "shard_merge",
            Phase::Snapshot => "snapshot",
            Phase::Eval => "eval",
            Phase::RoundGate => "round_gate",
            Phase::Overlap => "overlap",
            Phase::TileSweep => "tile_sweep",
        }
    }

    /// Phases whose events are pure functions of the run's deterministic
    /// state — identical across thread counts and topologies.  Worker
    /// scheduling ([`Phase::ProbeBatch`]), wall-clock attribution
    /// ([`Phase::RoundGate`], [`Phase::Overlap`]) and the commit sweep's
    /// layout span ([`Phase::TileSweep`], whose tile size is an
    /// environment knob) are observational only.
    pub fn is_logical(self) -> bool {
        !matches!(
            self,
            Phase::ProbeBatch | Phase::RoundGate | Phase::Overlap | Phase::TileSweep
        )
    }
}

/// One recorded event.  `shard` / `client` use -1 for "not applicable";
/// `n1` / `n2` are per-phase details (see [`Phase`]); `start_us` /
/// `dur_us` are wall-clock and never enter the logical sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    pub phase: Phase,
    pub round: u64,
    pub shard: i32,
    pub client: i64,
    pub n1: u64,
    pub n2: u64,
    pub start_us: u64,
    pub dur_us: u64,
}

impl Event {
    /// A zero-duration logical event stamped at the current trace clock.
    pub fn logical(phase: Phase, round: u64, shard: i32, client: i64, n1: u64, n2: u64) -> Event {
        Event { phase, round, shard, client, n1, n2, start_us: now_us(), dur_us: 0 }
    }

    /// The total-order key the logical sequence sorts by — everything
    /// except the wall-clock fields.
    fn logical_key(&self) -> (u64, Phase, i32, i64, u64, u64) {
        (self.round, self.phase, self.shard, self.client, self.n1, self.n2)
    }

    /// Render the timestamp-free form used in sequence comparisons.
    pub fn logical_repr(&self) -> String {
        format!(
            "r{} {} s{} c{} n1={} n2={}",
            self.round,
            self.phase.name(),
            self.shard,
            self.client,
            self.n1,
            self.n2
        )
    }
}

/// A worker-private event buffer: created at fan-out, filled lock-free,
/// returned with the worker's outcomes and absorbed by the session's
/// [`Tracer`].  `on = false` (or the `obs` feature off) makes every
/// `push` a no-op.
#[derive(Debug, Default)]
pub struct SpanBuf {
    on: bool,
    events: Vec<Event>,
}

impl SpanBuf {
    pub fn new(on: bool) -> SpanBuf {
        SpanBuf { on: cfg!(feature = "obs") && on, events: Vec::new() }
    }

    #[inline]
    pub fn on(&self) -> bool {
        cfg!(feature = "obs") && self.on
    }

    /// The trace clock, or 0 when recording is off (spares the syscall).
    #[inline]
    pub fn clock(&self) -> u64 {
        if self.on() {
            now_us()
        } else {
            0
        }
    }

    #[inline]
    pub fn push(&mut self, ev: Event) {
        if self.on() {
            self.events.push(ev);
        }
    }

    /// Record a completed span that began at `start_us` (from
    /// [`SpanBuf::clock`]).
    pub fn span(
        &mut self,
        phase: Phase,
        round: u64,
        shard: i32,
        client: i64,
        n1: u64,
        n2: u64,
        start_us: u64,
    ) {
        if self.on() {
            let end = now_us();
            self.events.push(Event {
                phase,
                round,
                shard,
                client,
                n1,
                n2,
                start_us,
                dur_us: end.saturating_sub(start_us),
            });
        }
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }
}

/// The session-resident trace sink.
#[derive(Debug, Default)]
pub struct Tracer {
    enabled: bool,
    events: Vec<Event>,
}

impl Tracer {
    pub fn new(enabled: bool) -> Tracer {
        Tracer { enabled, events: Vec::new() }
    }

    /// Construct from the `FEEDSIGN_TRACE` environment toggle.
    pub fn from_env() -> Tracer {
        Tracer::new(super::trace_env())
    }

    /// Turn recording on mid-lifetime (the CLI's `--trace-out` path).
    /// Never changes engine behavior — only whether events are kept.
    pub fn enable(&mut self) {
        self.enabled = true;
    }

    /// Whether events are recorded.  A compile-time `false` without the
    /// `obs` feature: every `if tracer.on()` branch folds away.
    #[inline]
    pub fn on(&self) -> bool {
        cfg!(feature = "obs") && self.enabled
    }

    /// The trace clock, or 0 when recording is off.
    #[inline]
    pub fn clock(&self) -> u64 {
        if self.on() {
            now_us()
        } else {
            0
        }
    }

    #[inline]
    pub fn push(&mut self, ev: Event) {
        if self.on() {
            self.events.push(ev);
        }
    }

    /// Record a completed span that began at `start_us`.
    pub fn span(
        &mut self,
        phase: Phase,
        round: u64,
        shard: i32,
        client: i64,
        n1: u64,
        n2: u64,
        start_us: u64,
    ) {
        if self.on() {
            let end = now_us();
            self.events.push(Event {
                phase,
                round,
                shard,
                client,
                n1,
                n2,
                start_us,
                dur_us: end.saturating_sub(start_us),
            });
        }
    }

    /// Fold a worker buffer in, stamping events that carry no shard with
    /// the worker's shard (-1 keeps them unstamped).  Absorb order is
    /// shard/bin order — deterministic for a fixed schedule, and
    /// irrelevant to the (sorted) logical sequence.
    pub fn absorb(&mut self, buf: SpanBuf, shard: i32) {
        if !self.on() {
            return;
        }
        for mut ev in buf.events {
            if ev.shard < 0 {
                ev.shard = shard;
            }
            self.events.push(ev);
        }
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The deterministic logical event sequence: every
    /// [`Phase::is_logical`] event, sorted by its timestamp-free key and
    /// rendered without wall-clock fields.  Identical across thread
    /// counts and topologies for the same configured run — the invariant
    /// `rust/tests/trace_parity.rs` pins.
    pub fn logical_sequence(&self) -> Vec<String> {
        self.logical_sequence_of(|_| true)
    }

    /// [`Tracer::logical_sequence`] restricted to a phase subset (e.g.
    /// the round-level phases both topologies emit).
    pub fn logical_sequence_of<F: Fn(Phase) -> bool>(&self, keep: F) -> Vec<String> {
        let mut evs: Vec<&Event> = self
            .events
            .iter()
            .filter(|e| e.phase.is_logical() && keep(e.phase))
            .collect();
        evs.sort_by_key(|e| e.logical_key());
        evs.into_iter().map(Event::logical_repr).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::new(false);
        assert!(!t.on());
        t.push(Event::logical(Phase::Plan, 0, -1, -1, 1, 0));
        t.span(Phase::Execute, 0, 0, -1, 1, 0, t.clock());
        assert!(t.is_empty());
        assert_eq!(t.clock(), 0, "no syscall when off");
    }

    #[test]
    #[cfg(feature = "obs")]
    fn spans_measure_and_absorb_stamps_shards() {
        let mut t = Tracer::new(true);
        let t0 = t.clock();
        t.span(Phase::Execute, 2, 1, -1, 4, 0, t0);
        assert_eq!(t.events().len(), 1);
        assert!(t.events()[0].start_us >= t0);

        let mut buf = SpanBuf::new(true);
        buf.push(Event::logical(Phase::Probe, 2, -1, 7, 11, 0));
        buf.push(Event { shard: 3, ..Event::logical(Phase::Probe, 2, 3, 8, 12, 0) });
        t.absorb(buf, 1);
        assert_eq!(t.events()[1].shard, 1, "unstamped events take the absorb shard");
        assert_eq!(t.events()[2].shard, 3, "explicit shards are preserved");
    }

    #[test]
    #[cfg(feature = "obs")]
    fn logical_sequence_sorts_and_drops_timing_phases() {
        let mut t = Tracer::new(true);
        // recorded out of order, with timing-derived noise interleaved
        t.push(Event::logical(Phase::Commit, 1, -1, 4, 1, 0));
        t.push(Event::logical(Phase::RoundGate, 0, 2, -1, 0, 0));
        t.push(Event::logical(Phase::Plan, 1, -1, -1, 3, 0));
        t.push(Event::logical(Phase::ProbeBatch, 0, 0, -1, 9, 9));
        t.push(Event::logical(Phase::Plan, 0, -1, -1, 2, 0));
        t.push(Event::logical(Phase::Overlap, 1, -1, -1, 55, 0));
        let seq = t.logical_sequence();
        assert_eq!(
            seq,
            vec![
                "r0 plan s-1 c-1 n1=2 n2=0",
                "r1 plan s-1 c-1 n1=3 n2=0",
                "r1 commit s-1 c4 n1=1 n2=0",
            ]
        );
        let plans_only = t.logical_sequence_of(|p| p == Phase::Plan);
        assert_eq!(plans_only.len(), 2);
    }

    #[test]
    fn phase_sort_rank_follows_pipeline_order() {
        assert!(Phase::Plan < Phase::NetAdmit);
        assert!(Phase::NetAdmit < Phase::Catchup);
        assert!(Phase::Catchup < Phase::Execute);
        assert!(Phase::Execute < Phase::Probe);
        assert!(Phase::Probe < Phase::Commit);
        assert!(Phase::Commit < Phase::ShardMerge);
        // observational phases ride after the logical pipeline; the
        // newest (TileSweep) must stay appended at the end so the frozen
        // ranks above never shift
        assert!(Phase::Overlap < Phase::TileSweep);
        assert!(!Phase::TileSweep.is_logical());
        assert_eq!(Phase::TileSweep.name(), "tile_sweep");
    }
}
