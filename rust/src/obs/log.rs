//! Leveled logger: the one gate every diagnostic print in library code
//! goes through, so no library path writes to stdout/stderr
//! unconditionally.
//!
//! Level resolution: an explicit [`set_level`] (the CLI: `info` by
//! default, `error` under `--quiet`) wins; otherwise `FEEDSIGN_LOG`
//! (`error | warn | info | debug`); otherwise [`Level::Warn`] — library
//! consumers see warnings and errors only.
//!
//! Routing: `info`/`debug` → stdout (progress), `warn`/`error` → stderr
//! (diagnostics).  Use the [`crate::log_error!`], [`crate::log_warn!`],
//! [`crate::log_info!`], [`crate::log_debug!`] macros.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

const UNSET: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNSET);

/// Pin the process log level (the CLI entry point calls this; it
/// overrides `FEEDSIGN_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The active level: explicit > `FEEDSIGN_LOG` > `warn`.
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNSET {
        return Level::from_u8(v);
    }
    let resolved = std::env::var("FEEDSIGN_LOG")
        .ok()
        .and_then(|v| Level::parse(&v))
        .unwrap_or(Level::Warn);
    // cache the env read; a later set_level still wins by overwriting
    LEVEL.store(resolved as u8, Ordering::Relaxed);
    resolved
}

#[inline]
pub fn enabled(at: Level) -> bool {
    at <= level()
}

/// Emit one record (used by the macros; not intended for direct calls).
pub fn emit(at: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(at) {
        return;
    }
    match at {
        Level::Error | Level::Warn => eprintln!("{args}"),
        Level::Info | Level::Debug => println!("{args}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_levels_and_orders_them() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse(" WARN "), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Info);
    }

    #[test]
    fn set_level_gates_enabled() {
        // the level is process-global; restore what other tests expect
        let before = level();
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Warn));
        set_level(Level::Debug);
        assert!(enabled(Level::Info));
        set_level(before);
    }
}
