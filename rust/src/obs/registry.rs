//! Counter / histogram registry with Prometheus text exposition.
//!
//! One naming scheme over the stats the run engine already keeps in
//! ad-hoc structs (`comm::Ledger`, `net::NetStats`,
//! `coordinator::replica::ReplicaStats`, `engine::ProbeBatchStats`,
//! `coordinator::shard::ShardStats`) plus rollups derived from the
//! trace ([`crate::obs::trace`]): phase-duration histograms, per-shard
//! round-gating counts, per-link-class virtual latency.  The registry
//! is a *sink* — nothing in the engine reads it back.

use super::trace::{Event, Phase};
use crate::metrics::RunResult;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Fixed exponential latency buckets (microseconds): 64 µs … ~67 s.
const BUCKETS_US: [u64; 11] =
    [64, 256, 1_024, 4_096, 16_384, 65_536, 262_144, 1_048_576, 4_194_304, 16_777_216, 67_108_864];

/// A fixed-bucket histogram (cumulative counts are computed at render).
#[derive(Debug, Clone, Default)]
pub struct Hist {
    counts: [u64; BUCKETS_US.len()],
    overflow: u64,
    sum_us: u64,
    total: u64,
}

impl Hist {
    pub fn observe_us(&mut self, us: u64) {
        match BUCKETS_US.iter().position(|&b| us <= b) {
            Some(i) => self.counts[i] += 1,
            None => self.overflow += 1,
        }
        self.sum_us += us;
        self.total += 1;
    }

    pub fn total(&self) -> u64 {
        self.total
    }
}

/// The metric sink.  Counter and histogram names may carry inline
/// Prometheus labels (`name{key="v"}`); families group by the part
/// before the brace for `# TYPE` lines.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl Registry {
    pub fn inc(&mut self, name: &str, v: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn set(&mut self, name: &str, v: u64) {
        self.counters.insert(name.to_string(), v);
    }

    pub fn observe_us(&mut self, name: &str, us: u64) {
        self.hists.entry(name.to_string()).or_default().observe_us(us);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Absorb a finished run's stats structs — the unified form of the
    /// reporting path that used to live in five structs and one
    /// `print_result`.
    pub fn absorb_result(&mut self, r: &RunResult) {
        self.set("feedsign_rounds_total", r.rounds);
        self.set("feedsign_uplink_bits_total", r.ledger.uplink_bits);
        self.set("feedsign_downlink_bits_total", r.ledger.downlink_bits);
        self.set("feedsign_uplink_msgs_total", r.ledger.uplink_msgs);
        self.set("feedsign_downlink_msgs_total", r.ledger.downlink_msgs);
        self.set("feedsign_wall_ms", (r.wall_s * 1e3) as u64);
        // net impairment
        self.set("feedsign_net_flipped_bits_total", r.net.flipped_bits);
        self.set("feedsign_net_dropped_msgs_total", r.net.dropped_msgs);
        self.set("feedsign_net_stragglers_total", r.net.stragglers);
        self.set("feedsign_net_virtual_ms", (r.net.virtual_s * 1e3) as u64);
        // replica plane
        self.set("feedsign_replica_canonical_commits_total", r.replica.canonical_commits);
        self.set("feedsign_replica_snapshots_total", r.replica.snapshots);
        self.set("feedsign_replica_snapshots_declined_total", r.replica.snapshots_declined);
        self.set("feedsign_replica_peak_bytes", r.replica.peak_bytes as u64);
        self.set("feedsign_replica_owned_clients", r.replica.owned_clients as u64);
        // tiered canonical store (all zeros when spill mode is off)
        self.set("feedsign_tile_resident_bytes", r.replica.tile.resident_bytes as u64);
        self.set("feedsign_tile_peak_resident_bytes", r.replica.tile.peak_resident_bytes as u64);
        self.set("feedsign_tile_spills_total", r.replica.tile.spills);
        self.set("feedsign_tile_fetches_total", r.replica.tile.fetches);
        // probe batching
        self.set("feedsign_probe_probes_total", r.probe.probes);
        self.set("feedsign_probe_canonical_passes_total", r.probe.canonical_passes);
        self.set("feedsign_probe_staged_total", r.probe.staged_probes);
        self.set("feedsign_probe_passes_saved_total", r.probe.passes_saved());
        // sharded plane
        self.set("feedsign_shards", r.shard.shards as u64);
        self.set("feedsign_shard_merges_total", r.shard.merges);
        self.set("feedsign_shard_merge_bits_total", r.shard.merge_bits);
        self.set("feedsign_shard_rounds_overlapped_total", r.shard.rounds_overlapped);
    }

    /// Derive duration histograms and straggler-attribution rollups from
    /// a recorded trace.
    pub fn absorb_events(&mut self, events: &[Event]) {
        for ev in events {
            match ev.phase {
                Phase::Execute => {
                    self.observe_us("feedsign_execute_duration_us", ev.dur_us);
                }
                Phase::ProbeBatch => {
                    self.observe_us("feedsign_probe_batch_duration_us", ev.dur_us);
                }
                Phase::TileSweep => {
                    self.observe_us("feedsign_tile_sweep_duration_us", ev.dur_us);
                }
                Phase::Eval => {
                    self.observe_us("feedsign_eval_duration_us", ev.dur_us);
                }
                Phase::RoundGate => {
                    self.inc(&format!("feedsign_round_gated_total{{shard=\"{}\"}}", ev.shard), 1);
                }
                Phase::Overlap => {
                    self.inc("feedsign_overlap_rounds_total", 1);
                    self.inc("feedsign_overlap_saved_us_total", ev.n1);
                }
                Phase::LinkGate => {
                    self.inc(
                        &format!(
                            "feedsign_round_gated_by_link_total{{class=\"{}\"}}",
                            crate::net::LINK_CLASS_NAMES
                                .get(ev.n1 as usize)
                                .copied()
                                .unwrap_or("unknown")
                        ),
                        1,
                    );
                    self.observe_us("feedsign_net_round_virtual_us", ev.n2);
                }
                _ => {}
            }
        }
    }

    /// Prometheus text exposition (one `# TYPE` per family; histograms
    /// render cumulative `_bucket` series plus `_sum` / `_count`).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last_family = String::new();
        for (name, v) in &self.counters {
            let family = name.split('{').next().unwrap_or(name);
            if family != last_family {
                let _ = writeln!(out, "# TYPE {family} counter");
                last_family = family.to_string();
            }
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, h) in &self.hists {
            let _ = writeln!(out, "# TYPE {name} histogram");
            let mut cum = 0u64;
            for (i, &b) in BUCKETS_US.iter().enumerate() {
                cum += h.counts[i];
                let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
            }
            cum += h.overflow;
            let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            let _ = writeln!(out, "{name}_sum {}", h.sum_us);
            let _ = writeln!(out, "{name}_count {}", h.total);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Event;

    #[test]
    fn counters_accumulate_and_expose() {
        let mut r = Registry::default();
        r.inc("feedsign_probe_probes_total", 2);
        r.inc("feedsign_probe_probes_total", 3);
        assert_eq!(r.counter("feedsign_probe_probes_total"), 5);
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE feedsign_probe_probes_total counter"));
        assert!(text.contains("feedsign_probe_probes_total 5"));
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let mut r = Registry::default();
        r.observe_us("x_us", 10); // <= 64
        r.observe_us("x_us", 1000); // <= 1024
        r.observe_us("x_us", u64::MAX / 2); // overflow
        let text = r.to_prometheus();
        assert!(text.contains("x_us_bucket{le=\"64\"} 1"));
        assert!(text.contains("x_us_bucket{le=\"1024\"} 2"));
        assert!(text.contains("x_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("x_us_count 3"));
    }

    #[test]
    fn labeled_counters_share_one_family_type_line() {
        let mut r = Registry::default();
        r.inc("g_total{shard=\"0\"}", 1);
        r.inc("g_total{shard=\"1\"}", 2);
        let text = r.to_prometheus();
        assert_eq!(text.matches("# TYPE g_total counter").count(), 1);
        assert!(text.contains("g_total{shard=\"1\"} 2"));
    }

    #[test]
    fn tile_sweep_spans_feed_their_own_histogram() {
        let mut r = Registry::default();
        let mut sweep = Event::logical(Phase::TileSweep, 3, -1, -1, 3, 4096);
        sweep.dur_us = 120;
        r.absorb_events(&[sweep]);
        let text = r.to_prometheus();
        assert!(text.contains("feedsign_tile_sweep_duration_us_count 1"));
    }

    #[test]
    fn event_rollups_attribute_gating() {
        let mut r = Registry::default();
        let mut gate = Event::logical(Phase::RoundGate, 0, 2, -1, 0, 0);
        gate.dur_us = 500;
        let link = Event::logical(Phase::LinkGate, 0, -1, 3, 2, 900);
        r.absorb_events(&[gate, link]);
        let text = r.to_prometheus();
        assert!(text.contains("feedsign_round_gated_total{shard=\"2\"} 1"));
        assert!(text.contains("feedsign_round_gated_by_link_total{class=\"iot\"} 1"));
        assert!(text.contains("feedsign_net_round_virtual_us_count 1"));
    }
}
