//! Trace exporters: Chrome `trace_event` JSON and JSONL.
//!
//! Built on the repo's offline [`crate::util::json::Json`] writer, so
//! the output is valid by construction (the tests parse it back).

use super::trace::{Event, Phase};
use crate::util::json::Json;
use std::collections::BTreeMap;

fn event_args(ev: &Event) -> Json {
    let mut args = BTreeMap::new();
    args.insert("round".to_string(), Json::Num(ev.round as f64));
    args.insert("shard".to_string(), Json::Num(ev.shard as f64));
    args.insert("client".to_string(), Json::Num(ev.client as f64));
    args.insert("n1".to_string(), Json::Num(ev.n1 as f64));
    args.insert("n2".to_string(), Json::Num(ev.n2 as f64));
    Json::Obj(args)
}

fn event_name(ev: &Event) -> String {
    match ev.phase {
        Phase::RoundGate => format!("round_gate shard={}", ev.shard),
        Phase::LinkGate => format!(
            "link_gate class={}",
            crate::net::LINK_CLASS_NAMES.get(ev.n1 as usize).copied().unwrap_or("unknown")
        ),
        p => p.name().to_string(),
    }
}

/// Chrome `trace_event` JSON (the object form: `{"traceEvents": [...]}`;
/// load via `chrome://tracing` or Perfetto).  Complete events
/// (`"ph": "X"`), timestamps in microseconds since the trace epoch.
/// Tracks: `pid` 0, `tid` = shard + 1 (0 = coordinator-level events).
pub fn chrome_trace(events: &[Event]) -> String {
    let rows: Vec<Json> = events
        .iter()
        .map(|ev| {
            let mut row = BTreeMap::new();
            row.insert("name".to_string(), Json::Str(event_name(ev)));
            row.insert("cat".to_string(), Json::Str("feedsign".to_string()));
            row.insert("ph".to_string(), Json::Str("X".to_string()));
            row.insert("ts".to_string(), Json::Num(ev.start_us as f64));
            row.insert("dur".to_string(), Json::Num(ev.dur_us.max(1) as f64));
            row.insert("pid".to_string(), Json::Num(0.0));
            row.insert("tid".to_string(), Json::Num((ev.shard + 1) as f64));
            row.insert("args".to_string(), event_args(ev));
            Json::Obj(row)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(rows));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(top).to_string_compact()
}

/// JSONL: one compact object per event, in recording order — the
/// tooling-friendly form (`--trace-out trace.jsonl`).
pub fn jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for ev in events {
        let mut row = BTreeMap::new();
        row.insert("phase".to_string(), Json::Str(ev.phase.name().to_string()));
        row.insert("round".to_string(), Json::Num(ev.round as f64));
        row.insert("shard".to_string(), Json::Num(ev.shard as f64));
        row.insert("client".to_string(), Json::Num(ev.client as f64));
        row.insert("n1".to_string(), Json::Num(ev.n1 as f64));
        row.insert("n2".to_string(), Json::Num(ev.n2 as f64));
        row.insert("ts_us".to_string(), Json::Num(ev.start_us as f64));
        row.insert("dur_us".to_string(), Json::Num(ev.dur_us as f64));
        out.push_str(&Json::Obj(row).to_string_compact());
        out.push('\n');
    }
    out
}

/// Write a trace to `path`; a `.jsonl` extension selects JSONL,
/// anything else the Chrome `trace_event` form.
pub fn write_trace(path: &std::path::Path, events: &[Event]) -> std::io::Result<()> {
    let text = if path.extension().is_some_and(|e| e == "jsonl") {
        jsonl(events)
    } else {
        chrome_trace(events)
    };
    std::fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        let mut gate = Event::logical(Phase::RoundGate, 1, 2, -1, 0, 0);
        gate.dur_us = 1234;
        vec![
            Event::logical(Phase::Plan, 0, -1, -1, 4, 0),
            Event::logical(Phase::Commit, 0, -1, 3, 1, 0),
            gate,
        ]
    }

    #[test]
    fn chrome_trace_is_valid_json_with_named_gate() {
        let text = chrome_trace(&sample());
        let v = Json::parse(&text).expect("chrome trace must parse");
        let rows = v.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        assert_eq!(rows.len(), 3);
        let gate = rows
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some("round_gate shard=2"))
            .expect("gating shard named");
        assert_eq!(gate.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(gate.get("args").and_then(|a| a.get("shard")).and_then(Json::as_f64), Some(2.0));
        assert!(gate.get("dur").and_then(Json::as_f64).unwrap() >= 1.0);
    }

    #[test]
    fn jsonl_emits_one_parseable_object_per_event() {
        let text = jsonl(&sample());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            let v = Json::parse(line).expect("each line parses");
            assert!(v.get("phase").is_some());
        }
    }

    #[test]
    fn write_trace_picks_format_by_extension() {
        let dir = std::env::temp_dir();
        let p1 = dir.join("feedsign_obs_test_trace.json");
        let p2 = dir.join("feedsign_obs_test_trace.jsonl");
        write_trace(&p1, &sample()).unwrap();
        write_trace(&p2, &sample()).unwrap();
        let a = std::fs::read_to_string(&p1).unwrap();
        let b = std::fs::read_to_string(&p2).unwrap();
        assert!(a.starts_with('{'));
        assert_eq!(b.lines().count(), 3);
        let _ = std::fs::remove_file(p1);
        let _ = std::fs::remove_file(p2);
    }
}
