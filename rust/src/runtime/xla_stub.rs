//! Compile-time stand-in for the `xla` PJRT bindings.
//!
//! The offline build environment cannot fetch the real `xla` crate, so this
//! module mirrors exactly the API surface `runtime::mod` consumes and fails
//! at *runtime* (from [`PjRtClient::cpu`] / [`HloModuleProto::from_text_file`]
//! onward) with a descriptive error.  Everything downstream of those entry
//! points only needs to typecheck: the PJRT request path is exercised solely
//! when AOT artifacts exist, and all tests/benches gate on
//! [`crate::runtime::artifacts_available`] first.  Swapping this module for
//! the real crate restores the hardware path without touching callers.

use std::fmt;

/// Error type standing in for `xla::Error`; converts into `anyhow::Error`
/// through the std-error blanket impl.
#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what}: XLA/PJRT backend not linked in this build (offline vendored stub); \
         build against the real `xla` crate to execute AOT artifacts"
    )))
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn platform_name(&self) -> String {
        "stub (no PJRT)".to_string()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

impl From<i32> for Literal {
    fn from(_v: i32) -> Self {
        Literal
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Self {
        Literal
    }
}
