//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` + the
//! manifest) and executes them on the XLA CPU client — the production path
//! of the three-layer architecture.  Python is never invoked here.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md for the 64-bit-proto-id gotcha).  Every
//! graph is compiled exactly once per process ([`PjrtModel`] caches the
//! loaded executables) and reused across all federated rounds.

pub mod manifest;

/// PJRT bindings: the offline build links a vendored stub that mirrors the
/// `xla` crate's API and errors at runtime; swap this declaration for the
/// real crate to run on hardware (see `xla_stub.rs`).
#[path = "xla_stub.rs"]
mod xla;

use crate::data::Batch;
use crate::engine::Engine;
use anyhow::{bail, Context, Result};
use manifest::{Manifest, ModelEntry};
use std::path::{Path, PathBuf};

/// A loaded model variant: every step graph compiled and ready.
pub struct PjrtModel {
    pub entry: ModelEntry,
    client: xla::PjRtClient,
    exe_probe: xla::PjRtLoadedExecutable,
    exe_update: xla::PjRtLoadedExecutable,
    exe_loss: xla::PjRtLoadedExecutable,
    exe_eval: xla::PjRtLoadedExecutable,
    exe_fo: xla::PjRtLoadedExecutable,
    exe_grad_proj: xla::PjRtLoadedExecutable,
    exe_zvec: xla::PjRtLoadedExecutable,
}

fn compile(client: &xla::PjRtClient, dir: &Path, file: &str) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(file);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("artifact path not utf-8")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

impl PjrtModel {
    /// Load one variant from an artifacts directory.
    pub fn load(dir: &Path, variant: &str) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let Some(entry) = manifest.models.get(variant) else {
            bail!(
                "variant {variant:?} not in manifest (have: {:?})",
                manifest.models.keys().collect::<Vec<_>>()
            );
        };
        let entry = entry.clone();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let art = |k: &str| -> Result<xla::PjRtLoadedExecutable> {
            let f = entry
                .artifacts
                .get(k)
                .with_context(|| format!("manifest missing artifact {k}"))?;
            compile(&client, dir, f)
        };
        Ok(PjrtModel {
            exe_probe: art("spsa_probe")?,
            exe_update: art("update")?,
            exe_loss: art("loss")?,
            exe_eval: art("eval")?,
            exe_fo: art("fo_step")?,
            exe_grad_proj: art("grad_proj")?,
            exe_zvec: art("zvec")?,
            entry,
            client,
        })
    }

    pub fn n_params(&self) -> usize {
        self.entry.padded_size
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn w_literal(&self, w: &[f32]) -> Result<xla::Literal> {
        if w.len() != self.entry.padded_size {
            bail!("parameter length {} != padded size {}", w.len(), self.entry.padded_size);
        }
        Ok(xla::Literal::vec1(w))
    }

    fn batch_literal(&self, batch: &Batch, expect_rows: usize) -> Result<xla::Literal> {
        let Batch::Tokens { data, rows, cols } = batch else {
            bail!("PJRT engine expects token batches");
        };
        if *rows != expect_rows || *cols != self.entry.seq_len + 1 {
            bail!(
                "batch shape ({rows}, {cols}) != expected ({expect_rows}, {})",
                self.entry.seq_len + 1
            );
        }
        let ints: Vec<i32> = data.iter().map(|&t| t as i32).collect();
        Ok(xla::Literal::vec1(&ints).reshape(&[*rows as i64, *cols as i64])?)
    }

    /// SPSA projection through the AOT graph.
    pub fn spsa_probe(&self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> Result<f32> {
        let args = [
            self.w_literal(w)?,
            self.batch_literal(batch, self.entry.batch_probe)?,
            xla::Literal::from(seed as i32),
            xla::Literal::from(mu),
        ];
        let out = self.exe_probe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = out.to_tuple1()?;
        Ok(tuple.to_vec::<f32>()?[0])
    }

    /// `w' = w - step * z(seed)` through the AOT graph.
    pub fn update(&self, w: &mut [f32], seed: u32, step: f32) -> Result<()> {
        let args = [
            self.w_literal(w)?,
            xla::Literal::from(seed as i32),
            xla::Literal::from(step),
        ];
        let out = self.exe_update.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let tuple = out.to_tuple1()?;
        let new_w = tuple.to_vec::<f32>()?;
        w.copy_from_slice(&new_w);
        Ok(())
    }

    /// Mean loss on an eval-shaped batch.
    pub fn loss(&self, w: &[f32], batch: &Batch) -> Result<f32> {
        let args = [self.w_literal(w)?, self.batch_literal(batch, self.entry.batch_eval)?];
        let out = self.exe_loss.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?[0])
    }

    /// `(mean loss, #correct-last-position)` on an eval-shaped batch.
    pub fn eval(&self, w: &[f32], batch: &Batch) -> Result<(f32, u32)> {
        let args = [self.w_literal(w)?, self.batch_literal(batch, self.entry.batch_eval)?];
        let mut out = self.exe_eval.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut parts = out.decompose_tuple()?;
        if parts.len() != 2 {
            bail!("eval graph returned {} outputs, expected 2", parts.len());
        }
        let correct = parts.pop().unwrap().to_vec::<i32>()?[0] as u32;
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0];
        Ok((loss, correct))
    }

    /// First-order step; returns loss.
    pub fn fo_step(&self, w: &mut [f32], batch: &Batch, lr: f32) -> Result<f32> {
        let args = [
            self.w_literal(w)?,
            self.batch_literal(batch, self.entry.batch_probe)?,
            xla::Literal::from(lr),
        ];
        let mut out = self.exe_fo.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let mut parts = out.decompose_tuple()?;
        if parts.len() != 2 {
            bail!("fo_step graph returned {} outputs, expected 2", parts.len());
        }
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0];
        let new_w = parts.pop().unwrap().to_vec::<f32>()?;
        w.copy_from_slice(&new_w);
        Ok(loss)
    }

    /// Exact directional derivative `z(seed) . grad L` (Appendix E study).
    pub fn grad_proj(&self, w: &[f32], batch: &Batch, seed: u32) -> Result<f32> {
        let args = [
            self.w_literal(w)?,
            self.batch_literal(batch, self.entry.batch_probe)?,
            xla::Literal::from(seed as i32),
        ];
        let out = self.exe_grad_proj.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?[0])
    }

    /// The raw direction z(seed) — parity testing against simkit's PRNG.
    pub fn zvec(&self, seed: u32) -> Result<Vec<f32>> {
        let args = [xla::Literal::from(seed as i32)];
        let out = self.exe_zvec.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        Ok(out.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Initial parameters from the manifest's segment layout (same
    /// construction as `compile.model.init_params`).
    pub fn init_params(&self, seed: u32) -> Vec<f32> {
        let segs: Vec<(String, Vec<usize>, f32)> = self
            .entry
            .segments
            .iter()
            .map(|s| (s.name.clone(), s.shape.clone(), s.init_std))
            .collect();
        crate::simkit::prng::init_flat_params(&segs, self.entry.padded_size, seed)
    }
}

/// [`Engine`] adapter over a shared loaded model (one compile, many
/// clients).  `Engine` carries a `Send` supertrait (the parallel round
/// engine fans client probes out over scoped threads), so the shared
/// model is held behind an `Arc`.  With the vendored stub this is
/// trivially sound (stateless placeholder types).  **Re-enabling the real
/// `xla` crate needs more than a swap here**: K clients share one
/// `PjrtModel`, so a `threads > 1` session would drive the same
/// loaded-executable handles from several workers at once — wrap the
/// model in a `Mutex`, give each client its own executables, or pin
/// PJRT-backed sessions to `threads = 1` before doing so.
pub struct SharedPjrtEngine {
    model: std::sync::Arc<PjrtModel>,
}

impl SharedPjrtEngine {
    pub fn new(model: std::sync::Arc<PjrtModel>) -> Self {
        SharedPjrtEngine { model }
    }

    /// Load a variant and wrap it for K clients.
    pub fn load_shared(dir: &Path, variant: &str) -> Result<std::sync::Arc<PjrtModel>> {
        Ok(std::sync::Arc::new(PjrtModel::load(dir, variant)?))
    }
}

impl Engine for SharedPjrtEngine {
    fn n_params(&self) -> usize {
        self.model.n_params()
    }

    fn probe(&mut self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> f32 {
        self.model.spsa_probe(w, batch, seed, mu).expect("pjrt probe")
    }

    fn update(&mut self, w: &mut [f32], seed: u32, step: f32) {
        self.model.update(w, seed, step).expect("pjrt update")
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32) {
        self.model.eval(w, batch).expect("pjrt eval")
    }

    fn fo_step(&mut self, w: &mut [f32], batch: &Batch, lr: f32) -> f32 {
        self.model.fo_step(w, batch, lr).expect("pjrt fo_step")
    }

    fn grad(&mut self, _w: &[f32], _batch: &Batch, _out: &mut [f32]) -> f32 {
        unimplemented!("dense gradient exchange is a native-engine baseline")
    }

    fn init_params(&self, seed: u32) -> Vec<f32> {
        self.model.init_params(seed)
    }
}

/// Default artifacts directory: `$FEEDSIGN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("FEEDSIGN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if the artifacts (manifest) are present — tests skip PJRT paths
/// otherwise.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
