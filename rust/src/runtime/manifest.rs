//! `artifacts/manifest.json` — the contract between the python compile
//! path and the rust runtime: model shapes, segment layout + init stds,
//! artifact file names, and the Philox test vectors that pin rust's PRNG
//! to the Pallas kernel.  Parsed with the in-tree JSON parser
//! ([`crate::util::json`]).

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub philox: PhiloxVectors,
    pub models: BTreeMap<String, ModelEntry>,
}

#[derive(Debug, Clone)]
pub struct PhiloxVectors {
    pub key1_init: u32,
    pub rounds: u32,
    pub vectors: Vec<PhiloxVector>,
}

#[derive(Debug, Clone)]
pub struct PhiloxVector {
    pub seed: u32,
    pub counters: Vec<u32>,
    /// words[lane][counter] for the 4 output lanes
    pub words: Vec<Vec<u32>>,
    pub normals: Vec<f32>,
}

#[derive(Debug, Clone)]
pub struct SegmentEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub init_std: f32,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub batch_probe: usize,
    pub batch_eval: usize,
    pub n_params: usize,
    pub padded_size: usize,
    pub segments: Vec<SegmentEntry>,
    pub artifacts: BTreeMap<String, String>,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest: missing numeric {key}"))
}

impl Manifest {
    pub fn from_json(v: &Json) -> Result<Self> {
        let ph = v.get("philox").context("manifest: missing philox")?;
        let vectors = ph
            .get("vectors")
            .and_then(Json::as_arr)
            .context("philox.vectors")?
            .iter()
            .map(|pv| -> Result<PhiloxVector> {
                let list_u32 = |key: &str| -> Result<Vec<u32>> {
                    Ok(pv
                        .get(key)
                        .and_then(Json::as_arr)
                        .with_context(|| format!("philox vector {key}"))?
                        .iter()
                        .filter_map(Json::as_u32)
                        .collect())
                };
                let words = pv
                    .get("words")
                    .and_then(Json::as_arr)
                    .context("words")?
                    .iter()
                    .map(|lane| {
                        lane.as_arr()
                            .map(|a| a.iter().filter_map(Json::as_u32).collect::<Vec<_>>())
                            .context("word lane")
                    })
                    .collect::<Result<Vec<_>>>()?;
                let normals = pv
                    .get("normals")
                    .and_then(Json::as_arr)
                    .context("normals")?
                    .iter()
                    .filter_map(|n| n.as_f64().map(|f| f as f32))
                    .collect();
                Ok(PhiloxVector {
                    seed: pv.get("seed").and_then(Json::as_u32).context("seed")?,
                    counters: list_u32("counters")?,
                    words,
                    normals,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let philox = PhiloxVectors {
            key1_init: ph.get("key1_init").and_then(Json::as_u32).context("key1_init")?,
            rounds: ph.get("rounds").and_then(Json::as_u32).context("rounds")?,
            vectors,
        };

        let mut models = BTreeMap::new();
        for (name, m) in v
            .get("models")
            .and_then(Json::as_obj)
            .context("manifest: missing models")?
        {
            let segments = m
                .get("segments")
                .and_then(Json::as_arr)
                .context("segments")?
                .iter()
                .map(|s| -> Result<SegmentEntry> {
                    Ok(SegmentEntry {
                        name: s.get("name").and_then(Json::as_str).context("segment name")?.to_string(),
                        shape: s
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("segment shape")?
                            .iter()
                            .filter_map(Json::as_usize)
                            .collect(),
                        init_std: s
                            .get("init_std")
                            .and_then(Json::as_f64)
                            .context("segment init_std")? as f32,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let artifacts = m
                .get("artifacts")
                .and_then(Json::as_obj)
                .context("artifacts")?
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            models.insert(
                name.clone(),
                ModelEntry {
                    vocab: req_usize(m, "vocab")?,
                    d_model: req_usize(m, "d_model")?,
                    n_layers: req_usize(m, "n_layers")?,
                    n_heads: req_usize(m, "n_heads")?,
                    seq_len: req_usize(m, "seq_len")?,
                    batch_probe: req_usize(m, "batch_probe")?,
                    batch_eval: req_usize(m, "batch_eval")?,
                    n_params: req_usize(m, "n_params")?,
                    padded_size: req_usize(m, "padded_size")?,
                    segments,
                    artifacts,
                },
            );
        }
        Ok(Manifest { philox, models })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&v)
    }

    /// Verify rust's Philox implementation reproduces the kernel's recorded
    /// vectors (u32 words bit-exactly, normals to 1e-5).  Returns the max
    /// normal deviation.
    pub fn verify_philox(&self) -> Result<f32> {
        use crate::simkit::prng;
        anyhow::ensure!(self.philox.key1_init == prng::KEY1_INIT, "KEY1_INIT mismatch");
        anyhow::ensure!(self.philox.rounds == 10, "round count mismatch");
        let mut max_dev = 0.0f32;
        for v in &self.philox.vectors {
            for (ci, &ctr) in v.counters.iter().enumerate() {
                let words = prng::philox4x32(v.seed, ctr);
                for lane in 0..4 {
                    anyhow::ensure!(
                        words[lane] == v.words[lane][ci],
                        "philox word mismatch at seed {} ctr {ctr} lane {lane}: {} != {}",
                        v.seed,
                        words[lane],
                        v.words[lane][ci]
                    );
                }
            }
            let normals = prng::normals_vec(v.seed, v.normals.len());
            for (a, b) in normals.iter().zip(&v.normals) {
                max_dev = max_dev.max((a - b).abs());
            }
            anyhow::ensure!(max_dev < 1e-5, "normals deviate by {max_dev}");
        }
        Ok(max_dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    #[test]
    fn parse_inline_manifest() {
        let text = r#"{
          "philox": {"key1_init": 3405705229, "rounds": 10, "vectors": []},
          "models": {
            "t": {"vocab": 8, "d_model": 4, "n_layers": 1, "n_heads": 2,
                   "seq_len": 4, "batch_probe": 2, "batch_eval": 4,
                   "n_params": 100, "padded_size": 1024,
                   "segments": [{"name": "embed", "shape": [8, 4], "init_std": 0.02}],
                   "artifacts": {"loss": "t_loss.hlo.txt"}}
          }
        }"#;
        let m = Manifest::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(m.models["t"].padded_size, 1024);
        assert_eq!(m.models["t"].segments[0].shape, vec![8, 4]);
        assert_eq!(m.philox.key1_init, 3_405_705_229);
    }

    #[test]
    fn real_manifest_philox_parity() {
        if !artifacts_available() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
        let dev = m.verify_philox().unwrap();
        assert!(dev < 1e-5, "kernel/rust PRNG deviation {dev}");
    }

    #[test]
    fn real_manifest_segments_match_simkit_layout() {
        if !artifacts_available() {
            crate::log_warn!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir().join("manifest.json")).unwrap();
        assert!(!m.models.is_empty());
        for (name, entry) in &m.models {
            let cfg = crate::simkit::nn::ModelCfg::new(
                entry.vocab,
                entry.d_model,
                entry.n_layers,
                entry.n_heads,
                entry.seq_len,
            );
            assert_eq!(cfg.n_params(), entry.n_params, "variant {name}");
            assert_eq!(cfg.padded_size(), entry.padded_size, "variant {name}");
            let segs = cfg.segments();
            assert_eq!(segs.len(), entry.segments.len(), "variant {name}");
            for (a, b) in segs.iter().zip(&entry.segments) {
                assert_eq!(a.0, b.name);
                assert_eq!(a.1, b.shape);
                assert!((a.2 - b.init_std).abs() < 1e-9);
            }
        }
    }
}
