//! The client compute abstraction: everything a federated client does to
//! its local model, behind one trait so the coordinator is agnostic to
//! whether the math runs through AOT-compiled XLA artifacts
//! ([`crate::runtime::SharedPjrtEngine`]) or the native substrate
//! ([`NativeEngine`]).

use crate::data::Batch;
use crate::simkit::nn::Model;
use crate::simkit::zo;

/// Client-side compute: SPSA probe, shared-direction update, eval and the
/// first-order baseline.  `w` is the client's own flat parameter vector —
/// the engine holds no model state (the paper's PS/parameter-privacy story
/// depends on parameters living only with clients).
///
/// `Send` is a supertrait: the parallel round engine
/// ([`crate::coordinator::session::Session`]) fans per-client probe work
/// out over scoped threads, each worker owning its clients' engines
/// exclusively for the duration of the round.
pub trait Engine: Send {
    /// Length of the flat (padded) parameter vector.
    fn n_params(&self) -> usize;

    /// SPSA projection `p = (L(w+mu z) - L(w-mu z)) / 2mu` for direction
    /// `z(seed)`.  Takes `w` by shared reference — the probe contract has
    /// always been "replica unchanged on return"; the signature now
    /// enforces it (perturbed views are regenerated into engine scratch).
    fn probe(&mut self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> f32;

    /// Mean loss of `w` on `batch` — the half-probe primitive
    /// [`probe_batch`] composes into batched SPSA projections.  Only
    /// engines that opt into batched probing
    /// ([`Engine::supports_batched_probe`]) need it; others keep the
    /// unreachable default and are probed one call at a time.
    fn loss(&mut self, _w: &[f32], _batch: &Batch) -> f32 {
        unreachable!("engine does not support batched probing (supports_batched_probe = false)")
    }

    /// Whether [`probe_batch`] may decompose this engine's probe into
    /// two [`Engine::loss`] calls over externally-materialised views.
    /// Requires `loss` to be pure in `(w, batch)` (no carried state), so
    /// evaluating several clients' `+mu` views before their `-mu` views
    /// is observationally identical to the per-client call order.
    fn supports_batched_probe(&self) -> bool {
        false
    }

    /// Apply the aggregated update `w -= step * z(seed)`.  Must be a
    /// pure function of `(w, seed, step)`: the coordinator's replica
    /// plane relies on one canonical apply being bit-identical to the K
    /// per-client applies a dense layout would perform
    /// ([`crate::coordinator::replica`]).  Implementations should also
    /// match the native replay primitive
    /// ([`crate::simkit::zo::apply_update`]) bit-for-bit — orbit replay,
    /// seed-history catch-up and the replica plane's cold stale-read
    /// reconstruction are all defined in terms of it (the PJRT kernel is
    /// currently pinned only to 1e-6; see
    /// `Session::replica` for the operational consequence).
    fn update(&mut self, w: &mut [f32], seed: u32, step: f32);

    /// Whether [`Engine::update`] IS [`crate::simkit::zo::apply_update`]
    /// bit-for-bit — the gate on the fused commit+probe sweep: the
    /// session may then route commits through
    /// [`crate::simkit::zo::fused_commit_probe`] (tiled, with the next
    /// round's ±mu views staged in the same pass) instead of this
    /// method, without changing a single parameter bit.  Engines whose
    /// update kernel is only *approximately* the native one (the PJRT
    /// path is pinned to 1e-6, not bitwise) must keep the `false`
    /// default and take the classic one-pass-per-view commit.
    fn fused_commit_exact(&self) -> bool {
        false
    }

    /// `(mean loss, #correct)` on an eval batch.  Takes `w` by shared
    /// reference — evaluation never mutates the replica, and with the
    /// copy-on-write replica plane many clients evaluate against the
    /// *same* canonical buffer.
    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32);

    /// First-order step `w -= lr * grad`; returns the pre-step loss.
    /// Powers the FedSGD baseline and pretraining.
    fn fo_step(&mut self, w: &mut [f32], batch: &Batch, lr: f32) -> f32;

    /// Full gradient (for FedSGD's gradient *exchange*); returns loss.
    /// Like [`Engine::probe`], read-only in `w` — FedSGD clients compute
    /// their local gradients against the shared canonical buffer.
    fn grad(&mut self, w: &[f32], batch: &Batch, out: &mut [f32]) -> f32;

    /// Fresh initial parameter vector (same across all clients/engines for
    /// a given seed — everyone starts from the shared checkpoint).
    fn init_params(&self, seed: u32) -> Vec<f32>;
}

/// Native-substrate engine: wraps any [`Model`] with the in-place SPSA
/// walker.  Probe memory overhead is O(1) over inference — the measured
/// basis of the Table 10 reproduction.
pub struct NativeEngine<M: Model> {
    pub model: M,
    grad_buf: Vec<f32>,
    probe_buf: Vec<f32>,
}

impl<M: Model> NativeEngine<M> {
    pub fn new(model: M) -> Self {
        NativeEngine { model, grad_buf: Vec::new(), probe_buf: Vec::new() }
    }

    /// Bytes of scratch the engine holds beyond the parameter vector —
    /// instrumentation for the Table 10 memory comparison (the FO path's
    /// dense gradient buffer dominates; the ZO path holds one perturbed
    /// view).
    pub fn scratch_bytes(&self) -> usize {
        (self.grad_buf.capacity() + self.probe_buf.capacity()) * std::mem::size_of::<f32>()
    }
}

impl<M: Model> Engine for NativeEngine<M> {
    fn n_params(&self) -> usize {
        self.model.n_params()
    }

    fn probe(&mut self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> f32 {
        let mut scratch = std::mem::take(&mut self.probe_buf);
        let p = zo::spsa_probe_scratch(&mut self.model, w, &mut scratch, batch, seed, mu);
        self.probe_buf = scratch;
        p
    }

    fn loss(&mut self, w: &[f32], batch: &Batch) -> f32 {
        self.model.loss(w, batch)
    }

    fn supports_batched_probe(&self) -> bool {
        // Model::loss is a pure forward pass — reordering view
        // evaluations cannot change any client's projection bits
        true
    }

    fn update(&mut self, w: &mut [f32], seed: u32, step: f32) {
        zo::apply_update(w, seed, step);
    }

    fn fused_commit_exact(&self) -> bool {
        // update IS zo::apply_update — fusing it into the tiled sweep
        // is the same per-element float expression in the same order
        true
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32) {
        self.model.eval(w, batch)
    }

    fn fo_step(&mut self, w: &mut [f32], batch: &Batch, lr: f32) -> f32 {
        let n = w.len();
        self.grad_buf.resize(n, 0.0);
        let mut grad = std::mem::take(&mut self.grad_buf);
        let loss = self.model.loss_and_grad(w, batch, &mut grad);
        for (wi, gi) in w.iter_mut().zip(&grad) {
            *wi -= lr * gi;
        }
        self.grad_buf = grad;
        loss
    }

    fn grad(&mut self, w: &[f32], batch: &Batch, out: &mut [f32]) -> f32 {
        self.model.loss_and_grad(w, batch, out)
    }

    fn init_params(&self, seed: u32) -> Vec<f32> {
        self.model.init(seed)
    }
}

// ---------------------------------------------------------------------------
// Per-worker probe batching
// ---------------------------------------------------------------------------

/// Most perturbed views one [`probe_batch`] pass materialises at once
/// (scratch is `MAX_GROUP_VIEWS · d` floats; each client costs two views,
/// so up to `MAX_GROUP_VIEWS / 2` distinct seeds share a canonical pass).
pub const MAX_GROUP_VIEWS: usize = 8;

/// One client's probe request inside a [`probe_batch`] call: its engine,
/// its local batch, its direction seed.  The shared `(w, mu)` live on
/// the call itself.
pub struct ProbeJob<'a> {
    pub engine: &'a mut dyn Engine,
    pub batch: &'a Batch,
    pub seed: u32,
}

/// Counters for the probe execute phase — the measured basis of the
/// "canonical buffer read once per worker" claim.  A *canonical pass* is
/// one full streaming read of the shared parameter buffer; the classic
/// per-client probe costs two (one fused AXPY per perturbed view), which
/// [`ProbeBatchStats::unbatched_passes`] reports for comparison.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ProbeBatchStats {
    /// Client probes served.
    pub probes: u64,
    /// Streaming passes over the canonical buffer actually performed.
    pub canonical_passes: u64,
    /// Probes served through [`Engine::probe`] because the engine opted
    /// out of batching (each costs two canonical passes).
    pub fallback_probes: u64,
    /// Probes served from views staged by the previous round's fused
    /// commit+probe sweep ([`StagedViews`]) — zero canonical passes at
    /// probe time; the sweep already paid them inside the commit pass.
    pub staged_probes: u64,
}

impl ProbeBatchStats {
    /// Accumulate another worker's counters.
    pub fn merge(&mut self, other: &ProbeBatchStats) {
        self.probes += other.probes;
        self.canonical_passes += other.canonical_passes;
        self.fallback_probes += other.fallback_probes;
        self.staged_probes += other.staged_probes;
    }

    /// Canonical passes the unbatched per-client probe would have made.
    pub fn unbatched_passes(&self) -> u64 {
        2 * self.probes
    }

    /// Streaming passes the batcher saved vs the unbatched baseline —
    /// the headline figure `RunResult::to_csv` and the metric registry
    /// report.
    pub fn passes_saved(&self) -> u64 {
        self.unbatched_passes().saturating_sub(self.canonical_passes)
    }
}

/// A `±mu` view pair staged ahead of time by the fused commit+probe
/// sweep ([`crate::simkit::zo::fused_commit_probe`]): at commit of
/// round `t` the sweep materialises `plus = w_head + mu·z(seed)` and
/// `minus = w_head - mu·z(seed)` for round `round = t + 1`'s announced
/// direction in the *same* pass that applies round `t`'s update.  A
/// probe group whose `(seed, mu)` matches is then served from these
/// buffers with **zero** canonical passes
/// ([`probe_batch_staged`]); a mismatch (stale staging after a no-op
/// round, a different direction) falls back to the normal
/// [`zo::axpy_many`] pass — exactly what the unstaged engine pays.
///
/// The buffers carry exactly the bits [`zo::axpy_into`] would produce
/// against the committed canonical (`fused_commit_probe` is pinned to
/// the multi-pass path bitwise), so staged service is bit-identical to
/// unstaged service by construction.
#[derive(Debug, Clone, Default)]
pub struct StagedViews {
    /// The round these views serve (staging round + 1).
    pub round: u64,
    /// The direction they were staged for.
    pub seed: u32,
    /// The probe radius they were staged at.
    pub mu: f32,
    /// `w_head + mu·z(seed)`.
    pub plus: Vec<f32>,
    /// `w_head - mu·z(seed)`.
    pub minus: Vec<f32>,
}

/// Serve a worker's probe jobs against the shared canonical buffer `w`,
/// streaming it **once per view group** instead of twice per client.
///
/// Jobs are grouped by seed: a FeedSign round (every client shares
/// `seed = t`) collapses to one `+mu` and one `-mu` view for the whole
/// worker, materialised in a single [`zo::axpy_many`] pass; ZO-FedSGD's
/// distinct seeds are packed `MAX_GROUP_VIEWS / 2` at a time.  Each
/// client's projection is then two pure [`Engine::loss`] calls on the
/// shared views.  Engines that opt out
/// ([`Engine::supports_batched_probe`]) fall back to [`Engine::probe`].
///
/// **Bit-exactness:** the views carry exactly the bits
/// [`zo::axpy_into`] would produce (`axpy_many` is pinned to it
/// bitwise), `loss` is pure, and per-client RNG state is untouched here
/// — so every projection equals the unbatched `engine.probe` result
/// bit-for-bit, for any grouping (pinned by the tests below and by the
/// four parity suites).
pub fn probe_batch(w: &[f32], mu: f32, jobs: &mut [ProbeJob]) -> (Vec<f32>, ProbeBatchStats) {
    probe_batch_staged(w, mu, jobs, None)
}

/// [`probe_batch`] with an optional [`StagedViews`] pair from the
/// previous round's fused commit sweep: a batchable seed group matching
/// `(staged.seed, mu)` is served straight from the staged buffers (its
/// loss calls see the same bits an [`zo::axpy_many`] pass would have
/// produced; `loss` is pure, so serving it first changes nothing),
/// counting zero canonical passes here.  All other groups, and engines
/// that opted out of batching, take the classic path untouched.
pub fn probe_batch_staged(
    w: &[f32],
    mu: f32,
    jobs: &mut [ProbeJob],
    staged: Option<&StagedViews>,
) -> (Vec<f32>, ProbeBatchStats) {
    let mut stats = ProbeBatchStats { probes: jobs.len() as u64, ..Default::default() };
    let mut out = vec![0.0f32; jobs.len()];
    let mut batchable: Vec<usize> = Vec::new();
    for (i, job) in jobs.iter_mut().enumerate() {
        if job.engine.supports_batched_probe() {
            batchable.push(i);
        } else {
            out[i] = job.engine.probe(w, job.batch, job.seed, mu);
            stats.fallback_probes += 1;
            stats.canonical_passes += 2;
        }
    }
    if batchable.is_empty() {
        return (out, stats);
    }
    // group by seed, preserving first-appearance order (determinism: the
    // grouping is a pure function of the job list)
    let mut groups: Vec<(u32, Vec<usize>)> = Vec::new();
    for &i in &batchable {
        let seed = jobs[i].seed;
        match groups.iter_mut().find(|(s, _)| *s == seed) {
            Some((_, idxs)) => idxs.push(i),
            None => groups.push((seed, vec![i])),
        }
    }
    // staged service: the matching group's views were materialised by
    // the commit sweep against this exact buffer — no pass needed
    if let Some(sv) = staged {
        if sv.mu == mu && sv.plus.len() == w.len() && sv.minus.len() == w.len() {
            if let Some(pos) = groups.iter().position(|(s, _)| *s == sv.seed) {
                let (_, idxs) = groups.remove(pos);
                for i in idxs {
                    let job = &mut jobs[i];
                    let lp = job.engine.loss(&sv.plus, job.batch);
                    let lm = job.engine.loss(&sv.minus, job.batch);
                    out[i] = (lp - lm) / (2.0 * mu);
                    stats.staged_probes += 1;
                }
            }
        }
    }
    let seeds_per_pass = (MAX_GROUP_VIEWS / 2).max(1);
    let mut view_bufs: Vec<Vec<f32>> = Vec::new();
    for chunk in groups.chunks(seeds_per_pass) {
        let views: Vec<(u32, f32)> =
            chunk.iter().flat_map(|(s, _)| [(*s, mu), (*s, -mu)]).collect();
        if view_bufs.len() < views.len() {
            view_bufs.resize_with(views.len(), Vec::new);
        }
        for v in view_bufs.iter_mut().take(views.len()) {
            v.resize(w.len(), 0.0);
        }
        {
            let mut outs: Vec<&mut [f32]> =
                view_bufs.iter_mut().take(views.len()).map(|v| v.as_mut_slice()).collect();
            zo::axpy_many(w, &views, &mut outs);
        }
        stats.canonical_passes += 1;
        for (g, (_, idxs)) in chunk.iter().enumerate() {
            for &i in idxs {
                let job = &mut jobs[i];
                let lp = job.engine.loss(&view_bufs[2 * g], job.batch);
                let lm = job.engine.loss(&view_bufs[2 * g + 1], job.batch);
                out[i] = (lp - lm) / (2.0 * mu);
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;
    use crate::simkit::nn::LinearProbe;
    use crate::simkit::prng::Rng;

    fn engine() -> NativeEngine<LinearProbe> {
        NativeEngine::new(LinearProbe::new(8, 3))
    }

    fn batch(seed: u32) -> Batch {
        let mut rng = Rng::new(seed, 0);
        let rows = 16;
        let x: Vec<f32> = (0..rows * 8).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..rows).map(|_| rng.below(3) as u32).collect();
        Batch::Features { x, y, rows, dim: 8 }
    }

    #[test]
    fn probe_preserves_params() {
        let mut e = engine();
        let w = e.init_params(0);
        let w0 = w.clone();
        e.probe(&w, &batch(1), 5, 1e-3);
        assert_eq!(w, w0);
    }

    #[test]
    fn engines_are_send() {
        fn assert_send<T: Send>(_: T) {}
        assert_send(engine());
        let boxed: Box<dyn Engine> = Box::new(engine());
        assert_send(boxed);
    }

    #[test]
    fn update_changes_params_deterministically() {
        let mut e = engine();
        let mut w1 = e.init_params(0);
        let mut w2 = w1.clone();
        e.update(&mut w1, 3, 0.01);
        e.update(&mut w2, 3, 0.01);
        assert_eq!(w1, w2);
        assert_ne!(w1, e.init_params(0));
    }

    #[test]
    fn fo_step_descends() {
        let mut e = engine();
        let mut w = e.init_params(0);
        let b = batch(2);
        let l0 = e.fo_step(&mut w, &b, 0.2);
        for _ in 0..10 {
            e.fo_step(&mut w, &b, 0.2);
        }
        let l1 = e.fo_step(&mut w, &b, 0.0);
        assert!(l1 < l0);
    }

    #[test]
    fn grad_matches_fo_step_direction() {
        let mut e = engine();
        let w = e.init_params(0);
        let b = batch(3);
        let mut g = vec![0.0; w.len()];
        e.grad(&w, &b, &mut g);
        let mut w2 = w.clone();
        e.fo_step(&mut w2, &b, 0.1);
        for i in 0..w.len() {
            assert!((w2[i] - (w[i] - 0.1 * g[i])).abs() < 1e-6);
        }
    }

    /// An engine that keeps the trait's opt-out defaults — exercises the
    /// [`probe_batch`] fallback leg.
    struct OptOut(NativeEngine<LinearProbe>);

    impl Engine for OptOut {
        fn n_params(&self) -> usize {
            self.0.n_params()
        }
        fn probe(&mut self, w: &[f32], b: &Batch, seed: u32, mu: f32) -> f32 {
            self.0.probe(w, b, seed, mu)
        }
        fn update(&mut self, w: &mut [f32], seed: u32, step: f32) {
            self.0.update(w, seed, step)
        }
        fn eval(&mut self, w: &[f32], b: &Batch) -> (f32, u32) {
            self.0.eval(w, b)
        }
        fn fo_step(&mut self, w: &mut [f32], b: &Batch, lr: f32) -> f32 {
            self.0.fo_step(w, b, lr)
        }
        fn grad(&mut self, w: &[f32], b: &Batch, out: &mut [f32]) -> f32 {
            self.0.grad(w, b, out)
        }
        fn init_params(&self, seed: u32) -> Vec<f32> {
            self.0.init_params(seed)
        }
    }

    #[test]
    fn probe_batch_shared_seed_matches_individual_probes_bitwise() {
        // the FeedSign shape: every client probes the same direction —
        // one view pair serves the whole group, same bits as one-by-one
        let mut engines: Vec<NativeEngine<LinearProbe>> = (0..5).map(|_| engine()).collect();
        let w = engines[0].init_params(0);
        let batches: Vec<Batch> = (0..5).map(|i| batch(i as u32)).collect();
        let expect: Vec<f32> = engines
            .iter_mut()
            .zip(&batches)
            .map(|(e, b)| e.probe(&w, b, 42, 1e-3))
            .collect();
        let mut jobs: Vec<ProbeJob> = engines
            .iter_mut()
            .zip(&batches)
            .map(|(e, b)| ProbeJob { engine: e, batch: b, seed: 42 })
            .collect();
        let (got, stats) = probe_batch(&w, 1e-3, &mut jobs);
        for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "client {i}");
        }
        assert_eq!(stats.probes, 5);
        assert_eq!(stats.fallback_probes, 0);
        assert_eq!(stats.canonical_passes, 1, "one shared pass for the whole group");
        assert_eq!(stats.unbatched_passes(), 10);
    }

    #[test]
    fn probe_batch_distinct_seeds_matches_individual_probes_bitwise() {
        // the ZO-FedSGD shape: distinct seeds pack MAX_GROUP_VIEWS / 2
        // per pass; 6 seeds -> 2 passes, bits unchanged
        let mut engines: Vec<NativeEngine<LinearProbe>> = (0..6).map(|_| engine()).collect();
        let w = engines[0].init_params(1);
        let batches: Vec<Batch> = (0..6).map(|i| batch(10 + i as u32)).collect();
        let seeds = [3u32, 1000, 7, 7, 2_000_000, 13];
        let expect: Vec<f32> = engines
            .iter_mut()
            .zip(&batches)
            .zip(&seeds)
            .map(|((e, b), &s)| e.probe(&w, b, s, 1e-3))
            .collect();
        let mut jobs: Vec<ProbeJob> = engines
            .iter_mut()
            .zip(&batches)
            .zip(&seeds)
            .map(|((e, b), &s)| ProbeJob { engine: e, batch: b, seed: s })
            .collect();
        let (got, stats) = probe_batch(&w, 1e-3, &mut jobs);
        for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "client {i} (seed {})", seeds[i]);
        }
        // 5 distinct seeds (7 repeats), 4 seeds per pass -> 2 passes
        assert_eq!(stats.canonical_passes, 2);
        assert_eq!(stats.unbatched_passes(), 12);
    }

    #[test]
    fn probe_batch_falls_back_for_opt_out_engines() {
        let mut native = engine();
        let mut opt_out = OptOut(engine());
        let w = native.init_params(0);
        let (b0, b1) = (batch(1), batch(2));
        let expect = [native.probe(&w, &b0, 9, 1e-3), opt_out.probe(&w, &b1, 9, 1e-3)];
        let mut jobs = vec![
            ProbeJob { engine: &mut native, batch: &b0, seed: 9 },
            ProbeJob { engine: &mut opt_out, batch: &b1, seed: 9 },
        ];
        let (got, stats) = probe_batch(&w, 1e-3, &mut jobs);
        assert_eq!(expect[0].to_bits(), got[0].to_bits());
        assert_eq!(expect[1].to_bits(), got[1].to_bits());
        assert_eq!(stats.fallback_probes, 1);
        assert_eq!(stats.canonical_passes, 3, "2 for the fallback + 1 for the group");
    }

    #[test]
    fn staged_views_serve_matching_group_bitwise_with_zero_passes() {
        use crate::simkit::zo;
        let mut engines: Vec<NativeEngine<LinearProbe>> = (0..5).map(|_| engine()).collect();
        let w = engines[0].init_params(0);
        let batches: Vec<Batch> = (0..5).map(|i| batch(i as u32)).collect();
        let mu = 1e-3f32;
        let expect: Vec<f32> = engines
            .iter_mut()
            .zip(&batches)
            .map(|(e, b)| e.probe(&w, b, 42, mu))
            .collect();
        // stage the views exactly as the fused commit sweep would
        let mut sv = StagedViews { round: 1, seed: 42, mu, ..Default::default() };
        sv.plus = vec![0.0; w.len()];
        sv.minus = vec![0.0; w.len()];
        zo::axpy_into(&w, &mut sv.plus, 42, mu);
        zo::axpy_into(&w, &mut sv.minus, 42, -mu);
        let mut jobs: Vec<ProbeJob> = engines
            .iter_mut()
            .zip(&batches)
            .map(|(e, b)| ProbeJob { engine: e, batch: b, seed: 42 })
            .collect();
        let (got, stats) = probe_batch_staged(&w, mu, &mut jobs, Some(&sv));
        for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "client {i}");
        }
        assert_eq!(stats.staged_probes, 5);
        assert_eq!(stats.canonical_passes, 0, "staged service pays no pass at probe time");
        assert_eq!(stats.passes_saved(), 10);
    }

    #[test]
    fn staged_views_with_wrong_seed_or_mu_fall_back_to_the_pass_path() {
        let mut engines: Vec<NativeEngine<LinearProbe>> = (0..3).map(|_| engine()).collect();
        let w = engines[0].init_params(0);
        let batches: Vec<Batch> = (0..3).map(|i| batch(i as u32)).collect();
        let expect: Vec<f32> = engines
            .iter_mut()
            .zip(&batches)
            .map(|(e, b)| e.probe(&w, b, 42, 1e-3))
            .collect();
        for sv in [
            StagedViews {
                round: 1,
                seed: 7, // wrong direction
                mu: 1e-3,
                plus: vec![0.0; w.len()],
                minus: vec![0.0; w.len()],
            },
            StagedViews {
                round: 1,
                seed: 42,
                mu: 2e-3, // wrong radius
                plus: vec![0.0; w.len()],
                minus: vec![0.0; w.len()],
            },
        ] {
            let mut jobs: Vec<ProbeJob> = engines
                .iter_mut()
                .zip(&batches)
                .map(|(e, b)| ProbeJob { engine: e, batch: b, seed: 42 })
                .collect();
            let (got, stats) = probe_batch_staged(&w, 1e-3, &mut jobs, Some(&sv));
            for (i, (a, b)) in expect.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "client {i}");
            }
            assert_eq!(stats.staged_probes, 0, "mismatched staging must not serve");
            assert_eq!(stats.canonical_passes, 1, "the miss costs the normal single pass");
        }
    }

    #[test]
    fn fused_commit_exact_gates_native_only() {
        assert!(engine().fused_commit_exact());
        assert!(!OptOut(engine()).fused_commit_exact(), "trait default must stay false");
    }
}
