//! The client compute abstraction: everything a federated client does to
//! its local model, behind one trait so the coordinator is agnostic to
//! whether the math runs through AOT-compiled XLA artifacts
//! ([`crate::runtime::SharedPjrtEngine`]) or the native substrate
//! ([`NativeEngine`]).

use crate::data::Batch;
use crate::simkit::nn::Model;
use crate::simkit::zo;

/// Client-side compute: SPSA probe, shared-direction update, eval and the
/// first-order baseline.  `w` is the client's own flat parameter vector —
/// the engine holds no model state (the paper's PS/parameter-privacy story
/// depends on parameters living only with clients).
///
/// `Send` is a supertrait: the parallel round engine
/// ([`crate::coordinator::session::Session`]) fans per-client probe work
/// out over scoped threads, each worker owning its clients' engines
/// exclusively for the duration of the round.
pub trait Engine: Send {
    /// Length of the flat (padded) parameter vector.
    fn n_params(&self) -> usize;

    /// SPSA projection `p = (L(w+mu z) - L(w-mu z)) / 2mu` for direction
    /// `z(seed)`.  Takes `w` by shared reference — the probe contract has
    /// always been "replica unchanged on return"; the signature now
    /// enforces it (perturbed views are regenerated into engine scratch).
    fn probe(&mut self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> f32;

    /// Apply the aggregated update `w -= step * z(seed)`.  Must be a
    /// pure function of `(w, seed, step)`: the coordinator's replica
    /// plane relies on one canonical apply being bit-identical to the K
    /// per-client applies a dense layout would perform
    /// ([`crate::coordinator::replica`]).  Implementations should also
    /// match the native replay primitive
    /// ([`crate::simkit::zo::apply_update`]) bit-for-bit — orbit replay,
    /// seed-history catch-up and the replica plane's cold stale-read
    /// reconstruction are all defined in terms of it (the PJRT kernel is
    /// currently pinned only to 1e-6; see
    /// `Session::replica` for the operational consequence).
    fn update(&mut self, w: &mut [f32], seed: u32, step: f32);

    /// `(mean loss, #correct)` on an eval batch.  Takes `w` by shared
    /// reference — evaluation never mutates the replica, and with the
    /// copy-on-write replica plane many clients evaluate against the
    /// *same* canonical buffer.
    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32);

    /// First-order step `w -= lr * grad`; returns the pre-step loss.
    /// Powers the FedSGD baseline and pretraining.
    fn fo_step(&mut self, w: &mut [f32], batch: &Batch, lr: f32) -> f32;

    /// Full gradient (for FedSGD's gradient *exchange*); returns loss.
    /// Like [`Engine::probe`], read-only in `w` — FedSGD clients compute
    /// their local gradients against the shared canonical buffer.
    fn grad(&mut self, w: &[f32], batch: &Batch, out: &mut [f32]) -> f32;

    /// Fresh initial parameter vector (same across all clients/engines for
    /// a given seed — everyone starts from the shared checkpoint).
    fn init_params(&self, seed: u32) -> Vec<f32>;
}

/// Native-substrate engine: wraps any [`Model`] with the in-place SPSA
/// walker.  Probe memory overhead is O(1) over inference — the measured
/// basis of the Table 10 reproduction.
pub struct NativeEngine<M: Model> {
    pub model: M,
    grad_buf: Vec<f32>,
    probe_buf: Vec<f32>,
}

impl<M: Model> NativeEngine<M> {
    pub fn new(model: M) -> Self {
        NativeEngine { model, grad_buf: Vec::new(), probe_buf: Vec::new() }
    }

    /// Bytes of scratch the engine holds beyond the parameter vector —
    /// instrumentation for the Table 10 memory comparison (the FO path's
    /// dense gradient buffer dominates; the ZO path holds one perturbed
    /// view).
    pub fn scratch_bytes(&self) -> usize {
        (self.grad_buf.capacity() + self.probe_buf.capacity()) * std::mem::size_of::<f32>()
    }
}

impl<M: Model> Engine for NativeEngine<M> {
    fn n_params(&self) -> usize {
        self.model.n_params()
    }

    fn probe(&mut self, w: &[f32], batch: &Batch, seed: u32, mu: f32) -> f32 {
        let mut scratch = std::mem::take(&mut self.probe_buf);
        let p = zo::spsa_probe_scratch(&mut self.model, w, &mut scratch, batch, seed, mu);
        self.probe_buf = scratch;
        p
    }

    fn update(&mut self, w: &mut [f32], seed: u32, step: f32) {
        zo::apply_update(w, seed, step);
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32) {
        self.model.eval(w, batch)
    }

    fn fo_step(&mut self, w: &mut [f32], batch: &Batch, lr: f32) -> f32 {
        let n = w.len();
        self.grad_buf.resize(n, 0.0);
        let mut grad = std::mem::take(&mut self.grad_buf);
        let loss = self.model.loss_and_grad(w, batch, &mut grad);
        for (wi, gi) in w.iter_mut().zip(&grad) {
            *wi -= lr * gi;
        }
        self.grad_buf = grad;
        loss
    }

    fn grad(&mut self, w: &[f32], batch: &Batch, out: &mut [f32]) -> f32 {
        self.model.loss_and_grad(w, batch, out)
    }

    fn init_params(&self, seed: u32) -> Vec<f32> {
        self.model.init(seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;
    use crate::simkit::nn::LinearProbe;
    use crate::simkit::prng::Rng;

    fn engine() -> NativeEngine<LinearProbe> {
        NativeEngine::new(LinearProbe::new(8, 3))
    }

    fn batch(seed: u32) -> Batch {
        let mut rng = Rng::new(seed, 0);
        let rows = 16;
        let x: Vec<f32> = (0..rows * 8).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..rows).map(|_| rng.below(3) as u32).collect();
        Batch::Features { x, y, rows, dim: 8 }
    }

    #[test]
    fn probe_preserves_params() {
        let mut e = engine();
        let w = e.init_params(0);
        let w0 = w.clone();
        e.probe(&w, &batch(1), 5, 1e-3);
        assert_eq!(w, w0);
    }

    #[test]
    fn engines_are_send() {
        fn assert_send<T: Send>(_: T) {}
        assert_send(engine());
        let boxed: Box<dyn Engine> = Box::new(engine());
        assert_send(boxed);
    }

    #[test]
    fn update_changes_params_deterministically() {
        let mut e = engine();
        let mut w1 = e.init_params(0);
        let mut w2 = w1.clone();
        e.update(&mut w1, 3, 0.01);
        e.update(&mut w2, 3, 0.01);
        assert_eq!(w1, w2);
        assert_ne!(w1, e.init_params(0));
    }

    #[test]
    fn fo_step_descends() {
        let mut e = engine();
        let mut w = e.init_params(0);
        let b = batch(2);
        let l0 = e.fo_step(&mut w, &b, 0.2);
        for _ in 0..10 {
            e.fo_step(&mut w, &b, 0.2);
        }
        let l1 = e.fo_step(&mut w, &b, 0.0);
        assert!(l1 < l0);
    }

    #[test]
    fn grad_matches_fo_step_direction() {
        let mut e = engine();
        let w = e.init_params(0);
        let b = batch(3);
        let mut g = vec![0.0; w.len()];
        e.grad(&w, &b, &mut g);
        let mut w2 = w.clone();
        e.fo_step(&mut w2, &b, 0.1);
        for i in 0..w.len() {
            assert!((w2[i] - (w[i] - 0.1 * g[i])).abs() < 1e-6);
        }
    }
}
