//! Per-round client sampling — the partial-participation regime that
//! resource-constrained federated deployments actually run (most devices
//! are offline, charging, or rate-limited in any given round; cf. the
//! FedKSeed / resource-constrained ZO-FFT line).
//!
//! The sampler is part of the round **plan**: participants are drawn from
//! a dedicated coordinator RNG stream *before* any client compute runs, so
//! the draw is identical whether the round executes sequentially or fans
//! out over worker threads.  [`ParticipationCfg::Full`] consumes no RNG
//! draws at all, which keeps full-participation runs bit-identical to the
//! pre-participation sequential engine.
//!
//! With `catchup = "off"` the synchronized algorithms (FeedSign,
//! DP-FeedSign, ZO-FedSGD) still broadcast the aggregated direction to
//! **every** client — non-participants skip the probe/vote (no uplink) but
//! must apply the global update to keep all replicas bit-identical, so
//! downlink is metered for all K clients.  With a
//! [`crate::coordinator::catchup`] policy on, only participants hear the
//! broadcast and everyone else replays the missed seed history on rejoin.
//!
//! A draw may legitimately be **empty** (`fraction:0`, useful as an
//! availability floor in sweeps): the round engine commits such a round as
//! a no-op rather than panicking.  `bernoulli:P` keeps its round-robin
//! fallback (`round % K`) so availability-model runs always make progress.
//!
//! **Sharded coordinators** ([`crate::coordinator::shard`]) must not give
//! each shard its own sampler: these draws are *sequenced* on one session
//! stream, so per-shard sampling would consume different draw counts at
//! different shard counts and break shard-count invariance.  The sharded
//! engine therefore draws the participant set once globally through this
//! module and *partitions* the sorted result along shard boundaries
//! (`ShardMap::split_participants`) — pinned by
//! `rust/tests/shard_parity.rs`.

use crate::simkit::prng::Rng;

/// Which clients take part in each aggregation round.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ParticipationCfg {
    /// Every client probes and votes every round (the paper's setting).
    Full,
    /// A fixed fraction of the pool, sampled without replacement each
    /// round: `ceil(fraction * K)` distinct clients (`fraction:0` draws
    /// nobody — every round commits as a no-op).
    Fraction(f32),
    /// Each client joins independently with probability `p` (device
    /// availability model); an empty draw falls back to the round-robin
    /// client `round % K` so every round makes progress.
    Bernoulli(f32),
}

impl ParticipationCfg {
    /// Parse a config/CLI spec: `full`, `fraction:0.2`, `bernoulli:0.3`.
    pub fn parse(s: &str) -> Option<ParticipationCfg> {
        let s = s.trim().to_ascii_lowercase();
        if s == "full" {
            return Some(ParticipationCfg::Full);
        }
        if let Some(f) = s.strip_prefix("fraction:") {
            let f: f32 = f.parse().ok()?;
            if (0.0..=1.0).contains(&f) {
                return Some(ParticipationCfg::Fraction(f));
            }
            return None;
        }
        if let Some(p) = s.strip_prefix("bernoulli:") {
            let p: f32 = p.parse().ok()?;
            if p > 0.0 && p <= 1.0 {
                return Some(ParticipationCfg::Bernoulli(p));
            }
            return None;
        }
        None
    }

    /// Render back to the config-string form [`ParticipationCfg::parse`]
    /// accepts.
    pub fn render(&self) -> String {
        match self {
            ParticipationCfg::Full => "full".to_string(),
            ParticipationCfg::Fraction(f) => format!("fraction:{f}"),
            ParticipationCfg::Bernoulli(p) => format!("bernoulli:{p}"),
        }
    }

    /// Whether this sampler can leave a client out of a round — the
    /// participation half of the session's snapshot-cache admission
    /// check (a sampler that always selects everyone cannot create a
    /// stale reader, so pre-commit snapshots would only be dead copies).
    /// `Fraction(1.0)` selects the whole pool every round and therefore
    /// cannot strand anyone; any smaller fraction and every Bernoulli
    /// rate can.
    pub fn can_strand_clients(&self) -> bool {
        match *self {
            ParticipationCfg::Full => false,
            ParticipationCfg::Fraction(f) => f < 1.0,
            ParticipationCfg::Bernoulli(_) => true,
        }
    }

    /// Expected participants per round for a pool of `k` (bench/report
    /// helper for matched-perturbation budgets).
    pub fn expected_participants(&self, k: usize) -> f32 {
        match self {
            ParticipationCfg::Full => k as f32,
            ParticipationCfg::Fraction(f) => (f * k as f32).ceil().min(k as f32),
            ParticipationCfg::Bernoulli(p) => (p * k as f32).max(1.0),
        }
    }

    /// Draw this round's participant set: sorted, distinct client ids in
    /// `[0, k)`.  Only `Fraction(0.0)` can draw an empty set (the round
    /// engine commits such rounds as no-ops); `Bernoulli` falls back to
    /// round-robin on an empty draw.  `Full` and `Fraction(0.0)` consume
    /// no draws from `rng`; the other modes consume a
    /// round-count-independent number of draws so runs with the same seed
    /// stay reproducible.
    pub fn sample(&self, k: usize, round: u64, rng: &mut Rng) -> Vec<usize> {
        assert!(k > 0);
        match *self {
            ParticipationCfg::Full => (0..k).collect(),
            ParticipationCfg::Fraction(f) => {
                let m = (((f * k as f32).ceil()) as usize).min(k);
                if m == 0 {
                    return Vec::new();
                }
                if m == k {
                    return (0..k).collect();
                }
                // partial Fisher-Yates: first m entries are a uniform
                // m-subset
                let mut ids: Vec<usize> = (0..k).collect();
                for i in 0..m {
                    let j = i + rng.below(k - i);
                    ids.swap(i, j);
                }
                ids.truncate(m);
                ids.sort_unstable();
                ids
            }
            ParticipationCfg::Bernoulli(p) => {
                let mut ids: Vec<usize> =
                    (0..k).filter(|_| rng.uniform() < p).collect();
                if ids.is_empty() {
                    ids.push((round % k as u64) as usize);
                }
                ids
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_selects_everyone_without_rng_draws() {
        let mut rng = Rng::new(1, 0);
        let before = rng.clone();
        assert_eq!(ParticipationCfg::Full.sample(7, 3, &mut rng), (0..7).collect::<Vec<_>>());
        // no draws consumed: the next word matches the untouched clone
        let mut untouched = before;
        assert_eq!(rng.next_u32(), untouched.next_u32());
    }

    #[test]
    fn fraction_sizes_and_bounds() {
        let mut rng = Rng::new(2, 0);
        for (f, k, expect) in [(0.2f32, 10usize, 2usize), (0.5, 5, 3), (0.01, 4, 1), (1.0, 6, 6)] {
            let ids = ParticipationCfg::Fraction(f).sample(k, 0, &mut rng);
            assert_eq!(ids.len(), expect, "fraction {f} of {k}");
            assert!(ids.windows(2).all(|p| p[0] < p[1]), "sorted distinct");
            assert!(ids.iter().all(|&i| i < k));
        }
    }

    #[test]
    fn fraction_varies_across_rounds() {
        let mut rng = Rng::new(3, 0);
        let cfg = ParticipationCfg::Fraction(0.3);
        let draws: Vec<Vec<usize>> = (0..20).map(|t| cfg.sample(20, t, &mut rng)).collect();
        assert!(draws.windows(2).any(|p| p[0] != p[1]), "sampling should move");
    }

    #[test]
    fn bernoulli_never_empty_and_deterministic() {
        let cfg = ParticipationCfg::Bernoulli(0.05);
        let mut a = Rng::new(4, 0);
        let mut b = Rng::new(4, 0);
        for t in 0..50 {
            let ia = cfg.sample(6, t, &mut a);
            let ib = cfg.sample(6, t, &mut b);
            assert_eq!(ia, ib, "same stream, same draw");
            assert!(!ia.is_empty());
            assert!(ia.iter().all(|&i| i < 6));
        }
    }

    #[test]
    fn parse_render_roundtrip() {
        for s in ["full", "fraction:0.25", "bernoulli:0.5", "fraction:0"] {
            let cfg = ParticipationCfg::parse(s).unwrap();
            assert_eq!(ParticipationCfg::parse(&cfg.render()), Some(cfg));
        }
        assert_eq!(ParticipationCfg::parse("FULL"), Some(ParticipationCfg::Full));
        assert_eq!(ParticipationCfg::parse("fraction:0"), Some(ParticipationCfg::Fraction(0.0)));
        assert!(ParticipationCfg::parse("fraction:1.5").is_none());
        assert!(ParticipationCfg::parse("fraction:-0.1").is_none());
        assert!(ParticipationCfg::parse("bernoulli:-1").is_none());
        assert!(ParticipationCfg::parse("bernoulli:0").is_none());
        assert!(ParticipationCfg::parse("sometimes").is_none());
    }

    #[test]
    fn stranding_capability_by_mode() {
        assert!(!ParticipationCfg::Full.can_strand_clients());
        assert!(!ParticipationCfg::Fraction(1.0).can_strand_clients());
        assert!(ParticipationCfg::Fraction(0.99).can_strand_clients());
        assert!(ParticipationCfg::Fraction(0.0).can_strand_clients());
        assert!(ParticipationCfg::Bernoulli(1.0).can_strand_clients());
    }

    #[test]
    fn expected_participants_shapes() {
        assert_eq!(ParticipationCfg::Full.expected_participants(8), 8.0);
        assert_eq!(ParticipationCfg::Fraction(0.25).expected_participants(8), 2.0);
        assert_eq!(ParticipationCfg::Fraction(0.0).expected_participants(8), 0.0);
        assert_eq!(ParticipationCfg::Bernoulli(0.5).expected_participants(8), 4.0);
    }

    #[test]
    fn fraction_zero_draws_nobody_and_consumes_no_rng() {
        let mut rng = Rng::new(9, 0);
        let before = rng.clone();
        for t in 0..10 {
            assert!(ParticipationCfg::Fraction(0.0).sample(5, t, &mut rng).is_empty());
        }
        let mut untouched = before;
        assert_eq!(rng.next_u32(), untouched.next_u32(), "empty draws must not move the stream");
    }

    #[test]
    fn bernoulli_empty_draw_falls_back_round_robin() {
        // p below the uniform draw's resolution floor (2^-25), so every
        // draw comes up empty; the fallback must walk `round % k`
        let cfg = ParticipationCfg::Bernoulli(1e-8);
        let mut rng = Rng::new(10, 0);
        for t in 0..12u64 {
            let ids = cfg.sample(3, t, &mut rng);
            assert_eq!(ids, vec![(t % 3) as usize], "round {t}");
        }
    }

    #[test]
    fn one_client_pool_every_mode() {
        let mut rng = Rng::new(11, 0);
        assert_eq!(ParticipationCfg::Full.sample(1, 0, &mut rng), vec![0]);
        assert_eq!(ParticipationCfg::Fraction(1.0).sample(1, 0, &mut rng), vec![0]);
        assert_eq!(ParticipationCfg::Fraction(0.01).sample(1, 0, &mut rng), vec![0]);
        assert!(ParticipationCfg::Fraction(0.0).sample(1, 0, &mut rng).is_empty());
        // bernoulli on one client: either it draws in, or the fallback
        // selects it — always exactly client 0
        for t in 0..20 {
            assert_eq!(ParticipationCfg::Bernoulli(0.3).sample(1, t, &mut rng), vec![0]);
        }
    }
}
