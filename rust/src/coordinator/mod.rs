//! Layer-3 coordinator: the paper's federated fine-tuning runtime.
//!
//! * [`aggregation`] — the FeedSign / ZO-FedSGD / DP / FO update rules;
//! * [`byzantine`] — attack models (sign flip, random projection, …);
//! * [`participation`] — per-round client sampling (full / fixed-fraction
//!   / Bernoulli availability);
//! * [`catchup`] — seed-history catch-up for clients that missed rounds
//!   (replay / rebroadcast policies + per-client sync watermarks);
//! * [`replica`] — the copy-on-write shared parameter store (one
//!   canonical buffer + per-client `Shared`/`Owned` logical replicas),
//!   which is what lets a pool of hundreds of clients cost `O(d)`
//!   coordinator memory instead of `K·d`;
//! * [`session`] — the deterministic plan/execute/commit round engine that
//!   all benches/examples drive (size-aware client fan-out over scoped
//!   threads, commits in client-id order);
//! * [`shard`] — the sharded coordinator plane (`--shards N`): contiguous
//!   client-id shards that own their probe fan-out and pre-reduce sign
//!   votes to associative `(sum, voters)` pairs, merged hierarchically
//!   and bit-identical to the barriered engine;
//! * [`tile`] — the tiered canonical store behind the replica plane's
//!   spill mode: a file-backed tile pager whose FIFO resident window is
//!   budget-bounded, driven page-by-page by the fused commit+probe
//!   sweep so `d` past the budget runs with flat canonical memory;
//! * [`distributed`] — the threaded leader/worker topology (same protocol,
//!   real message passing), pinned to the sync session by test.
//!
//! Both round engines consult the [`crate::net`] impaired-channel
//! simulator: deadline stragglers are cut in the plan phase, uplink
//! contributions cross the (possibly flipped/dropped) channel before
//! aggregation, and absence feeds the participation/catch-up machinery.

pub mod aggregation;
pub mod byzantine;
pub mod catchup;
pub mod distributed;
pub mod participation;
pub mod replica;
pub mod session;
pub mod shard;
pub mod tile;

pub use aggregation::Algorithm;
pub use byzantine::Attack;
pub use catchup::{CatchupCfg, CatchupTracker};
pub use participation::ParticipationCfg;
pub use replica::{ReplicaStats, ReplicaStore};
pub use session::{Client, Session, SessionCfg};
pub use shard::{ShardMap, ShardPlane, ShardStats};
pub use tile::{TileStats, TileStore};
