//! Layer-3 coordinator: the paper's federated fine-tuning runtime.
//!
//! * [`aggregation`] — the FeedSign / ZO-FedSGD / DP / FO update rules;
//! * [`byzantine`] — attack models (sign flip, random projection, …);
//! * [`session`] — the deterministic synchronous round loop that all
//!   benches/examples drive;
//! * [`distributed`] — the tokio leader/worker topology (same protocol,
//!   real message passing), pinned to the sync session by test.

pub mod aggregation;
pub mod byzantine;
pub mod distributed;
pub mod session;

pub use aggregation::Algorithm;
pub use byzantine::Attack;
pub use session::{Client, Session, SessionCfg};
