//! Tiered canonical store: a file-backed tile pager behind the replica
//! plane's canonical buffer.
//!
//! ROADMAP item 1 left open "shard or memory-map the canonical buffer
//! once single-host `d` exceeds RAM — the replica plane remains the seam
//! for a tiered store".  [`TileStore`] is that store: the authoritative
//! parameter bits live in an **unlinked temp file** (a plain pager, no
//! new dependencies; the file vanishes with the process on any exit
//! path), and a FIFO **resident window** of tile-sized pages — capped at
//! a configurable byte budget — is all the canonical storage the
//! coordinator ever holds.  The fused commit+probe sweep
//! ([`crate::simkit::zo::fused_commit_probe_span`]) walks the store one
//! page at a time, so the tile doubles as the prefetch unit: fetch,
//! commit, stage the next round's probe views, evict with write-back.
//!
//! Spill is a *memory policy, not a numerics policy*: pages round-trip
//! through the file as raw little-endian f32 bits, so a spill-mode run
//! is bit-identical to the in-RAM run (pinned by `tile_parity.rs` and
//! the `table10_memory` spill column).  What the budget bounds is the
//! canonical **store**; transient working views (probe scratch, staged
//! ±mu views, the evaluation mirror) remain `O(d)` exactly as in the
//! flat engine — out-of-core *loss* is future work, see the "Parameter
//! plane" section of `docs/ARCHITECTURE.md`.
//!
//! Spill/evict/fetch events go through the leveled [`crate::obs::log`]
//! plane (`FEEDSIGN_LOG=debug` shows them; never raw `eprintln!`), and
//! the counters surface as `feedsign_tile_resident_bytes` /
//! `feedsign_tile_spills_total` in the Prometheus registry.

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Tiered-store accounting, folded into
/// [`crate::coordinator::replica::ReplicaStats`] and exported as
/// Prometheus gauges/counters by the metrics registry.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TileStats {
    /// Tile length in elements (the page size).
    pub tile: usize,
    /// Resident-window byte budget the store was built with.
    pub budget_bytes: usize,
    /// Bytes currently held by resident pages.
    pub resident_bytes: usize,
    /// High-water mark of [`Self::resident_bytes`] — the spill-mode
    /// memory claim: stays ≤ the budget for any `d`.
    pub peak_resident_bytes: usize,
    /// Dirty pages written back to the file on eviction.
    pub spills: u64,
    /// Pages read (faulted) in from the file.
    pub fetches: u64,
}

/// One resident page of the store.
#[derive(Debug)]
struct Page {
    idx: usize,
    data: Vec<f32>,
    dirty: bool,
}

/// File-backed canonical tile pager; see the module docs.
#[derive(Debug)]
pub struct TileStore {
    d: usize,
    tile: usize,
    file: File,
    /// FIFO resident window, oldest first.
    window: VecDeque<Page>,
    cap_tiles: usize,
    budget_bytes: usize,
    resident_bytes: usize,
    peak_resident_bytes: usize,
    spills: u64,
    fetches: u64,
}

/// Distinguishes concurrently created stores within one process.
static STORE_SEQ: AtomicU64 = AtomicU64::new(0);

fn open_backing_file() -> File {
    let seq = STORE_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir()
        .join(format!("feedsign-tilestore-{}-{seq}.bin", std::process::id()));
    let file = OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&path)
        .unwrap_or_else(|e| panic!("tile store: create {}: {e}", path.display()));
    // unlink immediately: the open handle keeps the pages alive, the
    // name is gone, and the kernel reclaims the space on any process
    // exit — no cleanup path to forget
    let _ = std::fs::remove_file(&path);
    file
}

fn write_page_at(file: &File, offset: usize, data: &[f32]) {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    file.write_all_at(&bytes, (offset * 4) as u64).expect("tile store: page write-back");
}

fn read_page_at(file: &File, offset: usize, out: &mut [f32]) {
    let mut bytes = vec![0u8; out.len() * 4];
    file.read_exact_at(&mut bytes, (offset * 4) as u64).expect("tile store: page fetch");
    for (v, b) in out.iter_mut().zip(bytes.chunks_exact(4)) {
        *v = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    }
}

impl TileStore {
    /// Build a spill store over `init`, paged in `tile`-element tiles
    /// with at most `budget_bytes` of resident pages (always at least
    /// one page — a budget below one tile degenerates to a one-page
    /// window, which is still flat in `d`).
    pub fn new(init: &[f32], tile: usize, budget_bytes: usize) -> TileStore {
        assert!(tile >= 1, "tile must be at least one element");
        let file = open_backing_file();
        write_page_at(&file, 0, init);
        let cap_tiles = (budget_bytes / (4 * tile)).max(1);
        crate::log_info!(
            "tile store: d={} tile={tile} budget={budget_bytes}B window={cap_tiles} pages",
            init.len()
        );
        TileStore {
            d: init.len(),
            tile,
            file,
            window: VecDeque::new(),
            cap_tiles,
            budget_bytes,
            resident_bytes: 0,
            peak_resident_bytes: 0,
            spills: 0,
            fetches: 0,
        }
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Tile length in elements (the page size).
    pub fn tile(&self) -> usize {
        self.tile
    }

    pub fn n_tiles(&self) -> usize {
        self.d.div_ceil(self.tile)
    }

    fn page_len(&self, idx: usize) -> usize {
        (self.d - idx * self.tile).min(self.tile)
    }

    /// Fault page `idx` into the window (evicting the oldest resident
    /// page first if the window is at capacity) and return it.
    fn fetch(&mut self, idx: usize) -> &mut Page {
        if let Some(pos) = self.window.iter().position(|p| p.idx == idx) {
            return &mut self.window[pos];
        }
        while self.window.len() >= self.cap_tiles {
            let old = self.window.pop_front().expect("window non-empty at cap");
            self.resident_bytes -= 4 * old.data.len();
            if old.dirty {
                write_page_at(&self.file, old.idx * self.tile, &old.data);
                self.spills += 1;
                crate::log_debug!("tile store: spill page {} ({}B)", old.idx, 4 * old.data.len());
            } else {
                crate::log_debug!("tile store: evict clean page {}", old.idx);
            }
        }
        let mut data = vec![0.0f32; self.page_len(idx)];
        read_page_at(&self.file, idx * self.tile, &mut data);
        self.fetches += 1;
        self.resident_bytes += 4 * data.len();
        self.peak_resident_bytes = self.peak_resident_bytes.max(self.resident_bytes);
        self.window.push_back(Page { idx, data, dirty: false });
        self.window.back_mut().expect("just pushed")
    }

    /// Walk every tile in order through the resident window, calling
    /// `f(offset, tile)` with the absolute element offset and the
    /// mutable page — the fused commit+probe sweep's drive loop.  Every
    /// visited page is marked dirty (commits touch all of canonical).
    pub fn sweep_mut(&mut self, mut f: impl FnMut(usize, &mut [f32])) {
        for idx in 0..self.n_tiles() {
            let tile = self.tile;
            let page = self.fetch(idx);
            page.dirty = true;
            f(idx * tile, &mut page.data);
        }
    }

    /// Copy the whole store into `dst`, reading dirty resident pages
    /// from the window and everything else from the file, without
    /// disturbing the window.
    pub fn read_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.d);
        for idx in 0..self.n_tiles() {
            let at = idx * self.tile;
            let out = &mut dst[at..at + self.page_len(idx)];
            if let Some(p) = self.window.iter().find(|p| p.idx == idx) {
                out.copy_from_slice(&p.data);
            } else {
                read_page_at(&self.file, at, out);
            }
        }
    }

    /// Overwrite the whole store from `src` (the non-fused commit path:
    /// the session applies its closure to the materialized mirror and
    /// writes the result back through here).  Resident pages are
    /// dropped without write-back — `src` supersedes them.
    pub fn write_from(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.d);
        self.window.clear();
        self.resident_bytes = 0;
        write_page_at(&self.file, 0, src);
    }

    pub fn stats(&self) -> TileStats {
        TileStats {
            tile: self.tile,
            budget_bytes: self.budget_bytes,
            resident_bytes: self.resident_bytes,
            peak_resident_bytes: self.peak_resident_bytes,
            spills: self.spills,
            fetches: self.fetches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::{prng, zo};

    #[test]
    fn roundtrips_bits_through_the_pager() {
        // non-trivial bit patterns (negative zero, denormal-ish values)
        // must survive the file round trip exactly
        let mut init = prng::normals_vec(3, 1037);
        init[0] = -0.0;
        init[1] = f32::MIN_POSITIVE / 4.0;
        let mut s = TileStore::new(&init, 64, 4 * 64 * 2);
        let mut out = vec![0.0f32; init.len()];
        s.read_into(&mut out);
        let same = out.iter().zip(&init).all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "cold read must reproduce the init bits");
        // mutate through a sweep, read back through a dirty window
        s.sweep_mut(|at, tile| {
            for (j, v) in tile.iter_mut().enumerate() {
                *v = (at + j) as f32;
            }
        });
        s.read_into(&mut out);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn window_respects_the_budget_and_spills_dirty_pages() {
        let d = 1000usize;
        let tile = 64usize;
        let budget = 4 * tile * 3; // 3 resident pages of 16 tiles
        let init = prng::normals_vec(7, d);
        let mut s = TileStore::new(&init, tile, budget);
        s.sweep_mut(|_, t| t[0] += 1.0);
        s.sweep_mut(|_, t| t[0] += 1.0);
        let st = s.stats();
        assert!(st.resident_bytes <= budget, "window over budget: {}", st.resident_bytes);
        assert!(st.peak_resident_bytes <= budget);
        assert!(st.spills > 0, "two sweeps over a 16-page store must evict dirty pages");
        assert!(st.fetches >= s.n_tiles() as u64);
        // both increments landed despite the spills
        let mut out = vec![0.0f32; d];
        s.read_into(&mut out);
        for idx in 0..s.n_tiles() {
            assert_eq!(out[idx * tile], init[idx * tile] + 2.0, "tile {idx}");
        }
    }

    #[test]
    fn sub_tile_budget_degenerates_to_one_page() {
        let init = vec![1.0f32; 100];
        let mut s = TileStore::new(&init, 64, 1); // budget below one page
        s.sweep_mut(|_, t| t[0] *= 2.0);
        let st = s.stats();
        assert_eq!(st.resident_bytes, 4 * 36, "only the ragged tail page resident");
        assert!(st.peak_resident_bytes <= 4 * 64);
    }

    #[test]
    fn spill_sweep_matches_in_ram_fused_sweep_bitwise() {
        // the end-to-end exactness claim at the store level: the fused
        // commit+probe sweep driven tile-by-tile through the pager
        // produces the same canonical bits and staged views as the
        // in-RAM sweep
        let d = 4099usize;
        let tile = 128usize;
        let w0 = prng::normals_vec(11, d);
        let commits = [(5u32, 2e-3f32)];
        let views = [(6u32, 1e-3f32), (6, -1e-3)];
        let mut flat_w = w0.clone();
        let mut flat_outs = vec![vec![0.0f32; d]; views.len()];
        let mut outs: Vec<&mut [f32]> = flat_outs.iter_mut().map(|v| v.as_mut_slice()).collect();
        zo::fused_commit_probe_threads(&mut flat_w, &commits, &views, &mut outs, tile, 1);

        let mut s = TileStore::new(&w0, tile, 4 * tile * 2);
        let mut spill_outs = vec![vec![0.0f32; d]; views.len()];
        s.sweep_mut(|at, t| {
            let mut outs: Vec<&mut [f32]> =
                spill_outs.iter_mut().map(|v| &mut v[at..at + t.len()]).collect();
            zo::fused_commit_probe_span(t, &commits, &views, &mut outs, at, tile);
        });
        let mut spill_w = vec![0.0f32; d];
        s.read_into(&mut spill_w);
        assert_eq!(spill_w, flat_w, "canonical bits must survive the pager");
        assert_eq!(spill_outs, flat_outs, "staged views must match the in-RAM sweep");
        assert!(s.stats().peak_resident_bytes <= 4 * tile * 2);
    }
}
