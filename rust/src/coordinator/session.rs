//! The federated session: PS round loop + client pool (Algorithm 1),
//! organised as a **plan / execute / commit** round engine.
//!
//! One `Session` owns the K clients (each with its own parameter vector,
//! engine, data shard and attack model) and drives T aggregation rounds of
//! the configured algorithm, metering every protocol message through the
//! [`crate::comm::Ledger`] and recording the orbit as it goes.  Each round:
//!
//! 1. **plan** — the participant set is drawn from a dedicated coordinator
//!    RNG stream ([`ParticipationCfg`]), before any client compute runs;
//!    with an active [`crate::net`] simulation the virtual event clock
//!    then cuts deadline stragglers from the plan (they resync later via
//!    catch-up); when catch-up is on ([`CatchupCfg`]), stale participants
//!    replay their missed seed history *before* probing, so every vote is
//!    cast on the current model;
//! 2. **execute** — per-client probe work (batch draw → SPSA probe →
//!    attack mutation) fans out over `std::thread::scope` workers, each
//!    metering its uplink into a private sub-ledger;
//! 3. **commit** — outcomes are committed **in client-id order** (votes,
//!    sub-ledgers, orbit entries, seed-history records); each uplink
//!    contribution crosses the (possibly impaired) channel — flips
//!    corrupt it, drops make the PS treat the sender as absent — then
//!    the vote is aggregated and the global update is broadcast: to
//!    every client when `catchup = "off"` (the paper's assumption), or
//!    to the clients the PS heard from when catch-up is on (everyone
//!    else recovers the round from the [`crate::comm::SeedHistory`] on
//!    rejoin).
//!
//! A plan with **zero participants** (e.g. `fraction:0`) commits a no-op:
//! no votes, no broadcast, a 0-sign orbit entry and an empty history
//! round — round indices stay dense so both orbit replay and catch-up
//! replay keep working.
//!
//! **Determinism contract:** commit order is client id, every client's
//! randomness lives in its own Philox stream, and coordinator randomness
//! (participation, DP vote, eval) lives in dedicated streams — so a run is
//! bit-identical for *every* worker-thread count, including the sequential
//! `threads = 1` baseline (pinned by `rust/tests/parallel_parity.rs`), and
//! FeedSign's step seed remains the round index (`seed = t`, §I.1).  The
//! cross-topology test in `rust/tests/` (sync vs threaded-distributed)
//! relies on the same schedule.  Catch-up replay preserves the contract
//! because replay order equals commit order and every replayed record
//! goes through the same exact chunk-parallel AXPY the participants used
//! (pinned by `rust/tests/catchup_parity.rs`).

use crate::comm::{Ledger, Message, SeedHistory, SeedRecord};
use crate::coordinator::aggregation::{self, Algorithm};
use crate::coordinator::byzantine::Attack;
use crate::coordinator::catchup::{CatchupCfg, CatchupTracker};
use crate::coordinator::participation::ParticipationCfg;
use crate::data::{Batch, Dataset, Shard};
use crate::engine::Engine;
use crate::metrics::{RoundRecord, RunResult};
use crate::net::{NetCfg, NetSim};
use crate::orbit::Orbit;
use crate::simkit::prng::{self, Rng};

/// One federated client: local parameters + compute engine + data shard.
pub struct Client {
    pub id: usize,
    pub w: Vec<f32>,
    pub engine: Box<dyn Engine>,
    pub shard: Shard,
    pub rng: Rng,
    pub attack: Attack,
}

impl Client {
    pub fn new(id: usize, engine: Box<dyn Engine>, shard: Shard, init_seed: u32) -> Self {
        let w = engine.init_params(init_seed);
        Client {
            id,
            w,
            engine,
            shard,
            rng: Rng::new(init_seed ^ 0xC11E_17, id as u32 + 1),
            attack: Attack::None,
        }
    }

    pub fn with_attack(mut self, attack: Attack) -> Self {
        self.attack = attack;
        self
    }

    /// Start from an existing (pretrained) checkpoint instead of init.
    pub fn with_checkpoint(mut self, w: &[f32]) -> Self {
        assert_eq!(w.len(), self.w.len());
        self.w.copy_from_slice(w);
        self
    }
}

/// Session hyperparameters.
#[derive(Debug, Clone)]
pub struct SessionCfg {
    pub algorithm: Algorithm,
    pub rounds: u64,
    pub eta: f32,
    pub mu: f32,
    pub batch_size: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: u64,
    /// eval minibatches per evaluation
    pub eval_batches: usize,
    pub eval_batch_size: usize,
    /// extra multiplicative projection noise `1 + c_g_noise*N(0,1)` — the
    /// paper's Figure 2 heterogeneity amplifier (Appendix H)
    pub c_g_noise: f32,
    /// which clients probe and vote each round (synchronized algorithms
    /// only; the FO baseline and MeZO always run full participation)
    pub participation: ParticipationCfg,
    /// how clients that missed rounds are brought current on rejoin:
    /// `replay` ships the missed seed-sign history, `rebroadcast` ships a
    /// dense checkpoint, `off` broadcasts every round to every client
    pub catchup: CatchupCfg,
    /// round-engine worker threads: 0 = auto (machine parallelism),
    /// 1 = sequential baseline, N = exactly N workers.  Every setting
    /// produces the same bits; this only trades wall-clock.
    pub threads: usize,
    /// impaired-channel simulation ([`crate::net`]): bit-flip / erasure
    /// uplinks, per-client link profiles and a round deadline.  The
    /// default ([`NetCfg::ideal`]) takes exactly the pre-`net` code
    /// paths — pinned bit-identical by `rust/tests/net_parity.rs`.
    pub net: NetCfg,
    pub seed: u32,
    /// print progress to stderr
    pub verbose: bool,
}

impl Default for SessionCfg {
    fn default() -> Self {
        SessionCfg {
            algorithm: Algorithm::FeedSign,
            rounds: 1000,
            eta: 1e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 100,
            eval_batches: 4,
            eval_batch_size: 32,
            c_g_noise: 0.0,
            participation: ParticipationCfg::Full,
            catchup: CatchupCfg::Off,
            threads: 0,
            net: NetCfg::ideal(),
            seed: 0,
            verbose: false,
        }
    }
}

/// The immutable description of one aggregation round, fixed in the plan
/// phase before any client compute runs.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub round: u64,
    /// sorted ids of the clients that probe and vote this round
    pub participants: Vec<usize>,
}

/// A participant's round contribution, produced in the execute phase.
enum Contribution {
    Sign(i8),
    Pair { seed: u32, p: f32 },
}

/// Execute-phase output for one participant: contribution + the uplink
/// messages metered into a private sub-ledger, committed in id order.
struct ProbeOutcome {
    client: usize,
    contribution: Contribution,
    ledger: Ledger,
}

fn run_probe_job<F>(round: u64, c: &mut Client, job: &F) -> ProbeOutcome
where
    F: Fn(&mut Client, &mut Ledger) -> Contribution,
{
    let mut ledger = Ledger::default();
    // RoundStart carries the implicit seed schedule (0 payload bits)
    ledger.record(&Message::RoundStart { round });
    let contribution = job(c, &mut ledger);
    ProbeOutcome { client: c.id, contribution, ledger }
}

/// Execute phase: run `job` on every participant, fanning contiguous
/// id-ordered chunks out over `threads` scoped workers.  The returned
/// outcomes are in client-id order regardless of worker interleaving
/// (chunks are contiguous and joined in spawn order), which is what makes
/// the commit phase bit-identical to the sequential baseline.
fn execute_probes<F>(
    clients: &mut [Client],
    plan: &RoundPlan,
    threads: usize,
    pin_serial: bool,
    job: F,
) -> Vec<ProbeOutcome>
where
    F: Fn(&mut Client, &mut Ledger) -> Contribution + Sync,
{
    let mut selected: Vec<&mut Client> = Vec::with_capacity(plan.participants.len());
    {
        let mut want = plan.participants.iter().copied().peekable();
        for (id, c) in clients.iter_mut().enumerate() {
            if want.peek() == Some(&id) {
                selected.push(c);
                want.next();
            }
        }
    }
    assert_eq!(
        selected.len(),
        plan.participants.len(),
        "participant ids must be sorted, distinct and in range"
    );
    let round = plan.round;
    if threads <= 1 || selected.len() <= 1 {
        // `pin_serial` marks an explicitly requested sequential baseline
        // (cfg.threads == 1): keep the inner noise ops single-threaded
        // too, so "threads = 1" means exactly one thread.  A fan-out
        // that merely degenerated to one job (e.g. K = 1) keeps inner
        // chunk-parallelism — it is the only parallelism available.
        let _serial = pin_serial.then(prng::serial_zone);
        return selected.into_iter().map(|c| run_probe_job(round, c, &job)).collect();
    }
    let chunk = selected.len().div_ceil(threads);
    let mut out = Vec::with_capacity(selected.len());
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for ch in selected.chunks_mut(chunk) {
            let job = &job;
            handles.push(s.spawn(move || {
                // client-level parallelism is the outer fan-out; keep the
                // per-vector noise ops sequential inside each worker
                let _serial = prng::serial_zone();
                ch.iter_mut()
                    .map(|c| run_probe_job(round, &mut **c, job))
                    .collect::<Vec<_>>()
            }));
        }
        for h in handles {
            out.extend(h.join().expect("round worker panicked"));
        }
    });
    out
}

/// Run `job` on every client, chunk-parallel over `threads` workers (used
/// by the commit phase to apply the broadcast update).
fn for_each_client_parallel<F>(clients: &mut [Client], threads: usize, pin_serial: bool, job: F)
where
    F: Fn(&mut Client) + Sync,
{
    if threads <= 1 || clients.len() <= 1 {
        let _serial = pin_serial.then(prng::serial_zone);
        for c in clients {
            job(c);
        }
        return;
    }
    let chunk = clients.len().div_ceil(threads);
    std::thread::scope(|s| {
        for ch in clients.chunks_mut(chunk) {
            let job = &job;
            s.spawn(move || {
                let _serial = prng::serial_zone();
                for c in ch {
                    job(c);
                }
            });
        }
    });
}

/// The federated runtime.
pub struct Session {
    pub cfg: SessionCfg,
    pub clients: Vec<Client>,
    pub train: Dataset,
    pub test: Dataset,
    pub ledger: Ledger,
    pub orbit: Orbit,
    /// Per-round committed-update history (maintained only while
    /// [`SessionCfg::catchup`] is on; the compaction watermark is the
    /// slowest client in [`Session::tracker`]).
    pub history: SeedHistory,
    /// Per-client `last_synced_round` watermarks for catch-up.
    pub tracker: CatchupTracker,
    /// Impaired-channel simulator (a no-op shell when
    /// [`SessionCfg::net`] is the ideal default); `net.stats` holds the
    /// run's impairment counters.
    pub net: NetSim,
    dp_rng: Rng,
    eval_rng: Rng,
    part_rng: Rng,
}

impl Session {
    pub fn new(cfg: SessionCfg, clients: Vec<Client>, train: Dataset, test: Dataset) -> Self {
        assert!(!clients.is_empty());
        if matches!(cfg.algorithm, Algorithm::Mezo) {
            assert_eq!(clients.len(), 1, "MeZO is centralized (K = 1)");
        }
        if cfg.catchup.is_on() {
            assert!(
                matches!(
                    cfg.algorithm,
                    Algorithm::FeedSign | Algorithm::DpFeedSign { .. } | Algorithm::ZoFedSgd
                ),
                "catch-up applies to the synchronized seed-based algorithms only"
            );
        }
        let tracker = CatchupTracker::new(clients.len());
        let orbit = Orbit::new(cfg.algorithm.name(), cfg.seed, cfg.eta);
        let net = NetSim::new(cfg.net.clone());
        let dp_rng = Rng::new(cfg.seed ^ 0xD9, 0xD9);
        let eval_rng = Rng::new(cfg.seed ^ 0xEE, 0xEE);
        let part_rng = Rng::new(cfg.seed ^ 0x9A, 0x9A);
        Session {
            cfg,
            clients,
            train,
            test,
            ledger: Ledger::default(),
            orbit,
            history: SeedHistory::default(),
            tracker,
            net,
            dp_rng,
            eval_rng,
            part_rng,
        }
    }

    /// Drive all rounds; returns the run record.
    pub fn run(&mut self) -> RunResult {
        let start = std::time::Instant::now();
        let mut records = Vec::new();
        for t in 0..self.cfg.rounds {
            self.step(t);
            let do_eval = self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0;
            if do_eval {
                let (loss, acc) = self.evaluate();
                if self.cfg.verbose {
                    eprintln!(
                        "[{}] round {:>6}: eval loss {loss:.4} acc {:.1}% (up {} bits)",
                        self.cfg.algorithm.name(),
                        t + 1,
                        acc * 100.0,
                        self.ledger.uplink_bits
                    );
                }
                records.push(RoundRecord {
                    round: t + 1,
                    eval_loss: loss,
                    eval_acc: acc,
                    uplink_bits: self.ledger.uplink_bits,
                    downlink_bits: self.ledger.downlink_bits,
                });
            }
        }
        // run end: every straggler performs its (metered) rejoin so the
        // final model is distributed to the whole pool
        self.catch_up_all();
        let (final_loss, final_acc) = self.evaluate();
        RunResult {
            algorithm: self.cfg.algorithm.name().to_string(),
            records,
            ledger: self.ledger.clone(),
            final_loss,
            final_acc,
            rounds: self.cfg.rounds,
            wall_s: start.elapsed().as_secs_f64(),
            net: self.net.stats.clone(),
        }
    }

    /// One aggregation round.
    pub fn step(&mut self, t: u64) {
        match self.cfg.algorithm {
            Algorithm::FedSgd => self.step_fedsgd(t),
            Algorithm::Mezo => self.step_mezo(t),
            _ => {
                let plan = self.plan_round(t);
                self.step_with_plan(plan);
            }
        }
    }

    /// One synchronized round driven by an externally fixed plan — the
    /// plan-phase output made injectable so tests (and schedulers) can pin
    /// a deterministic participation schedule, e.g. forcing a client
    /// offline for exactly k rounds (`rust/tests/catchup_parity.rs`).
    /// Plans must arrive in round order when catch-up is on (the seed
    /// history commits in round order).
    pub fn step_with_plan(&mut self, plan: RoundPlan) {
        match self.cfg.algorithm {
            Algorithm::FeedSign => self.step_feedsign(plan, None),
            Algorithm::DpFeedSign { epsilon } => self.step_feedsign(plan, Some(epsilon)),
            Algorithm::ZoFedSgd => self.step_zo_fedsgd(plan),
            Algorithm::FedSgd | Algorithm::Mezo => {
                panic!("step_with_plan drives the synchronized seed-based algorithms only")
            }
        }
    }

    /// Plan phase: fix the participant set before any client compute —
    /// the participation draw, then (with an active [`SessionCfg::net`])
    /// the virtual-clock admission: stragglers whose link latency blows
    /// the round deadline are excluded here, before they probe, and
    /// resync later through the catch-up machinery.
    fn plan_round(&mut self, t: u64) -> RoundPlan {
        let mut participants =
            self.cfg.participation.sample(self.clients.len(), t, &mut self.part_rng);
        if self.net.is_active() {
            let (up, down) = self.round_payload_bits(participants.len());
            participants = self.net.admit(t, participants, up, down);
        }
        RoundPlan { round: t, participants }
    }

    /// Paper-accounting payload bits one participant moves in a round
    /// (uplink, downlink) — what the virtual event clock charges to the
    /// link.
    fn round_payload_bits(&self, participants: usize) -> (u64, u64) {
        let d = self.clients[0].engine.n_params() as u64;
        match self.cfg.algorithm {
            Algorithm::FeedSign | Algorithm::DpFeedSign { .. } => (1, 1),
            Algorithm::ZoFedSgd => (64, 64 * participants.max(1) as u64),
            Algorithm::FedSgd => (32 * d, 32 * d),
            Algorithm::Mezo => (0, 0),
        }
    }

    /// Replay (or dense-rebroadcast) the committed history to every client
    /// in `ids` that is stale relative to `to_round`, metering the
    /// downlink per [`CatchupCfg`].  Updates go through the same exact
    /// chunk-parallel AXPY path ([`crate::engine::Engine::update`] →
    /// `zo::apply_update`) the participants used when each round
    /// committed, in commit order — which is why a rejoining replica is
    /// bit-identical to an always-on one.
    fn catch_up_clients(&mut self, ids: &[usize], to_round: u64) {
        debug_assert!(self.cfg.catchup.is_on());
        let d = self.clients[0].engine.n_params();
        // honor the explicitly requested sequential baseline
        let _serial = (self.cfg.threads == 1).then(prng::serial_zone);
        for &id in ids {
            let span = self.tracker.span(id, to_round);
            if span.is_empty() {
                continue;
            }
            let records = self.history.replay_span(span.start, span.end).unwrap_or_else(|| {
                panic!(
                    "catch-up span {span:?} for client {id} was compacted away; \
                     compaction must respect the tracker watermark"
                )
            });
            if records.is_empty() {
                // the missed span held only zero-participant no-op
                // rounds: nothing to apply, nothing to bill (mirrors the
                // distributed topology's empty-replay guard)
                self.tracker.mark_synced(id, to_round);
                continue;
            }
            let records = match self.cfg.catchup {
                CatchupCfg::Replay => {
                    // meter through the actual message, then take the
                    // records back for the update loop (no span clone)
                    let msg = Message::ReplayHistory { records };
                    self.ledger.record(&msg);
                    let Message::ReplayHistory { records } = msg else { unreachable!() };
                    records
                }
                CatchupCfg::Rebroadcast => {
                    self.ledger.record(&Message::Rebroadcast { n_params: d });
                    records
                }
                CatchupCfg::Off => unreachable!(),
            };
            let c = &mut self.clients[id];
            for r in &records {
                c.engine.update(&mut c.w, r.seed, r.step());
            }
            self.tracker.mark_synced(id, to_round);
        }
    }

    /// Bring every client current with the committed history — the
    /// metered rejoin all stragglers perform when a run ends (no-op with
    /// catch-up off, where every client is always current).
    pub fn catch_up_all(&mut self) {
        if !self.cfg.catchup.is_on() {
            return;
        }
        let ids: Vec<usize> = (0..self.clients.len()).collect();
        let to = self.history.head_round();
        self.catch_up_clients(&ids, to);
        self.history.compact_to(self.tracker.watermark());
    }

    /// Commit-phase history bookkeeping: append this round's records and
    /// compact the ring down to the slowest client's watermark.
    fn commit_history(&mut self, round: u64, records: Vec<SeedRecord>) {
        if !self.cfg.catchup.is_on() {
            return;
        }
        self.history.commit_round(round, records);
        self.history.compact_to(self.tracker.watermark());
    }

    /// Worker count for a fan-out over `jobs` independent units.
    fn worker_threads(&self, jobs: usize) -> usize {
        let t = if self.cfg.threads > 0 {
            self.cfg.threads
        } else {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        };
        t.min(jobs.max(1))
    }

    /// FeedSign (Algorithm 1, FeedSign branch): shared seed = t, 1-bit
    /// votes up, 1-bit majority (or DP vote) down, synchronized update.
    fn step_feedsign(&mut self, plan: RoundPlan, dp_epsilon: Option<f32>) {
        let t = plan.round;
        // catch-up: stale participants replay their missed span *before*
        // probing, so every vote is cast on the current model
        if self.cfg.catchup.is_on() {
            let ids = plan.participants.clone();
            self.catch_up_clients(&ids, t);
        }
        if plan.participants.is_empty() {
            // zero-participant round: commit a no-op (no votes, no
            // broadcast); the 0-sign orbit entry and the empty history
            // round keep round indices dense for both replay paths
            self.orbit.push_sign(0);
            self.commit_history(t, Vec::new());
            return;
        }
        let threads = self.worker_threads(plan.participants.len());
        let seed = t as u32;
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let pin_serial = self.cfg.threads == 1;
        let train = &self.train;
        // execute: fan the probes out; each worker meters its own uplink
        let outcomes = execute_probes(&mut self.clients, &plan, threads, pin_serial, |c, ledger| {
            let batch = c.shard.next_batch(train, bs, &mut c.rng);
            let mut p = c.engine.probe(&c.w, &batch, seed, mu);
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let honest = if p >= 0.0 { 1i8 } else { -1 };
            let sign = c.attack.mutate_sign(honest, &mut c.rng);
            ledger.record(&Message::SignVote { sign });
            Contribution::Sign(sign)
        });
        // commit: votes and sub-ledgers in client-id order; each vote
        // then crosses the (possibly impaired) uplink — a flip lands in
        // the vote, a drop makes the PS treat the voter as absent this
        // round (the transmission is still billed: the bits were sent)
        let mut signs = Vec::with_capacity(outcomes.len());
        let mut voters = Vec::with_capacity(outcomes.len());
        let mut subs = Vec::with_capacity(outcomes.len());
        for (o, &id) in outcomes.into_iter().zip(&plan.participants) {
            debug_assert_eq!(o.client, id, "commit order must be client-id order");
            let Contribution::Sign(s) = o.contribution else {
                unreachable!("feedsign job yields sign votes");
            };
            subs.push(o.ledger);
            if let Some(s) = self.net.deliver_sign(t, id, s) {
                signs.push(s);
                voters.push(id);
            }
        }
        self.ledger.commit(subs);
        if signs.is_empty() {
            // every vote was lost in transit: the round aborts to a no-op
            // commit, exactly like a zero-participant plan
            self.orbit.push_sign(0);
            self.commit_history(t, Vec::new());
            return;
        }
        let f = match dp_epsilon {
            None => aggregation::majority_sign(&signs),
            Some(eps) => aggregation::dp_vote(&signs, eps, &mut self.dp_rng),
        };
        let step = f as f32 * self.cfg.eta;
        let msg = Message::GlobalSign { sign: f };
        if self.cfg.catchup.is_on() {
            // only the clients the PS heard from hear the broadcast;
            // everyone else (sampled out, deadline-cut, or dropped on the
            // uplink) recovers the round from the seed history on rejoin
            let _serial = pin_serial.then(prng::serial_zone);
            for &id in &voters {
                self.ledger.record(&msg);
                let c = &mut self.clients[id];
                c.engine.update(&mut c.w, seed, step);
                self.tracker.mark_synced(id, t + 1);
            }
        } else {
            // broadcast to every client (non-participants too: the 1-bit
            // downlink is what keeps all replicas synchronized)
            for _ in 0..self.clients.len() {
                self.ledger.record(&msg);
            }
            let threads_all = self.worker_threads(self.clients.len());
            for_each_client_parallel(&mut self.clients, threads_all, pin_serial, |c| {
                c.engine.update(&mut c.w, seed, step);
            });
        }
        self.orbit.push_sign(f);
        self.commit_history(t, vec![SeedRecord::sign_step(t, f, self.cfg.eta)]);
    }

    /// ZO-FedSGD (FwdLLM/FedKSeed-style): each participant samples its own
    /// seed, uploads a 64-bit seed-projection pair; everyone downloads all
    /// pairs and applies the mean update.
    fn step_zo_fedsgd(&mut self, plan: RoundPlan) {
        let t = plan.round;
        if self.cfg.catchup.is_on() {
            let ids = plan.participants.clone();
            self.catch_up_clients(&ids, t);
        }
        if plan.participants.is_empty() {
            self.orbit.push_pairs(Vec::new());
            self.commit_history(t, Vec::new());
            return;
        }
        let threads = self.worker_threads(plan.participants.len());
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let pin_serial = self.cfg.threads == 1;
        let train = &self.train;
        let outcomes = execute_probes(&mut self.clients, &plan, threads, pin_serial, |c, ledger| {
            let seed = c.rng.next_u32() & 0x7FFF_FFFF; // direction counters < 2^31
            let batch = c.shard.next_batch(train, bs, &mut c.rng);
            let mut p = c.engine.probe(&c.w, &batch, seed, mu);
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let p = c.attack.mutate_projection(p, &mut c.rng);
            ledger.record(&Message::Projection { seed, p });
            Contribution::Pair { seed, p }
        });
        // commit in client-id order; each 64-bit pair crosses the uplink
        // (flipped seed bits pick a different-but-valid direction,
        // flipped projection bits corrupt the coefficient, a drop makes
        // the PS treat the client as absent — transmission still billed)
        let mut pairs = Vec::with_capacity(outcomes.len());
        let mut voters = Vec::with_capacity(outcomes.len());
        let mut subs = Vec::with_capacity(outcomes.len());
        for (o, &id) in outcomes.into_iter().zip(&plan.participants) {
            debug_assert_eq!(o.client, id, "commit order must be client-id order");
            let Contribution::Pair { seed, p } = o.contribution else {
                unreachable!("zo-fedsgd job yields seed-projection pairs");
            };
            subs.push(o.ledger);
            if let Some((seed, p)) = self.net.deliver_pair(t, id, seed, p) {
                pairs.push((seed, p));
                voters.push(id);
            }
        }
        self.ledger.commit(subs);
        if pairs.is_empty() {
            // every pair was lost in transit: no-op round
            self.orbit.push_pairs(Vec::new());
            self.commit_history(t, Vec::new());
            return;
        }
        let k = pairs.len();
        let eta = self.cfg.eta;
        let msg = Message::GlobalProjections { pairs: pairs.clone() };
        if self.cfg.catchup.is_on() {
            let _serial = pin_serial.then(prng::serial_zone);
            for &id in &voters {
                self.ledger.record(&msg);
                let c = &mut self.clients[id];
                for &(seed, p) in &pairs {
                    c.engine.update(&mut c.w, seed, eta * p / k as f32);
                }
                self.tracker.mark_synced(id, t + 1);
            }
        } else {
            for _ in 0..self.clients.len() {
                self.ledger.record(&msg);
            }
            let threads_all = self.worker_threads(self.clients.len());
            let pairs_ref = &pairs;
            for_each_client_parallel(&mut self.clients, threads_all, pin_serial, |c| {
                for &(seed, p) in pairs_ref {
                    c.engine.update(&mut c.w, seed, eta * p / k as f32);
                }
            });
        }
        // history: one record per pair, the mean-projection coefficient
        // folded into (sign, lr_scale) so replay applies `sign·lr_scale`
        // == `eta·p/k` bit-exactly
        let records: Vec<SeedRecord> = pairs
            .iter()
            .map(|&(seed, p)| SeedRecord::pair_step(t, seed, eta * p / k as f32))
            .collect();
        self.orbit.push_pairs(pairs);
        self.commit_history(t, records);
    }

    /// FedSGD first-order baseline: dense gradient exchange (always full
    /// participation; partial regimes are a ZO-side study).  Each 32·d-bit
    /// gradient crosses the impaired uplink like every other message —
    /// which is where the dense baseline pays for its payload: one
    /// flipped exponent bit blows a gradient entry up by orders of
    /// magnitude, the fragility the BER robustness bench measures.
    fn step_fedsgd(&mut self, t: u64) {
        let bs = self.cfg.batch_size;
        let d = self.clients[0].engine.n_params();
        // virtual clock: a dense round still costs wall-clock on every
        // link (there is no plan phase here, so the deadline cut does not
        // apply — the config layer rejects deadline+fedsgd)
        if self.net.is_active() {
            let (up, down) = self.round_payload_bits(self.clients.len());
            let everyone: Vec<usize> = (0..self.clients.len()).collect();
            let _ = self.net.admit(t, everyone, up, down);
        }
        let mut acc = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        let mut delivered = 0usize;
        for c in &mut self.clients {
            let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
            c.engine.grad(&mut c.w, &batch, &mut g);
            c.attack.mutate_gradient(&mut g, &mut c.rng);
            self.ledger.record(&Message::Gradient { g: Vec::new() }); // meter below
            self.ledger.uplink_bits += 32 * d as u64;
            if self.net.deliver_gradient(t, c.id, &mut g) {
                aggregation::accumulate(&mut acc, &g);
                delivered += 1;
            }
        }
        if delivered == 0 {
            // every gradient was lost in transit: no update, no broadcast
            return;
        }
        aggregation::finish_mean(&mut acc, delivered);
        for c in &mut self.clients {
            self.ledger.record(&Message::GlobalGradient { g: Vec::new() });
            self.ledger.downlink_bits += 32 * d as u64;
            for (wi, gi) in c.w.iter_mut().zip(&acc) {
                *wi -= self.cfg.eta * gi;
            }
        }
    }

    /// Centralized MeZO (K = 1): no communication.
    fn step_mezo(&mut self, t: u64) {
        let seed = t as u32;
        let (mu, bs) = (self.cfg.mu, self.cfg.batch_size);
        let c = &mut self.clients[0];
        let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
        let p = c.engine.probe(&c.w, &batch, seed, mu);
        c.engine.update(&mut c.w, seed, self.cfg.eta * p);
        self.orbit.push_pairs(vec![(seed, p)]);
    }

    /// Evaluate the global model on the test set.  With catch-up off this
    /// is client 0's replica (identical across clients for every
    /// synchronized algorithm); with catch-up on, replicas legitimately
    /// differ mid-run, so the freshest replica (lowest id among the
    /// most-synced clients) stands in for the global model.
    pub fn evaluate(&mut self) -> (f32, f32) {
        let mut idx = 0usize;
        if self.cfg.catchup.is_on() {
            let mut best = self.tracker.last_synced(0);
            for i in 1..self.clients.len() {
                let s = self.tracker.last_synced(i);
                if s > best {
                    best = s;
                    idx = i;
                }
            }
        }
        let c = &mut self.clients[idx];
        let mut loss_sum = 0.0f64;
        let mut correct = 0u32;
        let mut total = 0u32;
        let mut eval_shard = Shard::new((0..self.test.len()).collect());
        for _ in 0..self.cfg.eval_batches {
            let batch =
                eval_shard.next_batch(&self.test, self.cfg.eval_batch_size, &mut self.eval_rng);
            let rows = batch.rows() as u32;
            let (l, corr) = c.engine.eval(&mut c.w, &batch);
            loss_sum += l as f64;
            correct += corr;
            total += rows;
        }
        (
            (loss_sum / self.cfg.eval_batches as f64) as f32,
            correct as f32 / total.max(1) as f32,
        )
    }

    /// Checksum of client replicas — synchronized algorithms must keep all
    /// replicas identical (`assert_synchronized` test hook).  With
    /// catch-up on this holds only once every client is current (e.g.
    /// after [`Session::catch_up_all`]), not mid-run.
    pub fn replicas_synchronized(&self) -> bool {
        let w0 = &self.clients[0].w;
        self.clients.iter().all(|c| &c.w == w0)
    }

    /// Batch for external probing (sign-reversal studies).
    pub fn sample_train_batch(&mut self, client: usize, size: usize) -> Batch {
        let c = &mut self.clients[client];
        c.shard.next_batch(&self.train, size, &mut c.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::vision::{generate, SYNTH_CIFAR10};
    use crate::engine::NativeEngine;
    use crate::simkit::nn::LinearProbe;

    fn make_session(algo: Algorithm, k: usize, rounds: u64) -> Session {
        let train = generate(&SYNTH_CIFAR10, 400, 0);
        let test = generate(&SYNTH_CIFAR10, 200, 1);
        let shards = split(&train, k, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: algo,
            rounds,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            eval_batches: 4,
            eval_batch_size: 32,
            seed: 7,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    }

    #[test]
    fn feedsign_improves_over_init() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        let (l0, a0) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, a1) = s.evaluate();
        assert!(l1 < l0, "loss {l0} -> {l1}");
        assert!(a1 > a0, "acc {a0} -> {a1}");
    }

    #[test]
    fn feedsign_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn zo_fedsgd_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::ZoFedSgd, 4, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn fedsgd_descends_fast() {
        let mut s = make_session(Algorithm::FedSgd, 3, 0);
        s.cfg.eta = 0.1;
        let (l0, _) = s.evaluate();
        for t in 0..60 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0 * 0.8, "FO should descend quickly: {l0} -> {l1}");
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn comm_accounting_feedsign_exact() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..100 {
            s.step(t);
        }
        // Eq. 5: 1 bit up per client per step, 1 bit down per client per step
        assert_eq!(s.ledger.uplink_bits, 100 * 5);
        assert_eq!(s.ledger.downlink_bits, 100 * 5);
    }

    #[test]
    fn comm_accounting_zo_fedsgd_exact() {
        let mut s = make_session(Algorithm::ZoFedSgd, 5, 0);
        for t in 0..10 {
            s.step(t);
        }
        // 64 bits up per client per step; 64*K bits down per client per step
        assert_eq!(s.ledger.uplink_bits, 10 * 5 * 64);
        assert_eq!(s.ledger.downlink_bits, 10 * 5 * 5 * 64);
    }

    #[test]
    fn mezo_has_zero_comm() {
        let mut s = make_session(Algorithm::Mezo, 1, 0);
        for t in 0..20 {
            s.step(t);
        }
        assert_eq!(s.ledger.total_bits(), 0);
    }

    #[test]
    fn orbit_replay_matches_final_params() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        for t in 0..200 {
            s.step(t);
        }
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w, s.clients[0].w, "orbit replay must reconstruct exactly");
    }

    #[test]
    fn run_produces_records() {
        let mut s = make_session(Algorithm::FeedSign, 2, 50);
        s.cfg.eval_every = 10;
        let result = s.run();
        assert_eq!(s.cfg.rounds, 50);
        assert_eq!(result.records.len(), 5);
        assert!(result.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = make_session(Algorithm::FeedSign, 3, 30).run();
        let r2 = make_session(Algorithm::FeedSign, 3, 30).run();
        assert_eq!(r1.final_loss, r2.final_loss);
        assert_eq!(r1.final_acc, r2.final_acc);
    }

    #[test]
    fn thread_count_does_not_change_the_run() {
        let mut seq = make_session(Algorithm::FeedSign, 5, 0);
        seq.cfg.threads = 1;
        let mut par = make_session(Algorithm::FeedSign, 5, 0);
        par.cfg.threads = 4;
        for t in 0..60 {
            seq.step(t);
            par.step(t);
        }
        assert_eq!(seq.clients[0].w, par.clients[0].w, "bit-identical across thread counts");
        assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits);
    }

    #[test]
    fn partial_participation_keeps_replicas_synchronized_and_meters_uplink() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4); // 2 of 5 per round
        for t in 0..100 {
            s.step(t);
        }
        assert!(s.replicas_synchronized(), "non-participants must track the broadcast");
        // uplink: only participants vote; downlink: everyone gets the bit
        assert_eq!(s.ledger.uplink_bits, 100 * 2);
        assert_eq!(s.ledger.downlink_bits, 100 * 5);
        assert_eq!(s.orbit.len(), 100);
    }

    #[test]
    fn partial_participation_still_learns() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Bernoulli(0.6);
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "partial participation should still learn: {l0} -> {l1}");
    }

    #[test]
    fn zo_fedsgd_partial_participation_divides_by_participants() {
        let mut s = make_session(Algorithm::ZoFedSgd, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4); // 2 of 5
        for t in 0..10 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
        // 64 bits per participant up; all K download the 2-pair bundle
        assert_eq!(s.ledger.uplink_bits, 10 * 2 * 64);
        assert_eq!(s.ledger.downlink_bits, 10 * 5 * 2 * 64);
    }

    #[test]
    fn zero_participant_round_commits_noop() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.0);
        let w0 = s.clients[0].w.clone();
        for t in 0..5 {
            s.step(t);
        }
        assert_eq!(s.clients[0].w, w0, "no participants, no update");
        assert_eq!(s.ledger.total_bits(), 0, "no votes, no broadcast");
        assert_eq!(s.orbit.len(), 5, "round indices stay dense");
        assert!(s.replicas_synchronized());
        // the 0-sign entries replay as no-ops, so the orbit still
        // reconstructs exactly
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w, s.clients[0].w);
    }

    #[test]
    fn catchup_replay_still_learns_and_resynchronizes() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4);
        s.cfg.catchup = CatchupCfg::Replay;
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        assert_eq!(s.history.head_round(), 800);
        s.catch_up_all();
        assert!(s.replicas_synchronized(), "rejoin must restore replica equality");
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "replay catch-up should still learn: {l0} -> {l1}");
    }

    #[test]
    fn byzantine_sign_flip_majority_resists() {
        // 1 attacker of 5: FeedSign majority vote must still learn
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.clients[0].attack = Attack::SignFlip;
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "FeedSign under 1/5 Byzantine should still learn");
    }

    #[test]
    fn drop_channel_voters_feed_catchup_and_resync() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.catchup = CatchupCfg::Replay;
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::Erasure { p: 0.4 },
            ..NetCfg::ideal()
        });
        for t in 0..200 {
            s.step(t);
        }
        assert!(s.net.stats.dropped_msgs > 0, "erasure channel must drop votes");
        // dropped voters were left stale; the end-of-run rejoin replays
        // their missed spans and restores replica equality
        s.catch_up_all();
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn deadline_cuts_iot_stragglers_from_the_plan() {
        use crate::net::{LinkAssignment, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FeedSign, 6, 0);
        s.net = NetSim::new(NetCfg {
            links: LinkAssignment::parse("mixed").unwrap(),
            deadline_s: 0.1,
            ..NetCfg::ideal()
        });
        for t in 0..20 {
            s.step(t);
        }
        // mixed cycle: ids 2 and 5 ride the iot profile (0.4 s RTT, over
        // the 0.1 s deadline every round) — cut at plan time, every round
        assert_eq!(s.net.stats.stragglers, 2 * 20);
        assert_eq!(s.ledger.uplink_bits, 20 * 4, "only on-time clients vote");
        // catch-up off: the broadcast still reaches everyone, so replicas
        // stay synchronized even though stragglers never probe
        assert_eq!(s.ledger.downlink_bits, 20 * 6);
        assert!(s.replicas_synchronized());
        assert!(s.net.stats.virtual_s > 0.0);
    }

    #[test]
    fn ber_corrupts_zo_pairs_but_replicas_stay_synchronized() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::ZoFedSgd, 4, 0);
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::BitFlip { ber: 0.02 },
            ..NetCfg::ideal()
        });
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.net.stats.flipped_bits > 0, "2% BER over 64-bit pairs must flip");
        // everyone applies the same delivered (possibly corrupted) pairs;
        // compare replicas as bit patterns — corruption can drive weights
        // non-finite, where f32 equality would lie
        let w0: Vec<u32> = s.clients[0].w.iter().map(|v| v.to_bits()).collect();
        for c in &s.clients[1..] {
            let wi: Vec<u32> = c.w.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wi, w0, "client {} diverged", c.id);
        }
    }

    #[test]
    fn fedsgd_drop_channel_averages_only_delivered_gradients() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FedSgd, 3, 0);
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::Erasure { p: 0.5 },
            ..NetCfg::ideal()
        });
        for t in 0..10 {
            s.step(t);
        }
        assert!(s.net.stats.dropped_msgs > 0);
        assert!(s.replicas_synchronized(), "the averaged broadcast reaches everyone");
    }

    #[test]
    fn dp_feedsign_runs_and_learns_at_high_epsilon() {
        let mut s = make_session(Algorithm::DpFeedSign { epsilon: 50.0 }, 5, 0);
        let (l0, _) = s.evaluate();
        for t in 0..600 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0);
    }
}
