//! The federated session: PS round loop + client pool (Algorithm 1),
//! organised as a **plan / execute / commit** round engine over a
//! copy-on-write **replica plane** ([`crate::coordinator::replica`]).
//!
//! One `Session` owns the K clients (engine, data shard, RNG stream and
//! attack model each) plus a single [`ReplicaStore`]: FeedSign's replica
//! invariant means every synchronized client holds bit-identical
//! parameters, so the pool shares **one canonical buffer** instead of
//! K dense copies — `O(d)` coordinator memory for arbitrarily large
//! pools, and one canonical AXPY per committed round where the dense
//! layout applied K.  Each round:
//!
//! 1. **plan** — the participant set is drawn from a dedicated coordinator
//!    RNG stream ([`ParticipationCfg`]), before any client compute runs;
//!    with an active [`crate::net`] simulation the virtual event clock
//!    then cuts deadline stragglers from the plan (they resync later via
//!    catch-up); when catch-up is on ([`CatchupCfg`]), stale participants
//!    replay their missed seed history *before* probing — for a `Shared`
//!    logical replica that replay is pure bookkeeping (bill the records,
//!    bump the watermark: the invariant makes the replayed bits the
//!    canonical buffer's), so every vote is cast on the current model;
//! 2. **execute** — per-client probe work (batch draw → SPSA probe →
//!    attack mutation) fans out over `std::thread::scope` workers, every
//!    synced participant probing the one shared canonical buffer
//!    (`probe` is read-only); workers are loaded by **size-aware
//!    bin-packing** (LPT over shard size × link class) instead of
//!    contiguous equal chunks, and each meters its uplink into a private
//!    sub-ledger;
//! 3. **commit** — outcomes are committed **in client-id order** (votes,
//!    sub-ledgers, orbit entries, seed-history records); each uplink
//!    contribution crosses the (possibly impaired) channel — flips
//!    corrupt it, drops make the PS treat the sender as absent — then
//!    the vote is aggregated and the global update is applied **once**
//!    to the canonical buffer.  Downlink billing is unchanged: every
//!    client is billed when `catchup = "off"` (the paper's broadcast
//!    assumption), or only the clients the PS heard from when catch-up
//!    is on (everyone else is left a *stale* logical replica and
//!    recovers the round from the [`crate::comm::SeedHistory`] on
//!    rejoin).
//!
//! A plan with **zero participants** (e.g. `fraction:0`) commits a no-op:
//! no votes, no broadcast, a 0-sign orbit entry, an empty history round
//! and a head-only advance of the replica plane — round indices stay
//! dense so orbit replay, catch-up replay and stale-replica reads keep
//! working.
//!
//! **Determinism contract:** commit order is client id, every client's
//! randomness lives in its own Philox stream, and coordinator randomness
//! (participation, DP vote, eval) lives in dedicated streams — so a run is
//! bit-identical for *every* worker-thread count and *every* worker
//! assignment (the bin-packing only schedules; outcomes are reassembled
//! in id order), including the sequential `threads = 1` baseline (pinned
//! by `rust/tests/parallel_parity.rs`), and FeedSign's step seed remains
//! the round index (`seed = t`, §I.1).  The single canonical apply is
//! bit-identical to the K per-client applies it replaced because
//! [`crate::engine::Engine::update`] is a pure function of
//! `(w, seed, step)` (pinned by `rust/tests/replica_parity.rs` against a
//! dense K-replica mirror).  The cross-topology tests in `rust/tests/`
//! (sync vs threaded-distributed, where clients *do* own dense replicas)
//! rely on the same schedule.
//!
//! **Sharded mode** ([`SessionCfg::shards`] >= 1, `--shards N` /
//! `FEEDSIGN_SHARDS`): the pool is partitioned into contiguous-id
//! coordinator shards ([`crate::coordinator::shard`]).  The plan phase is
//! unchanged — the participant set is drawn *globally* (sequenced RNG)
//! and split along shard boundaries; each shard executes its slice
//! against the shared read-only canonical buffer and pre-reduces its
//! sign votes to an associative `(sum, voters)` pair, shipped as one
//! [`Message::ShardVotes`] per round into the plane's merge ledger
//! (coordinator-internal — the client-facing ledger is byte-identical to
//! the unsharded run's).  The round loop goes event-driven: the first
//! shard to finish triggers the round-`t+1` plan draw while stragglers
//! drain, with commit ordering still enforced globally — so a sharded
//! run is **bit-identical** to the barriered engine for every shard
//! count, thread count and topology (pinned by
//! `rust/tests/shard_parity.rs`).

use crate::comm::{Ledger, Message, SeedHistory, SeedPool, SeedRecord};
use crate::coordinator::aggregation::{self, Algorithm};
use crate::coordinator::byzantine::Attack;
use crate::coordinator::catchup::{CatchupCfg, CatchupTracker};
use crate::coordinator::participation::ParticipationCfg;
use crate::coordinator::replica::{ReplicaState, ReplicaStats, ReplicaStore};
use crate::coordinator::shard::{ShardPlane, ShardStats, VoteAcc};
use crate::data::{Batch, Dataset, Shard};
use crate::engine::{probe_batch_staged, Engine, ProbeBatchStats, ProbeJob, StagedViews};
use crate::metrics::{RoundRecord, RunResult};
use crate::net::{NetCfg, NetSim};
use crate::obs::{Event, Phase, SpanBuf, Tracer};
use crate::orbit::Orbit;
use crate::simkit::prng::{self, Rng};
use std::borrow::Cow;

/// How a client's initial replica is specified.  The session materializes
/// these into the replica plane at construction: client 0's init becomes
/// the canonical buffer, and any client whose init differs bit-wise is
/// promoted to an owned (diverged) replica.
#[derive(Debug, Clone)]
enum ClientInit {
    /// `Engine::init_params(seed)` — identical across clients/engines for
    /// a given seed (the shared-checkpoint assumption).
    Seed(u32),
    /// An explicit dense checkpoint (e.g. pretrained weights).  Only one
    /// client needs to carry the buffer; the rest declare
    /// [`ClientInit::SessionCheckpoint`].
    Checkpoint(Vec<f32>),
    /// Starts bit-identical to the session's initial canonical buffer
    /// (client 0's init) without carrying a copy of it.
    SessionCheckpoint,
    /// The session consumed this client's explicit checkpoint at
    /// construction (it became the client's owned diverged buffer in the
    /// replica plane).  Only client 0's init stays load-bearing after
    /// construction — it seeds stale-replica reconstruction — so nothing
    /// retains a second dense copy.
    Consumed,
}

/// One federated client: compute engine + data shard + RNG stream.  The
/// parameter vector is *not* here — clients are logical replicas in the
/// session's [`ReplicaStore`]; read one through [`Session::replica`].
pub struct Client {
    pub id: usize,
    pub engine: Box<dyn Engine>,
    pub shard: Shard,
    pub rng: Rng,
    pub attack: Attack,
    init: ClientInit,
}

impl Client {
    pub fn new(id: usize, engine: Box<dyn Engine>, shard: Shard, init_seed: u32) -> Self {
        Client {
            id,
            engine,
            shard,
            rng: Rng::new(init_seed ^ 0xC11E_17, id as u32 + 1),
            attack: Attack::None,
            init: ClientInit::Seed(init_seed),
        }
    }

    pub fn with_attack(mut self, attack: Attack) -> Self {
        self.attack = attack;
        self
    }

    /// Start from an existing (pretrained) checkpoint instead of init.
    /// Give the checkpoint to client 0 and mark the rest with
    /// [`Client::with_session_checkpoint`] so the pool shares one copy.
    pub fn with_checkpoint(mut self, w: &[f32]) -> Self {
        assert_eq!(w.len(), self.engine.n_params());
        self.init = ClientInit::Checkpoint(w.to_vec());
        self
    }

    /// Start bit-identical to client 0's initial replica without holding
    /// a copy of it (the constructor-side arm of the copy-on-write
    /// replica plane).
    pub fn with_session_checkpoint(mut self) -> Self {
        self.init = ClientInit::SessionCheckpoint;
        self
    }

    /// Materialize this client's declared initial replica (`None` when
    /// the init defers to client 0 or was already consumed into the
    /// replica plane).
    fn initial_params(&self) -> Option<Vec<f32>> {
        match &self.init {
            ClientInit::Seed(s) => Some(self.engine.init_params(*s)),
            ClientInit::Checkpoint(w) => Some(w.clone()),
            ClientInit::SessionCheckpoint | ClientInit::Consumed => None,
        }
    }
}

/// Session hyperparameters.
#[derive(Debug, Clone)]
pub struct SessionCfg {
    pub algorithm: Algorithm,
    pub rounds: u64,
    pub eta: f32,
    pub mu: f32,
    pub batch_size: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: u64,
    /// eval minibatches per evaluation
    pub eval_batches: usize,
    pub eval_batch_size: usize,
    /// extra multiplicative projection noise `1 + c_g_noise*N(0,1)` — the
    /// paper's Figure 2 heterogeneity amplifier (Appendix H)
    pub c_g_noise: f32,
    /// which clients probe and vote each round (synchronized algorithms
    /// only; the FO baseline and MeZO always run full participation)
    pub participation: ParticipationCfg,
    /// how clients that missed rounds are brought current on rejoin:
    /// `replay` ships the missed seed-sign history, `rebroadcast` ships a
    /// dense checkpoint, `pool` ships the K accumulated per-pool-seed
    /// step scalars (`seed_pool` mode only), `off` broadcasts every
    /// round to every client
    pub catchup: CatchupCfg,
    /// restricted seed space (FedKSeed): `>= 2` derives a pool of that
    /// many candidate directions once from [`SessionCfg::seed`] and
    /// names each round's direction by a `ceil(log2 K)`-bit index
    /// instead of the implicit `seed = t` schedule; 0 disables the pool
    /// (FeedSign algorithms only)
    pub seed_pool: usize,
    /// round-engine worker threads: 0 = auto (machine parallelism),
    /// 1 = sequential baseline, N = exactly N workers.  Every setting
    /// produces the same bits; this only trades wall-clock.
    pub threads: usize,
    /// impaired-channel simulation ([`crate::net`]): bit-flip / erasure
    /// uplinks, per-client link profiles and a round deadline.  The
    /// default ([`NetCfg::ideal`]) takes exactly the pre-`net` code
    /// paths — pinned bit-identical by `rust/tests/net_parity.rs`.
    pub net: NetCfg,
    /// replica-plane snapshot cache capacity
    /// ([`crate::coordinator::replica`]): how many pre-commit canonical
    /// buffers are retained so a *stale* logical replica can be read
    /// without an init-plus-history reconstruction.  Memory bound is
    /// `replica_cache · d` floats, spent only while stragglers exist;
    /// 0 disables the cache.  Never affects the computed bits.
    pub replica_cache: usize,
    /// coordinator shards (`--shards N` / `FEEDSIGN_SHARDS`): `>= 1`
    /// partitions the client pool into that many contiguous-id shards
    /// ([`crate::coordinator::shard`]), each owning its clients' probe
    /// fan-out and a local sign-vote accumulator; shards share the one
    /// canonical buffer read-only and merge vote *sums* hierarchically,
    /// and a shard finishing early lets the planner draw round `t+1`
    /// while stragglers drain.  Bit-identical to the barriered engine
    /// for every shard count (pinned by `rust/tests/shard_parity.rs`);
    /// 0 keeps the legacy unsharded path.  Read at [`Session::new`], not
    /// live: the partition is construction-time state.
    pub shards: usize,
    /// fused-sweep tile size in f32 elements (`--tile` /
    /// `FEEDSIGN_TILE`): the commit phase walks the canonical store in
    /// tiles of this many elements, applying the round's update *and*
    /// materialising the next round's staged `±mu` probe views in one
    /// read-modify-write pass ([`crate::simkit::zo::fused_commit_probe_threads`]).
    /// 0 = auto (the L2-sized [`prng::DEFAULT_TILE_ELEMS`], or the
    /// `FEEDSIGN_TILE` override).  Never affects the computed bits —
    /// pinned across tile sizes by `rust/tests/tile_parity.rs`.
    pub tile: usize,
    /// tiered canonical store budget in **bytes** (`--tile-budget` /
    /// `FEEDSIGN_TILE_BUDGET`): > 0 spills the canonical parameter
    /// store to a file-backed tile pager
    /// ([`crate::coordinator::tile::TileStore`]) whose resident window
    /// never exceeds this budget, so `d` past the budget runs with flat
    /// canonical memory.  0 keeps the store fully in RAM.  Bit-identical
    /// either way (same fused sweep drives both).
    pub tile_budget: usize,
    /// single-sweep fused commit (the tiled parameter plane's hot
    /// path): `false` forces the legacy closure-verb commit plus
    /// probe-time view passes — the parity reference the tile suites
    /// compare against.  Same bits either way, by construction.
    pub fuse_commits: bool,
    pub seed: u32,
    /// print progress to stderr
    pub verbose: bool,
}

impl Default for SessionCfg {
    fn default() -> Self {
        SessionCfg {
            algorithm: Algorithm::FeedSign,
            rounds: 1000,
            eta: 1e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 100,
            eval_batches: 4,
            eval_batch_size: 32,
            c_g_noise: 0.0,
            participation: ParticipationCfg::Full,
            catchup: CatchupCfg::Off,
            seed_pool: 0,
            threads: 0,
            net: NetCfg::ideal(),
            replica_cache: 4,
            // the env override reroutes every `..Default::default()`
            // construction (the whole test suite) through the sharded
            // plane — the CI `FEEDSIGN_SHARDS=4` leg; explicit config
            // (TOML / CLI) builds SessionCfg literally and wins
            shards: std::env::var("FEEDSIGN_SHARDS")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(0),
            // 0 = auto: the commit sweep resolves the tile through
            // `prng::tile_elems()`, which already honours FEEDSIGN_TILE
            tile: 0,
            // the env override reroutes every default-constructed
            // session (the whole test suite) through the file-backed
            // tile pager — the CI spill-mode leg
            tile_budget: std::env::var("FEEDSIGN_TILE_BUDGET")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(0),
            fuse_commits: true,
            seed: 0,
            verbose: false,
        }
    }
}

/// The immutable description of one aggregation round, fixed in the plan
/// phase before any client compute runs.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    pub round: u64,
    /// sorted ids of the clients that probe and vote this round
    pub participants: Vec<usize>,
}

/// A participant's round contribution, produced in the execute phase.
enum Contribution {
    Sign(i8),
    Pair { seed: u32, p: f32 },
}

/// Execute-phase output for one participant: contribution + the uplink
/// messages metered into a private sub-ledger, committed in id order.
struct ProbeOutcome {
    client: usize,
    contribution: Contribution,
    ledger: Ledger,
}

/// One participant's probe request after the spec stage: its drawn batch
/// and direction seed, plus the ledger its messages meter into.  The
/// replica view `w` is the grouping key — participants staged against
/// the *same* buffer (the shared canonical case) are served by one
/// [`probe_batch_staged`] call.
struct Staged<'a> {
    rank: usize,
    client: &'a mut Client,
    w: &'a [f32],
    batch: Batch,
    seed: u32,
    ledger: Ledger,
}

/// Run one worker's probe jobs: stage every client (spec draws —
/// per-client RNG order is preserved exactly), group staged jobs by
/// replica-view identity, serve each group through [`probe_batch_staged`]
/// (streaming the shared buffer once per view group instead of twice
/// per client — or with **zero** passes when the previous round's fused
/// commit sweep pre-staged this group's `±mu` views), then finish every
/// client in rank order (noise / attack draws + uplink metering).
/// Bit-exact vs the per-client loop: each client's own RNG stream sees
/// the identical draw sequence (spec draws, then its finish draws),
/// `Engine::loss` is pure, and the batched views carry the bits of the
/// unbatched fused AXPYs.
///
/// `staged` carries the sweep-staged views keyed by the canonical
/// buffer's address (as `usize`, so it crosses the worker spawn): only
/// the group actually probing the canonical buffer may be served from
/// them — an owned (diverged) replica's views differ from canonical's
/// even at the same seed, so it always takes the pass path.
fn run_worker_probes<S, F>(
    round: u64,
    work: Vec<(usize, (&mut Client, &[f32]))>,
    mu: f32,
    spec: &S,
    finish: &F,
    staged: Option<(usize, &StagedViews)>,
    trace: bool,
) -> (Vec<(usize, ProbeOutcome)>, ProbeBatchStats, SpanBuf)
where
    S: Fn(&mut Client, &mut Ledger) -> (Batch, u32),
    F: Fn(&mut Client, u32, f32, &mut Ledger) -> Contribution,
{
    let staged: Vec<Staged> = work
        .into_iter()
        .map(|(rank, (c, w))| {
            let mut ledger = Ledger::default();
            // RoundStart carries the implicit seed schedule (0 payload bits)
            ledger.record(&Message::RoundStart { round });
            let (batch, seed) = spec(c, &mut ledger);
            Staged { rank, client: c, w, batch, seed, ledger }
        })
        .collect();
    // group by view identity, in first-appearance (= rank) order: synced
    // participants all borrow the one canonical buffer and land in one
    // group; an owned (diverged) replica forms its own
    let mut keys: Vec<*const f32> = Vec::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for (i, s) in staged.iter().enumerate() {
        let key = s.w.as_ptr();
        match keys.iter().position(|&k| std::ptr::eq(k, key)) {
            Some(g) => groups[g].push(i),
            None => {
                keys.push(key);
                groups.push(vec![i]);
            }
        }
    }
    let mut stats = ProbeBatchStats::default();
    let mut buf = SpanBuf::new(trace);
    let mut projections = vec![0.0f32; staged.len()];
    let mut slots: Vec<Option<Staged>> = staged.into_iter().map(Some).collect();
    for idxs in &groups {
        let mut members: Vec<(usize, Staged)> =
            idxs.iter().map(|&i| (i, slots[i].take().expect("grouped once"))).collect();
        let w = members[0].1.w;
        let mut jobs: Vec<ProbeJob> = members
            .iter_mut()
            .map(|(_, s)| ProbeJob {
                engine: s.client.engine.as_mut(),
                batch: &s.batch,
                seed: s.seed,
            })
            .collect();
        let g0 = buf.clock();
        let sv = staged.and_then(|(canon, sv)| (w.as_ptr() as usize == canon).then_some(sv));
        let (ps, group_stats) = probe_batch_staged(w, mu, &mut jobs, sv);
        drop(jobs);
        stats.merge(&group_stats);
        buf.span(
            Phase::ProbeBatch,
            round,
            -1,
            -1,
            group_stats.probes,
            group_stats.canonical_passes,
            g0,
        );
        for ((i, s), p) in members.into_iter().zip(ps) {
            projections[i] = p;
            slots[i] = Some(s);
        }
    }
    let out = slots
        .into_iter()
        .zip(projections)
        .map(|(slot, p)| {
            let mut s = slot.expect("every staged job returns to its slot");
            if buf.on() {
                let (id, seed) = (s.client.id as i64, s.seed as u64);
                buf.push(Event::logical(Phase::Probe, round, -1, id, seed, 0));
            }
            let contribution = finish(s.client, s.seed, p, &mut s.ledger);
            (s.rank, ProbeOutcome { client: s.client.id, contribution, ledger: s.ledger })
        })
        .collect();
    (out, stats, buf)
}

/// Size-aware worker assignment: LPT (longest-processing-time-first)
/// greedy bin-packing of participant ranks into `bins` workers.
/// Deterministic — ties break toward the lower rank and the lower bin —
/// and *only* a schedule: outcomes are reassembled in participant order
/// afterwards, so the committed bits are independent of the packing.
/// Replaces the contiguous equal chunks of the original fan-out, which
/// assumed uniform probe cost (Dirichlet shards and mixed device classes
/// break that assumption).
fn pack_bins(costs: &[u64], bins: usize) -> Vec<Vec<usize>> {
    let bins = bins.min(costs.len()).max(1);
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then_with(|| a.cmp(&b)));
    let mut load = vec![0u64; bins];
    let mut packed: Vec<Vec<usize>> = vec![Vec::new(); bins];
    for rank in order {
        let lightest = (0..bins).min_by_key(|&b| (load[b], b)).expect("bins >= 1");
        packed[lightest].push(rank);
        load[lightest] += costs[rank].max(1);
    }
    for bin in &mut packed {
        bin.sort_unstable();
    }
    packed
}

/// Execute phase: run the spec → batched-probe → finish pipeline on
/// every participant, fanning out over `threads` scoped workers loaded
/// by [`pack_bins`] over `costs` (one cost per participant, in
/// participant order).  Every synced participant's replica view resolves
/// to the one shared canonical buffer, so workers share it by reference
/// — no per-client copies — and each worker's clients are served by
/// grouped [`probe_batch_staged`] calls that stream that buffer once per
/// view group instead of twice per client ([`run_worker_probes`]), or
/// from the previous commit sweep's pre-staged views at zero passes.
/// Outcomes
/// return in client-id order regardless of worker interleaving or
/// assignment, which is what makes the commit phase bit-identical to the
/// sequential baseline; the returned [`ProbeBatchStats`] (summed over
/// workers) is equally schedule-deterministic.
///
/// `id_base` maps slice positions to global client ids: a coordinator
/// shard hands in its own contiguous sub-slice of the pool
/// (`clients[i]` is global client `id_base + i`), while the unsharded
/// engine passes the whole pool with `id_base = 0`.
fn execute_probes<S, F>(
    clients: &mut [Client],
    replicas: &ReplicaStore,
    plan: &RoundPlan,
    costs: &[u64],
    threads: usize,
    pin_serial: bool,
    mu: f32,
    spec: S,
    finish: F,
    staged: Option<&StagedViews>,
    id_base: usize,
    trace: bool,
) -> (Vec<ProbeOutcome>, ProbeBatchStats, SpanBuf)
where
    S: Fn(&mut Client, &mut Ledger) -> (Batch, u32) + Sync,
    F: Fn(&mut Client, u32, f32, &mut Ledger) -> Contribution + Sync,
{
    debug_assert_eq!(costs.len(), plan.participants.len());
    // key the staged views by the canonical buffer's address so workers
    // can tell the canonical view group from an owned replica's
    let staged = staged.map(|s| (replicas.canonical().as_ptr() as usize, s));
    let mut selected: Vec<(&mut Client, &[f32])> = Vec::with_capacity(plan.participants.len());
    {
        let mut want = plan.participants.iter().copied().peekable();
        for (i, c) in clients.iter_mut().enumerate() {
            let id = id_base + i;
            if want.peek() == Some(&id) {
                selected.push((c, replicas.probe_view(id)));
                want.next();
            }
        }
    }
    assert_eq!(
        selected.len(),
        plan.participants.len(),
        "participant ids must be sorted, distinct and in range"
    );
    let round = plan.round;
    if threads <= 1 || selected.len() <= 1 {
        // `pin_serial` marks an explicitly requested sequential baseline
        // (cfg.threads == 1): keep the inner noise ops single-threaded
        // too, so "threads = 1" means exactly one thread.  A fan-out
        // that merely degenerated to one job (e.g. K = 1) keeps inner
        // chunk-parallelism — it is the only parallelism available.
        let _serial = pin_serial.then(prng::serial_zone);
        let work: Vec<(usize, (&mut Client, &[f32]))> =
            selected.into_iter().enumerate().collect();
        let (mut ranked, stats, buf) =
            run_worker_probes(round, work, mu, &spec, &finish, staged, trace);
        ranked.sort_by_key(|(rank, _)| *rank);
        return (ranked.into_iter().map(|(_, o)| o).collect(), stats, buf);
    }
    let bins = pack_bins(costs, threads);
    let mut slots: Vec<Option<(&mut Client, &[f32])>> = selected.into_iter().map(Some).collect();
    let mut out: Vec<Option<ProbeOutcome>> =
        std::iter::repeat_with(|| None).take(slots.len()).collect();
    let mut stats = ProbeBatchStats::default();
    let mut buf = SpanBuf::new(trace);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(bins.len());
        for bin in &bins {
            if bin.is_empty() {
                continue;
            }
            let work: Vec<(usize, (&mut Client, &[f32]))> = bin
                .iter()
                .map(|&rank| (rank, slots[rank].take().expect("rank packed once")))
                .collect();
            let (spec, finish) = (&spec, &finish);
            handles.push(s.spawn(move || {
                // client-level parallelism is the outer fan-out; keep the
                // per-vector noise ops sequential inside each worker
                let _serial = prng::serial_zone();
                run_worker_probes(round, work, mu, spec, finish, staged, trace)
            }));
        }
        for h in handles {
            let (ranked, worker_stats, worker_buf) = h.join().expect("round worker panicked");
            stats.merge(&worker_stats);
            for ev in worker_buf.events() {
                buf.push(*ev);
            }
            for (rank, o) in ranked {
                out[rank] = Some(o);
            }
        }
    });
    let outcomes =
        out.into_iter().map(|o| o.expect("every participant probes exactly once")).collect();
    (outcomes, stats, buf)
}

/// Paper-accounting payload bits one participant moves in a round — the
/// free-function form of [`Session::round_payload_bits`], so the
/// event-driven lookahead planner ([`plan_round_with`]) can price a round
/// from disjoint field borrows while shard workers still hold the client
/// pool.
fn payload_bits_for(
    algorithm: Algorithm,
    pool_index_bits: Option<u16>,
    d: usize,
    participants: usize,
) -> (u64, u64) {
    match algorithm {
        // restricted seed space: the downlink names the round's
        // direction by index, so the broadcast is (index, sign) =
        // ceil(log2 K) + 1 bits instead of the implicit-schedule 1
        Algorithm::FeedSign | Algorithm::DpFeedSign { .. } => match pool_index_bits {
            Some(b) => (1, 1 + b as u64),
            None => (1, 1),
        },
        Algorithm::ZoFedSgd => (64, 64 * participants.max(1) as u64),
        Algorithm::FedSgd => (32 * d as u64, 32 * d as u64),
        Algorithm::Mezo => (0, 0),
    }
}

/// Everything the plan phase for round `t+1` needs, borrowed disjointly
/// from the session so the sharded execute scope can draw the next plan
/// while straggler shards still hold `&mut clients` — the event-driven
/// overlap.  Exactness: the participation stream is *sequenced* (one
/// session RNG), so lookahead only moves its draws earlier in wall-clock,
/// never earlier in draw order; the net admission for `t+1` likewise
/// stays in round order relative to every other `admit` call, and the
/// commit of round `t` only performs *keyed* channel draws — so the
/// overlapped schedule is bit-identical to the barriered one.
struct Lookahead<'a> {
    round: u64,
    k: usize,
    participation: ParticipationCfg,
    algorithm: Algorithm,
    pool_index_bits: Option<u16>,
    d: usize,
    part_rng: &'a mut Rng,
    net: &'a mut NetSim,
}

/// Plan one round from a [`Lookahead`] bundle: the participation draw,
/// then (with an active net simulation) the virtual-clock deadline
/// admission.  [`Session::plan_round`] delegates here, so the lookahead
/// path and the synchronous path are one code path by construction.
fn plan_round_with(la: Lookahead<'_>) -> RoundPlan {
    let mut participants = la.participation.sample(la.k, la.round, la.part_rng);
    if la.net.is_active() {
        let (up, down) =
            payload_bits_for(la.algorithm, la.pool_index_bits, la.d, participants.len());
        participants = la.net.admit(la.round, participants, up, down);
    }
    RoundPlan { round: la.round, participants }
}

/// Sharded execute phase: split the round's (globally drawn) participant
/// set along the [`ShardPlane`]'s contiguous id ranges, hand each shard
/// its own disjoint `&mut [Client]` sub-slice plus the shared read-only
/// replica plane, and run the shards event-driven: as soon as the first
/// shard finishes while stragglers are still draining, the planner draws
/// round `t+1` against the session's RNG/net watermarks (`lookahead`),
/// which [`Session::step`] then consumes.  Outcomes are reassembled in
/// shard order — which *is* global client-id order, because shards cover
/// ascending contiguous ranges — so the commit phase downstream is
/// byte-for-byte the unsharded engine's.
fn execute_sharded<S, F>(
    clients: &mut [Client],
    replicas: &ReplicaStore,
    plane: &mut ShardPlane,
    plan: &RoundPlan,
    costs: &[u64],
    threads: usize,
    pin_serial: bool,
    mu: f32,
    spec: S,
    finish: F,
    staged: Option<&StagedViews>,
    lookahead: Option<Lookahead<'_>>,
    tracer: &mut Tracer,
) -> (Vec<ProbeOutcome>, ProbeBatchStats, Option<RoundPlan>)
where
    S: Fn(&mut Client, &mut Ledger) -> (Batch, u32) + Sync,
    F: Fn(&mut Client, u32, f32, &mut Ledger) -> Contribution + Sync,
{
    let map = plane.map().clone();
    let n = map.shards();
    // partition the global draw (and its aligned cost vector) — never
    // re-draw per shard: participation draws are sequenced, and a
    // per-shard sampler would consume different streams at different N
    let shard_work: Vec<(RoundPlan, Vec<u64>)> = {
        let parts = map.split_participants(&plan.participants);
        let mut off = 0usize;
        parts
            .into_iter()
            .map(|p| {
                let c = costs[off..off + p.len()].to_vec();
                off += p.len();
                (RoundPlan { round: plan.round, participants: p.to_vec() }, c)
            })
            .collect()
    };
    // disjoint contiguous client sub-slices, one per shard
    let mut slices: Vec<(usize, &mut [Client])> = Vec::with_capacity(n);
    {
        let mut rest = clients;
        let mut base = 0usize;
        for s in 0..n {
            let len = map.range(s).len();
            let (head, tail) = rest.split_at_mut(len);
            slices.push((base, head));
            base += len;
            rest = tail;
        }
    }
    let shard_threads = (threads / n).max(1);
    let mut done: Vec<Option<(Vec<ProbeOutcome>, ProbeBatchStats, SpanBuf)>> =
        (0..n).map(|_| None).collect();
    let mut lookahead = lookahead;
    let mut next_plan: Option<RoundPlan> = None;
    let trace = tracer.on();
    let seq = threads <= 1 || n == 1;
    let r0 = tracer.clock();
    // straggler attribution (wall-clock, never read by control flow):
    // the shard whose execute completed the round, and its end time.
    // Sequential drain attributes the slowest shard instead of the last.
    let mut gate: (i32, u64, u64) = (-1, 0, 0); // (shard, end_us, dur_us)
    let mut overlap: Option<(u64, u64)> = None; // lookahead (start, end)
    if seq {
        // sequential baseline (or a degenerate single shard): drain the
        // shards in shard order on this thread.  The overlap point is the
        // same — after the first shard completes with stragglers left —
        // so `rounds_overlapped` is thread-count-invariant like every
        // other committed stat.
        for (s, ((base, slice), (shard_plan, shard_costs))) in
            slices.into_iter().zip(&shard_work).enumerate()
        {
            let t0 = tracer.clock();
            let (o, st, mut sbuf) = execute_probes(
                slice,
                replicas,
                shard_plan,
                shard_costs,
                shard_threads,
                pin_serial,
                mu,
                &spec,
                &finish,
                staged,
                base,
                trace,
            );
            if trace {
                let t1 = crate::obs::now_us();
                let dur = t1.saturating_sub(t0);
                sbuf.push(Event {
                    phase: Phase::Execute,
                    round: shard_plan.round,
                    shard: -1,
                    client: -1,
                    n1: shard_plan.participants.len() as u64,
                    n2: 0,
                    start_us: t0,
                    dur_us: dur,
                });
                if gate.0 < 0 || dur > gate.2 {
                    gate = (s as i32, t1, dur);
                }
            }
            done[s] = Some((o, st, sbuf));
            if s == 0 && n > 1 {
                if let Some(la) = lookahead.take() {
                    let p0 = tracer.clock();
                    next_plan = Some(plan_round_with(la));
                    plane.note_overlap();
                    if trace {
                        overlap = Some((p0, crate::obs::now_us()));
                    }
                }
            }
        }
    } else {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::scope(|scope| {
            for (s, ((base, slice), work)) in slices.into_iter().zip(&shard_work).enumerate() {
                let tx = tx.clone();
                let (spec, finish) = (&spec, &finish);
                let (shard_plan, shard_costs) = work;
                scope.spawn(move || {
                    let b0 = if trace { crate::obs::now_us() } else { 0 };
                    let (o, st, mut sbuf) = execute_probes(
                        slice,
                        replicas,
                        shard_plan,
                        shard_costs,
                        shard_threads,
                        pin_serial,
                        mu,
                        spec,
                        finish,
                        staged,
                        base,
                        trace,
                    );
                    sbuf.span(
                        Phase::Execute,
                        shard_plan.round,
                        -1,
                        -1,
                        shard_plan.participants.len() as u64,
                        0,
                        b0,
                    );
                    tx.send((s, (o, st, sbuf))).ok();
                });
            }
            drop(tx);
            // event loop: completions arrive as shards finish; the first
            // one that lands while others are still executing triggers
            // the round-(t+1) plan draw
            let mut finished = 0usize;
            while let Ok((s, out)) = rx.recv() {
                done[s] = Some(out);
                finished += 1;
                if trace {
                    gate = (s as i32, crate::obs::now_us(), 0);
                }
                if finished < n {
                    if let Some(la) = lookahead.take() {
                        let p0 = tracer.clock();
                        next_plan = Some(plan_round_with(la));
                        plane.note_overlap();
                        if trace {
                            overlap = Some((p0, crate::obs::now_us()));
                        }
                    }
                }
            }
        });
    }
    if trace && gate.0 >= 0 {
        let mut ev = Event::logical(Phase::RoundGate, plan.round, gate.0, -1, 0, 0);
        ev.start_us = r0;
        ev.dur_us = gate.1.saturating_sub(r0);
        tracer.push(ev);
    }
    if trace {
        if let Some((p0, p1)) = overlap {
            // wall-clock actually hidden: the planning window clipped to
            // the straggler window it ran under (zero on the sequential
            // drain, where nothing runs concurrently)
            let saved = if seq { 0 } else { p1.min(gate.1).saturating_sub(p0) };
            let mut ev = Event::logical(Phase::Overlap, plan.round, -1, -1, saved, 0);
            ev.start_us = p0;
            ev.dur_us = p1.saturating_sub(p0);
            tracer.push(ev);
        }
    }
    let mut outcomes = Vec::with_capacity(plan.participants.len());
    let mut stats = ProbeBatchStats::default();
    for (s, slot) in done.into_iter().enumerate() {
        let (o, st, sbuf) = slot.expect("every shard reports exactly once");
        outcomes.extend(o);
        stats.merge(&st);
        tracer.absorb(sbuf, s as i32);
    }
    (outcomes, stats, next_plan)
}

/// The federated runtime.
pub struct Session {
    pub cfg: SessionCfg,
    pub clients: Vec<Client>,
    /// The copy-on-write replica plane: one canonical parameter buffer
    /// at the committed head round + per-client logical replicas.
    pub replicas: ReplicaStore,
    pub train: Dataset,
    pub test: Dataset,
    pub ledger: Ledger,
    pub orbit: Orbit,
    /// Per-round committed-update history (maintained only while
    /// [`SessionCfg::catchup`] is on; the compaction watermark is the
    /// slowest client in the replica plane's tracker).
    pub history: SeedHistory,
    /// Impaired-channel simulator (a no-op shell when
    /// [`SessionCfg::net`] is the ideal default); `net.stats` holds the
    /// run's impairment counters.
    pub net: NetSim,
    /// Execute-phase probe-batching counters, summed over the run — the
    /// measured canonical-buffer-reads-per-round basis of the batching
    /// claim (reported in [`RunResult::probe`]).
    pub probe_stats: ProbeBatchStats,
    /// Restricted seed space (`seed_pool` mode): the K candidate
    /// directions every round's index resolves through, derived once
    /// from [`SessionCfg::seed`] — both topologies derive the identical
    /// pool, which is what keeps them bit-identical.
    pub pool: Option<SeedPool>,
    /// Per-pool-seed accumulated step scalars: `pool_scalars[i]` is the
    /// sum of `sign · eta` over committed rounds that drew direction
    /// `i`.  Drives the FedKSeed-Pro biased sampler, and *is* the model
    /// delta (`sum_i scalars[i] · z_i`) the [`CatchupCfg::PoolScalars`]
    /// download ships.
    pub pool_scalars: Vec<f32>,
    /// Deterministic event tracer ([`crate::obs`]): off unless
    /// `FEEDSIGN_TRACE` is set at construction or
    /// [`Session::enable_tracing`] is called.  Strictly write-only from
    /// the engine's perspective — no round-loop branch reads it, which
    /// is what keeps every parity suite bit-identical tracing on or off.
    pub tracer: Tracer,
    /// Sharded coordinator plane ([`SessionCfg::shards`] >= 1): the
    /// client-id partition, the hierarchical vote-merge ledger and the
    /// event-driven overlap counter.  `None` on the legacy unsharded
    /// path.
    shard_plane: Option<ShardPlane>,
    /// Round plan drawn ahead of time by the event-driven sharded
    /// execute (round `t+1`, planned while round `t`'s stragglers
    /// drained); consumed by the next in-order [`Session::step`].
    pending_plan: Option<RoundPlan>,
    /// `±mu` probe views staged by the previous round's fused commit
    /// sweep for the *next* round's announced direction
    /// ([`StagedViews`]) — session-owned scratch, deliberately outside
    /// the replica plane's byte accounting (it is a transient working
    /// surface like the probe views themselves, not canonical state).
    /// Consumed (and revalidated against the round/seed/mu actually
    /// planned) at the next execute; a mismatch falls back to the
    /// classic probe-time pass.
    staged: Option<StagedViews>,
    dp_rng: Rng,
    eval_rng: Rng,
    part_rng: Rng,
}

impl Session {
    pub fn new(cfg: SessionCfg, mut clients: Vec<Client>, train: Dataset, test: Dataset) -> Self {
        assert!(!clients.is_empty());
        if matches!(cfg.algorithm, Algorithm::Mezo) {
            assert_eq!(clients.len(), 1, "MeZO is centralized (K = 1)");
        }
        if cfg.catchup.is_on() {
            assert!(
                matches!(
                    cfg.algorithm,
                    Algorithm::FeedSign | Algorithm::DpFeedSign { .. } | Algorithm::ZoFedSgd
                ),
                "catch-up applies to the synchronized seed-based algorithms only"
            );
        }
        if cfg.seed_pool > 0 {
            assert!(cfg.seed_pool >= 2, "a seed pool needs at least 2 candidates");
            assert!(
                matches!(cfg.algorithm, Algorithm::FeedSign | Algorithm::DpFeedSign { .. }),
                "the restricted seed space applies to the FeedSign algorithms"
            );
        }
        assert!(
            !matches!(cfg.catchup, CatchupCfg::PoolScalars) || cfg.seed_pool >= 2,
            "catchup = \"pool\" requires seed_pool mode (the scalar download is indexed by pool seed)"
        );
        let d = clients[0].engine.n_params();
        for c in &clients {
            assert_eq!(c.engine.n_params(), d, "all clients must share one parameter space");
        }
        // replica plane: client 0's init is the canonical buffer; any
        // client whose declared init differs bit-wise starts as an owned
        // (diverged) replica, everyone else shares canonical at zero cost
        let canonical = clients[0]
            .initial_params()
            .expect("client 0 must carry the session init (seed or checkpoint)");
        let mut replicas = ReplicaStore::new(canonical, clients.len(), cfg.replica_cache);
        for id in 1..clients.len() {
            let shared_by_decl = match (&clients[id].init, &clients[0].init) {
                (ClientInit::SessionCheckpoint, _) => true,
                (ClientInit::Seed(a), ClientInit::Seed(b)) => a == b,
                _ => false,
            };
            if shared_by_decl {
                continue;
            }
            // materialize by *moving* an explicit checkpoint out of the
            // client (never cloning: a retained copy would double the
            // owned replica's memory and falsify the store's byte
            // accounting); only client 0's init is load-bearing after
            // construction
            let w = match std::mem::replace(&mut clients[id].init, ClientInit::Consumed) {
                ClientInit::Seed(s) => {
                    clients[id].init = ClientInit::Seed(s);
                    clients[id].engine.init_params(s)
                }
                ClientInit::Checkpoint(w) => w,
                ClientInit::SessionCheckpoint | ClientInit::Consumed => {
                    unreachable!("handled by shared_by_decl / constructed once")
                }
            };
            let same_bits = w.len() == d
                && w.iter().zip(replicas.canonical()).all(|(a, b)| a.to_bits() == b.to_bits());
            if same_bits {
                // drop the redundant copy: the client is canonical-shared
                clients[id].init = ClientInit::SessionCheckpoint;
            } else {
                replicas.set_owned(id, w);
            }
        }
        if cfg.tile_budget > 0 {
            // tiered canonical store: the authoritative parameter bits
            // move to the file-backed tile pager; every commit keeps the
            // in-RAM read mirror coherent, so the probe/eval read paths
            // are unchanged
            let tile = if cfg.tile == 0 { prng::tile_elems() } else { cfg.tile };
            replicas.enable_spill(tile, cfg.tile_budget);
        }
        let mut orbit = Orbit::new(cfg.algorithm.name(), cfg.seed, cfg.eta);
        let pool = (cfg.seed_pool >= 2).then(|| SeedPool::derive(cfg.seed, cfg.seed_pool));
        if let Some(p) = &pool {
            orbit.set_pool(p.pool_seed, p.k());
        }
        let pool_scalars = vec![0.0f32; pool.as_ref().map_or(0, |p| p.k())];
        let tracer = Tracer::from_env();
        let mut net = NetSim::new(cfg.net.clone());
        net.log_admissions = tracer.on();
        let dp_rng = Rng::new(cfg.seed ^ 0xD9, 0xD9);
        let eval_rng = Rng::new(cfg.seed ^ 0xEE, 0xEE);
        let part_rng = Rng::new(cfg.seed ^ 0x9A, 0x9A);
        let shard_plane = (cfg.shards >= 1).then(|| ShardPlane::new(clients.len(), cfg.shards));
        Session {
            cfg,
            clients,
            replicas,
            train,
            test,
            ledger: Ledger::default(),
            orbit,
            history: SeedHistory::default(),
            net,
            probe_stats: ProbeBatchStats::default(),
            pool,
            pool_scalars,
            tracer,
            shard_plane,
            pending_plan: None,
            staged: None,
            dp_rng,
            eval_rng,
            part_rng,
        }
    }

    /// The per-client catch-up watermarks (embedded in the replica
    /// plane, so staleness and memory state can never disagree).
    pub fn tracker(&self) -> &CatchupTracker {
        self.replicas.tracker()
    }

    /// Turn event tracing on mid-lifetime (the CLI's `--trace-out` path)
    /// and switch the net simulator's admission log on with it.  Purely
    /// additive — no engine branch reads the recorded state, so the run
    /// commits identical bits either way.
    pub fn enable_tracing(&mut self) {
        self.tracer.enable();
        self.net.log_admissions = self.tracer.on();
    }

    /// Read client `id`'s logical replica.  Resolution order: an owned
    /// buffer or the canonical buffer (borrowed, zero-copy) → the
    /// pre-commit snapshot cache for a stale shared replica (borrowed) →
    /// an init-plus-orbit-prefix reconstruction (owned, allocates `d`
    /// floats; exact, because the orbit *is* the committed update
    /// stream).
    ///
    /// The reconstruction fallback replays through the native
    /// [`crate::simkit::zo::apply_update`] primitive (the same code
    /// orbit replay and seed-history replay are defined in terms of).
    /// The native engine's [`Engine::update`] is that primitive, so the
    /// fallback is bit-exact; an engine whose update kernel is only
    /// *approximately* equal to it (the PJRT path is pinned to 1e-6, not
    /// to the bit) should raise [`SessionCfg::replica_cache`] so stale
    /// reads stay cache-resident instead of reconstructing.
    pub fn replica(&self, id: usize) -> Cow<'_, [f32]> {
        if let Some(w) = self.replicas.resident(id) {
            return Cow::Borrowed(w);
        }
        // stale shared replica: its logical value is canonical-as-of(r)
        let r = self.replicas.watermark(id);
        if self.cfg.catchup.is_on() {
            if let Some(missed) = self.history.replay_span(r, self.replicas.head()) {
                if missed.is_empty() {
                    // the missed span is all no-op rounds: bit-equal to head
                    return Cow::Borrowed(self.replicas.canonical());
                }
                // the snapshot taken when the first missed round committed
                // is canonical *before* that commit — exactly
                // canonical-as-of(r), since the span up to it is empty
                if let Some(w) = self.replicas.cached(missed[0].round) {
                    return Cow::Borrowed(w);
                }
            }
        }
        let mut w = self
            .clients[0]
            .initial_params()
            .expect("client 0 carries the session init");
        self.orbit.replay_prefix(&mut w, r as usize);
        Cow::Owned(w)
    }

    /// Mutable access to client `id`'s replica, promoting it to an owned
    /// (diverged) buffer if it is still shared — the external write API
    /// of the copy-on-write plane.  A stale client is materialized via
    /// [`Session::replica`] first.
    pub fn replica_mut(&mut self, id: usize) -> &mut Vec<f32> {
        if !self.replicas.is_owned(id) && !self.replicas.is_current(id) {
            let w = self.replica(id).into_owned();
            self.replicas.set_owned(id, w);
        }
        self.replicas.promote_owned(id)
    }

    /// Drive all rounds; returns the run record.
    pub fn run(&mut self) -> RunResult {
        let start = std::time::Instant::now();
        let mut records = Vec::new();
        for t in 0..self.cfg.rounds {
            self.step(t);
            let do_eval = self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0;
            if do_eval {
                let e0 = self.tracer.clock();
                let (loss, acc) = self.evaluate();
                self.tracer.span(Phase::Eval, t + 1, -1, -1, 0, 0, e0);
                if self.cfg.verbose {
                    crate::log_info!(
                        "[{}] round {:>6}: eval loss {loss:.4} acc {:.1}% (up {} bits)",
                        self.cfg.algorithm.name(),
                        t + 1,
                        acc * 100.0,
                        self.ledger.uplink_bits
                    );
                }
                records.push(RoundRecord {
                    round: t + 1,
                    eval_loss: loss,
                    eval_acc: acc,
                    uplink_bits: self.ledger.uplink_bits,
                    downlink_bits: self.ledger.downlink_bits,
                    wall_s: start.elapsed().as_secs_f64(),
                    canonical_commits: self.replicas.stats().canonical_commits,
                    probe_passes_saved: self.probe_stats.passes_saved(),
                    shard_merge_bits: self.shard_stats().merge_bits,
                    net_dropped: self.net.stats.dropped_msgs,
                    net_flipped: self.net.stats.flipped_bits,
                });
            }
        }
        // run end: every straggler performs its (metered) rejoin so the
        // final model is distributed to the whole pool
        self.catch_up_all();
        let e0 = self.tracer.clock();
        let (final_loss, final_acc) = self.evaluate();
        self.tracer.span(Phase::Eval, self.cfg.rounds, -1, -1, 0, 0, e0);
        RunResult {
            algorithm: self.cfg.algorithm.name().to_string(),
            records,
            ledger: self.ledger.clone(),
            final_loss,
            final_acc,
            rounds: self.cfg.rounds,
            wall_s: start.elapsed().as_secs_f64(),
            net: self.net.stats.clone(),
            replica: self.replica_stats(),
            probe: self.probe_stats,
            shard: self.shard_stats(),
        }
    }

    /// Replica-plane accounting (peak bytes, owned count, canonical
    /// commit count) — the coordinator-side Table 10 column.
    pub fn replica_stats(&self) -> ReplicaStats {
        self.replicas.stats()
    }

    /// Sharded-plane accounting: shard count, hierarchical merge traffic
    /// and event-driven overlap counter.  All-zero on the unsharded path.
    pub fn shard_stats(&self) -> ShardStats {
        self.shard_plane.as_ref().map(ShardPlane::stats).unwrap_or_default()
    }

    /// The [`SeedHistory`] compaction floor.  Unsharded: the flat
    /// tracker's global watermark.  Sharded: the **min across shards** of
    /// the shard-local watermarks — the hierarchical fold a physically
    /// sharded deployment computes.  Min is associative, so the two are
    /// equal; what the fold must never be is any *single* shard's local
    /// watermark, which would compact records another shard's straggler
    /// still needs (the regression
    /// `coordinator::shard` pins).
    fn compaction_watermark(&self) -> u64 {
        match &self.shard_plane {
            Some(plane) => plane.compaction_watermark(self.replicas.tracker()),
            None => self.replicas.tracker().watermark(),
        }
    }

    /// One aggregation round.
    pub fn step(&mut self, t: u64) {
        match self.cfg.algorithm {
            Algorithm::FedSgd => self.step_fedsgd(t),
            Algorithm::Mezo => self.step_mezo(t),
            _ => {
                // a plan drawn ahead by the event-driven sharded execute
                // (while round t-1's stragglers drained) is consumed
                // here; the draws happened in the identical order, so
                // the round is bit-identical either way
                let plan = match self.pending_plan.take() {
                    Some(p) => {
                        assert_eq!(p.round, t, "sharded lookahead requires in-order stepping");
                        p
                    }
                    None => self.plan_round(t),
                };
                self.step_planned(plan, true);
            }
        }
    }

    /// One synchronized round driven by an externally fixed plan — the
    /// plan-phase output made injectable so tests (and schedulers) can pin
    /// a deterministic participation schedule, e.g. forcing a client
    /// offline for exactly k rounds (`rust/tests/catchup_parity.rs`).
    /// Plans must arrive in round order (the seed history and the replica
    /// plane both commit in round order).
    ///
    /// Injected plans disable the sharded engine's lookahead planning:
    /// an external scheduler owns the plan stream, so drawing round
    /// `t+1` from the session sampler would desynchronize the sequenced
    /// participation RNG (and, with an active net simulation, the
    /// virtual clock) from the unsharded baseline.
    pub fn step_with_plan(&mut self, plan: RoundPlan) {
        self.step_planned(plan, false)
    }

    fn step_planned(&mut self, plan: RoundPlan, allow_lookahead: bool) {
        let round = plan.round;
        if self.tracer.on() {
            // the plan is traced where it is *consumed*, so a
            // lookahead-drawn plan lands in its own round; the net
            // admission summaries it drains carry their own round
            // numbers, and the sorted logical sequence puts both where
            // they belong regardless of when the draw happened
            self.tracer.push(Event::logical(
                Phase::Plan,
                round,
                -1,
                -1,
                plan.participants.len() as u64,
                0,
            ));
            for a in self.net.take_admit_log() {
                self.tracer.push(Event::logical(
                    Phase::NetAdmit,
                    a.round,
                    -1,
                    a.gating_client,
                    a.kept as u64,
                    a.cut as u64,
                ));
                if a.gating_client >= 0 {
                    self.tracer.push(Event::logical(
                        Phase::LinkGate,
                        a.round,
                        -1,
                        a.gating_client,
                        a.gating_class as u64,
                        a.virtual_us,
                    ));
                }
            }
        }
        // snapshot-cache admission (PR 5 follow-up): pre-commit snapshots
        // exist to serve *stale* readers, so only admit them when this
        // round's config can actually strand a client — a participation
        // sampler that skips clients, or a channel that erases votes or
        // cuts deadline stragglers.  Full participation over a delivering
        // channel declines the copy (the cold reconstruction path stays
        // bit-exact regardless, so this is memory policy, not numerics).
        // Evaluated live, not at construction: tests and schedulers
        // mutate `cfg` mid-run.
        let admit =
            self.cfg.participation.can_strand_clients() || self.cfg.net.can_strand_clients();
        self.replicas.set_snapshot_admission(admit);
        let snaps0 = if self.tracer.on() {
            let r = self.replicas.stats();
            (r.snapshots, r.snapshots_declined)
        } else {
            (0, 0)
        };
        match self.cfg.algorithm {
            Algorithm::FeedSign => self.step_feedsign(plan, None, allow_lookahead),
            Algorithm::DpFeedSign { epsilon } => {
                self.step_feedsign(plan, Some(epsilon), allow_lookahead)
            }
            Algorithm::ZoFedSgd => self.step_zo_fedsgd(plan, allow_lookahead),
            Algorithm::FedSgd | Algorithm::Mezo => {
                panic!("step_with_plan drives the synchronized seed-based algorithms only")
            }
        }
        if self.tracer.on() {
            let r = self.replicas.stats();
            let taken = r.snapshots - snaps0.0;
            let declined = r.snapshots_declined - snaps0.1;
            if taken > 0 || declined > 0 {
                self.tracer.push(Event::logical(Phase::Snapshot, round, -1, -1, taken, declined));
            }
        }
    }

    /// Plan phase: fix the participant set before any client compute —
    /// the participation draw, then (with an active [`SessionCfg::net`])
    /// the virtual-clock admission: stragglers whose link latency blows
    /// the round deadline are excluded here, before they probe, and
    /// resync later through the catch-up machinery.
    fn plan_round(&mut self, t: u64) -> RoundPlan {
        plan_round_with(self.lookahead(t))
    }

    /// Bundle the plan-phase state for round `t` — the synchronous
    /// [`Session::plan_round`] and the sharded engine's event-driven
    /// lookahead both plan through this, so there is one planner.
    fn lookahead(&mut self, t: u64) -> Lookahead<'_> {
        Lookahead {
            round: t,
            k: self.clients.len(),
            participation: self.cfg.participation,
            algorithm: self.cfg.algorithm,
            pool_index_bits: self.pool.as_ref().map(SeedPool::index_bits),
            d: self.replicas.d(),
            part_rng: &mut self.part_rng,
            net: &mut self.net,
        }
    }

    /// Paper-accounting payload bits one participant moves in a round
    /// (uplink, downlink) — what the virtual event clock charges to the
    /// link.  `participants` is the *round's* voter count, not the pool
    /// size K: the ZO-FedSGD downlink is `64 · participants` bits because
    /// every client downloads the round's full pair bundle, one 64-bit
    /// (seed, projection) pair per client that probed *this round* —
    /// under partial participation the bundle shrinks with the sample,
    /// never with K (`comm_accounting_zo_fedsgd_exact` and
    /// `zo_fedsgd_partial_participation_divides_by_participants` pin the
    /// distinction).  Reads the parameter count from the replica plane,
    /// so it is well-defined for any pool the store accepts.
    fn round_payload_bits(&self, participants: usize) -> (u64, u64) {
        payload_bits_for(
            self.cfg.algorithm,
            self.pool.as_ref().map(SeedPool::index_bits),
            self.replicas.d(),
            participants,
        )
    }

    /// Execute-phase cost model for the size-aware fan-out: a
    /// participant's probe cost scales with its shard size (Dirichlet
    /// partitions are heavily skewed) and, when the net simulation is
    /// active, with its link's device class (iot-class hardware is
    /// slower than a wifi workstation).  Only a schedule input — the
    /// committed bits are assignment-independent.
    fn probe_costs(&self, participants: &[usize]) -> Vec<u64> {
        participants
            .iter()
            .map(|&id| {
                let shard = self.clients[id].shard.len().max(1) as u64;
                let device = if self.net.is_active() {
                    self.net.cfg.links.profile(id).device_cost_weight()
                } else {
                    1
                };
                shard.saturating_mul(device)
            })
            .collect()
    }

    /// Replay (or dense-rebroadcast) the committed history to every client
    /// in `ids` that is stale relative to `to_round`, metering the
    /// downlink per [`CatchupCfg`].  For a `Shared` logical replica the
    /// replay is bookkeeping: the records are billed and the watermark
    /// advances, and the invariant (replay order = commit order through
    /// the same exact AXPY) guarantees the materialized result *is* the
    /// canonical buffer — so no math runs at all.  An `Owned` (diverged)
    /// replica applies the records for real through its own engine.
    /// Either way a rejoining replica is bit-identical to an always-on
    /// one (pinned by `rust/tests/catchup_parity.rs` and the dense
    /// mirror in `rust/tests/replica_parity.rs`).
    fn catch_up_clients(&mut self, ids: &[usize], to_round: u64) {
        debug_assert!(self.cfg.catchup.is_on());
        let d = self.replicas.d();
        // honor the explicitly requested sequential baseline
        let _serial = (self.cfg.threads == 1).then(prng::serial_zone);
        for &id in ids {
            let span = self.replicas.tracker().span(id, to_round);
            if span.is_empty() {
                continue;
            }
            let records = self.history.replay_span(span.start, span.end).unwrap_or_else(|| {
                panic!(
                    "catch-up span {span:?} for client {id} was compacted away; \
                     compaction must respect the tracker watermark"
                )
            });
            if records.is_empty() {
                // the missed span held only zero-participant no-op
                // rounds: nothing to apply, nothing to bill (mirrors the
                // distributed topology's empty-replay guard)
                self.replicas.mark_synced(id, to_round);
                continue;
            }
            if self.tracer.on() {
                self.tracer.push(Event::logical(
                    Phase::Catchup,
                    to_round,
                    -1,
                    id as i64,
                    span.end - span.start,
                    records.len() as u64,
                ));
            }
            let records = match self.cfg.catchup {
                CatchupCfg::Replay => {
                    // meter through the actual message, then take the
                    // records back for the update loop (no span clone)
                    let msg = Message::ReplayHistory { records };
                    self.ledger.record(&msg);
                    let Message::ReplayHistory { records } = msg else { unreachable!() };
                    records
                }
                CatchupCfg::Rebroadcast => {
                    self.ledger.record(&Message::Rebroadcast { n_params: d });
                    records
                }
                CatchupCfg::PoolScalars => {
                    // FedKSeed model-delta download: the K accumulated
                    // step scalars, 32·K bits, constant in the gap
                    // length.  A `Shared` replica's rejoin stays pure
                    // bookkeeping (the invariant makes the bits the
                    // canonical buffer's); an `Owned` replica realizes
                    // the mathematically equal scalar sum by applying
                    // the missed records in commit order — the
                    // order-stable evaluation of that sum, so it stays
                    // bit-identical to an always-on diverged client.
                    let k = self
                        .pool
                        .as_ref()
                        .expect("catchup = \"pool\" requires seed_pool mode")
                        .k();
                    self.ledger.record(&Message::PoolScalars { k });
                    records
                }
                CatchupCfg::Off => unreachable!(),
            };
            if self.replicas.is_owned(id) {
                let engine = &mut self.clients[id].engine;
                let w = self.replicas.owned_mut(id).expect("checked owned");
                for r in &records {
                    engine.update(w, r.seed, r.step());
                }
            }
            self.replicas.mark_synced(id, to_round);
        }
    }

    /// Bring every client current with the committed history — the
    /// metered rejoin all stragglers perform when a run ends (no-op with
    /// catch-up off, where every client is always current).
    pub fn catch_up_all(&mut self) {
        if !self.cfg.catchup.is_on() {
            return;
        }
        let ids: Vec<usize> = (0..self.clients.len()).collect();
        let to = self.history.head_round();
        self.catch_up_clients(&ids, to);
        let wm = self.compaction_watermark();
        self.history.compact_to(wm);
    }

    /// Commit-phase history bookkeeping: append this round's records and
    /// compact the ring down to the slowest client's watermark.
    fn commit_history(&mut self, round: u64, records: Vec<SeedRecord>) {
        if !self.cfg.catchup.is_on() {
            return;
        }
        self.history.commit_round(round, records);
        let wm = self.compaction_watermark();
        self.history.compact_to(wm);
    }

    /// Worker count for a fan-out over `jobs` independent units.
    fn worker_threads(&self, jobs: usize) -> usize {
        let t = if self.cfg.threads > 0 {
            self.cfg.threads
        } else {
            std::thread::available_parallelism().map_or(1, |p| p.get())
        };
        t.min(jobs.max(1))
    }

    /// Hierarchical vote merge (sharded mode): each shard that had
    /// planned participants ships its pre-reduced [`VoteAcc`] to the
    /// global merger as one [`Message::ShardVotes`] — metered into the
    /// plane's own merge ledger, **never** the client-facing
    /// [`Session::ledger`] (the conservation invariant the shard fuzz
    /// suite asserts) — and the merger folds the accumulators.  A shard
    /// whose planned votes were all lost in transit still reports its
    /// `(0, 0)` pair: the merger needs one message per planned shard to
    /// close the round.  Returns `None` on the unsharded path.
    fn merge_shard_votes(
        &mut self,
        plan: &RoundPlan,
        tally: &[VoteAcc],
        dense_pairs: bool,
    ) -> Option<VoteAcc> {
        let plane = self.shard_plane.as_mut()?;
        let mut total = VoteAcc::default();
        for (s, acc) in tally.iter().enumerate() {
            let r = plane.map().range(s);
            let lo = plan.participants.partition_point(|&id| id < r.start);
            let planned = lo < plan.participants.len() && plan.participants[lo] < r.end;
            if !planned {
                continue;
            }
            let msg = Message::ShardVotes {
                sum: acc.sum,
                voters: acc.voters,
                shard_size: r.len(),
                dense_pairs,
            };
            let bits = plane.record_merge(&msg);
            if self.tracer.on() {
                self.tracer.push(Event::logical(
                    Phase::ShardMerge,
                    plan.round,
                    s as i32,
                    -1,
                    acc.voters as u64,
                    bits,
                ));
            }
            total.merge(*acc);
        }
        Some(total)
    }

    /// FeedSign (Algorithm 1, FeedSign branch): shared seed = t, 1-bit
    /// votes up, 1-bit majority (or DP vote) down, synchronized update —
    /// applied **once** to the canonical buffer (the replica plane's
    /// whole point: the dense layout applied the same AXPY K times).
    fn step_feedsign(&mut self, plan: RoundPlan, dp_epsilon: Option<f32>, allow_lookahead: bool) {
        let t = plan.round;
        // catch-up: stale participants replay their missed span *before*
        // probing, so every vote is cast on the current model
        if self.cfg.catchup.is_on() {
            let ids = plan.participants.clone();
            self.catch_up_clients(&ids, t);
        }
        if plan.participants.is_empty() {
            // zero-participant round: commit a no-op (no votes, no
            // broadcast); the 0-sign orbit entry, the empty history round
            // and the head-only replica advance keep round indices dense
            // for every replay path
            self.orbit.push_sign(0);
            self.replicas.advance_noop(t, !self.cfg.catchup.is_on());
            self.commit_history(t, Vec::new());
            return;
        }
        let threads = self.worker_threads(plan.participants.len());
        // round -> direction derivation.  Pool mode (FedKSeed): the
        // coordinator draws one index per round from the deterministic
        // Philox-keyed sampler — biased toward high-|history| directions
        // once scalars accumulate (FedKSeed-Pro) — and every participant
        // probes the same pooled direction, so the whole worker still
        // shares one seed.  Without a pool the seed is the round index,
        // masked into the 31-bit direction space the channel simulator's
        // corruption model assumes (`t as u32` alone leaves it at
        // t >= 2^31 and whenever the low 32 bits carry the MSB).
        let (seed, pool_idx) = match &self.pool {
            Some(pool) => {
                let idx = pool.sample_index(&self.pool_scalars, t);
                (pool.seed_at(idx), Some((idx, pool.index_bits())))
            }
            None => (prng::round_direction_seed(t), None),
        };
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let pin_serial = self.cfg.threads == 1;
        let costs = self.probe_costs(&plan.participants);
        let pool_size = self.clients.len();
        let d = self.replicas.d();
        let pool_index_bits = self.pool.as_ref().map(SeedPool::index_bits);
        // views pre-staged by the previous round's fused commit sweep
        // serve this round's canonical-buffer probe group with zero
        // passes — but only if they were staged for exactly this
        // (round, seed, mu); anything stale (a no-op round intervened,
        // mu was mutated mid-run) is dropped and the group takes the
        // classic probe-time pass
        let staged_now = self
            .staged
            .take()
            .filter(|s| s.round == t && s.seed == seed && s.mu == mu && s.plus.len() == d);
        let train = &self.train;
        // execute: fan the probes out; each worker meters its own uplink
        // and serves its clients through grouped batched probes (the
        // whole worker shares seed = t, so one +mu/-mu view pair serves
        // every client it owns)
        let spec =
            |c: &mut Client, _ledger: &mut Ledger| (c.shard.next_batch(train, bs, &mut c.rng), seed);
        let finish = |c: &mut Client, _seed: u32, p: f32, ledger: &mut Ledger| {
            let mut p = p;
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let honest = if p >= 0.0 { 1i8 } else { -1 };
            let sign = c.attack.mutate_sign(honest, &mut c.rng);
            ledger.record(&Message::SignVote { sign });
            Contribution::Sign(sign)
        };
        let (outcomes, probe_stats) = match &mut self.shard_plane {
            Some(plane) => {
                let la = (allow_lookahead
                    && t + 1 < self.cfg.rounds
                    && self.pending_plan.is_none())
                .then(|| Lookahead {
                    round: t + 1,
                    k: pool_size,
                    participation: self.cfg.participation,
                    algorithm: self.cfg.algorithm,
                    pool_index_bits,
                    d,
                    part_rng: &mut self.part_rng,
                    net: &mut self.net,
                });
                let (o, st, next) = execute_sharded(
                    &mut self.clients,
                    &self.replicas,
                    plane,
                    &plan,
                    &costs,
                    threads,
                    pin_serial,
                    mu,
                    spec,
                    finish,
                    staged_now.as_ref(),
                    la,
                    &mut self.tracer,
                );
                if next.is_some() {
                    // a consumed RNG draw must never be dropped: only the
                    // lookahead that actually planned writes the slot
                    self.pending_plan = next;
                }
                (o, st)
            }
            None => {
                let e0 = self.tracer.clock();
                let (o, st, buf) = execute_probes(
                    &mut self.clients,
                    &self.replicas,
                    &plan,
                    &costs,
                    threads,
                    pin_serial,
                    mu,
                    spec,
                    finish,
                    staged_now.as_ref(),
                    0,
                    self.tracer.on(),
                );
                self.tracer.span(
                    Phase::Execute,
                    t,
                    -1,
                    -1,
                    plan.participants.len() as u64,
                    0,
                    e0,
                );
                self.tracer.absorb(buf, -1);
                (o, st)
            }
        };
        self.probe_stats.merge(&probe_stats);
        // commit: votes and sub-ledgers in client-id order; each vote
        // then crosses the (possibly impaired) uplink — a flip lands in
        // the vote, a drop makes the PS treat the voter as absent this
        // round (the transmission is still billed: the bits were sent)
        let mut signs = Vec::with_capacity(outcomes.len());
        let mut voters = Vec::with_capacity(outcomes.len());
        let mut subs = Vec::with_capacity(outcomes.len());
        let mut tally: Vec<VoteAcc> = self
            .shard_plane
            .as_ref()
            .map(|p| vec![VoteAcc::default(); p.map().shards()])
            .unwrap_or_default();
        for (o, &id) in outcomes.into_iter().zip(&plan.participants) {
            debug_assert_eq!(o.client, id, "commit order must be client-id order");
            let Contribution::Sign(s) = o.contribution else {
                unreachable!("feedsign job yields sign votes");
            };
            subs.push(o.ledger);
            if let Some(s) = self.net.deliver_sign(t, id, s) {
                if let Some(p) = &self.shard_plane {
                    tally[p.map().shard_of(id)].push(s);
                }
                if self.tracer.on() {
                    self.tracer.push(Event::logical(
                        Phase::Commit,
                        t,
                        -1,
                        id as i64,
                        (s > 0) as u64,
                        0,
                    ));
                }
                signs.push(s);
                voters.push(id);
            }
        }
        self.ledger.commit(subs);
        // sharded mode: fold the per-shard edge aggregations into the
        // global accumulator (exact — sign votes are associative integer
        // sums) and meter one ShardVotes pair per planned shard
        let merged = self.merge_shard_votes(&plan, &tally, false);
        if signs.is_empty() {
            // every vote was lost in transit: the round aborts to a no-op
            // commit, exactly like a zero-participant plan
            self.orbit.push_sign(0);
            self.replicas.advance_noop(t, !self.cfg.catchup.is_on());
            self.commit_history(t, Vec::new());
            return;
        }
        // only the final majority / DP threshold is global: the sharded
        // path thresholds the merged (sum, voters) pair through the exact
        // same expressions the flat path applies to the vote vector
        // (`majority_sign` / `dp_vote` delegate to these forms)
        let f = match (merged, dp_epsilon) {
            (Some(acc), None) => {
                debug_assert_eq!(acc.voters, signs.len());
                aggregation::majority_from_sum(acc.sum)
            }
            (Some(acc), Some(eps)) => {
                aggregation::dp_vote_counts(acc.q_plus(), acc.voters, eps, &mut self.dp_rng)
            }
            (None, None) => aggregation::majority_sign(&signs),
            (None, Some(eps)) => aggregation::dp_vote(&signs, eps, &mut self.dp_rng),
        };
        if self.tracer.on() {
            self.tracer.push(Event::logical(
                Phase::Commit,
                t,
                -1,
                -1,
                (f > 0) as u64,
                signs.len() as u64,
            ));
        }
        let step = f as f32 * self.cfg.eta;
        let msg = Message::GlobalSign { sign: f };
        // pool mode: the broadcast also names the round's direction —
        // the ceil(log2 K)-bit index rides down with the 1-bit sign, so
        // each billed client's downlink prices at index_bits + 1
        let idx_msg = pool_idx
            .map(|(index, index_bits)| Message::PoolIndex { round: t, index, index_bits });
        // downlink billing (pure accounting — never reads the model)
        if self.cfg.catchup.is_on() {
            // only the clients the PS heard from are billed the
            // downlink; everyone else (sampled out, deadline-cut, or
            // dropped on the uplink) is left a stale logical replica and
            // recovers the round from the seed history on rejoin
            for _ in &voters {
                self.ledger.record(&msg);
                if let Some(m) = &idx_msg {
                    self.ledger.record(m);
                }
            }
        } else {
            // every client is billed the broadcast (non-participants too:
            // the downlink is what keeps all replicas synchronized)
            for _ in 0..pool_size {
                self.ledger.record(&msg);
                if let Some(m) = &idx_msg {
                    self.ledger.record(m);
                }
            }
        }
        // FedKSeed-Pro state: accumulate this direction's step scalar
        // (the sampler's bias signal, and the PoolScalars download's
        // payload) — *before* the commit, so the fused sweep can name
        // round t+1's direction through the post-round sampler state
        // (the sampler is a pure function of `(scalars, t)`, so the
        // pre-draw below returns exactly the index round t+1 will draw)
        if let Some((idx, _)) = pool_idx {
            self.pool_scalars[idx as usize] += step;
        }
        // one canonical sweep commits the round for the whole pool; with
        // an explicit sequential baseline the inner chunk-parallel noise
        // walk is pinned to one thread (same bits either way)
        let _serial = pin_serial.then(prng::serial_zone);
        let (fuse, batched) = {
            let e = &self.clients[0].engine;
            (self.cfg.fuse_commits && e.fused_commit_exact(), e.supports_batched_probe())
        };
        if fuse {
            // the tiled parameter plane's hot path: round t's commit
            // AXPY *and* round t+1's ±mu probe views in one fused
            // read-modify-write sweep — the staged views replace the
            // probe-time axpy pass next round (zero canonical passes),
            // so the steady state streams the store once per round
            // instead of 1 + views times
            let next_seed = (batched && t + 1 < self.cfg.rounds).then(|| match &self.pool {
                Some(pool) => pool.seed_at(pool.sample_index(&self.pool_scalars, t + 1)),
                None => prng::round_direction_seed(t + 1),
            });
            let tile = if self.cfg.tile == 0 { prng::tile_elems() } else { self.cfg.tile };
            let nthreads = prng::noise_threads(d);
            let commits = [(seed, step)];
            let mut sv = next_seed.map(|ns| StagedViews {
                round: t + 1,
                seed: ns,
                mu,
                plus: vec![0.0f32; d],
                minus: vec![0.0f32; d],
            });
            let views: Vec<(u32, f32)> = match &sv {
                Some(s) => vec![(s.seed, mu), (s.seed, -mu)],
                None => Vec::new(),
            };
            let ts0 = self.tracer.clock();
            {
                let mut outs: Vec<&mut [f32]> = match &mut sv {
                    Some(s) => vec![&mut s.plus, &mut s.minus],
                    None => Vec::new(),
                };
                let recipients = self.cfg.catchup.is_on().then(|| voters.as_slice());
                self.replicas
                    .advance_fused(t, recipients, &commits, &views, &mut outs, tile, nthreads);
            }
            self.tracer.span(Phase::TileSweep, t, -1, -1, 1 + views.len() as u64, tile as u64, ts0);
            self.staged = sv;
        } else if self.cfg.catchup.is_on() {
            let engine = &mut self.clients[0].engine;
            self.replicas.advance(t, &voters, |w| engine.update(w, seed, step));
        } else {
            let engine = &mut self.clients[0].engine;
            self.replicas.advance_all(t, |w| engine.update(w, seed, step));
        }
        match pool_idx {
            Some((idx, bits)) => {
                self.orbit.push_index(idx, f);
                self.commit_history(
                    t,
                    vec![SeedRecord::index_step(t, seed, idx, bits, f, self.cfg.eta)],
                );
            }
            None => {
                self.orbit.push_sign(f);
                self.commit_history(t, vec![SeedRecord::sign_step(t, f, self.cfg.eta)]);
            }
        }
    }

    /// ZO-FedSGD (FwdLLM/FedKSeed-style): each participant samples its own
    /// seed, uploads a 64-bit seed-projection pair; everyone downloads all
    /// pairs and the mean update commits once to the canonical buffer.
    fn step_zo_fedsgd(&mut self, plan: RoundPlan, allow_lookahead: bool) {
        let t = plan.round;
        if self.cfg.catchup.is_on() {
            let ids = plan.participants.clone();
            self.catch_up_clients(&ids, t);
        }
        if plan.participants.is_empty() {
            self.orbit.push_pairs(Vec::new());
            self.replicas.advance_noop(t, !self.cfg.catchup.is_on());
            self.commit_history(t, Vec::new());
            return;
        }
        let threads = self.worker_threads(plan.participants.len());
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let pin_serial = self.cfg.threads == 1;
        let costs = self.probe_costs(&plan.participants);
        let pool_size = self.clients.len();
        let d = self.replicas.d();
        let train = &self.train;
        // execute: every client draws its private direction seed first
        // (same per-client RNG order as the unbatched loop), then the
        // worker serves the distinct-seed probes in blocked multi-view
        // passes over the shared buffer
        let spec = |c: &mut Client, _ledger: &mut Ledger| {
            let seed = c.rng.next_u32() & 0x7FFF_FFFF; // direction counters < 2^31
            (c.shard.next_batch(train, bs, &mut c.rng), seed)
        };
        let finish = |c: &mut Client, seed: u32, p: f32, ledger: &mut Ledger| {
            let mut p = p;
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let p = c.attack.mutate_projection(p, &mut c.rng);
            ledger.record(&Message::Projection { seed, p });
            Contribution::Pair { seed, p }
        };
        let (outcomes, probe_stats) = match &mut self.shard_plane {
            Some(plane) => {
                let la = (allow_lookahead
                    && t + 1 < self.cfg.rounds
                    && self.pending_plan.is_none())
                .then(|| Lookahead {
                    round: t + 1,
                    k: pool_size,
                    participation: self.cfg.participation,
                    algorithm: self.cfg.algorithm,
                    pool_index_bits: None,
                    d,
                    part_rng: &mut self.part_rng,
                    net: &mut self.net,
                });
                let (o, st, next) = execute_sharded(
                    &mut self.clients,
                    &self.replicas,
                    plane,
                    &plan,
                    &costs,
                    threads,
                    pin_serial,
                    mu,
                    spec,
                    finish,
                    // per-client private direction seeds are drawn inside
                    // the execute phase, so no views can be staged ahead
                    None,
                    la,
                    &mut self.tracer,
                );
                if next.is_some() {
                    self.pending_plan = next;
                }
                (o, st)
            }
            None => {
                let e0 = self.tracer.clock();
                let (o, st, buf) = execute_probes(
                    &mut self.clients,
                    &self.replicas,
                    &plan,
                    &costs,
                    threads,
                    pin_serial,
                    mu,
                    spec,
                    finish,
                    None,
                    0,
                    self.tracer.on(),
                );
                self.tracer.span(
                    Phase::Execute,
                    t,
                    -1,
                    -1,
                    plan.participants.len() as u64,
                    0,
                    e0,
                );
                self.tracer.absorb(buf, -1);
                (o, st)
            }
        };
        self.probe_stats.merge(&probe_stats);
        // commit in client-id order; each 64-bit pair crosses the uplink
        // (flipped seed bits pick a different-but-valid direction,
        // flipped projection bits corrupt the coefficient, a drop makes
        // the PS treat the client as absent — transmission still billed)
        let mut pairs = Vec::with_capacity(outcomes.len());
        let mut voters = Vec::with_capacity(outcomes.len());
        let mut subs = Vec::with_capacity(outcomes.len());
        let mut tally: Vec<VoteAcc> = self
            .shard_plane
            .as_ref()
            .map(|p| vec![VoteAcc::default(); p.map().shards()])
            .unwrap_or_default();
        for (o, &id) in outcomes.into_iter().zip(&plan.participants) {
            debug_assert_eq!(o.client, id, "commit order must be client-id order");
            let Contribution::Pair { seed, p } = o.contribution else {
                unreachable!("zo-fedsgd job yields seed-projection pairs");
            };
            subs.push(o.ledger);
            if let Some((seed, p)) = self.net.deliver_pair(t, id, seed, p) {
                if let Some(pl) = &self.shard_plane {
                    // pair bundles have no sign sum — the shard merger
                    // forwards the dense 64-bit pairs, so only the
                    // delivered count matters for the merge pricing
                    tally[pl.map().shard_of(id)].voters += 1;
                }
                if self.tracer.on() {
                    self.tracer.push(Event::logical(
                        Phase::Commit,
                        t,
                        -1,
                        id as i64,
                        seed as u64,
                        p.to_bits() as u64,
                    ));
                }
                pairs.push((seed, p));
                voters.push(id);
            }
        }
        self.ledger.commit(subs);
        // sharded mode: one dense_pairs ShardVotes per planned shard —
        // the shard -> merger hop carries the shard's delivered pair
        // bundle; concatenation in shard order *is* client-id order, so
        // the mean aggregation below is byte-for-byte the flat engine's
        let _ = self.merge_shard_votes(&plan, &tally, true);
        if pairs.is_empty() {
            // every pair was lost in transit: no-op round
            self.orbit.push_pairs(Vec::new());
            self.replicas.advance_noop(t, !self.cfg.catchup.is_on());
            self.commit_history(t, Vec::new());
            return;
        }
        let k = pairs.len();
        if self.tracer.on() {
            // no global sign in the pair-bundle aggregation: n1 = 0,
            // n2 = the delivered pair count the mean divides by
            self.tracer.push(Event::logical(Phase::Commit, t, -1, -1, 0, k as u64));
        }
        let eta = self.cfg.eta;
        let msg = Message::GlobalProjections { pairs: pairs.clone() };
        let pool = self.clients.len();
        if self.cfg.catchup.is_on() {
            for _ in &voters {
                self.ledger.record(&msg);
            }
        } else {
            for _ in 0..pool {
                self.ledger.record(&msg);
            }
        }
        let _serial = pin_serial.then(prng::serial_zone);
        let fuse = self.cfg.fuse_commits && self.clients[0].engine.fused_commit_exact();
        let recipients = self.cfg.catchup.is_on().then(|| voters.as_slice());
        if fuse {
            // k delivered pairs fused into ONE tiled sweep over the
            // canonical store — the closure verb streamed it k times
            // (once per `engine.update`).  Next round's directions are
            // private per-client draws, so nothing can be staged.
            let commits: Vec<(u32, f32)> =
                pairs.iter().map(|&(seed, p)| (seed, eta * p / k as f32)).collect();
            let tile = if self.cfg.tile == 0 { prng::tile_elems() } else { self.cfg.tile };
            let nthreads = prng::noise_threads(self.replicas.d());
            let ts0 = self.tracer.clock();
            let mut outs: Vec<&mut [f32]> = Vec::new();
            self.replicas.advance_fused(t, recipients, &commits, &[], &mut outs, tile, nthreads);
            self.tracer.span(Phase::TileSweep, t, -1, -1, commits.len() as u64, tile as u64, ts0);
        } else {
            let engine = &mut self.clients[0].engine;
            let pairs_ref = &pairs;
            let apply = |w: &mut [f32]| {
                for &(seed, p) in pairs_ref {
                    engine.update(w, seed, eta * p / k as f32);
                }
            };
            match recipients {
                Some(r) => self.replicas.advance(t, r, apply),
                None => self.replicas.advance_all(t, apply),
            }
        }
        // history: one record per pair, the mean-projection coefficient
        // folded into (sign, lr_scale) so replay applies `sign·lr_scale`
        // == `eta·p/k` bit-exactly
        let records: Vec<SeedRecord> = pairs
            .iter()
            .map(|&(seed, p)| SeedRecord::pair_step(t, seed, eta * p / k as f32))
            .collect();
        self.orbit.push_pairs(pairs);
        self.commit_history(t, records);
    }

    /// FedSGD first-order baseline: dense gradient exchange (always full
    /// participation; partial regimes are a ZO-side study).  Each 32·d-bit
    /// gradient crosses the impaired uplink like every other message —
    /// which is where the dense baseline pays for its payload: one
    /// flipped exponent bit blows a gradient entry up by orders of
    /// magnitude, the fragility the BER robustness bench measures.  The
    /// mean gradient commits once to the canonical buffer (every client
    /// applies the identical mean, so the dense per-client loop was
    /// K-fold redundant here too).
    fn step_fedsgd(&mut self, t: u64) {
        let bs = self.cfg.batch_size;
        let d = self.replicas.d();
        // virtual clock: a dense round still costs wall-clock on every
        // link (there is no plan phase here, so the deadline cut does not
        // apply — the config layer rejects deadline+fedsgd)
        if self.net.is_active() {
            let (up, down) = self.round_payload_bits(self.clients.len());
            let everyone: Vec<usize> = (0..self.clients.len()).collect();
            let _ = self.net.admit(t, everyone, up, down);
            if self.tracer.on() {
                for a in self.net.take_admit_log() {
                    self.tracer.push(Event::logical(
                        Phase::NetAdmit,
                        a.round,
                        -1,
                        a.gating_client,
                        a.kept as u64,
                        a.cut as u64,
                    ));
                    if a.gating_client >= 0 {
                        self.tracer.push(Event::logical(
                            Phase::LinkGate,
                            a.round,
                            -1,
                            a.gating_client,
                            a.gating_class as u64,
                            a.virtual_us,
                        ));
                    }
                }
            }
        }
        let mut acc = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        let mut delivered = 0usize;
        for c in &mut self.clients {
            let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
            c.engine.grad(self.replicas.probe_view(c.id), &batch, &mut g);
            c.attack.mutate_gradient(&mut g, &mut c.rng);
            self.ledger.record(&Message::Gradient { g: Vec::new() }); // meter below
            self.ledger.uplink_bits += 32 * d as u64;
            if self.net.deliver_gradient(t, c.id, &mut g) {
                aggregation::accumulate(&mut acc, &g);
                delivered += 1;
            }
        }
        if delivered == 0 {
            // every gradient was lost in transit: no update, no broadcast
            return;
        }
        aggregation::finish_mean(&mut acc, delivered);
        for _ in 0..self.clients.len() {
            self.ledger.record(&Message::GlobalGradient { g: Vec::new() });
            self.ledger.downlink_bits += 32 * d as u64;
        }
        let eta = self.cfg.eta;
        self.replicas.advance_all(t, |w| {
            for (wi, gi) in w.iter_mut().zip(&acc) {
                *wi -= eta * gi;
            }
        });
    }

    /// Centralized MeZO (K = 1): no communication; the single client's
    /// replica *is* the canonical buffer.
    fn step_mezo(&mut self, t: u64) {
        let seed = prng::round_direction_seed(t);
        let (mu, bs) = (self.cfg.mu, self.cfg.batch_size);
        let c = &mut self.clients[0];
        let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
        let p = c.engine.probe(self.replicas.probe_view(0), &batch, seed, mu);
        let step = self.cfg.eta * p;
        let engine = &mut c.engine;
        self.replicas.advance_all(t, |w| engine.update(w, seed, step));
        self.orbit.push_pairs(vec![(seed, p)]);
    }

    /// Evaluate the global model on the test set.  With catch-up off the
    /// global model is the canonical buffer (every client is a current
    /// shared view of it); with catch-up on, logical replicas legitimately
    /// lag mid-run, so the freshest replica (lowest id among the
    /// most-synced clients) stands in — and because a committed round
    /// always marks its voters current, the freshest replica's bits are
    /// the canonical buffer's (any rounds past its watermark are no-ops).
    pub fn evaluate(&mut self) -> (f32, f32) {
        let mut idx = 0usize;
        if self.cfg.catchup.is_on() {
            let mut best = self.replicas.watermark(0);
            for i in 1..self.clients.len() {
                let s = self.replicas.watermark(i);
                if s > best {
                    best = s;
                    idx = i;
                }
            }
        }
        let view = self.replicas.eval_view(idx);
        let c = &mut self.clients[idx];
        let mut loss_sum = 0.0f64;
        let mut correct = 0u32;
        let mut total = 0u32;
        let mut eval_shard = Shard::new((0..self.test.len()).collect());
        for _ in 0..self.cfg.eval_batches {
            let batch =
                eval_shard.next_batch(&self.test, self.cfg.eval_batch_size, &mut self.eval_rng);
            let rows = batch.rows() as u32;
            let (l, corr) = c.engine.eval(view, &batch);
            loss_sum += l as f64;
            correct += corr;
            total += rows;
        }
        (
            (loss_sum / self.cfg.eval_batches as f64) as f32,
            correct as f32 / total.max(1) as f32,
        )
    }

    /// Whether every logical replica currently holds the same bits —
    /// synchronized algorithms must keep this true (`assert_synchronized`
    /// test hook).  With catch-up on it holds only once every client is
    /// current (e.g. after [`Session::catch_up_all`]), not mid-run.
    /// Shared replicas compare by construction; a stale shared replica
    /// counts as synchronized only when its missed span is all no-ops;
    /// owned replicas compare bit patterns against the canonical buffer
    /// (NaN-safe — an impaired channel can legitimately drive weights
    /// non-finite, and bit equality must not hide behind `NaN != NaN`).
    pub fn replicas_synchronized(&self) -> bool {
        let head = self.replicas.head();
        let canonical = self.replicas.canonical();
        (0..self.clients.len()).all(|id| match self.replicas.state(id) {
            ReplicaState::Shared => {
                self.replicas.watermark(id) == head
                    || self
                        .history
                        .replay_span(self.replicas.watermark(id), head)
                        .is_some_and(|missed| missed.is_empty())
            }
            ReplicaState::Owned(w) => {
                self.replicas.watermark(id) == head
                    && w.len() == canonical.len()
                    && w.iter().zip(canonical).all(|(a, b)| a.to_bits() == b.to_bits())
            }
        })
    }

    /// Batch for external probing (sign-reversal studies).
    pub fn sample_train_batch(&mut self, client: usize, size: usize) -> Batch {
        let c = &mut self.clients[client];
        c.shard.next_batch(&self.train, size, &mut c.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::vision::{generate, SYNTH_CIFAR10};
    use crate::engine::NativeEngine;
    use crate::simkit::nn::LinearProbe;

    fn make_session(algo: Algorithm, k: usize, rounds: u64) -> Session {
        let train = generate(&SYNTH_CIFAR10, 400, 0);
        let test = generate(&SYNTH_CIFAR10, 200, 1);
        let shards = split(&train, k, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: algo,
            rounds,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            eval_batches: 4,
            eval_batch_size: 32,
            seed: 7,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    }

    #[test]
    fn feedsign_improves_over_init() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        let (l0, a0) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, a1) = s.evaluate();
        assert!(l1 < l0, "loss {l0} -> {l1}");
        assert!(a1 > a0, "acc {a0} -> {a1}");
    }

    #[test]
    fn feedsign_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn zo_fedsgd_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::ZoFedSgd, 4, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn fedsgd_descends_fast() {
        let mut s = make_session(Algorithm::FedSgd, 3, 0);
        s.cfg.eta = 0.1;
        let (l0, _) = s.evaluate();
        for t in 0..60 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0 * 0.8, "FO should descend quickly: {l0} -> {l1}");
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn comm_accounting_feedsign_exact() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..100 {
            s.step(t);
        }
        // Eq. 5: 1 bit up per client per step, 1 bit down per client per step
        assert_eq!(s.ledger.uplink_bits, 100 * 5);
        assert_eq!(s.ledger.downlink_bits, 100 * 5);
    }

    #[test]
    fn comm_accounting_zo_fedsgd_exact() {
        let mut s = make_session(Algorithm::ZoFedSgd, 5, 0);
        for t in 0..10 {
            s.step(t);
        }
        // 64 bits up per client per step; 64*K bits down per client per step
        assert_eq!(s.ledger.uplink_bits, 10 * 5 * 64);
        assert_eq!(s.ledger.downlink_bits, 10 * 5 * 5 * 64);
    }

    #[test]
    fn mezo_has_zero_comm() {
        let mut s = make_session(Algorithm::Mezo, 1, 0);
        for t in 0..20 {
            s.step(t);
        }
        assert_eq!(s.ledger.total_bits(), 0);
    }

    #[test]
    fn orbit_replay_matches_final_params() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        for t in 0..200 {
            s.step(t);
        }
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w.as_slice(), &*s.replica(0), "orbit replay must reconstruct exactly");
    }

    #[test]
    fn run_produces_records() {
        let mut s = make_session(Algorithm::FeedSign, 2, 50);
        s.cfg.eval_every = 10;
        let result = s.run();
        assert_eq!(s.cfg.rounds, 50);
        assert_eq!(result.records.len(), 5);
        assert!(result.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = make_session(Algorithm::FeedSign, 3, 30).run();
        let r2 = make_session(Algorithm::FeedSign, 3, 30).run();
        assert_eq!(r1.final_loss, r2.final_loss);
        assert_eq!(r1.final_acc, r2.final_acc);
    }

    #[test]
    fn thread_count_does_not_change_the_run() {
        let mut seq = make_session(Algorithm::FeedSign, 5, 0);
        seq.cfg.threads = 1;
        let mut par = make_session(Algorithm::FeedSign, 5, 0);
        par.cfg.threads = 4;
        for t in 0..60 {
            seq.step(t);
            par.step(t);
        }
        assert_eq!(seq.replica(0), par.replica(0), "bit-identical across thread counts");
        assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits);
    }

    #[test]
    fn partial_participation_keeps_replicas_synchronized_and_meters_uplink() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4); // 2 of 5 per round
        for t in 0..100 {
            s.step(t);
        }
        assert!(s.replicas_synchronized(), "non-participants must track the broadcast");
        // uplink: only participants vote; downlink: everyone gets the bit
        assert_eq!(s.ledger.uplink_bits, 100 * 2);
        assert_eq!(s.ledger.downlink_bits, 100 * 5);
        assert_eq!(s.orbit.len(), 100);
    }

    #[test]
    fn partial_participation_still_learns() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Bernoulli(0.6);
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "partial participation should still learn: {l0} -> {l1}");
    }

    #[test]
    fn zo_fedsgd_partial_participation_divides_by_participants() {
        let mut s = make_session(Algorithm::ZoFedSgd, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4); // 2 of 5
        for t in 0..10 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
        // 64 bits per participant up; all K download the 2-pair bundle
        assert_eq!(s.ledger.uplink_bits, 10 * 2 * 64);
        assert_eq!(s.ledger.downlink_bits, 10 * 5 * 2 * 64);
    }

    #[test]
    fn zero_participant_round_commits_noop() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.0);
        let w0 = s.replica(0).into_owned();
        for t in 0..5 {
            s.step(t);
        }
        assert_eq!(&*s.replica(0), w0.as_slice(), "no participants, no update");
        assert_eq!(s.ledger.total_bits(), 0, "no votes, no broadcast");
        assert_eq!(s.orbit.len(), 5, "round indices stay dense");
        assert!(s.replicas_synchronized());
        assert_eq!(s.replicas.head(), 5, "no-op rounds still advance the head");
        // the 0-sign entries replay as no-ops, so the orbit still
        // reconstructs exactly
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w.as_slice(), &*s.replica(0));
    }

    #[test]
    fn catchup_replay_still_learns_and_resynchronizes() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.4);
        s.cfg.catchup = CatchupCfg::Replay;
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        assert_eq!(s.history.head_round(), 800);
        s.catch_up_all();
        assert!(s.replicas_synchronized(), "rejoin must restore replica equality");
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "replay catch-up should still learn: {l0} -> {l1}");
    }

    #[test]
    fn byzantine_sign_flip_majority_resists() {
        // 1 attacker of 5: FeedSign majority vote must still learn
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.clients[0].attack = Attack::SignFlip;
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "FeedSign under 1/5 Byzantine should still learn");
    }

    #[test]
    fn drop_channel_voters_feed_catchup_and_resync() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.catchup = CatchupCfg::Replay;
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::Erasure { p: 0.4 },
            ..NetCfg::ideal()
        });
        for t in 0..200 {
            s.step(t);
        }
        assert!(s.net.stats.dropped_msgs > 0, "erasure channel must drop votes");
        // dropped voters were left stale; the end-of-run rejoin replays
        // their missed spans and restores replica equality
        s.catch_up_all();
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn deadline_cuts_iot_stragglers_from_the_plan() {
        use crate::net::{LinkAssignment, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FeedSign, 6, 0);
        s.net = NetSim::new(NetCfg {
            links: LinkAssignment::parse("mixed").unwrap(),
            deadline_s: 0.1,
            ..NetCfg::ideal()
        });
        for t in 0..20 {
            s.step(t);
        }
        // mixed cycle: ids 2 and 5 ride the iot profile (0.4 s RTT, over
        // the 0.1 s deadline every round) — cut at plan time, every round
        assert_eq!(s.net.stats.stragglers, 2 * 20);
        assert_eq!(s.ledger.uplink_bits, 20 * 4, "only on-time clients vote");
        // catch-up off: the broadcast still reaches everyone, so replicas
        // stay synchronized even though stragglers never probe
        assert_eq!(s.ledger.downlink_bits, 20 * 6);
        assert!(s.replicas_synchronized());
        assert!(s.net.stats.virtual_s > 0.0);
    }

    #[test]
    fn ber_corrupts_zo_pairs_but_replicas_stay_synchronized() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::ZoFedSgd, 4, 0);
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::BitFlip { ber: 0.02 },
            ..NetCfg::ideal()
        });
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.net.stats.flipped_bits > 0, "2% BER over 64-bit pairs must flip");
        // everyone applies the same delivered (possibly corrupted) pairs;
        // compare replicas as bit patterns — corruption can drive weights
        // non-finite, where f32 equality would lie
        let w0: Vec<u32> = s.replica(0).iter().map(|v| v.to_bits()).collect();
        for id in 1..4 {
            let wi: Vec<u32> = s.replica(id).iter().map(|v| v.to_bits()).collect();
            assert_eq!(wi, w0, "client {id} diverged");
        }
        assert!(s.replicas_synchronized(), "bit-level equality, NaN included");
    }

    #[test]
    fn fedsgd_drop_channel_averages_only_delivered_gradients() {
        use crate::net::{ChannelModel, NetCfg, NetSim};
        let mut s = make_session(Algorithm::FedSgd, 3, 0);
        s.net = NetSim::new(NetCfg {
            channel: ChannelModel::Erasure { p: 0.5 },
            ..NetCfg::ideal()
        });
        for t in 0..10 {
            s.step(t);
        }
        assert!(s.net.stats.dropped_msgs > 0);
        assert!(s.replicas_synchronized(), "the averaged broadcast reaches everyone");
    }

    #[test]
    fn dp_feedsign_runs_and_learns_at_high_epsilon() {
        let mut s = make_session(Algorithm::DpFeedSign { epsilon: 50.0 }, 5, 0);
        let (l0, _) = s.evaluate();
        for t in 0..600 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0);
    }

    #[test]
    fn all_synced_run_holds_one_canonical_buffer_and_commits_once_per_round() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..40 {
            s.step(t);
        }
        let st = s.replica_stats();
        let d = s.replicas.d();
        assert_eq!(st.peak_bytes, 4 * d, "all-synced pool must cost exactly one d-float buffer");
        assert!(st.peak_bytes <= 2 * 4 * d, "the acceptance bound, with margin");
        assert_eq!(st.owned_clients, 0);
        assert_eq!(st.canonical_commits, 40, "exactly one canonical AXPY per round");
        assert_eq!(st.dense_bytes, 4 * d * 5);
    }

    #[test]
    fn cow_write_diverges_one_client_without_touching_the_pool() {
        let mut s = make_session(Algorithm::FeedSign, 4, 0);
        for t in 0..10 {
            s.step(t);
        }
        let before = s.replica(0).into_owned();
        s.replica_mut(2)[0] += 1.0;
        assert!(!s.replicas_synchronized(), "a diverged owned replica breaks equality");
        assert_eq!(&*s.replica(0), before.as_slice(), "canonical untouched by the COW write");
        assert_eq!(s.replica_stats().owned_clients, 1);
        // the diverged client keeps riding commits with real math
        for t in 10..20 {
            s.step(t);
        }
        assert_ne!(s.replica(2), s.replica(0));
        let gap = s.replica(2)[0] - s.replica(0)[0];
        assert!((gap - 1.0).abs() < 1e-4, "divergence tracks the injected write: {gap}");
    }

    #[test]
    fn stale_replica_reads_resolve_through_cache_and_reconstruction() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        s.cfg.catchup = CatchupCfg::Replay;
        // injected plans bypass the sampler, so declare a configuration
        // that *can* strand clients — snapshot admission is config-driven
        s.cfg.participation = ParticipationCfg::Fraction(0.75);
        let all = |t: u64| RoundPlan { round: t, participants: vec![0, 1, 2] };
        let without2 = |t: u64| RoundPlan { round: t, participants: vec![0, 1] };
        for t in 0..4 {
            s.step_with_plan(all(t));
        }
        let frozen = s.replica(2).into_owned();
        for t in 4..8 {
            s.step_with_plan(without2(t));
        }
        // client 2 is stale at round 4; its logical replica must read as
        // the pre-round-4 canonical, via the snapshot cache
        assert!(s.replicas.resident(2).is_none());
        assert_eq!(&*s.replica(2), frozen.as_slice(), "cache-resolved stale read");
        assert!(s.replica_stats().snapshots > 0);
        // with the cache disabled the same read reconstructs from the
        // orbit prefix — same bits, one allocation
        let mut cold = make_session(Algorithm::FeedSign, 3, 0);
        cold.cfg.catchup = CatchupCfg::Replay;
        cold.cfg.participation = ParticipationCfg::Fraction(0.75);
        cold.cfg.replica_cache = 0;
        cold.replicas = ReplicaStore::new(
            cold.clients[0].initial_params().unwrap(),
            3,
            0,
        );
        for t in 0..4 {
            cold.step_with_plan(all(t));
        }
        for t in 4..8 {
            cold.step_with_plan(without2(t));
        }
        assert_eq!(cold.replica_stats().snapshots, 0);
        assert!(matches!(cold.replica(2), Cow::Owned(_)), "cold read reconstructs");
        assert_eq!(&*cold.replica(2), frozen.as_slice(), "reconstruction-resolved stale read");
    }

    #[test]
    fn full_participation_config_declines_snapshots_but_stale_reads_stay_exact() {
        // default cfg: Full participation over an ideal channel — the
        // admission check judges that nothing can strand a client, so
        // pre-commit snapshots are declined even when injected plans
        // *do* strand one; the stale read then resolves through the
        // reconstruction fallback with the same bits
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        s.cfg.catchup = CatchupCfg::Replay;
        for t in 0..4 {
            s.step_with_plan(RoundPlan { round: t, participants: vec![0, 1, 2] });
        }
        let frozen = s.replica(2).into_owned();
        for t in 4..8 {
            s.step_with_plan(RoundPlan { round: t, participants: vec![0, 1] });
        }
        let st = s.replica_stats();
        assert_eq!(st.snapshots, 0, "admission must decline the copies");
        assert!(st.snapshots_declined > 0, "declined admissions are counted");
        assert!(s.replicas.resident(2).is_none());
        assert!(matches!(s.replica(2), Cow::Owned(_)), "stale read reconstructs");
        assert_eq!(&*s.replica(2), frozen.as_slice(), "same bits without the cache");
    }

    #[test]
    fn probe_batching_reduces_canonical_passes() {
        // FeedSign: every participant shares seed = t, and the fused
        // commit sweep stages round t+1's ±mu views while committing
        // round t — so only round 0 (nothing staged yet) pays a probe-
        // time canonical pass; every later round is served from the
        // staged buffers at zero passes.  Pinned unsharded: a sharded
        // run batch-groups per shard, so the exact counts below assume
        // one global group — the FEEDSIGN_SHARDS env leg must not
        // reroute this test.
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.cfg.shards = 0;
        s.shard_plane = None;
        s.cfg.threads = 1;
        for t in 0..20 {
            s.step(t);
        }
        assert_eq!(s.probe_stats.probes, 20 * 5);
        assert_eq!(s.probe_stats.fallback_probes, 0);
        assert_eq!(s.probe_stats.canonical_passes, 1, "only round 0 pays a probe-time pass");
        assert_eq!(s.probe_stats.staged_probes, 19 * 5, "rounds 1.. serve from staged views");
        assert_eq!(s.probe_stats.unbatched_passes(), 20 * 5 * 2);

        // fusion kill-switch: the legacy engine pays one shared-seed
        // pass per round and never stages
        let mut u = make_session(Algorithm::FeedSign, 5, 0);
        u.cfg.shards = 0;
        u.shard_plane = None;
        u.cfg.threads = 1;
        u.cfg.fuse_commits = false;
        for t in 0..20 {
            u.step(t);
        }
        assert_eq!(u.probe_stats.canonical_passes, 20, "one shared-seed pass per round");
        assert_eq!(u.probe_stats.staged_probes, 0);

        // ZO-FedSGD: distinct per-client seeds still pack several ±mu
        // view pairs into each blocked pass over the shared buffer
        let mut z = make_session(Algorithm::ZoFedSgd, 5, 0);
        z.cfg.shards = 0;
        z.shard_plane = None;
        z.cfg.threads = 1;
        for t in 0..10 {
            z.step(t);
        }
        assert_eq!(z.probe_stats.probes, 10 * 5);
        assert!(
            z.probe_stats.canonical_passes < z.probe_stats.unbatched_passes(),
            "{} passes should beat the unbatched {}",
            z.probe_stats.canonical_passes,
            z.probe_stats.unbatched_passes()
        );
    }

    #[test]
    fn divergent_initial_checkpoint_starts_owned() {
        let train = generate(&SYNTH_CIFAR10, 200, 0);
        let test = generate(&SYNTH_CIFAR10, 100, 1);
        let shards = split(&train, 3, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                let mut c = Client::new(
                    id,
                    Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                    shard,
                    7,
                );
                if id == 2 {
                    let w = vec![0.5; c.engine.n_params()];
                    c = c.with_checkpoint(&w);
                }
                c
            })
            .collect();
        let cfg = SessionCfg { algorithm: Algorithm::FeedSign, seed: 7, ..Default::default() };
        let s = Session::new(cfg, clients, train, test);
        assert_eq!(s.replica_stats().owned_clients, 1);
        assert!(s.replicas.is_owned(2));
        assert_eq!(s.replica(2)[0], 0.5);
        assert!(!s.replicas_synchronized());
        assert!(
            matches!(s.clients[2].init, ClientInit::Consumed),
            "the materialized checkpoint is moved into the store, never retained as a dead copy"
        );
    }

    #[test]
    fn pack_bins_balances_and_preserves_every_rank() {
        // skewed costs: LPT must not put the two giants in one bin
        let costs = [100u64, 1, 1, 1, 90, 1, 1, 1];
        let bins = pack_bins(&costs, 2);
        assert_eq!(bins.len(), 2);
        let mut all: Vec<usize> = bins.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>(), "every rank packed exactly once");
        let load = |b: &[usize]| b.iter().map(|&r| costs[r]).sum::<u64>();
        let (a, b) = (load(&bins[0]), load(&bins[1]));
        assert!(a.abs_diff(b) <= 8, "LPT must balance skewed loads: {a} vs {b}");
        // determinism: identical inputs, identical packing
        assert_eq!(pack_bins(&costs, 2), bins);
        // degenerate shapes
        assert_eq!(pack_bins(&[5], 4).iter().flatten().count(), 1);
        assert_eq!(pack_bins(&[0, 0, 0], 2).iter().flatten().count(), 3);
    }

    #[test]
    fn dirichlet_skewed_shards_stay_bit_identical_across_assignments() {
        // the size-aware packing is schedule-only: a heavily skewed
        // Dirichlet partition must produce the same bits for 1 and N
        // workers (which exercises genuinely unequal bins)
        let build = |threads: usize| {
            let train = generate(&SYNTH_CIFAR10, 400, 0);
            let test = generate(&SYNTH_CIFAR10, 100, 1);
            let shards = split(&train, 5, Partition::Dirichlet { beta: 0.1 }, 3);
            let clients: Vec<Client> = shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    Client::new(
                        id,
                        Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                        shard,
                        7,
                    )
                })
                .collect();
            let cfg = SessionCfg {
                algorithm: Algorithm::FeedSign,
                threads,
                seed: 7,
                eval_every: 0,
                ..Default::default()
            };
            Session::new(cfg, clients, train, test)
        };
        let mut seq = build(1);
        let mut par = build(3);
        for t in 0..40 {
            seq.step(t);
            par.step(t);
        }
        assert_eq!(seq.replica(0), par.replica(0));
        assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits);
    }

    fn make_pool_session(k: usize, pool: usize, catchup: CatchupCfg, threads: usize) -> Session {
        let train = generate(&SYNTH_CIFAR10, 400, 0);
        let test = generate(&SYNTH_CIFAR10, 200, 1);
        let shards = split(&train, k, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: Algorithm::FeedSign,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            seed_pool: pool,
            catchup,
            threads,
            seed: 7,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    }

    #[test]
    fn seed_pool_run_is_thread_invariant() {
        let mut seq = make_pool_session(5, 32, CatchupCfg::Off, 1);
        let mut par = make_pool_session(5, 32, CatchupCfg::Off, 4);
        for t in 0..60 {
            seq.step(t);
            par.step(t);
        }
        assert_eq!(seq.replica(0), par.replica(0), "pool draws must be schedule-independent");
        assert_eq!(seq.ledger.uplink_bits, par.ledger.uplink_bits);
        assert_eq!(seq.ledger.downlink_bits, par.ledger.downlink_bits);
        assert!(seq.replicas_synchronized());
    }

    #[test]
    fn seed_pool_still_learns() {
        let mut s = make_pool_session(5, 1024, CatchupCfg::Off, 0);
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "restricted directions should still descend: {l0} -> {l1}");
    }

    #[test]
    fn seed_pool_accounting_prices_indices_at_log2k_plus_one() {
        let mut s = make_pool_session(5, 32, CatchupCfg::Off, 0);
        for t in 0..100 {
            s.step(t);
        }
        // uplink: the vote is still 1 bit; downlink: every client
        // receives (index, sign) = 5 + 1 bits per round at K = 32
        assert_eq!(s.ledger.uplink_bits, 100 * 5);
        assert_eq!(s.ledger.downlink_bits, 100 * 5 * 6);
        assert_eq!(s.orbit.len(), 100);
    }

    #[test]
    fn seed_pool_orbit_replays_and_roundtrips() {
        let mut s = make_pool_session(3, 64, CatchupCfg::Off, 0);
        for t in 0..150 {
            s.step(t);
        }
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w.as_slice(), &*s.replica(0), "index orbit replay must reconstruct exactly");
        let back = crate::orbit::decode(&crate::orbit::encode(&s.orbit)).unwrap();
        assert_eq!(back.entries, s.orbit.entries);
    }

    #[test]
    fn pool_scalars_track_committed_steps() {
        let mut s = make_pool_session(4, 16, CatchupCfg::Off, 0);
        for t in 0..50 {
            s.step(t);
        }
        // the scalars are exactly the per-index sums of the committed
        // orbit steps, accumulated in round order
        let mut expect = vec![0.0f32; 16];
        for e in &s.orbit.entries {
            if let crate::orbit::OrbitEntry::IndexSign { index, sign } = e {
                expect[*index as usize] += *sign as f32 * s.cfg.eta;
            }
        }
        assert_eq!(s.pool_scalars, expect);
        assert!(expect.iter().any(|v| *v != 0.0), "50 committed rounds must move scalars");
    }

    #[test]
    fn pool_scalar_catchup_bills_constant_in_gap_and_resyncs() {
        let mut s = make_pool_session(3, 16, CatchupCfg::PoolScalars, 0);
        s.cfg.participation = ParticipationCfg::Fraction(0.75);
        for t in 0..3 {
            s.step_with_plan(RoundPlan { round: t, participants: vec![0, 1, 2] });
        }
        for t in 3..10 {
            s.step_with_plan(RoundPlan { round: t, participants: vec![0, 1] });
        }
        let before = s.ledger.downlink_bits;
        s.catch_up_all();
        // one 32·K-bit scalar download rejoins client 2, regardless of
        // how many rounds it missed
        assert_eq!(s.ledger.downlink_bits - before, 32 * 16);
        assert!(s.replicas_synchronized());
    }

    #[test]
    #[should_panic(expected = "requires seed_pool mode")]
    fn pool_catchup_without_a_pool_is_rejected() {
        let _ = make_pool_session(3, 0, CatchupCfg::PoolScalars, 0);
    }

    /// Sharded builder with the shard count pinned at construction —
    /// env-proof (the FEEDSIGN_SHARDS leg must not change what these
    /// tests compare), and explicit `shards: 0` pins the unsharded
    /// baseline the same way.
    fn make_sharded(algo: Algorithm, k: usize, rounds: u64, shards: usize) -> Session {
        let train = generate(&SYNTH_CIFAR10, 400, 0);
        let test = generate(&SYNTH_CIFAR10, 200, 1);
        let data_shards = split(&train, k, Partition::Iid, 0);
        let clients: Vec<Client> = data_shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: algo,
            rounds,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            participation: ParticipationCfg::Fraction(0.6),
            shards,
            seed: 7,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    }

    #[test]
    fn sharded_run_is_bit_identical_to_unsharded() {
        // the heavy matrix lives in rust/tests/shard_parity.rs; this is
        // the engine-local smoke over sequenced partial participation
        let mut base = make_sharded(Algorithm::FeedSign, 7, 40, 0);
        let flat = base.run();
        let flat_w = base.replica(0).into_owned();
        for n in [1usize, 3] {
            let mut s = make_sharded(Algorithm::FeedSign, 7, 40, n);
            let r = s.run();
            assert_eq!(&*s.replica(0), flat_w.as_slice(), "shards = {n}");
            assert_eq!(r.ledger.uplink_bits, flat.ledger.uplink_bits, "shards = {n}");
            assert_eq!(r.ledger.downlink_bits, flat.ledger.downlink_bits, "shards = {n}");
            assert_eq!(r.final_loss.to_bits(), flat.final_loss.to_bits(), "shards = {n}");
            assert_eq!(r.shard.shards, n.min(7));
        }
    }

    #[test]
    fn sharded_merge_traffic_is_coordinator_internal_and_overlap_counts() {
        let mut s = make_sharded(Algorithm::FeedSign, 6, 10, 2);
        s.cfg.participation = ParticipationCfg::Full;
        let r = s.run();
        // every round plans participants in both shards -> 2 merges/round,
        // each priced at the pair's information content (nonzero voters)
        assert_eq!(r.shard.shards, 2);
        assert_eq!(r.shard.merges, 2 * 10);
        assert!(r.shard.merge_bits > 0);
        // event-driven overlap: every round but the last plans t+1 while
        // the straggler shard drains — thread-count-invariantly
        assert_eq!(r.shard.rounds_overlapped, 9);
        // the client-facing ledger carries exactly the flat accounting:
        // merge traffic is a coordinator-internal hop, never client bits
        assert_eq!(r.ledger.uplink_bits, 10 * 6);
        assert_eq!(r.ledger.downlink_bits, 10 * 6);
    }

    #[test]
    fn sharded_lookahead_consumes_the_same_draw_stream() {
        // manual in-order stepping (no run loop): pending plans are drawn
        // ahead and consumed; the participation stream matches the flat
        // engine draw for draw
        let mut flat = make_sharded(Algorithm::FeedSign, 7, 30, 0);
        let mut sharded = make_sharded(Algorithm::FeedSign, 7, 30, 3);
        for t in 0..30 {
            flat.step(t);
            sharded.step(t);
        }
        assert_eq!(flat.replica(0), sharded.replica(0));
        assert_eq!(flat.ledger.uplink_bits, sharded.ledger.uplink_bits);
        assert!(sharded.shard_stats().rounds_overlapped > 0);
    }

    #[test]
    fn sharded_dp_vote_consumes_one_draw_via_the_counts_form() {
        let flat = make_sharded(Algorithm::DpFeedSign { epsilon: 3.0 }, 6, 25, 0).run();
        let sharded = make_sharded(Algorithm::DpFeedSign { epsilon: 3.0 }, 6, 25, 4).run();
        assert_eq!(flat.final_loss.to_bits(), sharded.final_loss.to_bits());
        assert_eq!(flat.ledger.uplink_bits, sharded.ledger.uplink_bits);
    }

    #[test]
    #[should_panic(expected = "in-order stepping")]
    fn sharded_lookahead_rejects_out_of_order_steps() {
        let mut s = make_sharded(Algorithm::FeedSign, 6, 30, 2);
        s.cfg.participation = ParticipationCfg::Full;
        s.step(0); // plans round 1 ahead
        assert!(s.pending_plan.is_some());
        s.step(2); // skips the pending round
    }
}
