//! The federated session: PS round loop + client pool (Algorithm 1).
//!
//! One `Session` owns the K clients (each with its own parameter vector,
//! engine, data shard and attack model) and drives T aggregation rounds of
//! the configured algorithm, metering every protocol message through the
//! [`crate::comm::Ledger`] and recording the orbit as it goes.
//!
//! The loop is deterministic: FeedSign's step seed is the round index
//! (`seed = t`, §I.1), client-private randomness comes from per-client
//! Philox streams, and eval cadence is fixed — so two sessions with the
//! same config produce identical runs, which the cross-topology test in
//! `rust/tests/` (sync vs tokio-distributed) relies on.

use crate::comm::{Ledger, Message};
use crate::coordinator::aggregation::{self, Algorithm};
use crate::coordinator::byzantine::Attack;
use crate::data::{Batch, Dataset, Shard};
use crate::engine::Engine;
use crate::metrics::{RoundRecord, RunResult};
use crate::orbit::Orbit;
use crate::simkit::prng::Rng;

/// One federated client: local parameters + compute engine + data shard.
pub struct Client {
    pub id: usize,
    pub w: Vec<f32>,
    pub engine: Box<dyn Engine>,
    pub shard: Shard,
    pub rng: Rng,
    pub attack: Attack,
}

impl Client {
    pub fn new(id: usize, engine: Box<dyn Engine>, shard: Shard, init_seed: u32) -> Self {
        let w = engine.init_params(init_seed);
        Client {
            id,
            w,
            engine,
            shard,
            rng: Rng::new(init_seed ^ 0xC11E_17, id as u32 + 1),
            attack: Attack::None,
        }
    }

    pub fn with_attack(mut self, attack: Attack) -> Self {
        self.attack = attack;
        self
    }

    /// Start from an existing (pretrained) checkpoint instead of init.
    pub fn with_checkpoint(mut self, w: &[f32]) -> Self {
        assert_eq!(w.len(), self.w.len());
        self.w.copy_from_slice(w);
        self
    }
}

/// Session hyperparameters.
#[derive(Debug, Clone)]
pub struct SessionCfg {
    pub algorithm: Algorithm,
    pub rounds: u64,
    pub eta: f32,
    pub mu: f32,
    pub batch_size: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: u64,
    /// eval minibatches per evaluation
    pub eval_batches: usize,
    pub eval_batch_size: usize,
    /// extra multiplicative projection noise `1 + c_g_noise*N(0,1)` — the
    /// paper's Figure 2 heterogeneity amplifier (Appendix H)
    pub c_g_noise: f32,
    pub seed: u32,
    /// print progress to stderr
    pub verbose: bool,
}

impl Default for SessionCfg {
    fn default() -> Self {
        SessionCfg {
            algorithm: Algorithm::FeedSign,
            rounds: 1000,
            eta: 1e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 100,
            eval_batches: 4,
            eval_batch_size: 32,
            c_g_noise: 0.0,
            seed: 0,
            verbose: false,
        }
    }
}

/// The federated runtime.
pub struct Session {
    pub cfg: SessionCfg,
    pub clients: Vec<Client>,
    pub train: Dataset,
    pub test: Dataset,
    pub ledger: Ledger,
    pub orbit: Orbit,
    dp_rng: Rng,
    eval_rng: Rng,
}

impl Session {
    pub fn new(cfg: SessionCfg, clients: Vec<Client>, train: Dataset, test: Dataset) -> Self {
        assert!(!clients.is_empty());
        if matches!(cfg.algorithm, Algorithm::Mezo) {
            assert_eq!(clients.len(), 1, "MeZO is centralized (K = 1)");
        }
        let orbit = Orbit::new(cfg.algorithm.name(), cfg.seed, cfg.eta);
        let dp_rng = Rng::new(cfg.seed ^ 0xD9, 0xD9);
        let eval_rng = Rng::new(cfg.seed ^ 0xEE, 0xEE);
        Session { cfg, clients, train, test, ledger: Ledger::default(), orbit, dp_rng, eval_rng }
    }

    /// Drive all rounds; returns the run record.
    pub fn run(&mut self) -> RunResult {
        let start = std::time::Instant::now();
        let mut records = Vec::new();
        for t in 0..self.cfg.rounds {
            self.step(t);
            let do_eval = self.cfg.eval_every > 0 && (t + 1) % self.cfg.eval_every == 0;
            if do_eval {
                let (loss, acc) = self.evaluate();
                if self.cfg.verbose {
                    eprintln!(
                        "[{}] round {:>6}: eval loss {loss:.4} acc {:.1}% (up {} bits)",
                        self.cfg.algorithm.name(),
                        t + 1,
                        acc * 100.0,
                        self.ledger.uplink_bits
                    );
                }
                records.push(RoundRecord {
                    round: t + 1,
                    eval_loss: loss,
                    eval_acc: acc,
                    uplink_bits: self.ledger.uplink_bits,
                    downlink_bits: self.ledger.downlink_bits,
                });
            }
        }
        let (final_loss, final_acc) = self.evaluate();
        RunResult {
            algorithm: self.cfg.algorithm.name().to_string(),
            records,
            ledger: self.ledger.clone(),
            final_loss,
            final_acc,
            rounds: self.cfg.rounds,
            wall_s: start.elapsed().as_secs_f64(),
        }
    }

    /// One aggregation round.
    pub fn step(&mut self, t: u64) {
        match self.cfg.algorithm {
            Algorithm::FeedSign => self.step_feedsign(t, None),
            Algorithm::DpFeedSign { epsilon } => self.step_feedsign(t, Some(epsilon)),
            Algorithm::ZoFedSgd => self.step_zo_fedsgd(),
            Algorithm::FedSgd => self.step_fedsgd(),
            Algorithm::Mezo => self.step_mezo(t),
        }
    }


    /// FeedSign (Algorithm 1, FeedSign branch): shared seed = t, 1-bit
    /// votes up, 1-bit majority (or DP vote) down, synchronized update.
    fn step_feedsign(&mut self, t: u64, dp_epsilon: Option<f32>) {
        let seed = t as u32;
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let mut signs = Vec::with_capacity(self.clients.len());
        for c in &mut self.clients {
            // RoundStart carries the implicit seed schedule (0 payload bits)
            self.ledger.record(&Message::RoundStart { round: t });
            let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
            let mut p = c.engine.probe(&mut c.w, &batch, seed, mu);
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let honest = if p >= 0.0 { 1i8 } else { -1 };
            let sign = c.attack.mutate_sign(honest, &mut c.rng);
            let msg = Message::SignVote { sign };
            self.ledger.record(&msg);
            signs.push(sign);
        }
        let f = match dp_epsilon {
            None => aggregation::majority_sign(&signs),
            Some(eps) => aggregation::dp_vote(&signs, eps, &mut self.dp_rng),
        };
        let step = f as f32 * self.cfg.eta;
        for c in &mut self.clients {
            self.ledger.record(&Message::GlobalSign { sign: f });
            c.engine.update(&mut c.w, seed, step);
        }
        self.orbit.push_sign(f);
    }

    /// ZO-FedSGD (FwdLLM/FedKSeed-style): each client samples its own seed,
    /// uploads a 64-bit seed-projection pair; everyone downloads all K
    /// pairs and applies the mean update.
    fn step_zo_fedsgd(&mut self) {
        let (mu, bs, c_g) = (self.cfg.mu, self.cfg.batch_size, self.cfg.c_g_noise);
        let k = self.clients.len();
        let mut pairs = Vec::with_capacity(k);
        for c in &mut self.clients {
            let seed = c.rng.next_u32() & 0x7FFF_FFFF; // direction counters < 2^31
            let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
            let mut p = c.engine.probe(&mut c.w, &batch, seed, mu);
            if c_g > 0.0 {
                p *= 1.0 + c_g * c.rng.normal();
            }
            let p = c.attack.mutate_projection(p, &mut c.rng);
            let msg = Message::Projection { seed, p };
            self.ledger.record(&msg);
            pairs.push((seed, p));
        }
        for c in &mut self.clients {
            self.ledger.record(&Message::GlobalProjections { pairs: pairs.clone() });
            for &(seed, p) in &pairs {
                c.engine.update(&mut c.w, seed, self.cfg.eta * p / k as f32);
            }
        }
        self.orbit.push_pairs(pairs);
    }

    /// FedSGD first-order baseline: dense gradient exchange.
    fn step_fedsgd(&mut self) {
        let bs = self.cfg.batch_size;
        let d = self.clients[0].engine.n_params();
        let mut acc = vec![0.0f32; d];
        let mut g = vec![0.0f32; d];
        for c in &mut self.clients {
            let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
            c.engine.grad(&mut c.w, &batch, &mut g);
            c.attack.mutate_gradient(&mut g, &mut c.rng);
            self.ledger.record(&Message::Gradient { g: Vec::new() }); // meter below
            self.ledger.uplink_bits += 32 * d as u64;
            aggregation::accumulate(&mut acc, &g);
        }
        aggregation::finish_mean(&mut acc, self.clients.len());
        for c in &mut self.clients {
            self.ledger.record(&Message::GlobalGradient { g: Vec::new() });
            self.ledger.downlink_bits += 32 * d as u64;
            for (wi, gi) in c.w.iter_mut().zip(&acc) {
                *wi -= self.cfg.eta * gi;
            }
        }
    }

    /// Centralized MeZO (K = 1): no communication.
    fn step_mezo(&mut self, t: u64) {
        let seed = t as u32;
        let (mu, bs) = (self.cfg.mu, self.cfg.batch_size);
        let c = &mut self.clients[0];
        let batch = c.shard.next_batch(&self.train, bs, &mut c.rng);
        let p = c.engine.probe(&mut c.w, &batch, seed, mu);
        c.engine.update(&mut c.w, seed, self.cfg.eta * p);
        self.orbit.push_pairs(vec![(seed, p)]);
    }

    /// Evaluate the global model (client 0's replica — identical across
    /// clients for every synchronized algorithm) on the test set.
    pub fn evaluate(&mut self) -> (f32, f32) {
        let c = &mut self.clients[0];
        let mut loss_sum = 0.0f64;
        let mut correct = 0u32;
        let mut total = 0u32;
        let mut eval_shard = Shard::new((0..self.test.len()).collect());
        for _ in 0..self.cfg.eval_batches {
            let batch = eval_shard.next_batch(&self.test, self.cfg.eval_batch_size, &mut self.eval_rng);
            let rows = batch.rows() as u32;
            let (l, corr) = c.engine.eval(&mut c.w, &batch);
            loss_sum += l as f64;
            correct += corr;
            total += rows;
        }
        (
            (loss_sum / self.cfg.eval_batches as f64) as f32,
            correct as f32 / total.max(1) as f32,
        )
    }

    /// Checksum of client replicas — synchronized algorithms must keep all
    /// replicas identical (`assert_synchronized` test hook).
    pub fn replicas_synchronized(&self) -> bool {
        let w0 = &self.clients[0].w;
        self.clients.iter().all(|c| &c.w == w0)
    }

    /// Batch for external probing (sign-reversal studies).
    pub fn sample_train_batch(&mut self, client: usize, size: usize) -> Batch {
        let c = &mut self.clients[client];
        c.shard.next_batch(&self.train, size, &mut c.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::vision::{generate, SYNTH_CIFAR10};
    use crate::engine::NativeEngine;
    use crate::simkit::nn::LinearProbe;

    fn make_session(algo: Algorithm, k: usize, rounds: u64) -> Session {
        let train = generate(&SYNTH_CIFAR10, 400, 0);
        let test = generate(&SYNTH_CIFAR10, 200, 1);
        let shards = split(&train, k, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            algorithm: algo,
            rounds,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            eval_batches: 4,
            eval_batch_size: 32,
            seed: 7,
            ..Default::default()
        };
        Session::new(cfg, clients, train, test)
    }

    #[test]
    fn feedsign_improves_over_init() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        let (l0, a0) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, a1) = s.evaluate();
        assert!(l1 < l0, "loss {l0} -> {l1}");
        assert!(a1 > a0, "acc {a0} -> {a1}");
    }

    #[test]
    fn feedsign_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn zo_fedsgd_keeps_replicas_synchronized() {
        let mut s = make_session(Algorithm::ZoFedSgd, 4, 0);
        for t in 0..50 {
            s.step(t);
        }
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn fedsgd_descends_fast() {
        let mut s = make_session(Algorithm::FedSgd, 3, 0);
        s.cfg.eta = 0.1;
        let (l0, _) = s.evaluate();
        for t in 0..60 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0 * 0.8, "FO should descend quickly: {l0} -> {l1}");
        assert!(s.replicas_synchronized());
    }

    #[test]
    fn comm_accounting_feedsign_exact() {
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        for t in 0..100 {
            s.step(t);
        }
        // Eq. 5: 1 bit up per client per step, 1 bit down per client per step
        assert_eq!(s.ledger.uplink_bits, 100 * 5);
        assert_eq!(s.ledger.downlink_bits, 100 * 5);
    }

    #[test]
    fn comm_accounting_zo_fedsgd_exact() {
        let mut s = make_session(Algorithm::ZoFedSgd, 5, 0);
        for t in 0..10 {
            s.step(t);
        }
        // 64 bits up per client per step; 64*K bits down per client per step
        assert_eq!(s.ledger.uplink_bits, 10 * 5 * 64);
        assert_eq!(s.ledger.downlink_bits, 10 * 5 * 5 * 64);
    }

    #[test]
    fn mezo_has_zero_comm() {
        let mut s = make_session(Algorithm::Mezo, 1, 0);
        for t in 0..20 {
            s.step(t);
        }
        assert_eq!(s.ledger.total_bits(), 0);
    }

    #[test]
    fn orbit_replay_matches_final_params() {
        let mut s = make_session(Algorithm::FeedSign, 3, 0);
        for t in 0..200 {
            s.step(t);
        }
        let mut w = s.clients[0].engine.init_params(7);
        s.orbit.replay(&mut w);
        assert_eq!(w, s.clients[0].w, "orbit replay must reconstruct exactly");
    }

    #[test]
    fn run_produces_records() {
        let mut s = make_session(Algorithm::FeedSign, 2, 50);
        s.cfg.eval_every = 10;
        let result = s.run();
        assert_eq!(s.cfg.rounds, 50);
        assert_eq!(result.records.len(), 5);
        assert!(result.wall_s > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let r1 = make_session(Algorithm::FeedSign, 3, 30).run();
        let r2 = make_session(Algorithm::FeedSign, 3, 30).run();
        assert_eq!(r1.final_loss, r2.final_loss);
        assert_eq!(r1.final_acc, r2.final_acc);
    }

    #[test]
    fn byzantine_sign_flip_majority_resists() {
        // 1 attacker of 5: FeedSign majority vote must still learn
        let mut s = make_session(Algorithm::FeedSign, 5, 0);
        s.clients[0].attack = Attack::SignFlip;
        let (l0, _) = s.evaluate();
        for t in 0..800 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0, "FeedSign under 1/5 Byzantine should still learn");
    }

    #[test]
    fn dp_feedsign_runs_and_learns_at_high_epsilon() {
        let mut s = make_session(Algorithm::DpFeedSign { epsilon: 50.0 }, 5, 0);
        let (l0, _) = s.evaluate();
        for t in 0..600 {
            s.step(t);
        }
        let (l1, _) = s.evaluate();
        assert!(l1 < l0);
    }
}
