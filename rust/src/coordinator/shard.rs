//! Sharded coordinator plane: scale the round loop past one barriered
//! client pool (ROADMAP item 1, the K ≥ 100 000 regime).
//!
//! FeedSign's aggregation is a sum of ±1 votes, and integer sums are
//! associative — so the client pool can be partitioned across N
//! coordinator shards that each own their clients' probe fan-out and a
//! local vote accumulator, ship one pre-reduced
//! [`Message::ShardVotes`]`(sum, voters)` pair to the global merger per
//! round, and remain **exact**: only the final majority / DP threshold is
//! global ([`crate::coordinator::aggregation::majority_from_sum`] /
//! `dp_vote_counts`).  The shards share the one canonical parameter
//! buffer read-only (the replica plane is already copy-on-write), so
//! sharding multiplies probe throughput without multiplying memory.
//!
//! Three invariants keep a sharded run **bit-identical** to the
//! barriered engine, whatever N:
//!
//! * **Global draw, shard partition.**  Participation draws are
//!   *sequenced* on one session RNG, so the round's participant set is
//!   sampled once globally and then split along the [`ShardMap`]'s
//!   contiguous id ranges — a per-shard sampler would consume different
//!   draw streams at different N.  Channel impairment draws need no such
//!   care: they are *keyed* `(channel_seed, round, client, direction)`
//!   ([`crate::net`]), hence shard-count-invariant by construction.
//! * **Merge order = shard order = id order.**  Shards cover contiguous
//!   ascending id ranges, so concatenating per-shard results in shard
//!   order reproduces the flat engine's client-id commit order exactly
//!   (f32 accumulation is order-sensitive; vote sums are not, but ZO
//!   pair lists and ledger sub-commits are ordered).
//! * **Compaction watermark = min across shards.**  Each shard tracks
//!   its own slowest client
//!   ([`crate::coordinator::CatchupTracker::watermark_over`]); the
//!   [`crate::comm::SeedHistory`] compaction floor must fold the **min
//!   across all shards** ([`ShardPlane::compaction_watermark`]).  Any
//!   single shard's local watermark — however "slow" that shard looks —
//!   would let compaction drop records a straggler in *another* shard
//!   still needs (pinned by
//!   `single_shard_watermark_compaction_loses_records_min_across_shards_keeps_them`).
//!
//! The round loop goes *event-driven* on top of this: a shard that
//! finishes its probe fan-out early signals the planner, which — while
//! straggler shards are still draining — draws round `t+1`'s participant
//! set and channel admission against the engine's watermarks
//! ([`ShardPlane::note_overlap`] counts these overlapped plans).  Commit
//! ordering is still enforced by the existing `CatchupTracker` / replica
//! watermarks, which is why overlapping planning with execution cannot
//! change a single bit (lookahead only moves *sequenced* draws earlier in
//! wall-clock, never earlier in draw order).

use crate::comm::{Ledger, Message};
use crate::coordinator::catchup::CatchupTracker;

/// Contiguous, balanced partition of client ids `0..k` into `n` shards.
///
/// Shard sizes differ by at most one (the first `k % n` shards take the
/// extra client), and ranges ascend with the shard index — the property
/// the merge-order invariant rides on.  `n` is clamped to `1..=k`, so a
/// `--shards 7` request over a 3-client pool degrades to 3 singleton
/// shards instead of manufacturing empty ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMap {
    bounds: Vec<usize>,
}

impl ShardMap {
    pub fn new(k: usize, n: usize) -> ShardMap {
        assert!(k > 0, "cannot shard an empty client pool");
        let n = n.clamp(1, k);
        let (base, extra) = (k / n, k % n);
        let mut bounds = Vec::with_capacity(n + 1);
        let mut at = 0usize;
        bounds.push(0);
        for s in 0..n {
            at += base + usize::from(s < extra);
            bounds.push(at);
        }
        debug_assert_eq!(at, k);
        ShardMap { bounds }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total clients covered.
    pub fn clients(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Client-id range owned by shard `s`.
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// The shard owning client `id`.
    pub fn shard_of(&self, id: usize) -> usize {
        debug_assert!(id < self.clients());
        self.bounds.partition_point(|&b| b <= id) - 1
    }

    /// Tile ownership for a physically sharded parameter plane: the
    /// half-open range of fused-sweep tiles (tile indices, elements
    /// `[i·tile, min((i+1)·tile, d))`) shard `s` of [`Self::shards`]
    /// would walk when the canonical store of `d` elements is
    /// partitioned in contiguous tile-aligned spans.  Pure bookkeeping —
    /// today's coordinator shards share one canonical buffer and the
    /// whole sweep runs on the replica plane — but the split is the
    /// contract a multi-node deployment (and its spill files) would
    /// partition the [`crate::coordinator::tile::TileStore`] by, and it
    /// is total: concatenated in shard order the ranges cover every
    /// tile exactly once, for any `(d, tile)`.
    pub fn tile_range(&self, s: usize, d: usize, tile: usize) -> std::ops::Range<usize> {
        let tile = tile.max(1);
        let n_tiles = d.div_ceil(tile);
        let n = self.shards();
        // same contiguous balanced split rule as the client partition:
        // the first (n_tiles % n) shards take one extra tile
        let base = n_tiles / n;
        let extra = n_tiles % n;
        let start = s * base + s.min(extra);
        let len = base + usize::from(s < extra);
        start..(start + len).min(n_tiles)
    }

    /// Split a sorted participant list along shard boundaries.  Returns
    /// one (possibly empty) slice per shard; concatenated in shard order
    /// they reproduce the input exactly — the global draw is partitioned,
    /// never re-drawn.
    pub fn split_participants<'a>(&self, participants: &'a [usize]) -> Vec<&'a [usize]> {
        debug_assert!(participants.windows(2).all(|w| w[0] < w[1]), "participants must be sorted");
        (0..self.shards())
            .map(|s| {
                let r = self.range(s);
                let lo = participants.partition_point(|&id| id < r.start);
                let hi = participants.partition_point(|&id| id < r.end);
                &participants[lo..hi]
            })
            .collect()
    }
}

/// One shard's per-round sign-vote accumulator: the associative
/// `(sum, voters)` reduction that crosses the shard -> merger hop instead
/// of the individual votes.
#[derive(Debug, Clone, Copy, Default)]
pub struct VoteAcc {
    pub sum: i32,
    pub voters: usize,
}

impl VoteAcc {
    pub fn push(&mut self, sign: i8) {
        self.sum += sign as i32;
        self.voters += 1;
    }

    /// Fold another accumulator in (merger side).
    pub fn merge(&mut self, other: VoteAcc) {
        self.sum += other.sum;
        self.voters += other.voters;
    }

    /// `q_+` reconstructed from the reduction — exact, because
    /// `sum = q_+ - q_-` and `voters = q_+ + q_-`.
    pub fn q_plus(&self) -> usize {
        debug_assert!(self.sum.unsigned_abs() as usize <= self.voters);
        ((self.sum + self.voters as i32) / 2) as usize
    }
}

/// Headline counters for the sharded plane, surfaced in
/// [`crate::metrics::RunResult`] and the CLI run summary.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardStats {
    /// Shard count the run executed with (0 = unsharded legacy path).
    pub shards: usize,
    /// Hierarchical merge messages (one [`Message::ShardVotes`] per shard
    /// with planned participants per round).
    pub merges: u64,
    /// Bits those merges carried.  Coordinator-internal: the client-facing
    /// ledger is byte-identical to the unsharded run's (the conservation
    /// invariant the shard fuzz suite asserts).
    pub merge_bits: u64,
    /// Rounds whose `t+1` plan was drawn while at least one straggler
    /// shard was still executing round `t` (the event-driven overlap).
    pub rounds_overlapped: u64,
}

/// The session-side sharded coordinator plane: the partition, the merge
/// ledger, and the overlap bookkeeping.
#[derive(Debug, Clone)]
pub struct ShardPlane {
    map: ShardMap,
    merge_ledger: Ledger,
    rounds_overlapped: u64,
}

impl ShardPlane {
    pub fn new(k: usize, n: usize) -> ShardPlane {
        ShardPlane { map: ShardMap::new(k, n), merge_ledger: Ledger::default(), rounds_overlapped: 0 }
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Meter one shard -> merger message into the (coordinator-internal)
    /// merge ledger; returns the metered payload bits so callers can
    /// attach them to a trace event without re-deriving the encoding.
    pub fn record_merge(&mut self, msg: &Message) -> u64 {
        debug_assert!(matches!(msg, Message::ShardVotes { .. }));
        let before = self.merge_ledger.uplink_bits;
        self.merge_ledger.record(msg);
        self.merge_ledger.uplink_bits - before
    }

    /// A shard finished executing while stragglers were still draining
    /// and the planner drew the next round's plan against the watermarks.
    pub fn note_overlap(&mut self) {
        self.rounds_overlapped += 1;
    }

    /// The [`crate::comm::SeedHistory`] compaction floor: the **min
    /// across shards** of the shard-local watermarks.  Associativity of
    /// min makes this equal to the flat tracker's global watermark — the
    /// point is that it is computed hierarchically, the only form a
    /// physically sharded deployment has, and that no single shard's
    /// local watermark is ever used alone (the regression the shard test
    /// suite pins).
    pub fn compaction_watermark(&self, tracker: &CatchupTracker) -> u64 {
        (0..self.map.shards())
            .map(|s| tracker.watermark_over(self.map.range(s)))
            .min()
            .unwrap_or(0)
    }

    pub fn stats(&self) -> ShardStats {
        ShardStats {
            shards: self.map.shards(),
            merges: self.merge_ledger.uplink_msgs,
            merge_bits: self.merge_ledger.uplink_bits,
            rounds_overlapped: self.rounds_overlapped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{SeedHistory, SeedPool, SeedRecord};

    #[test]
    fn tile_ranges_cover_every_tile_exactly_once() {
        for (k, n) in [(8usize, 1usize), (8, 3), (16, 4), (5, 5)] {
            let m = ShardMap::new(k, n);
            for d in [1usize, 63, 64, 4099, 1 << 16] {
                for tile in [1usize, 61, 4096, d, d + 7] {
                    let n_tiles = d.div_ceil(tile);
                    let mut next = 0usize;
                    for s in 0..m.shards() {
                        let r = m.tile_range(s, d, tile);
                        assert_eq!(r.start, next, "contiguous at shard {s} (d={d} tile={tile})");
                        next = r.end;
                    }
                    assert_eq!(next, n_tiles, "exhaustive (d={d} tile={tile} shards={n})");
                    // balanced: no shard owns 2+ more tiles than another
                    let lens: Vec<usize> =
                        (0..m.shards()).map(|s| m.tile_range(s, d, tile).len()).collect();
                    let (lo, hi) =
                        (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(hi - lo <= 1, "balanced split: {lens:?}");
                }
            }
        }
        // tile = 0 degenerates to 1-element tiles instead of dividing by 0
        let m = ShardMap::new(4, 2);
        assert_eq!(m.tile_range(0, 10, 0).end, 5);
    }

    #[test]
    fn shard_map_is_contiguous_balanced_and_exhaustive() {
        for k in [1usize, 2, 3, 7, 100, 1013] {
            for n in [1usize, 2, 4, 7, 64] {
                let m = ShardMap::new(k, n);
                assert_eq!(m.shards(), n.min(k));
                assert_eq!(m.clients(), k);
                let mut seen = 0usize;
                let mut sizes = Vec::new();
                for s in 0..m.shards() {
                    let r = m.range(s);
                    assert_eq!(r.start, seen, "ranges must be contiguous and ascending");
                    assert!(!r.is_empty(), "clamping must prevent empty shards");
                    for id in r.clone() {
                        assert_eq!(m.shard_of(id), s);
                    }
                    sizes.push(r.len());
                    seen = r.end;
                }
                assert_eq!(seen, k, "every client owned exactly once");
                let (lo, hi) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(hi - lo <= 1, "balanced to within one client ({sizes:?})");
            }
        }
    }

    #[test]
    fn split_participants_partitions_the_global_draw() {
        let m = ShardMap::new(10, 4); // ranges 0..3, 3..6, 6..8, 8..10
        let parts = vec![0usize, 2, 3, 7, 9];
        let split = m.split_participants(&parts);
        assert_eq!(split.len(), 4);
        assert_eq!(split[0], &[0, 2]);
        assert_eq!(split[1], &[3]);
        assert_eq!(split[2], &[7]);
        assert_eq!(split[3], &[9]);
        // concatenation in shard order reproduces the draw exactly
        let rejoined: Vec<usize> = split.iter().flat_map(|s| s.iter().copied()).collect();
        assert_eq!(rejoined, parts);
        // empty shards yield empty slices, not omissions
        let none: Vec<usize> = vec![4];
        let split = m.split_participants(&none);
        assert_eq!(split.iter().map(|s| s.len()).sum::<usize>(), 1);
        assert_eq!(split[1], &[4]);
    }

    #[test]
    fn vote_acc_reduction_is_exact_and_associative() {
        // any split of any vote vector: merging shard accumulators must
        // reproduce the flat (sum, voters, q_plus) triple
        let votes: Vec<i8> = (0..23).map(|i| if i % 3 == 0 { -1 } else { 1 }).collect();
        let mut flat = VoteAcc::default();
        votes.iter().for_each(|&s| flat.push(s));
        for cut in 0..=votes.len() {
            let (a_votes, b_votes) = votes.split_at(cut);
            let mut a = VoteAcc::default();
            a_votes.iter().for_each(|&s| a.push(s));
            let mut b = VoteAcc::default();
            b_votes.iter().for_each(|&s| b.push(s));
            a.merge(b);
            assert_eq!(a.sum, flat.sum);
            assert_eq!(a.voters, flat.voters);
            assert_eq!(a.q_plus(), flat.q_plus());
        }
        assert_eq!(flat.q_plus(), votes.iter().filter(|&&s| s > 0).count());
    }

    #[test]
    fn merge_ledger_meters_shard_votes_separately() {
        let mut p = ShardPlane::new(100, 4);
        let b0 = p
            .record_merge(&Message::ShardVotes { sum: 3, voters: 20, shard_size: 25, dense_pairs: false });
        let b1 = p
            .record_merge(&Message::ShardVotes { sum: -5, voters: 25, shard_size: 25, dense_pairs: false });
        assert_eq!(b0, 6 + 5, "record_merge reports the metered bits");
        assert_eq!(b1, 6 + 5);
        let s = p.stats();
        assert_eq!(s.shards, 4);
        assert_eq!(s.merges, 2);
        // 20 voters: sum in [-20,20] -> ceil(log2 41) = 6, count in
        // [0,25] -> ceil(log2 26) = 5; 25 voters: 6 + 5
        assert_eq!(s.merge_bits, (6 + 5) + (6 + 5));
        assert_eq!(s.rounds_overlapped, 0);
        p.note_overlap();
        assert_eq!(p.stats().rounds_overlapped, 1);
    }

    #[test]
    fn compaction_watermark_folds_min_across_shards() {
        let plane = ShardPlane::new(9, 3);
        let mut t = CatchupTracker::new(9);
        for id in 0..9 {
            t.mark_synced(id, 10 + id as u64);
        }
        // shard floors: 10, 13, 16 — the fold takes the min
        assert_eq!(plane.compaction_watermark(&t), 10);
        assert_eq!(plane.compaction_watermark(&t), t.watermark());
        // drag one client in the *last* shard down: the fold must follow
        let mut t2 = CatchupTracker::new(9);
        t2.mark_synced(8, 0); // no-op, but explicit
        for id in 0..8 {
            t2.mark_synced(id, 50);
        }
        assert_eq!(plane.compaction_watermark(&t2), 0);
    }

    /// The satellite regression: the compaction floor must be the min
    /// across *all* shards' local watermarks.  The old single-watermark
    /// logic — compacting to the watermark of whichever shard drove the
    /// commit (here shard 0, fully synced) — drops the exact records a
    /// straggler in another shard still needs, and its rejoin replay dies
    /// with a refused span.  The min-across-shards fold keeps them.
    #[test]
    fn single_shard_watermark_compaction_loses_records_min_across_shards_keeps_them() {
        let plane = ShardPlane::new(8, 2); // shard 0: ids 0..4, shard 1: ids 4..8
        let mut tracker = CatchupTracker::new(8);
        let records = |t: u64| [SeedRecord::sign_step(t, if t % 2 == 0 { 1 } else { -1 }, 1e-3)];

        // 20 rounds; shard 0's clients all stay current, client 6 (shard 1)
        // went offline after round 3
        let mut good = SeedHistory::new(2); // tiny ring: compaction is live
        let mut bad = SeedHistory::new(2);
        for t in 0..20u64 {
            for id in 0..8 {
                if id != 6 || t < 3 {
                    tracker.mark_synced(id, t + 1);
                }
            }
            good.commit_round(t, records(t));
            bad.commit_round(t, records(t));
            // fixed logic: fold the min across both shards' local floors
            good.compact_to(plane.compaction_watermark(&tracker));
            // old logic: one shard's watermark stands in for the pool's
            bad.compact_to(tracker.watermark_over(plane.map().range(0)));
        }
        assert_eq!(plane.compaction_watermark(&tracker), 3, "client 6 pins the floor");

        // client 6 rejoins and asks for rounds 3..20
        let span = tracker.span(6, 20);
        assert_eq!(span, 3..20);
        assert!(
            good.replay_span(span.start, span.end).is_some(),
            "min-across-shards retains the straggler's records"
        );
        assert!(
            bad.replay_span(span.start, span.end).is_none(),
            "single-shard watermark compacted the straggler's records away — \
             the bug the min-across-shards fold fixes"
        );
    }

    /// Orbit-v2-era rings hold v1 derivable sign records and v2
    /// restricted-pool index records side by side.  Sharded compaction
    /// must treat the eras uniformly: whole rounds drop at the
    /// min-across-shards floor, and a straggler's replay span comes back
    /// with both record kinds — and their wire pricing — intact.
    #[test]
    fn mixed_v1_v2_records_compact_and_replay_under_sharded_watermarks() {
        let plane = ShardPlane::new(6, 3); // shards: 0..2, 2..4, 4..6
        let mut tracker = CatchupTracker::new(6);
        let mut hist = SeedHistory::new(4); // tiny ring: compaction is live
        let pool = SeedPool::derive(9, 16); // 4 index bits
        for t in 0..12u64 {
            // alternate eras: even rounds commit a v1 sign record, odd
            // rounds a v2 pool-index record
            let rec = if t % 2 == 0 {
                SeedRecord::sign_step(t, 1, 1e-3)
            } else {
                let index = (t % 16) as u32;
                SeedRecord::index_step(t, pool.seed_at(index), index, pool.index_bits(), -1, 1e-3)
            };
            hist.commit_round(t, [rec]);
            // client 5 (last shard) goes offline after round 5
            for id in 0..6 {
                if id != 5 || t < 5 {
                    tracker.mark_synced(id, t + 1);
                }
            }
            hist.compact_to(plane.compaction_watermark(&tracker));
        }
        assert_eq!(plane.compaction_watermark(&tracker), 5, "client 5 pins the floor");
        assert_eq!(hist.tail_round(), 5, "compaction reached the sharded floor, never past it");
        assert_eq!(hist.records_len(), 7, "rounds 5..12 retained above the soft capacity");

        // the straggler's rejoin span carries both eras, pricing intact:
        // rounds 5,7,9,11 are 5-bit index records, 6,8,10 are 1-bit signs
        let span = tracker.span(5, 12);
        assert_eq!(span, 5..12);
        let records = hist.replay_span(span.start, span.end).expect("span must be replayable");
        assert_eq!(records.len(), 7);
        for r in &records {
            match r.pool_index {
                Some((index, bits)) => {
                    assert_eq!(r.round % 2, 1, "odd rounds committed the v2 era");
                    assert_eq!(bits, 4);
                    assert_eq!(r.seed, pool.seed_at(index), "v2 records resolve their pool seed");
                    assert_eq!(r.payload_bits(), 5);
                }
                None => {
                    assert_eq!(r.round % 2, 0, "even rounds committed the v1 era");
                    assert!(r.seed_from_round);
                    assert_eq!(r.payload_bits(), 1);
                }
            }
        }
        assert_eq!(records.iter().map(SeedRecord::payload_bits).sum::<u64>(), 4 * 5 + 3 * 1);
    }
}
