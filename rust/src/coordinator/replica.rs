//! The replica plane: a copy-on-write shared parameter store for the
//! synchronous session's client pool.
//!
//! FeedSign's defining invariant — every synchronized client's replica is
//! **bit-identical**, because the model is fully determined by the
//! committed `(seed, sign)` stream — means storing one dense parameter
//! vector per client (`K · d` floats) is pure redundancy.  This module
//! exploits the invariant instead of merely asserting it:
//!
//! * one **canonical buffer** holds the parameters at the committed head
//!   round; the commit phase applies each aggregated update **once** to
//!   it (`O(d)` per round) instead of broadcasting `K` identical AXPYs
//!   (`O(K·d)`);
//! * each client is a **logical replica** `(watermark, state)`:
//!   - [`ReplicaState::Shared`] — zero extra memory; a *current* shared
//!     client (watermark == head) reads the canonical buffer directly,
//!     and a *stale* one (watermark < head) denotes
//!     "canonical-as-of(watermark)" without materializing it — the
//!     seed-history catch-up replay that would bring it current is, by
//!     the invariant, pure bookkeeping (bill the records, bump the
//!     watermark; the resulting bits *are* the canonical buffer's);
//!   - [`ReplicaState::Owned`] — a copy-on-write promotion for clients
//!     that genuinely diverge from the committed stream (external
//!     mutation through [`ReplicaStore::promote_owned`], or a
//!     non-canonical initial checkpoint).  Owned replicas pay their own
//!     `d` floats and participate in commits/catch-up with real math.
//! * a small bounded **snapshot cache** retains pre-commit canonical
//!   buffers (one per round that left a shared client behind), so a
//!   stale logical replica can still be *read* without a full
//!   init-plus-history reconstruction.  Capacity is the session's
//!   `replica_cache` knob; `0` disables the cache.  The cache is only
//!   fed while stragglers exist, so the all-synced hot path holds
//!   exactly one `d`-float buffer regardless of `K`.
//!
//! Per-client watermarks are the same [`CatchupTracker`] the catch-up
//! machinery uses (embedded here so the replica plane and the catch-up
//! billing can never disagree about who is stale); its minimum remains
//! the [`crate::comm::SeedHistory`] compaction floor.
//!
//! The store is engine-agnostic: commits take a closure so the session
//! can route the apply through [`crate::engine::Engine::update`]
//! (native or PJRT), and `Engine::update` being a pure function of
//! `(w, seed, step)` is what makes one canonical apply bit-identical to
//! the `K` per-client applies it replaces (pinned by
//! `rust/tests/replica_parity.rs`).
//!
//! The store holds **no interior mutability**, so a `&ReplicaStore` is
//! `Sync` and the sharded coordinator ([`crate::coordinator::shard`])
//! shares the one canonical buffer read-only across its shard workers
//! during the execute phase ([`ReplicaStore::probe_view`] is `&self`);
//! commits stay on the single merger thread.  That sharing is what keeps
//! coordinator memory flat in the shard count *and* in `K`.

use crate::coordinator::catchup::CatchupTracker;
use crate::coordinator::tile::{TileStats, TileStore};
use crate::simkit::zo;

/// Memory state of one logical client replica.
#[derive(Debug, Clone, PartialEq)]
pub enum ReplicaState {
    /// The replica is `canonical-as-of(watermark)` — no buffer of its
    /// own.  Current (watermark == head) shared replicas read the
    /// canonical buffer; stale ones resolve through the snapshot cache
    /// or a history reconstruction.
    Shared,
    /// A materialized divergent buffer (copy-on-write promotion).
    Owned(Vec<f32>),
}

/// Replica-plane accounting, exported into
/// [`crate::metrics::RunResult`]: the coordinator-side counterpart of
/// the paper's Table 10 client-memory story.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ReplicaStats {
    /// Flat parameter count `d`.
    pub d: usize,
    /// Pool size `K`.
    pub clients: usize,
    /// Live replica-plane bytes (canonical + owned + cache) at readout.
    pub current_bytes: usize,
    /// Peak replica-plane bytes over the run — `4·d` on the all-synced
    /// path, vs the `4·K·d` a dense layout pays ([`Self::dense_bytes`]).
    pub peak_bytes: usize,
    /// Clients currently holding an owned (diverged) buffer.
    pub owned_clients: usize,
    /// Canonical-buffer applies — exactly one per committed (non-no-op)
    /// round, where the dense layout performed `K`.
    pub canonical_commits: u64,
    /// Pre-commit canonical snapshots taken for stale-replica reads.
    pub snapshots: u64,
    /// Snapshots the admission policy declined (a straggler existed but
    /// the session judged stale readers unlikely —
    /// [`ReplicaStore::set_snapshot_admission`]).
    pub snapshots_declined: u64,
    /// What `K` dense replicas would cost: `4·K·d` bytes.
    pub dense_bytes: usize,
    /// Tiered-store (spill-mode) accounting — all zeros when spill is
    /// off.  In spill mode the authoritative canonical bits live in the
    /// file-backed [`TileStore`] and `tile.peak_resident_bytes` (≤ the
    /// configured budget for any `d`) is the canonical-store memory
    /// claim; [`Self::current_bytes`]/[`Self::peak_bytes`] keep counting
    /// the transient working surfaces (read mirror, owned, cache) the
    /// flat engine also pays.
    pub tile: TileStats,
}

/// The copy-on-write shared parameter store.  See the module docs for
/// the state machine; the session drives it through three commit verbs
/// ([`ReplicaStore::advance_all`], [`ReplicaStore::advance`],
/// [`ReplicaStore::advance_noop`]) plus the catch-up bookkeeping
/// ([`ReplicaStore::mark_synced`]).
#[derive(Debug)]
pub struct ReplicaStore {
    d: usize,
    canonical: Vec<f32>,
    /// Rounds `[0, head)` are folded into the canonical buffer.
    head: u64,
    states: Vec<ReplicaState>,
    /// Per-client `last_synced_round` watermarks (shared with the
    /// catch-up machinery: the minimum is the history compaction floor).
    tracker: CatchupTracker,
    /// FIFO ring of `(round, pre-commit canonical)` snapshots.
    cache: Vec<(u64, Vec<f32>)>,
    cache_cap: usize,
    /// Admission switch over the cache (see
    /// [`ReplicaStore::set_snapshot_admission`]); defaults to permissive
    /// so direct store users keep the PR 5 semantics.
    admit_snapshots: bool,
    current_bytes: usize,
    peak_bytes: usize,
    canonical_commits: u64,
    snapshots: u64,
    snapshots_declined: u64,
    /// Spill mode: the authoritative canonical bits live in this
    /// file-backed tile pager, and `canonical` doubles as the
    /// always-fresh read mirror (every commit verb refreshes it — the
    /// fused sweep mirrors each committed tile in the same pass, the
    /// closure verbs write the mirror back through
    /// [`TileStore::write_from`]), so every `&self` read path is
    /// untouched by the mode.
    tiled: Option<TileStore>,
}

impl ReplicaStore {
    /// A pool of `k` logical replicas, all starting as shared views of
    /// `canonical` at round 0.  `cache_cap` bounds the stale-read
    /// snapshot cache (buffers, not bytes; each is `d` floats).
    pub fn new(canonical: Vec<f32>, k: usize, cache_cap: usize) -> Self {
        assert!(k > 0);
        let d = canonical.len();
        let mut store = ReplicaStore {
            d,
            canonical,
            head: 0,
            states: (0..k).map(|_| ReplicaState::Shared).collect(),
            tracker: CatchupTracker::new(k),
            cache: Vec::new(),
            cache_cap,
            admit_snapshots: true,
            current_bytes: 0,
            peak_bytes: 0,
            canonical_commits: 0,
            snapshots: 0,
            snapshots_declined: 0,
            tiled: None,
        };
        store.account();
        store
    }

    /// Switch the canonical store to spill mode: the current canonical
    /// bits seed a file-backed [`TileStore`] paged in `tile`-element
    /// tiles with at most `budget_bytes` of resident pages, and the
    /// in-RAM buffer becomes the read mirror.  Purely a memory policy —
    /// every commit verb and read path produces the same bits either
    /// way (pinned by `tile_parity.rs` and the `table10_memory` spill
    /// column).
    pub fn enable_spill(&mut self, tile: usize, budget_bytes: usize) {
        assert!(self.tiled.is_none(), "spill mode already enabled");
        self.tiled = Some(TileStore::new(&self.canonical, tile, budget_bytes));
    }

    /// Whether the canonical store is in spill mode.
    pub fn is_spill(&self) -> bool {
        self.tiled.is_some()
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n_clients(&self) -> usize {
        self.states.len()
    }

    /// First round not yet folded into the canonical buffer.
    pub fn head(&self) -> u64 {
        self.head
    }

    /// The shared parameter buffer at the committed head round.
    pub fn canonical(&self) -> &[f32] {
        &self.canonical
    }

    /// The per-client sync watermarks (also the catch-up tracker).
    pub fn tracker(&self) -> &CatchupTracker {
        &self.tracker
    }

    /// First round client `id` has not applied yet.
    pub fn watermark(&self, id: usize) -> u64 {
        self.tracker.last_synced(id)
    }

    pub fn state(&self, id: usize) -> &ReplicaState {
        &self.states[id]
    }

    pub fn is_owned(&self, id: usize) -> bool {
        matches!(self.states[id], ReplicaState::Owned(_))
    }

    /// Whether client `id` is synced to the head round.
    pub fn is_current(&self, id: usize) -> bool {
        self.watermark(id) == self.head
    }

    /// The physically materialized buffer backing client `id`, if any:
    /// its owned buffer, or the canonical buffer when the client is a
    /// *current* shared replica.  `None` for a stale shared replica
    /// (resolve those through the cache / a reconstruction).
    pub fn resident(&self, id: usize) -> Option<&[f32]> {
        match &self.states[id] {
            ReplicaState::Owned(w) => Some(w),
            ReplicaState::Shared if self.is_current(id) => Some(&self.canonical),
            ReplicaState::Shared => None,
        }
    }

    /// The buffer a *participant* probes against.  Participants are
    /// always caught up before the execute phase (the session replays
    /// stale participants at plan time), so a stale view here is an
    /// engine bug, not a data condition.
    pub fn probe_view(&self, id: usize) -> &[f32] {
        self.resident(id).unwrap_or_else(|| {
            panic!(
                "client {id} probes while stale (watermark {} < head {}); \
                 participants must be caught up before the execute phase",
                self.watermark(id),
                self.head
            )
        })
    }

    /// The buffer evaluation reads for client `id`: its owned buffer, or
    /// the canonical buffer for shared replicas.  For a stale *shared*
    /// replica this is only bit-exact when the missed span is a no-op —
    /// which the session's freshest-replica selection guarantees (a
    /// non-no-op round always marks its voters current).
    pub fn eval_view(&self, id: usize) -> &[f32] {
        match &self.states[id] {
            ReplicaState::Owned(w) => w,
            ReplicaState::Shared => &self.canonical,
        }
    }

    /// Mutable access to an owned (diverged) buffer.
    pub fn owned_mut(&mut self, id: usize) -> Option<&mut Vec<f32>> {
        match &mut self.states[id] {
            ReplicaState::Owned(w) => Some(w),
            ReplicaState::Shared => None,
        }
    }

    /// Copy-on-write promotion: materialize client `id` as an owned copy
    /// of its current logical replica and return the buffer.  The client
    /// must be current (promote-then-diverge is the supported order; a
    /// stale client is caught up, or read through
    /// [`ReplicaStore::set_owned`] with an externally materialized
    /// buffer, first).
    pub fn promote_owned(&mut self, id: usize) -> &mut Vec<f32> {
        if let ReplicaState::Shared = self.states[id] {
            assert!(
                self.is_current(id),
                "cannot promote stale client {id} (watermark {} < head {}); \
                 catch it up or set_owned an explicit buffer",
                self.watermark(id),
                self.head
            );
            self.states[id] = ReplicaState::Owned(self.canonical.clone());
            self.account();
        }
        match &mut self.states[id] {
            ReplicaState::Owned(w) => w,
            ReplicaState::Shared => unreachable!(),
        }
    }

    /// Install an explicit owned buffer for client `id` (a divergent
    /// initial checkpoint, or an externally materialized stale replica).
    pub fn set_owned(&mut self, id: usize, w: Vec<f32>) {
        assert_eq!(w.len(), self.d, "owned replica must match the parameter count");
        self.states[id] = ReplicaState::Owned(w);
        self.account();
    }

    /// Record that client `id` has applied every round below `round`
    /// (catch-up bookkeeping; for shared replicas this IS the whole
    /// catch-up — the invariant makes the replayed bits canonical).
    pub fn mark_synced(&mut self, id: usize, round: u64) {
        assert!(round <= self.head, "cannot sync client {id} past the head round");
        self.tracker.mark_synced(id, round);
    }

    /// Commit a round delivered to **every** client (`catchup = "off"`,
    /// the FO baseline, MeZO): apply once to the canonical buffer and to
    /// each owned buffer, then advance every watermark to the new head.
    pub fn advance_all(&mut self, round: u64, mut apply: impl FnMut(&mut [f32])) {
        assert!(round >= self.head, "rounds must commit in order");
        apply(&mut self.canonical);
        if let Some(store) = &mut self.tiled {
            store.write_from(&self.canonical);
        }
        self.canonical_commits += 1;
        for state in &mut self.states {
            if let ReplicaState::Owned(w) = state {
                apply(w);
            }
        }
        self.head = round + 1;
        for id in 0..self.states.len() {
            self.tracker.mark_synced(id, self.head);
        }
    }

    /// Commit a round delivered to `recipients` only (catch-up on: the
    /// clients the PS heard from).  Shared non-recipients become stale
    /// logical replicas — if the cache is enabled and any current shared
    /// client is being left behind, the pre-commit canonical is
    /// snapshotted first so its logical value stays readable.
    pub fn advance(&mut self, round: u64, recipients: &[usize], mut apply: impl FnMut(&mut [f32])) {
        assert!(round >= self.head, "rounds must commit in order");
        debug_assert!(recipients.windows(2).all(|p| p[0] < p[1]), "recipients must be sorted");
        if self.cache_cap > 0 {
            let mut rec = recipients.iter().copied().peekable();
            let left_behind = (0..self.states.len()).any(|id| {
                while rec.peek().is_some_and(|&r| r < id) {
                    rec.next();
                }
                let hears = rec.peek() == Some(&id);
                !hears && matches!(self.states[id], ReplicaState::Shared) && self.is_current(id)
            });
            if left_behind {
                if self.admit_snapshots {
                    self.snapshot(round);
                } else {
                    // admission declined: stale reads of this round fall
                    // back to the init-plus-orbit reconstruction, which
                    // is bit-exact — this is a memory policy only
                    self.snapshots_declined += 1;
                }
            }
        }
        apply(&mut self.canonical);
        if let Some(store) = &mut self.tiled {
            store.write_from(&self.canonical);
        }
        self.canonical_commits += 1;
        self.head = round + 1;
        for &id in recipients {
            if let ReplicaState::Owned(w) = &mut self.states[id] {
                apply(w);
            }
            self.tracker.mark_synced(id, self.head);
        }
    }

    /// The fused commit verb: apply round `round`'s aggregated
    /// update(s) `commits = [(seed, step)]` ([`zo::apply_update`]
    /// semantics) **and** materialise the next round's staged probe
    /// views `views = [(seed, ±mu)]` into `outs` in one tiled
    /// read-modify-write sweep over the canonical store
    /// ([`zo::fused_commit_probe_threads`]) — replacing the
    /// `1 + views` full-buffer passes the closure verbs plus a probe-
    /// time [`zo::axpy_many`] pass would make.  `recipients = None` is
    /// the [`Self::advance_all`] delivery contract, `Some` the
    /// [`Self::advance`] one (same snapshot/watermark behaviour,
    /// including for `step == 0.0` commits).  Owned replicas take plain
    /// [`zo::apply_update`] per commit — bit-identical to routing
    /// through `Engine::update`, which is the
    /// `Engine::fused_commit_exact` gate the session checks before
    /// calling this.  In spill mode the sweep drives the tile pager
    /// page by page and mirrors each committed tile into the read
    /// surface within the same pass.
    #[allow(clippy::too_many_arguments)]
    pub fn advance_fused(
        &mut self,
        round: u64,
        recipients: Option<&[usize]>,
        commits: &[(u32, f32)],
        views: &[(u32, f32)],
        outs: &mut [&mut [f32]],
        tile: usize,
        threads: usize,
    ) {
        assert!(round >= self.head, "rounds must commit in order");
        if let Some(recipients) = recipients {
            debug_assert!(recipients.windows(2).all(|p| p[0] < p[1]), "recipients must be sorted");
            if self.cache_cap > 0 {
                let mut rec = recipients.iter().copied().peekable();
                let left_behind = (0..self.states.len()).any(|id| {
                    while rec.peek().is_some_and(|&r| r < id) {
                        rec.next();
                    }
                    let hears = rec.peek() == Some(&id);
                    !hears && matches!(self.states[id], ReplicaState::Shared) && self.is_current(id)
                });
                if left_behind {
                    if self.admit_snapshots {
                        self.snapshot(round);
                    } else {
                        self.snapshots_declined += 1;
                    }
                }
            }
        }
        match &mut self.tiled {
            Some(store) => {
                let canonical = &mut self.canonical;
                store.sweep_mut(|at, page| {
                    let mut outs_t: Vec<&mut [f32]> =
                        outs.iter_mut().map(|o| &mut o[at..at + page.len()]).collect();
                    zo::fused_commit_probe_span(page, commits, views, &mut outs_t, at, tile);
                    canonical[at..at + page.len()].copy_from_slice(page);
                });
            }
            None => zo::fused_commit_probe_threads(
                &mut self.canonical,
                commits,
                views,
                outs,
                tile,
                threads,
            ),
        }
        self.canonical_commits += 1;
        self.head = round + 1;
        match recipients {
            Some(recipients) => {
                for &id in recipients {
                    if let ReplicaState::Owned(w) = &mut self.states[id] {
                        for &(seed, step) in commits {
                            zo::apply_update(w, seed, step);
                        }
                    }
                    self.tracker.mark_synced(id, self.head);
                }
            }
            None => {
                for state in &mut self.states {
                    if let ReplicaState::Owned(w) = state {
                        for &(seed, step) in commits {
                            zo::apply_update(w, seed, step);
                        }
                    }
                }
                for id in 0..self.states.len() {
                    self.tracker.mark_synced(id, self.head);
                }
            }
        }
    }

    /// Commit a no-op round (zero participants, or every vote lost in
    /// transit): the canonical buffer is untouched, the head advances to
    /// keep round indices dense.  `sync_all` mirrors the delivery
    /// assumption: true when every client is considered current through
    /// the no-op (`catchup = "off"`), false when watermarks only move
    /// via explicit delivery (catch-up on).
    pub fn advance_noop(&mut self, round: u64, sync_all: bool) {
        assert!(round >= self.head, "rounds must commit in order");
        self.head = round + 1;
        if sync_all {
            for id in 0..self.states.len() {
                self.tracker.mark_synced(id, self.head);
            }
        }
    }

    /// Gate the snapshot cache on whether stale readers are *likely*:
    /// the session consults its participation sampler and channel model
    /// each round and declines pre-commit snapshots when neither can
    /// strand a client (full participation over a delivering channel) —
    /// then injected plans that do strand someone cost a reconstruction
    /// on read instead of a `d`-float copy on every commit.  Defaults to
    /// `true` (always admit), the PR 5 behaviour, for direct store
    /// users.  Purely a memory/throughput policy: stale reads resolve
    /// bit-identically through the reconstruction fallback either way.
    pub fn set_snapshot_admission(&mut self, admit: bool) {
        self.admit_snapshots = admit;
    }

    /// Pre-commit canonical snapshot for round `round` (the buffer is
    /// `canonical-as-of(round)`, i.e. *before* round `round`'s update).
    fn snapshot(&mut self, round: u64) {
        if self.cache_cap == 0 {
            return;
        }
        self.cache.push((round, self.canonical.clone()));
        self.snapshots += 1;
        while self.cache.len() > self.cache_cap {
            self.cache.remove(0);
        }
        self.account();
    }

    /// The cached pre-commit canonical for round `round`, if retained.
    pub fn cached(&self, round: u64) -> Option<&[f32]> {
        self.cache.iter().find(|(r, _)| *r == round).map(|(_, w)| w.as_slice())
    }

    /// Replica-plane accounting snapshot.
    pub fn stats(&self) -> ReplicaStats {
        ReplicaStats {
            d: self.d,
            clients: self.states.len(),
            current_bytes: self.current_bytes,
            peak_bytes: self.peak_bytes,
            owned_clients: self
                .states
                .iter()
                .filter(|s| matches!(s, ReplicaState::Owned(_)))
                .count(),
            canonical_commits: self.canonical_commits,
            snapshots: self.snapshots,
            snapshots_declined: self.snapshots_declined,
            dense_bytes: 4 * self.d * self.states.len(),
            tile: self.tiled.as_ref().map(|t| t.stats()).unwrap_or_default(),
        }
    }

    /// Recompute live bytes (canonical + owned + cache) and fold into
    /// the peak.  Called on every allocation-changing transition.
    fn account(&mut self) {
        let owned: usize = self
            .states
            .iter()
            .map(|s| match s {
                ReplicaState::Owned(w) => w.len(),
                ReplicaState::Shared => 0,
            })
            .sum();
        let cached: usize = self.cache.iter().map(|(_, w)| w.len()).sum();
        self.current_bytes = 4 * (self.canonical.len() + owned + cached);
        self.peak_bytes = self.peak_bytes.max(self.current_bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(d: usize, k: usize, cache: usize) -> ReplicaStore {
        ReplicaStore::new(vec![1.0; d], k, cache)
    }

    #[test]
    fn all_synced_pool_costs_one_buffer_regardless_of_k() {
        for k in [1usize, 5, 200, 1000] {
            let mut s = store(64, k, 4);
            for t in 0..10 {
                s.advance_all(t, |w| w[0] += 1.0);
            }
            let st = s.stats();
            assert_eq!(st.peak_bytes, 4 * 64, "K={k}: all-synced must stay O(d)");
            assert_eq!(st.owned_clients, 0);
            assert_eq!(st.canonical_commits, 10);
            assert_eq!(st.dense_bytes, 4 * 64 * k);
            for id in 0..k {
                assert!(s.is_current(id));
                assert_eq!(s.probe_view(id), s.canonical());
            }
        }
    }

    #[test]
    fn partial_delivery_leaves_stragglers_stale_and_snapshots_once() {
        let mut s = store(8, 3, 4);
        s.advance(0, &[0, 1, 2], |w| w[0] += 1.0); // everyone current, no snapshot
        assert_eq!(s.stats().snapshots, 0);
        s.advance(1, &[0, 1], |w| w[0] += 1.0); // client 2 left behind -> snapshot
        assert_eq!(s.stats().snapshots, 1);
        assert!(s.is_current(0) && s.is_current(1));
        assert!(!s.is_current(2));
        assert_eq!(s.watermark(2), 1);
        assert!(s.resident(2).is_none(), "stale shared replica holds no buffer");
        // the snapshot is canonical-as-of(1): one update applied
        assert_eq!(s.cached(1).unwrap()[0], 2.0);
        assert_eq!(s.canonical()[0], 3.0);
        // catch-up is bookkeeping for shared replicas
        s.mark_synced(2, s.head());
        assert_eq!(s.probe_view(2), s.canonical());
    }

    #[test]
    fn advance_skips_snapshot_when_straggler_was_already_stale() {
        let mut s = store(4, 2, 4);
        s.advance(0, &[0], |w| w[0] += 1.0); // leaves client 1 at 0 -> snapshot(0)
        s.advance(1, &[0], |w| w[0] += 1.0); // client 1 already stale -> no new snapshot
        assert_eq!(s.stats().snapshots, 1);
        assert_eq!(s.cached(0).unwrap()[0], 1.0);
    }

    #[test]
    fn snapshot_cache_is_bounded_fifo() {
        let mut s = store(4, 2, 2);
        // client 1 resyncs right before each commit, so every commit
        // leaves a *current* shared client behind and snapshots
        for t in 0..5 {
            s.mark_synced(1, s.head());
            s.advance(t, &[0], |w| w[0] += 1.0);
        }
        assert_eq!(s.stats().snapshots, 5);
        assert!(s.cached(0).is_none(), "oldest snapshots evicted");
        assert!(s.cached(3).is_some() && s.cached(4).is_some());
        assert!(s.stats().current_bytes <= 4 * 4 * 3, "canonical + 2 cached buffers");
    }

    #[test]
    fn declined_admission_counts_and_takes_no_copy() {
        let mut s = store(4, 2, 4);
        s.set_snapshot_admission(false);
        s.mark_synced(1, s.head());
        s.advance(0, &[0], |w| w[0] += 1.0); // would snapshot, but declined
        assert_eq!(s.stats().snapshots, 0);
        assert_eq!(s.stats().snapshots_declined, 1);
        assert!(s.cached(0).is_none());
        assert_eq!(s.stats().peak_bytes, 4 * 4, "no cache copy was taken");
        // re-admitting restores the PR 5 behaviour
        s.set_snapshot_admission(true);
        s.mark_synced(1, s.head());
        s.advance(1, &[0], |w| w[0] += 1.0);
        assert_eq!(s.stats().snapshots, 1);
        assert!(s.cached(1).is_some());
    }

    #[test]
    fn cache_capacity_zero_disables_snapshots() {
        let mut s = store(4, 2, 0);
        s.advance(0, &[0], |w| w[0] += 1.0);
        assert_eq!(s.stats().snapshots, 0);
        assert!(s.cached(0).is_none());
        assert_eq!(s.stats().peak_bytes, 4 * 4);
    }

    #[test]
    fn cow_promotion_materializes_and_diverges() {
        let mut s = store(8, 3, 4);
        s.advance_all(0, |w| w[0] += 1.0);
        let w = s.promote_owned(1);
        w[3] = 99.0;
        assert!(s.is_owned(1));
        assert_eq!(s.stats().owned_clients, 1);
        assert_eq!(s.stats().current_bytes, 4 * 8 * 2, "canonical + one owned");
        assert_eq!(s.probe_view(1)[3], 99.0);
        assert_eq!(s.canonical()[3], 1.0, "canonical untouched by the owned write");
        // owned replicas ride subsequent full commits
        s.advance_all(1, |w| w[0] += 1.0);
        assert_eq!(s.probe_view(1)[0], 3.0);
        assert_eq!(s.canonical()[0], 3.0);
        assert_eq!(s.stats().canonical_commits, 2);
    }

    #[test]
    fn promote_is_idempotent() {
        let mut s = store(4, 2, 0);
        s.promote_owned(0)[0] = 5.0;
        assert_eq!(s.promote_owned(0)[0], 5.0, "second promote returns the same buffer");
        assert_eq!(s.stats().owned_clients, 1);
    }

    #[test]
    #[should_panic(expected = "stale")]
    fn probing_a_stale_replica_panics() {
        let mut s = store(4, 2, 0);
        s.advance(0, &[0], |w| w[0] += 1.0);
        s.probe_view(1);
    }

    #[test]
    #[should_panic(expected = "promote stale")]
    fn promoting_a_stale_replica_panics() {
        let mut s = store(4, 2, 0);
        s.advance(0, &[0], |w| w[0] += 1.0);
        s.promote_owned(1);
    }

    #[test]
    fn noop_rounds_advance_head_without_touching_canonical() {
        let mut s = store(4, 2, 4);
        s.advance_noop(0, true);
        assert_eq!(s.head(), 1);
        assert_eq!(s.canonical()[0], 1.0);
        assert!(s.is_current(0) && s.is_current(1));
        s.advance_noop(1, false);
        assert_eq!(s.head(), 2);
        assert!(!s.is_current(0), "catch-up-on no-ops move only the head");
        assert_eq!(s.stats().canonical_commits, 0);
    }

    #[test]
    fn advance_fused_matches_closure_verbs_bitwise() {
        // fused commit (flat mode) vs the classic closure verbs: same
        // canonical bits, same owned bits, same watermarks/counters —
        // and the staged views equal a probe-time axpy pass
        let d = 1037;
        let init = crate::simkit::prng::normals_vec(4, d);
        let mut classic = ReplicaStore::new(init.clone(), 3, 4);
        let mut fused = ReplicaStore::new(init, 3, 4);
        classic.set_owned(2, vec![0.5; d]);
        fused.set_owned(2, vec![0.5; d]);
        let mu = 1e-3f32;
        for t in 0..6u64 {
            let seed = crate::simkit::prng::round_direction_seed(t);
            let next = crate::simkit::prng::round_direction_seed(t + 1);
            let step = if t == 3 { 0.0 } else { 2e-3 };
            let recipients: &[usize] = if t % 2 == 0 { &[0, 1, 2] } else { &[0, 2] };
            classic.advance(t, recipients, |w| zo::apply_update(w, seed, step));
            let mut plus = vec![0.0f32; d];
            let mut minus = vec![0.0f32; d];
            {
                let mut outs: Vec<&mut [f32]> = vec![&mut plus, &mut minus];
                fused.advance_fused(
                    t,
                    Some(recipients),
                    &[(seed, step)],
                    &[(next, mu), (next, -mu)],
                    &mut outs,
                    64,
                    2,
                );
            }
            assert_eq!(classic.canonical(), fused.canonical(), "round {t}");
            assert_eq!(classic.eval_view(2), fused.eval_view(2), "owned, round {t}");
            // the staged views are exactly what a probe-time pass makes
            let mut expect = vec![0.0f32; d];
            zo::axpy_into(fused.canonical(), &mut expect, next, mu);
            assert_eq!(plus, expect, "staged +mu view, round {t}");
            zo::axpy_into(fused.canonical(), &mut expect, next, -mu);
            assert_eq!(minus, expect, "staged -mu view, round {t}");
        }
        assert_eq!(classic.head(), fused.head());
        for id in 0..3 {
            assert_eq!(classic.watermark(id), fused.watermark(id), "client {id}");
        }
        let (cs, fs) = (classic.stats(), fused.stats());
        assert_eq!(cs.canonical_commits, fs.canonical_commits);
        assert_eq!(cs.snapshots, fs.snapshots);
    }

    #[test]
    fn spill_mode_advances_match_flat_mode_bitwise_under_budget() {
        let d = 2051;
        let tile = 128;
        let init = crate::simkit::prng::normals_vec(9, d);
        let mut flat = ReplicaStore::new(init.clone(), 2, 0);
        let mut spill = ReplicaStore::new(init, 2, 0);
        spill.enable_spill(tile, 4 * tile * 2); // 2 resident pages of 17
        assert!(spill.is_spill());
        for t in 0..5u64 {
            let seed = crate::simkit::prng::round_direction_seed(t);
            let mut fp = vec![0.0f32; d];
            let mut fm = vec![0.0f32; d];
            let mut sp = vec![0.0f32; d];
            let mut sm = vec![0.0f32; d];
            let views = [(seed + 1, 1e-3f32), (seed + 1, -1e-3f32)];
            let mut fouts: Vec<&mut [f32]> = vec![&mut fp, &mut fm];
            flat.advance_fused(t, None, &[(seed, 2e-3)], &views, &mut fouts, tile, 1);
            let mut souts: Vec<&mut [f32]> = vec![&mut sp, &mut sm];
            spill.advance_fused(t, None, &[(seed, 2e-3)], &views, &mut souts, tile, 1);
            assert_eq!(flat.canonical(), spill.canonical(), "round {t}");
            assert_eq!(fp, sp, "+mu views, round {t}");
            assert_eq!(fm, sm, "-mu views, round {t}");
        }
        // the closure verb also keeps the pager coherent
        flat.advance_all(5, |w| w[17] += 1.0);
        spill.advance_all(5, |w| w[17] += 1.0);
        let mut p = vec![0.0f32; d];
        let mut o: Vec<&mut [f32]> = vec![&mut p];
        spill.advance_fused(6, None, &[(3, 1e-3)], &[(4, 1e-3)], &mut o, tile, 1);
        let mut q = vec![0.0f32; d];
        let mut o2: Vec<&mut [f32]> = vec![&mut q];
        flat.advance_fused(6, None, &[(3, 1e-3)], &[(4, 1e-3)], &mut o2, tile, 1);
        assert_eq!(flat.canonical(), spill.canonical());
        assert_eq!(p, q);
        let st = spill.stats().tile;
        assert!(st.peak_resident_bytes <= 4 * tile * 2, "window must honour the budget");
        assert!(st.spills > 0, "a 17-page store under a 2-page window must spill");
        assert_eq!(flat.stats().tile, super::TileStats::default(), "flat mode reports zeros");
    }

    #[test]
    fn peak_bytes_tracks_high_water_mark() {
        let mut s = store(16, 4, 8);
        s.set_owned(2, vec![0.0; 16]);
        s.set_owned(3, vec![0.0; 16]);
        let peak = s.stats().peak_bytes;
        assert_eq!(peak, 4 * 16 * 3);
        // demote by overwriting state is not supported; peak persists
        assert!(s.stats().peak_bytes >= peak);
    }
}
