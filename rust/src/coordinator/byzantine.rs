//! Byzantine client models (§4.3, Remark 4.1).
//!
//! Because the direction `z` is pinned by the shared PRNG, *every* attack
//! on a seed-pair system collapses to corrupting the scalar the client
//! uploads (Remark 3.14): gradient-noise injection and label flipping are
//! both equivalent to a wrong projection.  The paper's strongest attacker
//! per protocol:
//!
//! * FeedSign — always transmit the **reversed sign**;
//! * ZO-FedSGD — transmit a **random number** as the projection;
//! * FedSGD — transmit the negated gradient (sign-flip analogue).

use crate::simkit::prng::Rng;

/// Attack behaviour of one client.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Attack {
    /// Honest client.
    None,
    /// FeedSign's worst case: reversed sign (also negates FO gradients).
    SignFlip,
    /// ZO-FedSGD's Table 5 attacker: projection replaced by `N(0, scale²)`.
    RandomProjection { scale: f32 },
    /// Additive Gaussian corruption of the projection.
    GaussNoise { scale: f32 },
    /// Labels permuted at shard setup (handled in data plumbing; at the
    /// protocol layer the client is honest about its corrupted data).
    LabelFlip,
}

impl Attack {
    pub fn is_byzantine(&self) -> bool {
        !matches!(self, Attack::None)
    }

    /// Corrupt an uplink *sign* (FeedSign protocol).
    pub fn mutate_sign(&self, sign: i8, rng: &mut Rng) -> i8 {
        match self {
            Attack::None | Attack::LabelFlip => sign,
            Attack::SignFlip => -sign,
            Attack::RandomProjection { .. } => {
                if rng.uniform() < 0.5 {
                    1
                } else {
                    -1
                }
            }
            Attack::GaussNoise { scale } => {
                // noise on the projection flips the sign when it dominates;
                // model as flip with prob related to scale
                let flip_p = 0.5 * (1.0 - (-scale).exp());
                if rng.uniform() < flip_p {
                    -sign
                } else {
                    sign
                }
            }
        }
    }

    /// Corrupt an uplink *projection* (ZO-FedSGD protocol).
    pub fn mutate_projection(&self, p: f32, rng: &mut Rng) -> f32 {
        match self {
            Attack::None | Attack::LabelFlip => p,
            Attack::SignFlip => -p,
            Attack::RandomProjection { scale } => rng.normal() * scale,
            Attack::GaussNoise { scale } => p + rng.normal() * scale,
        }
    }

    /// Corrupt an uplink *gradient* in place (FedSGD protocol).
    pub fn mutate_gradient(&self, g: &mut [f32], rng: &mut Rng) {
        match self {
            Attack::None | Attack::LabelFlip => {}
            Attack::SignFlip => {
                for v in g.iter_mut() {
                    *v = -*v;
                }
            }
            Attack::RandomProjection { scale } => {
                for v in g.iter_mut() {
                    *v = rng.normal() * scale;
                }
            }
            Attack::GaussNoise { scale } => {
                for v in g.iter_mut() {
                    *v += rng.normal() * scale;
                }
            }
        }
    }

    pub fn parse(s: &str) -> Option<Attack> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "none" | "" => Some(Attack::None),
            "sign-flip" | "signflip" => Some(Attack::SignFlip),
            "label-flip" | "labelflip" => Some(Attack::LabelFlip),
            _ => {
                if let Some(rest) = s.strip_prefix("random-projection") {
                    let scale = rest.strip_prefix(':').and_then(|v| v.parse().ok()).unwrap_or(1.0);
                    Some(Attack::RandomProjection { scale })
                } else if let Some(rest) = s.strip_prefix("gauss-noise") {
                    let scale = rest.strip_prefix(':').and_then(|v| v.parse().ok()).unwrap_or(1.0);
                    Some(Attack::GaussNoise { scale })
                } else {
                    None
                }
            }
        }
    }
}

/// Assign attacks: the first `n_byzantine` clients attack, the rest are
/// honest.  (Client order is already a random permutation of the shard
/// assignment, so "first B" is equivalent to a random subset.)
pub fn assign(k: usize, n_byzantine: usize, attack: Attack) -> Vec<Attack> {
    assert!(n_byzantine <= k);
    (0..k)
        .map(|i| if i < n_byzantine { attack } else { Attack::None })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn honest_passthrough() {
        let mut rng = Rng::new(0, 0);
        assert_eq!(Attack::None.mutate_sign(1, &mut rng), 1);
        assert_eq!(Attack::None.mutate_projection(0.7, &mut rng), 0.7);
    }

    #[test]
    fn sign_flip_reverses() {
        let mut rng = Rng::new(0, 0);
        assert_eq!(Attack::SignFlip.mutate_sign(1, &mut rng), -1);
        assert_eq!(Attack::SignFlip.mutate_sign(-1, &mut rng), 1);
        assert_eq!(Attack::SignFlip.mutate_projection(0.5, &mut rng), -0.5);
    }

    #[test]
    fn random_projection_is_random() {
        let mut rng = Rng::new(1, 0);
        let a = Attack::RandomProjection { scale: 1.0 };
        let vals: Vec<f32> = (0..100).map(|_| a.mutate_projection(5.0, &mut rng)).collect();
        // none should equal the honest value; mean near 0
        assert!(vals.iter().all(|&v| v != 5.0));
        let mean = vals.iter().sum::<f32>() / 100.0;
        assert!(mean.abs() < 0.5);
    }

    #[test]
    fn gradient_sign_flip() {
        let mut rng = Rng::new(2, 0);
        let mut g = vec![1.0, -2.0, 3.0];
        Attack::SignFlip.mutate_gradient(&mut g, &mut rng);
        assert_eq!(g, vec![-1.0, 2.0, -3.0]);
    }

    #[test]
    fn assign_counts() {
        let attacks = assign(5, 2, Attack::SignFlip);
        assert_eq!(attacks.iter().filter(|a| a.is_byzantine()).count(), 2);
        assert_eq!(attacks[0], Attack::SignFlip);
        assert_eq!(attacks[4], Attack::None);
    }

    #[test]
    fn parse_attacks() {
        assert_eq!(Attack::parse("none"), Some(Attack::None));
        assert_eq!(Attack::parse("sign-flip"), Some(Attack::SignFlip));
        assert_eq!(
            Attack::parse("random-projection:2.0"),
            Some(Attack::RandomProjection { scale: 2.0 })
        );
        assert_eq!(
            Attack::parse("gauss-noise"),
            Some(Attack::GaussNoise { scale: 1.0 })
        );
        assert_eq!(Attack::parse("bogus"), None);
    }
}
