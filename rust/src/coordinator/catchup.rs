//! Offline-client catch-up: the bookkeeping that lets partial
//! participation drop the broadcast-to-everyone assumption.
//!
//! FeedSign's 1-bit protocol only works while every client holds an
//! identical replica, so the seed-history design (FedKSeed-style) keeps a
//! compact PS-side record of every committed update
//! ([`crate::comm::SeedHistory`]) and replays the missed span to a client
//! the moment it rejoins — *before* it probes, so its vote is computed on
//! the current model.  This module holds the two pieces the session
//! engine threads through its plan/execute/commit phases:
//!
//! * [`CatchupCfg`] — the `catchup = "replay" | "rebroadcast" | "off"`
//!   knob (config TOML + `--catchup` CLI): `replay` ships the missed
//!   seed-sign records (1 bit per missed FeedSign round), `rebroadcast`
//!   ships a dense 32·d-bit checkpoint (the cost baseline the Table 8
//!   replay column compares against), `off` keeps the paper's
//!   every-round broadcast.
//! * [`CatchupTracker`] — per-client `last_synced_round` watermarks.  The
//!   minimum over all clients ([`CatchupTracker::watermark`]) is the
//!   compaction floor handed to [`crate::comm::SeedHistory::compact_to`],
//!   which is what guarantees a record is never dropped while the slowest
//!   tracked client still needs it.
//!
//! Exactness invariant: replay applies the recorded updates **in commit
//! order** through the same chunk-parallel AXPY path
//! ([`crate::simkit::zo::apply_update`]) every participant used when the
//! round committed, so a client offline for arbitrarily many rounds
//! rejoins with a replica bit-identical to an always-on client's (pinned
//! by `rust/tests/catchup_parity.rs` for k ∈ {1, 7, 50} missed rounds).

/// How a client that missed rounds is brought current when it rejoins.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CatchupCfg {
    /// Every round is broadcast to every client (the paper's assumption);
    /// no history is kept.
    #[default]
    Off,
    /// Rejoining clients download and replay the missed
    /// `(round, seed, sign, lr_scale)` records — communication scales
    /// with rounds missed, not with model size.
    Replay,
    /// Rejoining clients download a dense 32·d-bit checkpoint — the
    /// classical fallback replay is benchmarked against.  (The threaded
    /// `coordinator::distributed` topology cannot run this mode: its PS
    /// holds no parameters, per the paper's §D.2 privacy property.)
    Rebroadcast,
    /// Rejoining clients download the K accumulated per-pool-seed step
    /// scalars (`seed_pool` mode only; the FedKSeed model-delta
    /// representation): 32·K bits per rejoin, **constant in the gap
    /// length**, because `sum_i scalars[i] · z(pool_seed_i)` *is* the
    /// model delta.  Like `rebroadcast`, the threaded topology rejects
    /// it: a dense distributed client must apply the missed updates in
    /// commit order to stay bit-identical to the session's canonical
    /// buffer, which is replay, not a scalar download.
    PoolScalars,
}

impl CatchupCfg {
    /// Parse a config/CLI spec: `off`, `replay`, `rebroadcast`, `pool`.
    pub fn parse(s: &str) -> Option<CatchupCfg> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" => Some(CatchupCfg::Off),
            "replay" => Some(CatchupCfg::Replay),
            "rebroadcast" => Some(CatchupCfg::Rebroadcast),
            "pool" => Some(CatchupCfg::PoolScalars),
            _ => None,
        }
    }

    /// Render back to the config-string form [`CatchupCfg::parse`]
    /// accepts.
    pub fn render(&self) -> &'static str {
        match self {
            CatchupCfg::Off => "off",
            CatchupCfg::Replay => "replay",
            CatchupCfg::Rebroadcast => "rebroadcast",
            CatchupCfg::PoolScalars => "pool",
        }
    }

    /// Whether the session maintains a seed history and per-client sync
    /// watermarks (both catch-up modes do; `off` skips the bookkeeping
    /// entirely).
    pub fn is_on(&self) -> bool {
        !matches!(self, CatchupCfg::Off)
    }
}

/// Per-client sync watermarks: `last_synced[id]` is the first round
/// client `id` has **not** yet applied, i.e. it holds the replica an
/// always-on client held when round `last_synced[id]` was planned.
#[derive(Debug, Clone)]
pub struct CatchupTracker {
    last_synced: Vec<u64>,
}

impl CatchupTracker {
    /// All `k` clients start at the shared checkpoint (round 0).
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        CatchupTracker { last_synced: vec![0; k] }
    }

    pub fn len(&self) -> usize {
        self.last_synced.len()
    }

    pub fn is_empty(&self) -> bool {
        self.last_synced.is_empty()
    }

    /// First round client `id` has not applied yet.
    pub fn last_synced(&self, id: usize) -> u64 {
        self.last_synced[id]
    }

    /// Record that client `id` has applied every round below `round`.
    /// Sync never moves backwards.
    pub fn mark_synced(&mut self, id: usize, round: u64) {
        assert!(
            round >= self.last_synced[id],
            "client {id} sync watermark must be monotone ({} -> {round})",
            self.last_synced[id]
        );
        self.last_synced[id] = round;
    }

    /// The compaction floor: the slowest client's synced round.  History
    /// records at or above this round must be retained.
    pub fn watermark(&self) -> u64 {
        self.last_synced.iter().copied().min().unwrap_or(0)
    }

    /// The compaction floor over one contiguous client-id range — a
    /// coordinator shard's *local* watermark (`coordinator::shard`).  The
    /// session-global floor handed to
    /// [`crate::comm::SeedHistory::compact_to`] must be the **min across
    /// shards** of these (min is associative, so that equals
    /// [`CatchupTracker::watermark`] exactly); compacting to any single
    /// shard's local watermark instead would drop records another shard's
    /// slowest client still needs.  An empty range returns `u64::MAX`,
    /// the identity of the min fold.
    pub fn watermark_over(&self, ids: std::ops::Range<usize>) -> u64 {
        self.last_synced[ids].iter().copied().min().unwrap_or(u64::MAX)
    }

    /// The replay span client `id` must apply to be current through
    /// round `now` (empty when already synced).
    pub fn span(&self, id: usize, now: u64) -> std::ops::Range<u64> {
        self.last_synced[id]..now.max(self.last_synced[id])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_roundtrip() {
        for s in ["off", "replay", "rebroadcast", "pool"] {
            let cfg = CatchupCfg::parse(s).unwrap();
            assert_eq!(CatchupCfg::parse(cfg.render()), Some(cfg));
        }
        assert_eq!(CatchupCfg::parse("REPLAY"), Some(CatchupCfg::Replay));
        assert!(CatchupCfg::parse("resend").is_none());
        assert!(!CatchupCfg::Off.is_on());
        assert!(CatchupCfg::Replay.is_on());
        assert!(CatchupCfg::Rebroadcast.is_on());
        assert!(CatchupCfg::PoolScalars.is_on());
    }

    #[test]
    fn tracker_watermark_is_slowest_client() {
        let mut t = CatchupTracker::new(3);
        assert_eq!(t.watermark(), 0);
        t.mark_synced(0, 5);
        t.mark_synced(1, 9);
        assert_eq!(t.watermark(), 0, "client 2 pins the floor");
        t.mark_synced(2, 4);
        assert_eq!(t.watermark(), 4);
        assert_eq!(t.span(2, 9), 4..9);
        assert_eq!(t.span(1, 9), 9..9);
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn tracker_rejects_regressing_sync() {
        let mut t = CatchupTracker::new(2);
        t.mark_synced(0, 5);
        t.mark_synced(0, 3);
    }

    #[test]
    fn shard_local_watermarks_fold_to_the_global_floor() {
        let mut t = CatchupTracker::new(6);
        for (id, wm) in [(0, 9), (1, 7), (2, 9), (3, 9), (4, 2), (5, 9)] {
            t.mark_synced(id, wm);
        }
        // two shards of 3: local floors are the per-range minima
        assert_eq!(t.watermark_over(0..3), 7);
        assert_eq!(t.watermark_over(3..6), 2);
        // min across shards == the flat global watermark
        assert_eq!(t.watermark_over(0..3).min(t.watermark_over(3..6)), t.watermark());
        // empty range is the fold identity
        assert_eq!(t.watermark_over(3..3), u64::MAX);
        assert_eq!(t.watermark_over(0..6).min(t.watermark_over(6..6)), t.watermark());
    }
}
