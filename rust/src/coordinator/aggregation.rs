//! Update aggregation rules (Definition 3.2 / Equation 4).
//!
//! * **FeedSign** — majority vote over client signs:
//!   `f = Sign(sum_k Sign(p_k))`; the PS never sees a magnitude.
//! * **ZO-FedSGD** — mean projection: `f = (1/K) sum_k p_k` applied along
//!   each client's own direction (seed-projection pairs).
//! * **DP-FeedSign** — Definition D.1's exponential-mechanism vote.
//! * **FedSGD** — dense gradient averaging (the FO baseline).
//! * **MeZO** — centralized ZO (K = 1), no aggregation.

use crate::simkit::prng::Rng;

/// Which federated algorithm a session runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    FeedSign,
    ZoFedSgd,
    FedSgd,
    Mezo,
    /// FeedSign with the (epsilon, 0)-DP vote of Definition D.1.
    DpFeedSign { epsilon: f32 },
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FeedSign => "feedsign",
            Algorithm::ZoFedSgd => "zo-fedsgd",
            Algorithm::FedSgd => "fedsgd",
            Algorithm::Mezo => "mezo",
            Algorithm::DpFeedSign { .. } => "dp-feedsign",
        }
    }

    /// Parse from a config string (`dp-feedsign:eps` carries the budget).
    pub fn parse(s: &str) -> Option<Algorithm> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "feedsign" => Some(Algorithm::FeedSign),
            "zo-fedsgd" | "zofedsgd" => Some(Algorithm::ZoFedSgd),
            "fedsgd" | "fo" => Some(Algorithm::FedSgd),
            "mezo" => Some(Algorithm::Mezo),
            _ => s.strip_prefix("dp-feedsign:").and_then(|eps| {
                eps.parse::<f32>().ok().map(|epsilon| Algorithm::DpFeedSign { epsilon })
            }),
        }
    }
}

/// FeedSign's majority vote.  Ties (even K, split vote) resolve to +1 —
/// a fixed convention both PS and clients share, so it costs no bits.
pub fn majority_sign(signs: &[i8]) -> i8 {
    majority_from_sum(signs.iter().map(|&s| s as i32).sum())
}

/// The majority threshold over a pre-reduced vote *sum* — the hierarchical
/// form the sharded coordinator folds (`coordinator::shard`): sign votes
/// are associative integer sums, so per-shard edge aggregation is exact
/// and only this final threshold is global.  [`majority_sign`] delegates
/// here, so the flat and sharded paths share one tie convention by
/// construction.
pub fn majority_from_sum(sum: i32) -> i8 {
    if sum >= 0 {
        1
    } else {
        -1
    }
}

/// ZO-FedSGD's mean projection.
pub fn mean_projection(ps: &[f32]) -> f32 {
    ps.iter().sum::<f32>() / ps.len() as f32
}

/// Definition D.1: sample the global sign from the exponential mechanism
/// over vote counts.  `q_+`/`q_-` are the counts of +1/-1 votes;
/// `P(f = s) ∝ exp(eps * q_s / 4)`.  `eps -> 0` degenerates to a fair
/// coin (perfect privacy, no signal); `eps -> inf` recovers the majority
/// vote.
pub fn dp_vote(signs: &[i8], epsilon: f32, rng: &mut Rng) -> i8 {
    dp_vote_counts(signs.iter().filter(|&&s| s > 0).count(), signs.len(), epsilon, rng)
}

/// Definition D.1 over pre-reduced counts `(q_+, total)` — the sharded
/// merge path: a shard ships its vote `(sum, voters)` pair and the merger
/// reconstructs `q_+ = (Σ sum + Σ voters) / 2` exactly (the counts are
/// associative integers).  [`dp_vote`] delegates here, so the exponential-
/// mechanism arithmetic and the single `rng.uniform()` draw are the same
/// IEEE-754 expression on both paths — bit-identical by construction.
pub fn dp_vote_counts(q_plus: usize, total: usize, epsilon: f32, rng: &mut Rng) -> i8 {
    let q_plus = q_plus as f32;
    let q_minus = total as f32 - q_plus;
    // subtract the max exponent for numerical stability
    let e_plus = epsilon * q_plus / 4.0;
    let e_minus = epsilon * q_minus / 4.0;
    let m = e_plus.max(e_minus);
    let p_plus = (e_plus - m).exp();
    let p_minus = (e_minus - m).exp();
    let threshold = p_plus / (p_plus + p_minus);
    if rng.uniform() < threshold {
        1
    } else {
        -1
    }
}

/// Average dense gradients in place into `acc` (which must be zeroed by
/// the caller before the first call); `count` is applied by
/// [`finish_mean`].
pub fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, b) in acc.iter_mut().zip(g) {
        *a += b;
    }
}

pub fn finish_mean(acc: &mut [f32], count: usize) {
    let inv = 1.0 / count as f32;
    for a in acc.iter_mut() {
        *a *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_basic() {
        assert_eq!(majority_sign(&[1, 1, -1]), 1);
        assert_eq!(majority_sign(&[-1, -1, 1]), -1);
        assert_eq!(majority_sign(&[1, -1]), 1); // tie convention
    }

    #[test]
    fn majority_unanimous() {
        assert_eq!(majority_sign(&[-1; 25]), -1);
        assert_eq!(majority_sign(&[1; 25]), 1);
    }

    #[test]
    fn mean_projection_basic() {
        assert!((mean_projection(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-7);
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        assert_eq!(Algorithm::parse("feedsign"), Some(Algorithm::FeedSign));
        assert_eq!(Algorithm::parse("ZO-FedSGD"), Some(Algorithm::ZoFedSgd));
        assert_eq!(Algorithm::parse("fo"), Some(Algorithm::FedSgd));
        assert_eq!(Algorithm::parse("mezo"), Some(Algorithm::Mezo));
        assert_eq!(
            Algorithm::parse("dp-feedsign:2.5"),
            Some(Algorithm::DpFeedSign { epsilon: 2.5 })
        );
        assert_eq!(Algorithm::parse("nope"), None);
    }

    #[test]
    fn sum_and_count_forms_match_the_flat_vote_paths() {
        use crate::simkit::prng::Rng as R;
        // every (q_plus, total) split at a few pool sizes: the flat vote
        // over an explicit sign vector and the pre-reduced form must agree
        // exactly — including the identical rng draw sequence for DP
        for total in 0..12usize {
            for q_plus in 0..=total {
                let mut signs = vec![1i8; q_plus];
                signs.extend(std::iter::repeat(-1i8).take(total - q_plus));
                let sum = q_plus as i32 - (total - q_plus) as i32;
                assert_eq!(majority_sign(&signs), majority_from_sum(sum));
                // counts reconstruct from the (sum, voters) shard pair
                assert_eq!(((sum + total as i32) / 2) as usize, q_plus);
                for eps in [0.0f32, 0.7, 3.0] {
                    let mut a = R::new(99, 5);
                    let mut b = R::new(99, 5);
                    assert_eq!(
                        dp_vote(&signs, eps, &mut a),
                        dp_vote_counts(q_plus, total, eps, &mut b)
                    );
                    // both consumed exactly one draw
                    assert_eq!(a.next_u32(), b.next_u32());
                }
            }
        }
    }

    #[test]
    fn dp_vote_high_epsilon_recovers_majority() {
        let mut rng = Rng::new(0, 0);
        let signs = [1i8, 1, 1, -1, -1];
        for _ in 0..50 {
            assert_eq!(dp_vote(&signs, 1000.0, &mut rng), 1);
        }
    }

    #[test]
    fn dp_vote_zero_epsilon_fair_coin() {
        let mut rng = Rng::new(1, 0);
        let signs = [1i8; 9];
        let plus = (0..4000)
            .filter(|_| dp_vote(&signs, 0.0, &mut rng) == 1)
            .count();
        let frac = plus as f32 / 4000.0;
        assert!((frac - 0.5).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn dp_vote_distribution_matches_mechanism() {
        // K=5, 4 votes +1, 1 vote -1, eps=2: P(+) = e^{2*4/4} / (e^2 + e^{0.5})
        let mut rng = Rng::new(2, 0);
        let signs = [1i8, 1, 1, 1, -1];
        let eps = 2.0f32;
        let expect = (eps * 4.0 / 4.0).exp() / ((eps * 4.0 / 4.0).exp() + (eps * 1.0 / 4.0).exp());
        let n = 20_000;
        let plus = (0..n).filter(|_| dp_vote(&signs, eps, &mut rng) == 1).count();
        let frac = plus as f32 / n as f32;
        assert!((frac - expect).abs() < 0.02, "frac {frac} expect {expect}");
    }

    #[test]
    fn dp_epsilon_ratio_bounded() {
        // (eps,0)-DP: changing ONE vote changes the outcome distribution by
        // at most e^eps (Theorem D.2)
        let eps = 1.5f32;
        let p_of = |signs: &[i8]| {
            let q_plus = signs.iter().filter(|&&s| s > 0).count() as f32;
            let q_minus = signs.len() as f32 - q_plus;
            let a = (eps * q_plus / 4.0).exp();
            let b = (eps * q_minus / 4.0).exp();
            a / (a + b)
        };
        let p1 = p_of(&[1, 1, 1, -1, -1]);
        let p2 = p_of(&[1, 1, -1, -1, -1]); // one vote flipped
        let ratio = (p1 / p2).max(p2 / p1);
        assert!(ratio <= eps.exp(), "ratio {ratio} > e^eps {}", eps.exp());
        let r_neg = ((1.0 - p1) / (1.0 - p2)).max((1.0 - p2) / (1.0 - p1));
        assert!(r_neg <= eps.exp());
    }

    #[test]
    fn accumulate_and_mean() {
        let mut acc = vec![0.0; 3];
        accumulate(&mut acc, &[1.0, 2.0, 3.0]);
        accumulate(&mut acc, &[3.0, 2.0, 1.0]);
        finish_mean(&mut acc, 2);
        assert_eq!(acc, vec![2.0, 2.0, 2.0]);
    }
}
