//! Threaded leader/worker topology: the same FeedSign protocol as
//! [`super::session::Session`], but with the PS and every client as
//! separate OS threads exchanging [`crate::comm::Message`]s over metered
//! channels — the deployment shape of Figure 1.
//!
//! The PS thread holds **no model parameters** (the paper's §D.2
//! property): it sees only 1-bit votes and emits 1-bit directions plus
//! seed-history records.  That property is also why this topology
//! supports `catchup = "replay"` but not `"rebroadcast"` — a dense
//! checkpoint rebroadcast would require a PS-side replica, so the dense
//! baseline lives only in the synchronous session's cost model.
//!
//! Unlike the synchronous session — whose [`super::replica`] plane
//! shares one canonical buffer across the pool — every client thread
//! here owns a real dense replica: this topology *is* the deployment
//! shape, so per-client memory is the client device's, not the
//! coordinator's.  The cross-topology parity tests double as the
//! replica plane's strongest check: K independently-updated dense
//! buffers must land bit-for-bit on the session's single canonical one.
//!
//! Partial participation works here exactly as in the session engine:
//! the participant set is drawn per round from the same dedicated
//! coordinator stream (`seed ^ 0x9A`), participants run the
//! probe → vote → update exchange, and non-participants are kept current
//! either by an immediate one-record [`Message::ReplayHistory`] push
//! (`catchup = "off"` — bit-for-bit the same downlink cost as the
//! session's broadcast) or lazily on rejoin from the PS-side
//! [`crate::comm::SeedHistory`] (`catchup = "replay"`).  Cross-topology
//! tests pin this runtime against the synchronous session: identical
//! seeds must produce bit-identical final models and ledgers.

use crate::comm::{self, Ledger, Message, SeedHistory, SeedPool, SeedRecord};
use crate::coordinator::aggregation;
use crate::coordinator::byzantine::Attack;
use crate::coordinator::catchup::{CatchupCfg, CatchupTracker};
use crate::coordinator::participation::ParticipationCfg;
use crate::coordinator::shard::{ShardPlane, ShardStats, VoteAcc};
use crate::data::{Dataset, Shard};
use crate::engine::Engine;
use crate::net::{NetCfg, NetSim, NetStats};
use crate::obs::{Event, Phase, Tracer};
use crate::simkit::prng::{self, Rng};
use std::sync::Arc;

/// Client task configuration.
pub struct DistClient {
    /// `Engine` carries a `Send` supertrait, so any boxed engine can move
    /// onto the worker thread.
    pub engine: Box<dyn Engine>,
    pub w: Vec<f32>,
    pub shard: Shard,
    pub attack: Attack,
    pub rng: Rng,
}

/// Run configuration for the threaded topology.
#[derive(Debug, Clone)]
pub struct DistCfg {
    pub rounds: u64,
    pub eta: f32,
    pub mu: f32,
    pub batch_size: usize,
    /// Per-round client sampling, drawn from the same dedicated
    /// coordinator stream construction as the sync session (`seed ^
    /// 0x9A`) so cross-topology runs share one schedule.
    pub participation: ParticipationCfg,
    /// `Off` pushes every committed round to non-participants
    /// immediately; `Replay` defers to a rejoin-time history replay.
    /// `Rebroadcast` is rejected: the PS holds no parameters (§D.2).
    pub catchup: CatchupCfg,
    /// Impaired-channel simulation ([`crate::net`]).  Draws are keyed by
    /// `(channel_seed, round, client, direction)`, so an impaired run
    /// here observes exactly the trace the synchronous session observes
    /// for the same configuration — the cross-topology parity tests pin
    /// this with flips, drops and deadline stragglers in flight.
    pub net: NetCfg,
    /// Coordinator seed (must match the sync session's `cfg.seed` for
    /// cross-topology parity).
    pub seed: u32,
    /// Restricted seed space (FedKSeed): `>= 2` derives the same K
    /// candidate directions the sync session derives from `seed`, and
    /// each round's trigger becomes a [`Message::PoolIndex`] carrying
    /// the `ceil(log2 K)`-bit index; 0 keeps the implicit `seed = t`
    /// schedule.
    pub seed_pool: usize,
    /// Coordinator shards ([`crate::coordinator::shard`]): `>= 1`
    /// partitions the *collected* votes into contiguous client-id shards
    /// whose pre-reduced `(sum, voters)` pairs cross a metered
    /// [`Message::ShardVotes`] hop before the global majority threshold
    /// — bit-identical to the flat vote by associativity of the sum.
    /// 0 keeps the flat path.  The client threads are untouched either
    /// way: sharding is PS-internal here, as it is session-internal in
    /// the sync engine.
    pub shards: usize,
}

impl DistCfg {
    /// Full-participation run with catch-up off — the original topology.
    pub fn full(rounds: u64, eta: f32, mu: f32, batch_size: usize) -> Self {
        DistCfg {
            rounds,
            eta,
            mu,
            batch_size,
            participation: ParticipationCfg::Full,
            catchup: CatchupCfg::Off,
            net: NetCfg::ideal(),
            seed: 0,
            seed_pool: 0,
            // same env override as `SessionCfg::default()`: the CI
            // `FEEDSIGN_SHARDS=4` leg reroutes every `full()`-built test
            // through the hierarchical merge; explicit DistCfg literals
            // pin their own value
            shards: std::env::var("FEEDSIGN_SHARDS")
                .ok()
                .and_then(|v| v.trim().parse().ok())
                .unwrap_or(0),
        }
    }
}

/// Outcome of a distributed FeedSign run.
pub struct DistResult {
    /// final parameter replicas, one per client (must all be equal)
    pub finals: Vec<Vec<f32>>,
    pub ledger: Ledger,
    /// per-round votes **as the PS received them** (delivered, possibly
    /// flipped), in client-id order
    pub votes_per_round: Vec<Vec<i8>>,
    /// impaired-channel counters (all zero on an ideal channel)
    pub net: NetStats,
    /// hierarchical vote-merge counters (all zero on the flat path);
    /// PS-internal — `ledger` is byte-identical either way
    pub shard: ShardStats,
    /// PS-side event trace ([`crate::obs`]); empty unless tracing was
    /// requested.  Emits the same logical payloads for the round-level
    /// phases (plan / net-admit / commit) as the synchronous session, so
    /// cross-topology logical sequences can be compared directly.
    pub trace: Tracer,
}

/// Run distributed FeedSign over worker threads.
///
/// Protocol per round `t`: the PS draws the participant set (minus any
/// deadline stragglers the virtual clock cut), replays any missed
/// history span to stale participants (`catchup = "replay"`), broadcasts
/// `RoundStart` to them (seed = t is implicit), collects `SignVote`s in
/// client-id order — each crossing the impaired uplink — majority-votes
/// over the *delivered* signs, and returns `GlobalSign` to the clients
/// it heard from, who apply the update locally.  Everyone else receives
/// either the round's single committed record immediately
/// (`catchup = "off"`) or nothing until they rejoin.  After the last
/// round every stale client is caught up, so the returned replicas are
/// always identical.
pub fn run_feedsign(clients: Vec<DistClient>, train: Dataset, cfg: DistCfg) -> DistResult {
    run_feedsign_with(clients, train, cfg, crate::obs::trace_env())
}

/// [`run_feedsign`] with tracing chosen explicitly instead of via
/// `FEEDSIGN_TRACE` — what the CLI's `--trace-out` and the trace parity
/// suite call (env mutation races parallel tests; a parameter does not).
pub fn run_feedsign_with(
    clients: Vec<DistClient>,
    train: Dataset,
    cfg: DistCfg,
    trace: bool,
) -> DistResult {
    assert!(
        cfg.catchup != CatchupCfg::Rebroadcast,
        "the threaded PS holds no parameters (§D.2); only replay catch-up is possible here"
    );
    assert!(
        cfg.catchup != CatchupCfg::PoolScalars,
        "the threaded topology's dense clients must apply missed updates in commit order \
         to stay bit-identical; use catchup = \"replay\""
    );
    let k = clients.len();
    let train = Arc::new(train);
    let mut ps_links = Vec::with_capacity(k);
    let mut handles = Vec::with_capacity(k);
    let (eta, mu, batch_size) = (cfg.eta, cfg.mu, cfg.batch_size);
    // restricted seed space: PS and every client derive the identical
    // pool from (seed, K) — the pool seed is setup-time metadata, so
    // only the per-round index crosses the wire
    let ps_pool = (cfg.seed_pool >= 2).then(|| SeedPool::derive(cfg.seed, cfg.seed_pool));
    let (pool_seed, pool_k) = (cfg.seed, cfg.seed_pool);

    for mut c in clients {
        let (duplex, port) = comm::link();
        ps_links.push(duplex);
        let train = Arc::clone(&train);
        handles.push(std::thread::spawn(move || {
            // one OS thread per client IS the fan-out here — keep the
            // per-vector noise ops sequential inside it (same policy as
            // the session round engine's workers)
            let _serial = prng::serial_zone();
            // The loop is event-driven rather than strict request/
            // response: after voting, the client does NOT block on a
            // GlobalSign — over an impaired uplink its vote may never
            // reach the PS, in which case the next message is simply the
            // next round's trigger (or a catch-up replay).  `round_seed`
            // remembers the seed the most recent RoundStart announced;
            // the PS never interleaves rounds, so a GlobalSign always
            // applies along it.
            let mut round_seed = 0u32;
            let pool = (pool_k >= 2).then(|| SeedPool::derive(pool_seed, pool_k));
            while let Ok(msg) = port.from_ps.recv() {
                match msg {
                    Message::ReplayHistory { records } => {
                        // catch-up span (or the single-record push a
                        // non-participant gets in "off" mode): apply in
                        // commit order, seeds are explicit
                        for r in &records {
                            c.engine.update(&mut c.w, r.seed, r.step());
                        }
                    }
                    Message::RoundStart { round } => {
                        // same masked round -> seed derivation as the
                        // session engine (31-bit direction space)
                        round_seed = prng::round_direction_seed(round);
                        let batch = c.shard.next_batch(&train, batch_size, &mut c.rng);
                        let p = c.engine.probe(&c.w, &batch, round_seed, mu);
                        let honest = if p >= 0.0 { 1i8 } else { -1 };
                        let sign = c.attack.mutate_sign(honest, &mut c.rng);
                        if port.to_ps.send(Message::SignVote { sign }).is_err() {
                            break;
                        }
                    }
                    Message::PoolIndex { index, .. } => {
                        // pool-mode round trigger: resolve the direction
                        // through the locally derived pool, then probe
                        // and vote exactly as a RoundStart round
                        round_seed = pool
                            .as_ref()
                            .expect("PoolIndex requires seed_pool mode")
                            .seed_at(index);
                        let batch = c.shard.next_batch(&train, batch_size, &mut c.rng);
                        let p = c.engine.probe(&c.w, &batch, round_seed, mu);
                        let honest = if p >= 0.0 { 1i8 } else { -1 };
                        let sign = c.attack.mutate_sign(honest, &mut c.rng);
                        if port.to_ps.send(Message::SignVote { sign }).is_err() {
                            break;
                        }
                    }
                    Message::GlobalSign { sign: f } => {
                        c.engine.update(&mut c.w, round_seed, f as f32 * eta);
                    }
                    _ => break,
                }
            }
            c.w
        }));
    }

    // PS loop (this thread): drives rounds, meters the ledger, keeps the
    // seed history — and still holds no parameter vector.
    let mut ledger = Ledger::default();
    let mut history = SeedHistory::default();
    let mut tracker = CatchupTracker::new(k);
    let mut net = NetSim::new(cfg.net.clone());
    let mut tracer = Tracer::new(trace);
    net.log_admissions = tracer.on();
    let mut part_rng = Rng::new(cfg.seed ^ 0x9A, 0x9A);
    // hierarchical vote merge (PS-internal): contiguous-id shards
    // pre-reduce their delivered votes to (sum, voters) pairs
    let mut shard_plane = (cfg.shards >= 1).then(|| ShardPlane::new(k, cfg.shards));
    // FedKSeed-Pro state: the same per-pool-seed scalar accumulation the
    // sync session keeps, so both topologies' samplers see identical
    // history and draw identical indices
    let mut pool_scalars = vec![0.0f32; ps_pool.as_ref().map_or(0, |p| p.k())];
    let mut votes_per_round = Vec::with_capacity(cfg.rounds as usize);
    for t in 0..cfg.rounds {
        let mut participants = cfg.participation.sample(k, t, &mut part_rng);
        if net.is_active() {
            // virtual-clock admission, same keyed draws and payload
            // pricing as the session's plan phase: deadline stragglers
            // never get a round trigger
            let (up, down) = match &ps_pool {
                Some(p) => (1, 1 + p.index_bits() as u64),
                None => (1, 1),
            };
            participants = net.admit(t, participants, up, down);
        }
        if tracer.on() {
            // identical payloads to the session's plan-phase events, so
            // the cross-topology logical subset compares directly
            tracer.push(Event::logical(Phase::Plan, t, -1, -1, participants.len() as u64, 0));
            for a in net.take_admit_log() {
                tracer.push(Event::logical(
                    Phase::NetAdmit,
                    a.round,
                    -1,
                    a.gating_client,
                    a.kept as u64,
                    a.cut as u64,
                ));
                if a.gating_client >= 0 {
                    tracer.push(Event::logical(
                        Phase::LinkGate,
                        a.round,
                        -1,
                        a.gating_client,
                        a.gating_class as u64,
                        a.virtual_us,
                    ));
                }
            }
        }
        if participants.is_empty() {
            // zero-participant no-op round: keep round indices dense
            if cfg.catchup.is_on() {
                history.commit_round(t, []);
            }
            votes_per_round.push(Vec::new());
            continue;
        }
        if cfg.catchup.is_on() {
            for &id in &participants {
                let span = tracker.span(id, t);
                if span.is_empty() {
                    continue;
                }
                let records = history
                    .replay_span(span.start, span.end)
                    .expect("compaction must respect the slowest client");
                if tracer.on() && !records.is_empty() {
                    tracer.push(Event::logical(
                        Phase::Catchup,
                        t,
                        -1,
                        id as i64,
                        span.end - span.start,
                        records.len() as u64,
                    ));
                }
                let msg = Message::ReplayHistory { records };
                ledger.record(&msg);
                ps_links[id].to_client.send(msg).expect("client alive");
                tracker.mark_synced(id, t);
            }
        }
        // round trigger: pool mode draws this round's index from the
        // deterministic sampler and names it on the downlink
        // (ceil(log2 K) bits); otherwise RoundStart's implicit seed = t
        // schedule costs 0 payload bits
        let round_step = ps_pool.as_ref().map(|p| {
            let idx = p.sample_index(&pool_scalars, t);
            (idx, p.index_bits(), p.seed_at(idx))
        });
        for &id in &participants {
            let msg = match round_step {
                Some((index, index_bits, _)) => Message::PoolIndex { round: t, index, index_bits },
                None => Message::RoundStart { round: t },
            };
            ledger.record(&msg);
            ps_links[id].to_client.send(msg).expect("client alive");
        }
        // collect votes in client-id order; each one then crosses the
        // impaired uplink (transmission billed either way — the bits
        // were sent; a drop means the PS treats the voter as absent)
        let mut signs = Vec::with_capacity(participants.len());
        let mut voters = Vec::with_capacity(participants.len());
        for &id in &participants {
            let msg = ps_links[id].from_client.recv().expect("client alive");
            let Message::SignVote { sign } = msg else {
                panic!("protocol violation: expected SignVote");
            };
            ledger.record(&Message::SignVote { sign });
            if let Some(sign) = net.deliver_sign(t, id, sign) {
                if tracer.on() {
                    tracer.push(Event::logical(
                        Phase::Commit,
                        t,
                        -1,
                        id as i64,
                        (sign > 0) as u64,
                        0,
                    ));
                }
                signs.push(sign);
                voters.push(id);
            }
        }
        // shard pre-reduction: every shard with *planned* participants
        // ships one ShardVotes pair (drained shards report (0, 0)), the
        // merger folds them — recorded before the all-lost early return
        // so the merge traffic matches the sync engine's round for round
        let merged = shard_plane.as_mut().map(|plane| {
            let mut tally = vec![VoteAcc::default(); plane.map().shards()];
            for (&id, &sign) in voters.iter().zip(&signs) {
                tally[plane.map().shard_of(id)].push(sign);
            }
            let mut total = VoteAcc::default();
            for s in 0..plane.map().shards() {
                let r = plane.map().range(s);
                let lo = participants.partition_point(|&id| id < r.start);
                if lo >= participants.len() || participants[lo] >= r.end {
                    continue; // no planned participants in this shard
                }
                let acc = tally[s];
                let bits = plane.record_merge(&Message::ShardVotes {
                    sum: acc.sum,
                    voters: acc.voters,
                    shard_size: r.len(),
                    dense_pairs: false,
                });
                if tracer.on() {
                    tracer.push(Event::logical(
                        Phase::ShardMerge,
                        t,
                        s as i32,
                        -1,
                        acc.voters as u64,
                        bits,
                    ));
                }
                total.merge(acc);
            }
            total
        });
        if signs.is_empty() {
            // every vote was lost in transit: the round commits as a
            // no-op; the voters' pending GlobalSign never arrives and
            // their event loops simply see the next round's trigger
            if cfg.catchup.is_on() {
                history.commit_round(t, []);
                history.compact_to(tracker.watermark());
            }
            votes_per_round.push(Vec::new());
            continue;
        }
        // sharded: threshold the merged sum (bit-identical to the flat
        // majority — `majority_from_sum` is pinned against it)
        let f = match &merged {
            Some(acc) => {
                debug_assert_eq!(acc.voters, signs.len());
                aggregation::majority_from_sum(acc.sum)
            }
            None => aggregation::majority_sign(&signs),
        };
        if tracer.on() {
            tracer.push(Event::logical(
                Phase::Commit,
                t,
                -1,
                -1,
                (f > 0) as u64,
                signs.len() as u64,
            ));
        }
        votes_per_round.push(signs);
        for &id in &voters {
            let msg = Message::GlobalSign { sign: f };
            ledger.record(&msg);
            ps_links[id].to_client.send(msg).expect("client alive");
            if cfg.catchup.is_on() {
                tracker.mark_synced(id, t + 1);
            }
        }
        let record = match round_step {
            Some((idx, bits, seed)) => {
                // accumulate this direction's committed step scalar —
                // identical formula and order to the sync session
                pool_scalars[idx as usize] += f as f32 * eta;
                SeedRecord::index_step(t, seed, idx, bits, f, eta)
            }
            None => SeedRecord::sign_step(t, f, eta),
        };
        if cfg.catchup.is_on() {
            history.commit_round(t, [record]);
            history.compact_to(tracker.watermark());
        } else {
            // immediate one-record push keeps everyone the PS did not
            // hear from current (non-participants, deadline stragglers,
            // dropped voters) — the same 1-bit-per-client downlink the
            // session broadcast meters, seed explicit instead of
            // counter-implied
            let mut heard = vec![false; k];
            for &id in &voters {
                heard[id] = true;
            }
            for (id, link) in ps_links.iter().enumerate() {
                if !heard[id] {
                    let msg = Message::ReplayHistory { records: vec![record] };
                    ledger.record(&msg);
                    link.to_client.send(msg).expect("client alive");
                }
            }
        }
    }
    // run end: every straggler rejoins (metered), so finals are identical
    if cfg.catchup.is_on() {
        for (id, link) in ps_links.iter().enumerate() {
            let span = tracker.span(id, cfg.rounds);
            if span.is_empty() {
                continue;
            }
            let records = history
                .replay_span(span.start, span.end)
                .expect("compaction must respect the slowest client");
            if !records.is_empty() {
                if tracer.on() {
                    tracer.push(Event::logical(
                        Phase::Catchup,
                        cfg.rounds,
                        -1,
                        id as i64,
                        span.end - span.start,
                        records.len() as u64,
                    ));
                }
                let msg = Message::ReplayHistory { records };
                ledger.record(&msg);
                link.to_client.send(msg).expect("client alive");
            }
            tracker.mark_synced(id, cfg.rounds);
        }
    }
    drop(ps_links); // closes channels; clients exit their loops

    let mut finals = Vec::with_capacity(k);
    for h in handles {
        finals.push(h.join().expect("client thread panicked"));
    }
    let shard = shard_plane.map(|p| p.stats()).unwrap_or_default();
    DistResult { finals, ledger, votes_per_round, net: net.stats, shard, trace: tracer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::vision::{generate, SYNTH_CIFAR10};
    use crate::engine::NativeEngine;
    use crate::simkit::nn::LinearProbe;

    fn dist_clients(k: usize, train: &Dataset) -> Vec<DistClient> {
        let shards = split(train, k, Partition::Iid, 0);
        shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                let engine: Box<dyn Engine> =
                    Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
                let w = engine.init_params(7);
                DistClient {
                    engine,
                    w,
                    shard,
                    attack: Attack::None,
                    rng: Rng::new(7 ^ 0xC11E_17, id as u32 + 1),
                }
            })
            .collect()
    }

    #[test]
    fn distributed_replicas_converge_identically() {
        let train = generate(&SYNTH_CIFAR10, 300, 0);
        let clients = dist_clients(4, &train);
        let res = run_feedsign(clients, train, DistCfg::full(50, 2e-3, 1e-3, 16));
        for w in &res.finals[1..] {
            assert_eq!(w, &res.finals[0], "replica drift in distributed topology");
        }
        assert_eq!(res.ledger.uplink_bits, 50 * 4);
        assert_eq!(res.ledger.downlink_bits, 50 * 4);
        assert_eq!(res.votes_per_round.len(), 50);
    }

    #[test]
    fn distributed_matches_sync_session() {
        use crate::coordinator::session::{Client, Session, SessionCfg};
        let train = generate(&SYNTH_CIFAR10, 300, 0);
        let test = generate(&SYNTH_CIFAR10, 100, 1);

        // sync run
        let shards = split(&train, 3, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            rounds: 40,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            seed: 7,
            ..Default::default()
        };
        let mut sync = Session::new(cfg, clients, train.clone(), test);
        for t in 0..40 {
            sync.step(t);
        }

        // distributed run with identical seeds
        let dclients = dist_clients(3, &train);
        let res = run_feedsign(dclients, train, DistCfg::full(40, 2e-3, 1e-3, 16));
        assert_eq!(
            res.finals[0].as_slice(),
            &*sync.replica(0),
            "topologies diverged despite identical seeds"
        );
    }

    #[test]
    fn distributed_partial_participation_matches_session_for_both_catchup_modes() {
        use crate::coordinator::session::{Client, Session, SessionCfg};
        for catchup in [CatchupCfg::Off, CatchupCfg::Replay] {
            let train = generate(&SYNTH_CIFAR10, 300, 0);
            let test = generate(&SYNTH_CIFAR10, 100, 1);
            let shards = split(&train, 4, Partition::Iid, 0);
            let clients: Vec<Client> = shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    Client::new(
                        id,
                        Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                        shard,
                        7,
                    )
                })
                .collect();
            let cfg = SessionCfg {
                rounds: 60,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 16,
                eval_every: 0,
                participation: ParticipationCfg::Fraction(0.5),
                catchup,
                seed: 7,
                ..Default::default()
            };
            let mut sync = Session::new(cfg, clients, train.clone(), test);
            for t in 0..60 {
                sync.step(t);
            }
            sync.catch_up_all();

            let dclients = dist_clients(4, &train);
            let dcfg = DistCfg {
                rounds: 60,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 16,
                participation: ParticipationCfg::Fraction(0.5),
                catchup,
                net: NetCfg::ideal(),
                seed: 7,
                seed_pool: 0,
                shards: 0,
            };
            let res = run_feedsign(dclients, train, dcfg);
            for (id, w) in res.finals.iter().enumerate() {
                assert_eq!(
                    w.as_slice(),
                    &*sync.replica(id),
                    "catchup={catchup:?}: client {id} diverged across topologies"
                );
            }
            assert_eq!(res.ledger.uplink_bits, sync.ledger.uplink_bits, "{catchup:?}");
            assert_eq!(res.ledger.downlink_bits, sync.ledger.downlink_bits, "{catchup:?}");
        }
    }

    #[test]
    fn sharded_ps_merge_is_bit_identical_to_flat() {
        // same seeds, flat vs 3-shard PS: finals, votes and the
        // client-facing ledger must not move a bit; only the PS-internal
        // merge counters appear
        let run = |shards: usize| {
            let train = generate(&SYNTH_CIFAR10, 300, 0);
            let clients = dist_clients(5, &train);
            let cfg = DistCfg {
                rounds: 30,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 16,
                participation: ParticipationCfg::Fraction(0.6),
                catchup: CatchupCfg::Replay,
                net: NetCfg::ideal(),
                seed: 7,
                seed_pool: 0,
                shards,
            };
            run_feedsign(clients, train, cfg)
        };
        let flat = run(0);
        let sharded = run(3);
        assert_eq!(sharded.finals, flat.finals, "sharded PS merge changed the model");
        assert_eq!(sharded.votes_per_round, flat.votes_per_round);
        assert_eq!(sharded.ledger.uplink_bits, flat.ledger.uplink_bits);
        assert_eq!(sharded.ledger.downlink_bits, flat.ledger.downlink_bits);
        assert_eq!(flat.shard.shards, 0);
        assert_eq!(flat.shard.merges, 0);
        assert_eq!(sharded.shard.shards, 3);
        assert!(sharded.shard.merges > 0, "merge traffic must be metered");
        assert!(sharded.shard.merge_bits > 0);
    }

    #[test]
    #[should_panic(expected = "holds no parameters")]
    fn distributed_rejects_rebroadcast() {
        let train = generate(&SYNTH_CIFAR10, 60, 0);
        let clients = dist_clients(2, &train);
        let mut cfg = DistCfg::full(5, 2e-3, 1e-3, 8);
        cfg.catchup = CatchupCfg::Rebroadcast;
        run_feedsign(clients, train, cfg);
    }

    #[test]
    #[should_panic(expected = "commit order")]
    fn distributed_rejects_pool_scalar_catchup() {
        let train = generate(&SYNTH_CIFAR10, 60, 0);
        let clients = dist_clients(2, &train);
        let mut cfg = DistCfg::full(5, 2e-3, 1e-3, 8);
        cfg.seed_pool = 16;
        cfg.catchup = CatchupCfg::PoolScalars;
        run_feedsign(clients, train, cfg);
    }

    #[test]
    fn seed_pool_matches_sync_session_for_both_catchup_modes() {
        use crate::coordinator::session::{Client, Session, SessionCfg};
        for catchup in [CatchupCfg::Off, CatchupCfg::Replay] {
            let train = generate(&SYNTH_CIFAR10, 300, 0);
            let test = generate(&SYNTH_CIFAR10, 100, 1);
            let shards = split(&train, 4, Partition::Iid, 0);
            let clients: Vec<Client> = shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    Client::new(
                        id,
                        Box::new(NativeEngine::new(LinearProbe::new(128, 10))),
                        shard,
                        7,
                    )
                })
                .collect();
            let cfg = SessionCfg {
                rounds: 60,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 16,
                eval_every: 0,
                participation: ParticipationCfg::Fraction(0.5),
                catchup,
                seed_pool: 32,
                seed: 7,
                ..Default::default()
            };
            let mut sync = Session::new(cfg, clients, train.clone(), test);
            for t in 0..60 {
                sync.step(t);
            }
            sync.catch_up_all();

            let dclients = dist_clients(4, &train);
            let dcfg = DistCfg {
                rounds: 60,
                eta: 2e-3,
                mu: 1e-3,
                batch_size: 16,
                participation: ParticipationCfg::Fraction(0.5),
                catchup,
                net: NetCfg::ideal(),
                seed: 7,
                seed_pool: 32,
                shards: 0,
            };
            let res = run_feedsign(dclients, train, dcfg);
            for (id, w) in res.finals.iter().enumerate() {
                assert_eq!(
                    w.as_slice(),
                    &*sync.replica(id),
                    "catchup={catchup:?}: pool client {id} diverged across topologies"
                );
            }
            assert_eq!(res.ledger.uplink_bits, sync.ledger.uplink_bits, "{catchup:?}");
            assert_eq!(res.ledger.downlink_bits, sync.ledger.downlink_bits, "{catchup:?}");
        }
    }
}
