//! Threaded leader/worker topology: the same FeedSign protocol as
//! [`super::session::Session`], but with the PS and every client as
//! separate OS threads exchanging [`crate::comm::Message`]s over metered
//! channels — the deployment shape of Figure 1.
//!
//! The PS thread holds **no model parameters** (the paper's §D.2
//! property): it sees only 1-bit votes and emits 1-bit directions.  A
//! cross-topology test pins this runtime against the synchronous session:
//! identical seeds must produce bit-identical final models.

use crate::comm::{self, Ledger, Message};
use crate::coordinator::aggregation;
use crate::coordinator::byzantine::Attack;
use crate::data::{Dataset, Shard};
use crate::engine::Engine;
use crate::simkit::prng::{self, Rng};
use std::sync::Arc;

/// Client task configuration.
pub struct DistClient {
    /// `Engine` carries a `Send` supertrait, so any boxed engine can move
    /// onto the worker thread.
    pub engine: Box<dyn Engine>,
    pub w: Vec<f32>,
    pub shard: Shard,
    pub attack: Attack,
    pub rng: Rng,
}

/// Outcome of a distributed FeedSign run.
pub struct DistResult {
    /// final parameter replicas, one per client (must all be equal)
    pub finals: Vec<Vec<f32>>,
    pub ledger: Ledger,
    pub votes_per_round: Vec<Vec<i8>>,
}

/// Run `rounds` of distributed FeedSign over worker threads.
///
/// Protocol per round `t`: PS broadcasts `RoundStart` (seed = t is
/// implicit), each client probes its shard and uploads `SignVote`, the PS
/// majority-votes and broadcasts `GlobalSign`, each client applies the
/// update locally.
pub fn run_feedsign(
    clients: Vec<DistClient>,
    train: Dataset,
    rounds: u64,
    eta: f32,
    mu: f32,
    batch_size: usize,
) -> DistResult {
    let k = clients.len();
    let train = Arc::new(train);
    let mut ps_links = Vec::with_capacity(k);
    let mut handles = Vec::with_capacity(k);

    for mut c in clients {
        let (duplex, port) = comm::link();
        ps_links.push(duplex);
        let train = Arc::clone(&train);
        handles.push(std::thread::spawn(move || {
            // one OS thread per client IS the fan-out here — keep the
            // per-vector noise ops sequential inside it (same policy as
            // the session round engine's workers)
            let _serial = prng::serial_zone();
            while let Ok(msg) = port.from_ps.recv() {
                match msg {
                    Message::RoundStart { round } => {
                        let seed = round as u32;
                        let batch = c.shard.next_batch(&train, batch_size, &mut c.rng);
                        let p = c.engine.probe(&c.w, &batch, seed, mu);
                        let honest = if p >= 0.0 { 1i8 } else { -1 };
                        let sign = c.attack.mutate_sign(honest, &mut c.rng);
                        // upload the vote, then wait for the global direction
                        if port.to_ps.send(Message::SignVote { sign }).is_err() {
                            break;
                        }
                        let Ok(Message::GlobalSign { sign: f }) = port.from_ps.recv() else {
                            break;
                        };
                        c.engine.update(&mut c.w, seed, f as f32 * eta);
                    }
                    _ => break,
                }
            }
            c.w
        }));
    }

    // PS loop (this thread): drives rounds, meters the ledger, holds no w.
    let mut ledger = Ledger::default();
    let mut votes_per_round = Vec::with_capacity(rounds as usize);
    for t in 0..rounds {
        for link in &ps_links {
            let msg = Message::RoundStart { round: t };
            ledger.record(&msg);
            link.to_client.send(msg).expect("client alive");
        }
        let mut signs = Vec::with_capacity(k);
        for link in &ps_links {
            let msg = link.from_client.recv().expect("client alive");
            let Message::SignVote { sign } = msg else {
                panic!("protocol violation: expected SignVote");
            };
            ledger.record(&Message::SignVote { sign });
            signs.push(sign);
        }
        let f = aggregation::majority_sign(&signs);
        votes_per_round.push(signs);
        for link in &ps_links {
            let msg = Message::GlobalSign { sign: f };
            ledger.record(&msg);
            link.to_client.send(msg).expect("client alive");
        }
    }
    drop(ps_links); // closes channels; clients exit their loops

    let mut finals = Vec::with_capacity(k);
    for h in handles {
        finals.push(h.join().expect("client thread panicked"));
    }
    DistResult { finals, ledger, votes_per_round }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::vision::{generate, SYNTH_CIFAR10};
    use crate::engine::NativeEngine;
    use crate::simkit::nn::LinearProbe;

    fn dist_clients(k: usize, train: &Dataset) -> Vec<DistClient> {
        let shards = split(train, k, Partition::Iid, 0);
        shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                let engine: Box<dyn Engine> =
                    Box::new(NativeEngine::new(LinearProbe::new(128, 10)));
                let w = engine.init_params(7);
                DistClient {
                    engine,
                    w,
                    shard,
                    attack: Attack::None,
                    rng: Rng::new(7 ^ 0xC11E_17, id as u32 + 1),
                }
            })
            .collect()
    }

    #[test]
    fn distributed_replicas_converge_identically() {
        let train = generate(&SYNTH_CIFAR10, 300, 0);
        let clients = dist_clients(4, &train);
        let res = run_feedsign(clients, train, 50, 2e-3, 1e-3, 16);
        for w in &res.finals[1..] {
            assert_eq!(w, &res.finals[0], "replica drift in distributed topology");
        }
        assert_eq!(res.ledger.uplink_bits, 50 * 4);
        assert_eq!(res.ledger.downlink_bits, 50 * 4);
        assert_eq!(res.votes_per_round.len(), 50);
    }

    #[test]
    fn distributed_matches_sync_session() {
        use crate::coordinator::session::{Client, Session, SessionCfg};
        let train = generate(&SYNTH_CIFAR10, 300, 0);
        let test = generate(&SYNTH_CIFAR10, 100, 1);

        // sync run
        let shards = split(&train, 3, Partition::Iid, 0);
        let clients: Vec<Client> = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                Client::new(id, Box::new(NativeEngine::new(LinearProbe::new(128, 10))), shard, 7)
            })
            .collect();
        let cfg = SessionCfg {
            rounds: 40,
            eta: 2e-3,
            mu: 1e-3,
            batch_size: 16,
            eval_every: 0,
            seed: 7,
            ..Default::default()
        };
        let mut sync = Session::new(cfg, clients, train.clone(), test);
        for t in 0..40 {
            sync.step(t);
        }

        // distributed run with identical seeds
        let dclients = dist_clients(3, &train);
        let res = run_feedsign(dclients, train, 40, 2e-3, 1e-3, 16);
        assert_eq!(
            res.finals[0], sync.clients[0].w,
            "topologies diverged despite identical seeds"
        );
    }
}
