//! Differentially private FeedSign (Definition D.1 / Theorem D.2).
//!
//! The vote mechanism itself lives in
//! [`crate::coordinator::aggregation::dp_vote`]; this module adds the
//! analysis utilities: the exact mechanism distribution, the (eps, 0)-DP
//! certificate check, the privacy-convergence trade-off curve (Remark D.3:
//! eps -> 0 pushes the sign-reversing probability p_t -> 1/2, killing the
//! Theorem 3.11 rate), and composition accounting across rounds.

/// Exact `P(f = +1)` of the Definition D.1 mechanism for a vote multiset
/// with `q_plus` +1 votes out of `k`.
pub fn mechanism_p_plus(q_plus: usize, k: usize, epsilon: f32) -> f64 {
    let q_minus = (k - q_plus) as f64;
    let e_plus = (epsilon as f64) * q_plus as f64 / 4.0;
    let e_minus = (epsilon as f64) * q_minus / 4.0;
    let m = e_plus.max(e_minus);
    let a = (e_plus - m).exp();
    let b = (e_minus - m).exp();
    a / (a + b)
}

/// `(P(f=+1), P(f=-1))` computed directly (no `1 - p` cancellation, so the
/// tail probabilities stay exact down to ~e^-700).
pub fn mechanism_probs(q_plus: usize, k: usize, epsilon: f32) -> (f64, f64) {
    let q_minus = (k - q_plus) as f64;
    let e_plus = (epsilon as f64) * q_plus as f64 / 4.0;
    let e_minus = (epsilon as f64) * q_minus / 4.0;
    let m = e_plus.max(e_minus);
    let a = (e_plus - m).exp();
    let b = (e_minus - m).exp();
    (a / (a + b), b / (a + b))
}

/// Worst-case privacy-loss ratio over all adjacent vote vectors (differing
/// in one client's vote) — must be `<= e^eps` for the (eps, 0)-DP claim.
pub fn worst_case_ratio(k: usize, epsilon: f32) -> f64 {
    let mut worst: f64 = 1.0;
    for q in 0..k {
        // adjacent: q vs q+1 positive votes
        let (p1p, p1m) = mechanism_probs(q, k, epsilon);
        let (p2p, p2m) = mechanism_probs(q + 1, k, epsilon);
        let r = (p1p / p2p).max(p2p / p1p);
        let rn = (p1m / p2m).max(p2m / p1m);
        worst = worst.max(r).max(rn);
    }
    worst
}

/// Effective sign-reversing probability induced by the DP vote when the
/// honest majority is `q_plus`/`k` and the true global sign is +1: the
/// probability the broadcast direction is wrong (Remark D.3's p_t term).
pub fn dp_sign_error(q_plus: usize, k: usize, epsilon: f32) -> f64 {
    1.0 - mechanism_p_plus(q_plus, k, epsilon)
}

/// Linear (basic) composition: total privacy budget after `rounds` steps.
pub fn composed_epsilon(epsilon_per_round: f32, rounds: u64) -> f64 {
    epsilon_per_round as f64 * rounds as f64
}

/// One point of the privacy-convergence trade-off (Remark D.3): with a
/// unanimous honest vote, the mechanism's error rate as a function of eps.
#[derive(Debug, Clone, Copy)]
pub struct TradeoffPoint {
    pub epsilon: f32,
    pub sign_error: f64,
    /// the `(1 - 2 p_t)` rate factor this error implies in Theorem 3.11
    pub rate_factor: f64,
}

/// Sweep the trade-off for `k` unanimous voters.
pub fn tradeoff_curve(k: usize, epsilons: &[f32]) -> Vec<TradeoffPoint> {
    epsilons
        .iter()
        .map(|&epsilon| {
            let err = dp_sign_error(k, k, epsilon);
            TradeoffPoint { epsilon, sign_error: err, rate_factor: 1.0 - 2.0 * err }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mechanism_degenerates_to_coin_at_zero_eps() {
        assert!((mechanism_p_plus(5, 5, 0.0) - 0.5).abs() < 1e-12);
        assert!((mechanism_p_plus(0, 5, 0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mechanism_recovers_majority_at_high_eps() {
        assert!(mechanism_p_plus(4, 5, 100.0) > 0.999_999);
        assert!(mechanism_p_plus(1, 5, 100.0) < 1e-6);
    }

    #[test]
    fn dp_certificate_holds_for_range_of_eps_and_k() {
        for &eps in &[0.1f32, 0.5, 1.0, 2.0, 8.0] {
            for &k in &[2usize, 5, 25] {
                let r = worst_case_ratio(k, eps);
                assert!(
                    r <= (eps as f64).exp() + 1e-9,
                    "eps={eps} k={k}: ratio {r} > e^eps"
                );
            }
        }
    }

    #[test]
    fn sign_error_monotone_in_epsilon() {
        let curve = tradeoff_curve(5, &[0.0, 0.5, 1.0, 2.0, 4.0, 8.0]);
        for w in curve.windows(2) {
            assert!(
                w[1].sign_error <= w[0].sign_error + 1e-12,
                "error must shrink as eps grows"
            );
        }
        // eps=0: rate factor 0 (no convergence); eps large: factor -> 1
        assert!(curve.first().unwrap().rate_factor.abs() < 1e-9);
        assert!(curve.last().unwrap().rate_factor > 0.99);
    }

    #[test]
    fn composition_linear() {
        assert!((composed_epsilon(0.1, 100) - 10.0).abs() < 1e-5);
        assert_eq!(composed_epsilon(0.5, 4), 2.0);
    }
}
