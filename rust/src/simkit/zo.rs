//! Zeroth-order SPSA in flat parameter space — the client-side compute of
//! FeedSign and ZO-FedSGD (Definition 3.1 with n = 1).
//!
//! The probe regenerates each perturbed view `w ± mu z` from the pristine
//! replica into a scratch buffer with a fused AXPY (never materialising
//! `z`), so the protocol invariant "probe leaves the replica bit-identical"
//! holds exactly; see [`spsa_probe_scratch`] for why the in-place
//! `+mu, -2mu, +mu` telescope is *not* used.  The AXPYs themselves are
//! **chunk-parallel**: counter-based Philox makes element `i` of `z(seed)`
//! a pure function of `(seed, i)` (counter-space purity), so
//! [`axpy_into`] / [`perturb_in_place`] split the counter space across
//! worker threads and stay bit-identical to the sequential loop for every
//! thread count (the rust analogue of the grid-parallel `spsa_axpy`
//! Pallas kernel).  All span variants are per-block closures over the one
//! shared dispatching walker, [`prng::for_each_span`] — scalar or W-lane
//! wide, same bits either way (see [`prng::SimdWidth`]).  [`axpy_many`]
//! is the probe-batching form: one streaming pass over the canonical
//! buffer materialises several clients' `w + scale_c · z(seed_c)` views
//! at once.
//!
//! [`apply_update`] is also the replay primitive of the seed-history
//! catch-up path (`coordinator::catchup`): a rejoining client applies its
//! missed `(seed, sign·lr)` records through exactly this code, in commit
//! order, which is what makes the replayed replica bit-identical to an
//! always-on client's.

use super::nn::Model;
use super::prng;
use crate::data::Batch;

/// In-place `w[j] += scale * z_{start+j}(seed)` for a span beginning at
/// absolute element offset `start` of the direction stream — the
/// accumulate instance of [`prng::for_each_span`].  `start` may
/// land mid-lane; the partial head lane is regenerated and sliced.
pub fn perturb_span(w: &mut [f32], seed: u32, scale: f32, start: usize) {
    perturb_span_w(w, seed, scale, start, prng::simd_width());
}

/// [`perturb_span`] at an explicit dispatch width (parity tests and
/// benches sweep widths without touching the environment).
pub fn perturb_span_w(w: &mut [f32], seed: u32, scale: f32, start: usize, width: prng::SimdWidth) {
    prng::for_each_span_w(seed, start, w.len(), width, |i, z| {
        for (wj, zj) in w[i..i + z.len()].iter_mut().zip(z) {
            *wj += scale * zj;
        }
    });
}

/// Fused `out[j] = w[j] + scale * z_{start+j}(seed)` for a span beginning
/// at absolute element offset `start` (out-of-place form of
/// [`perturb_span`]; the write instance of [`prng::for_each_span`]).
pub fn axpy_span(w: &[f32], out: &mut [f32], seed: u32, scale: f32, start: usize) {
    axpy_span_w(w, out, seed, scale, start, prng::simd_width());
}

/// [`axpy_span`] at an explicit dispatch width.
pub fn axpy_span_w(
    w: &[f32],
    out: &mut [f32],
    seed: u32,
    scale: f32,
    start: usize,
    width: prng::SimdWidth,
) {
    debug_assert_eq!(w.len(), out.len());
    prng::for_each_span_w(seed, start, w.len(), width, |i, z| {
        for (j, zj) in z.iter().enumerate() {
            out[i + j] = w[i + j] + scale * zj;
        }
    });
}

/// Block length for [`axpy_many`]: long enough to amortise the per-view
/// walker setup, short enough that one canonical block stays resident in
/// L1/L2 while every view consumes it.
const MANY_BLOCK: usize = 1 << 14;

/// Multi-view fused AXPY: for each `(seed_v, scale_v)` in `views`,
/// `outs[v][j] = w[j] + scale_v * z_j(seed_v)` — bit-identical to `V`
/// separate [`axpy_span`] calls (counter-space purity makes the
/// per-block interleaving invisible), but the canonical buffer `w`
/// streams through the cache **once per block for all views** instead of
/// once per view.  This is the probe-batching primitive behind
/// `engine::probe_batch`: the memory traffic drops from `V` reads of `w`
/// to ~1.
pub fn axpy_many(w: &[f32], views: &[(u32, f32)], outs: &mut [&mut [f32]]) {
    assert_eq!(views.len(), outs.len());
    for out in outs.iter() {
        debug_assert_eq!(w.len(), out.len());
    }
    let mut at = 0usize;
    while at < w.len() {
        let end = (at + MANY_BLOCK).min(w.len());
        let wc = &w[at..end];
        for ((seed, scale), out) in views.iter().zip(outs.iter_mut()) {
            axpy_span(wc, &mut out[at..end], *seed, *scale, at);
        }
        at = end;
    }
}

/// Tiled fused commit+probe sweep over a span of the canonical buffer:
/// walk `w` in `tile`-element tiles and, within each tile, apply every
/// commit `w -= step_c * z(seed_c)` ([`apply_update`] semantics —
/// `±0.0` steps are skipped so no-op rounds stay bit-exact) and then
/// materialise every staged view `outs[v] = w' + scale_v * z(seed_v)`
/// from the *committed* tile, in ONE read-modify-write pass instead of
/// `commits + views` full-buffer passes.  `start` is the absolute
/// element offset of `w[0]` in the direction streams, so the chunk-
/// parallel driver can cut the sweep anywhere; `outs[v]` spans the same
/// elements as `w`.
///
/// Bit-identical to the multi-pass flat engine by construction: the
/// per-element float expression and its evaluation order (commits in
/// order, then views) are exactly those of sequential [`apply_update`]
/// passes followed by [`axpy_span`] passes — tiling only reorders
/// *which elements* are touched when, and counter-space purity makes
/// element `i` of every `z` a pure function of `(seed, i)`.  Pinned by
/// `fused_sweep_matches_multipass_bitwise` here and the
/// `rust/tests/tile_parity.rs` suite end to end.
pub fn fused_commit_probe_span_w(
    w: &mut [f32],
    commits: &[(u32, f32)],
    views: &[(u32, f32)],
    outs: &mut [&mut [f32]],
    start: usize,
    tile: usize,
    width: prng::SimdWidth,
) {
    assert_eq!(views.len(), outs.len());
    for out in outs.iter() {
        debug_assert_eq!(w.len(), out.len());
    }
    let tile = tile.max(1);
    let mut at = 0usize;
    while at < w.len() {
        let end = (at + tile).min(w.len());
        let wt = &mut w[at..end];
        for &(seed, step) in commits {
            if step != 0.0 {
                perturb_span_w(wt, seed, -step, start + at, width);
            }
        }
        let wt = &w[at..end];
        for ((seed, scale), out) in views.iter().zip(outs.iter_mut()) {
            axpy_span_w(wt, &mut out[at..end], *seed, *scale, start + at, width);
        }
        at = end;
    }
}

/// [`fused_commit_probe_span_w`] at the process-wide dispatch width.
pub fn fused_commit_probe_span(
    w: &mut [f32],
    commits: &[(u32, f32)],
    views: &[(u32, f32)],
    outs: &mut [&mut [f32]],
    start: usize,
    tile: usize,
) {
    fused_commit_probe_span_w(w, commits, views, outs, start, tile, prng::simd_width());
}

/// Chunk-parallel fused commit+probe sweep with an explicit worker
/// count: the counter space is cut into lane-aligned chunks
/// ([`prng::chunk_size`]) and each worker runs the tiled span sweep over
/// its chunk — bit-identical to the sequential sweep for every thread
/// count *and* every tile length (both only re-tile the counter space).
pub fn fused_commit_probe_threads(
    w: &mut [f32],
    commits: &[(u32, f32)],
    views: &[(u32, f32)],
    outs: &mut [&mut [f32]],
    tile: usize,
    threads: usize,
) {
    assert_eq!(views.len(), outs.len());
    if threads <= 1 || w.len() <= 4 {
        fused_commit_probe_span(w, commits, views, outs, 0, tile);
        return;
    }
    let chunk = prng::chunk_size(w.len(), threads);
    let mut out_chunks: Vec<std::slice::ChunksMut<'_, f32>> =
        outs.iter_mut().map(|o| o.chunks_mut(chunk)).collect();
    let items: Vec<(&mut [f32], Vec<&mut [f32]>)> = w
        .chunks_mut(chunk)
        .map(|wc| (wc, out_chunks.iter_mut().map(|it| it.next().unwrap()).collect()))
        .collect();
    prng::scoped_spawn(items, |i, (wc, ocs)| {
        let mut ocs = ocs;
        fused_commit_probe_span(wc, commits, views, &mut ocs, i * chunk, tile);
    });
}

/// The fused round kernel at the auto thread policy
/// ([`prng::noise_threads`]) and the process-wide tile length
/// ([`prng::tile_elems`]) — one sweep over canonical applies the
/// committed round-t update(s) and stages the round-t+1 probe views.
pub fn fused_commit_probe(
    w: &mut [f32],
    commits: &[(u32, f32)],
    views: &[(u32, f32)],
    outs: &mut [&mut [f32]],
) {
    let threads = prng::noise_threads(w.len());
    fused_commit_probe_threads(w, commits, views, outs, prng::tile_elems(), threads);
}

/// In-place `w += scale * z(seed)` with streaming noise regeneration,
/// chunk-parallel over [`prng::noise_threads`] workers (bit-identical to
/// the sequential walk for every thread count).
pub fn perturb_in_place(w: &mut [f32], seed: u32, scale: f32) {
    let threads = prng::noise_threads(w.len());
    perturb_in_place_threads(w, seed, scale, threads);
}

/// [`perturb_in_place`] with an explicit worker count (benches and the
/// parity tests pin `threads` instead of relying on the auto policy).
pub fn perturb_in_place_threads(w: &mut [f32], seed: u32, scale: f32, threads: usize) {
    if threads <= 1 || w.len() <= 4 {
        perturb_span(w, seed, scale, 0);
        return;
    }
    let chunk = prng::chunk_size(w.len(), threads);
    prng::scoped_spawn(w.chunks_mut(chunk), |i, c| perturb_span(c, seed, scale, i * chunk));
}

/// Fused `out[i] = w[i] + scale * z_i(seed)`, chunk-parallel over
/// [`prng::noise_threads`] workers (the rust analogue of the `spsa_axpy`
/// Pallas kernel's out-of-place form).
pub fn axpy_into(w: &[f32], out: &mut [f32], seed: u32, scale: f32) {
    let threads = prng::noise_threads(w.len());
    axpy_into_threads(w, out, seed, scale, threads);
}

/// [`axpy_into`] with an explicit worker count.
pub fn axpy_into_threads(w: &[f32], out: &mut [f32], seed: u32, scale: f32, threads: usize) {
    debug_assert_eq!(w.len(), out.len());
    if threads <= 1 || w.len() <= 4 {
        axpy_span(w, out, seed, scale, 0);
        return;
    }
    let chunk = prng::chunk_size(w.len(), threads);
    prng::scoped_spawn(w.chunks(chunk).zip(out.chunks_mut(chunk)), |i, (wc, oc)| {
        axpy_span(wc, oc, seed, scale, i * chunk)
    });
}

/// SPSA gradient projection
/// `p = (L(w + mu z, B) - L(w - mu z, B)) / (2 mu)`.
///
/// `w` is never mutated: each perturbed view is regenerated from `w` into
/// `scratch` by the fused AXPY, so the protocol invariant "probe leaves the
/// replica bit-identical" holds exactly (an in-place `+mu, -2mu, +mu`
/// telescope drifts by ~1 ulp per step, which breaks ZO-FedSGD replica
/// synchronization and orbit replay).  The cost is one d-float scratch
/// buffer — still far below backprop's activations + dense gradient
/// (Table 10).
pub fn spsa_probe_scratch<M: Model + ?Sized>(
    model: &mut M,
    w: &[f32],
    scratch: &mut Vec<f32>,
    batch: &Batch,
    seed: u32,
    mu: f32,
) -> f32 {
    scratch.resize(w.len(), 0.0);
    axpy_into(w, scratch, seed, mu);
    let lp = model.loss(scratch, batch);
    axpy_into(w, scratch, seed, -mu);
    let lm = model.loss(scratch, batch);
    (lp - lm) / (2.0 * mu)
}

/// Allocation-per-call convenience wrapper around
/// [`spsa_probe_scratch`]; like it, never mutates `w`.
pub fn spsa_probe<M: Model + ?Sized>(
    model: &mut M,
    w: &[f32],
    batch: &Batch,
    seed: u32,
    mu: f32,
) -> f32 {
    let mut scratch = Vec::new();
    spsa_probe_scratch(model, w, &mut scratch, batch, seed, mu)
}

/// Apply the aggregated update `w -= step * z(seed)`; `step` folds the
/// global sign/projection and the learning rate.  A `±0.0` step (a
/// zero-participant no-op round) returns without touching `w` — adding
/// `-0.0 · z` could flip the sign bit of `-0.0` parameters, and a no-op
/// must be bit-exact too.
pub fn apply_update(w: &mut [f32], seed: u32, step: f32) {
    if step == 0.0 {
        return;
    }
    perturb_in_place(w, seed, -step);
}

/// One centralized ZO-SGD (MeZO) step; returns the projection.
pub fn mezo_step<M: Model + ?Sized>(
    model: &mut M,
    w: &mut [f32],
    batch: &Batch,
    seed: u32,
    mu: f32,
    eta: f32,
) -> f32 {
    let p = spsa_probe(model, w, batch, seed, mu);
    apply_update(w, seed, eta * p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::nn::{LinearProbe, Model, ModelCfg, TransformerSim};
    use crate::simkit::prng::Rng;
    use crate::util::proptest_lite::{check, Gen};

    /// Linearly separable features: class c has +2 planted on coordinate c.
    fn feature_batch(dim: usize, classes: usize, rows: usize, seed: u32) -> Batch {
        let mut rng = Rng::new(seed, 0);
        let mut x = vec![0.0f32; rows * dim];
        let mut y = vec![0u32; rows];
        for r in 0..rows {
            let c = rng.below(classes);
            y[r] = c as u32;
            for j in 0..dim {
                x[r * dim + j] = rng.normal() + if j == c { 2.0 } else { 0.0 };
            }
        }
        Batch::Features { x, y, rows, dim }
    }

    #[test]
    fn perturb_matches_normals_vec() {
        let mut w = vec![0.0f32; 100];
        perturb_in_place(&mut w, 42, 2.0);
        let z = prng::normals_vec(42, 100);
        for (a, b) in w.iter().zip(&z) {
            assert!((a - 2.0 * b).abs() < 1e-6);
        }
    }

    #[test]
    fn spans_reproduce_full_stream_at_arbitrary_splits() {
        // the proptest-lite property the chunk-parallel engine rests on:
        // cutting the AXPY at ANY split points reproduces the reference
        // stream bit-exactly.
        check("axpy split points", |g: &mut Gen| {
            let n = g.usize_in(5, 400);
            let w = g.vec_normal(n);
            let seed = g.u32() & 0x7FFF_FFFF;
            let scale = g.f32_in(-2.0, 2.0);
            // reference: scalar formula from the materialised stream
            let z = prng::normals_vec(seed, n);
            let expect: Vec<f32> = w.iter().zip(&z).map(|(wi, zi)| wi + scale * zi).collect();
            // cut [0, n) into 1..=4 spans at arbitrary (unsorted draws,
            // then sorted) boundaries, including mid-lane ones
            let mut cuts = vec![0usize, n];
            for _ in 0..g.usize_in(0, 3) {
                cuts.push(g.usize_in(0, n + 1));
            }
            cuts.sort_unstable();
            // every dispatch width must survive the same arbitrary cuts
            // (mid-lane and mid-wide-block alike) bit-exactly
            for width in prng::SimdWidth::ALL {
                let mut out = vec![0.0f32; n];
                for pair in cuts.windows(2) {
                    let (a, b) = (pair[0], pair[1]);
                    axpy_span_w(&w[a..b], &mut out[a..b], seed, scale, a, width);
                }
                assert_eq!(out, expect, "axpy at {width:?}");
                // and the perturb form over the same cuts
                let mut wp = w.clone();
                for pair in cuts.windows(2) {
                    let (a, b) = (pair[0], pair[1]);
                    perturb_span_w(&mut wp[a..b], seed, scale, a, width);
                }
                assert_eq!(wp, expect, "perturb at {width:?}");
            }
        });
    }

    #[test]
    fn axpy_many_matches_separate_axpys_bitwise() {
        // the probe-batching primitive: interleaving views per block must
        // be invisible — each view equals its standalone fused AXPY
        for n in [0usize, 5, MANY_BLOCK - 1, MANY_BLOCK, MANY_BLOCK + 37] {
            let w = prng::normals_vec(4, n);
            let views = [(11u32, 1e-3f32), (12, -1e-3), (11, -1e-3), (900, 0.25)];
            let mut expect = vec![vec![0.0f32; n]; views.len()];
            for ((seed, scale), out) in views.iter().zip(expect.iter_mut()) {
                axpy_span(&w, out, *seed, *scale, 0);
            }
            let mut many = vec![vec![0.0f32; n]; views.len()];
            let mut outs: Vec<&mut [f32]> = many.iter_mut().map(|v| v.as_mut_slice()).collect();
            axpy_many(&w, &views, &mut outs);
            for (v, (e, m)) in expect.iter().zip(&many).enumerate() {
                let same = e.iter().zip(m).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "view {v} diverged (n={n})");
            }
        }
    }

    #[test]
    fn fused_sweep_matches_multipass_bitwise() {
        // the tentpole invariant: ONE tiled commit+probe sweep must
        // reproduce the multi-pass flat engine (sequential apply_update
        // per commit, then one axpy pass per view) bit-for-bit, for
        // every tile length — including 1, d, d+1 and non-divisors of
        // the SIMD lane block — and every thread count.
        let n = 4099; // ragged: not a lane multiple, not a tile multiple
        let w0 = prng::normals_vec(6, n);
        let commits = [(21u32, 2e-3f32), (22, 0.0), (23, -1e-3)];
        let views = [(31u32, 1e-3f32), (31, -1e-3), (77, 0.25)];
        let mut expect_w = w0.clone();
        for &(seed, step) in &commits {
            apply_update(&mut expect_w, seed, step);
        }
        let mut expect_outs = vec![vec![0.0f32; n]; views.len()];
        for ((seed, scale), out) in views.iter().zip(expect_outs.iter_mut()) {
            axpy_span(&expect_w, out, *seed, *scale, 0);
        }
        for tile in [1usize, 3, 61, 4096, n, n + 1, 2 * n] {
            for threads in [1usize, 2, 3, 8] {
                let mut w = w0.clone();
                let mut outs_v = vec![vec![0.0f32; n]; views.len()];
                let mut outs: Vec<&mut [f32]> =
                    outs_v.iter_mut().map(|v| v.as_mut_slice()).collect();
                fused_commit_probe_threads(&mut w, &commits, &views, &mut outs, tile, threads);
                let same_w = w.iter().zip(&expect_w).all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same_w, "canonical diverged (tile={tile}, threads={threads})");
                for (v, (e, m)) in expect_outs.iter().zip(&outs_v).enumerate() {
                    let same = e.iter().zip(m).all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "view {v} diverged (tile={tile}, threads={threads})");
                }
            }
        }
    }

    #[test]
    fn fused_sweep_handles_empty_stages_and_noop_commits() {
        // views-only (a no-op round still stages t+1), commits-only
        // (no staged probes), and fully empty sweeps must all be exact
        let n = 517;
        let w0 = prng::normals_vec(8, n);
        // views only: canonical untouched, views == axpy from w0
        let mut w = w0.clone();
        let mut out = vec![0.0f32; n];
        let mut outs: Vec<&mut [f32]> = vec![out.as_mut_slice()];
        fused_commit_probe_threads(&mut w, &[], &[(9, 1e-3)], &mut outs, 64, 2);
        assert_eq!(w, w0, "views-only sweep must leave canonical bit-identical");
        let mut expect = vec![0.0f32; n];
        axpy_span(&w0, &mut expect, 9, 1e-3, 0);
        assert_eq!(out, expect);
        // commits only: canonical == apply_update
        let mut w = w0.clone();
        fused_commit_probe_threads(&mut w, &[(5, 0.125)], &[], &mut [], 64, 2);
        let mut expect_w = w0.clone();
        apply_update(&mut expect_w, 5, 0.125);
        assert_eq!(w, expect_w);
        // all-zero steps: a pure no-op, -0.0 sign bits preserved
        let mut w = vec![-0.0f32; 8];
        fused_commit_probe_threads(&mut w, &[(5, 0.0)], &[], &mut [], 4, 1);
        for v in &w {
            assert_eq!(v.to_bits(), (-0.0f32).to_bits(), "no-op must not touch sign bits");
        }
    }

    #[test]
    fn explicit_thread_counts_bit_identical() {
        let n = 4099; // ragged: not a lane multiple, not a chunk multiple
        let w = prng::normals_vec(2, n);
        let mut reference = vec![0.0f32; n];
        axpy_into_threads(&w, &mut reference, 77, 0.3, 1);
        for threads in [2usize, 3, 4, 8] {
            let mut out = vec![0.0f32; n];
            axpy_into_threads(&w, &mut out, 77, 0.3, threads);
            assert_eq!(out, reference, "axpy with {threads} threads");
            let mut wp = w.clone();
            perturb_in_place_threads(&mut wp, 77, 0.3, threads);
            assert_eq!(wp, reference, "perturb with {threads} threads");
        }
    }

    #[test]
    fn probe_restores_w() {
        let mut model = LinearProbe::new(16, 4);
        let w0 = model.init(0);
        let w = w0.clone();
        let batch = feature_batch(16, 4, 8, 1);
        spsa_probe(&mut model, &w, &batch, 7, 1e-3);
        assert_eq!(w, w0, "probe must leave the replica bit-identical");
    }

    #[test]
    fn probe_approximates_gradient_projection() {
        let mut model = LinearProbe::new(8, 3);
        let w = model.init(0);
        let batch = feature_batch(8, 3, 16, 2);
        let mut grad = vec![0.0; w.len()];
        model.loss_and_grad(&w.clone(), &batch, &mut grad);
        for seed in 0..8u32 {
            let p = spsa_probe(&mut model, &w, &batch, seed, 1e-4);
            let z = prng::normals_vec(seed, w.len());
            let exact = crate::simkit::ops::dot(&z, &grad);
            assert!(
                (p - exact).abs() < 0.05 * exact.abs().max(1.0),
                "seed {seed}: spsa {p} vs exact {exact}"
            );
        }
    }

    #[test]
    fn update_inverse_roundtrip() {
        let mut w = prng::normals_vec(3, 256);
        let w0 = w.clone();
        apply_update(&mut w, 9, 0.05);
        apply_update(&mut w, 9, -0.05);
        for (a, b) in w.iter().zip(&w0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mezo_descends_on_probe() {
        let mut model = LinearProbe::new(8, 3);
        let mut w = model.init(0);
        let batch = feature_batch(8, 3, 32, 4);
        let l0 = model.loss(&w, &batch);
        for t in 0..300 {
            mezo_step(&mut model, &mut w, &batch, t, 1e-3, 1e-4);
        }
        let l1 = model.loss(&w, &batch);
        assert!(l1 < l0 - 0.02, "MeZO failed to descend: {l0} -> {l1}");
    }

    #[test]
    fn mezo_descends_transformer() {
        let cfg = ModelCfg::test_tiny();
        let mut model = TransformerSim::new(cfg.clone());
        let mut w = model.init(0);
        let mut rng = Rng::new(5, 0);
        let cols = cfg.seq_len + 1;
        // low-entropy batch (repeated token pattern) so ZO makes progress fast
        let data: Vec<u32> = (0..8 * cols).map(|i| ((i % 3) + 1) as u32).collect();
        let batch = Batch::Tokens { data, rows: 8, cols };
        let _ = rng.next_u32();
        let l0 = model.loss(&w, &batch);
        let mut best = l0;
        for t in 0..400 {
            mezo_step(&mut model, &mut w, &batch, t, 1e-3, 1e-4);
            if t % 50 == 0 {
                best = best.min(model.loss(&w, &batch));
            }
        }
        let l1 = model.loss(&w, &batch);
        best = best.min(l1);
        assert!(best < l0, "transformer MeZO failed to descend: {l0} -> best {best}");
    }
}
