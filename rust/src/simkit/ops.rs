//! Dense f32 kernels for the native simulation substrate.
//!
//! These are the rust analogues of the Layer-1/Layer-2 compute: a blocked
//! matmul (the probe hot-spot), layernorm, softmax, GeLU and cross-entropy.
//! Everything operates on flat `&[f32]` slices with explicit dimensions —
//! the model code in [`crate::simkit::nn`] owns the shapes.

/// `c[m,n] += a[m,k] @ b[k,n]` — i-k-j loop order so the inner loop is a
/// contiguous SAXPY over `b`'s rows (auto-vectorizes well on one core).
pub fn matmul_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

/// `c = a @ b` (overwrites `c`).
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    c.fill(0.0);
    matmul_acc(a, b, c, m, k, n);
}

/// `c[m,n] += a[m,k] @ b^T` where `b` is `[n,k]` (row-major).  Used by
/// backprop (dX = dY @ W^T) and the tied LM head.
pub fn matmul_bt_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (&av, &bv) in arow.iter().zip(brow.iter()) {
                s += av * bv;
            }
            *cv += s;
        }
    }
}

/// `c[k,n] += a^T @ b` where `a` is `[m,k]`, `b` is `[m,n]`.  Weight
/// gradients: dW = X^T @ dY.
pub fn matmul_at_acc(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += av * bv;
            }
        }
    }
}

pub const SQRT_2_OVER_PI: f32 = 0.797_884_6;
pub const GELU_C: f32 = 0.044_715;

/// tanh-approximation GeLU — identical formula to the Pallas kernel.
#[inline(always)]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + GELU_C * x * x * x)).tanh())
}

/// d gelu(x) / dx.
#[inline(always)]
pub fn gelu_grad(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + GELU_C * x * x * x);
    let t = u.tanh();
    let du = SQRT_2_OVER_PI * (1.0 + 3.0 * GELU_C * x * x);
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du
}

/// In-place row-wise softmax over a `[rows, cols]` buffer.
pub fn softmax_rows(x: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(x.len(), rows * cols);
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Layer norm of one row: `y = (x - mean) / sqrt(var + eps) * gain + bias`.
/// Returns `(mean, rstd)` for the backward pass.
pub fn layernorm_row(
    x: &[f32],
    gain: &[f32],
    bias: &[f32],
    y: &mut [f32],
    eps: f32,
) -> (f32, f32) {
    let d = x.len() as f32;
    let mean = x.iter().sum::<f32>() / d;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d;
    let rstd = 1.0 / (var + eps).sqrt();
    for ((yv, &xv), (&g, &b)) in y.iter_mut().zip(x).zip(gain.iter().zip(bias)) {
        *yv = (xv - mean) * rstd * g + b;
    }
    (mean, rstd)
}

/// Backward of [`layernorm_row`]: accumulates into `dx`, `dgain`, `dbias`.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_row_backward(
    x: &[f32],
    gain: &[f32],
    dy: &[f32],
    mean: f32,
    rstd: f32,
    dx: &mut [f32],
    dgain: &mut [f32],
    dbias: &mut [f32],
) {
    let d = x.len() as f32;
    // xhat = (x - mean) * rstd ; y = xhat*g + b
    let mut sum_dxhat = 0.0f32;
    let mut sum_dxhat_xhat = 0.0f32;
    for i in 0..x.len() {
        let xhat = (x[i] - mean) * rstd;
        let dxhat = dy[i] * gain[i];
        sum_dxhat += dxhat;
        sum_dxhat_xhat += dxhat * xhat;
        dgain[i] += dy[i] * xhat;
        dbias[i] += dy[i];
    }
    for i in 0..x.len() {
        let xhat = (x[i] - mean) * rstd;
        let dxhat = dy[i] * gain[i];
        dx[i] += rstd * (dxhat - sum_dxhat / d - xhat * sum_dxhat_xhat / d);
    }
}

/// Mean cross-entropy over logits `[rows, classes]` with integer targets;
/// writes softmax probabilities into `probs` (for the backward pass) and
/// returns the mean NLL.
pub fn cross_entropy(
    logits: &[f32],
    targets: &[u32],
    probs: &mut [f32],
    rows: usize,
    classes: usize,
) -> f32 {
    debug_assert_eq!(logits.len(), rows * classes);
    debug_assert_eq!(targets.len(), rows);
    probs.copy_from_slice(logits);
    softmax_rows(probs, rows, classes);
    let mut nll = 0.0f64;
    for r in 0..rows {
        let p = probs[r * classes + targets[r] as usize].max(1e-30);
        nll -= (p as f64).ln();
    }
    (nll / rows as f64) as f32
}

/// dlogits for mean cross-entropy given the cached probs: `(p - onehot)/rows`.
pub fn cross_entropy_backward(
    probs: &[f32],
    targets: &[u32],
    dlogits: &mut [f32],
    rows: usize,
    classes: usize,
) {
    let inv = 1.0 / rows as f32;
    dlogits.copy_from_slice(probs);
    for v in dlogits.iter_mut() {
        *v *= inv;
    }
    for r in 0..rows {
        dlogits[r * classes + targets[r] as usize] -= inv;
    }
}

/// Euclidean norm.
pub fn l2_norm(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>().sqrt() as f32
}

/// Dot product (f64 accumulation).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum::<f64>() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn rand_vec(n: usize, seed: u32) -> Vec<f32> {
        crate::simkit::prng::normals_vec(seed, n)
    }

    #[test]
    fn matmul_matches_naive() {
        let (m, k, n) = (7, 11, 5);
        let a = rand_vec(m * k, 1);
        let b = rand_vec(k * n, 2);
        let mut c = vec![0.0; m * n];
        matmul(&a, &b, &mut c, m, k, n);
        let expect = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_bt_matches_naive() {
        let (m, k, n) = (4, 6, 9);
        let a = rand_vec(m * k, 3);
        let bt = rand_vec(n * k, 4); // b^T stored as [n, k]
        // b[p, j] = bt[j, p]
        let mut b = vec![0.0; k * n];
        for p in 0..k {
            for j in 0..n {
                b[p * n + j] = bt[j * k + p];
            }
        }
        let mut c = vec![0.0; m * n];
        matmul_bt_acc(&a, &bt, &mut c, m, k, n);
        let expect = naive_matmul(&a, &b, m, k, n);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_at_matches_naive() {
        let (m, k, n) = (8, 3, 4);
        let a = rand_vec(m * k, 5);
        let b = rand_vec(m * n, 6);
        // a^T is [k, m]
        let mut at = vec![0.0; k * m];
        for i in 0..m {
            for p in 0..k {
                at[p * m + i] = a[i * k + p];
            }
        }
        let mut c = vec![0.0; k * n];
        matmul_at_acc(&a, &b, &mut c, m, k, n);
        let expect = naive_matmul(&at, &b, k, m, n);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_normalized() {
        let mut x = rand_vec(6 * 10, 7);
        softmax_rows(&mut x, 6, 10);
        for r in 0..6 {
            let s: f32 = x[r * 10..(r + 1) * 10].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gelu_grad_finite_diff() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.5, 2.0, 4.0] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn layernorm_roundtrip_stats() {
        let x = rand_vec(64, 8);
        let gain = vec![1.0; 64];
        let bias = vec![0.0; 64];
        let mut y = vec![0.0; 64];
        layernorm_row(&x, &gain, &bias, &mut y, 1e-5);
        let mean: f32 = y.iter().sum::<f32>() / 64.0;
        let var: f32 = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_backward_finite_diff() {
        let d = 16;
        let x = rand_vec(d, 9);
        let gain = rand_vec(d, 10);
        let bias = rand_vec(d, 11);
        let dy = rand_vec(d, 12);
        let mut y = vec![0.0; d];
        let (mean, rstd) = layernorm_row(&x, &gain, &bias, &mut y, 1e-5);
        let loss = |xx: &[f32]| -> f32 {
            let mut yy = vec![0.0; d];
            layernorm_row(xx, &gain, &bias, &mut yy, 1e-5);
            dot(&yy, &dy)
        };
        let mut dx = vec![0.0; d];
        let mut dg = vec![0.0; d];
        let mut db = vec![0.0; d];
        layernorm_row_backward(&x, &gain, &dy, mean, rstd, &mut dx, &mut dg, &mut db);
        for i in 0..d {
            let mut xp = x.clone();
            let mut xm = x.clone();
            let h = 1e-2;
            xp[i] += h;
            xm[i] -= h;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * h);
            assert!((dx[i] - fd).abs() < 2e-2, "i={i} dx={} fd={fd}", dx[i]);
        }
    }

    #[test]
    fn cross_entropy_uniform_logits() {
        let rows = 4;
        let classes = 8;
        let logits = vec![0.0; rows * classes];
        let targets = vec![0u32, 1, 2, 3];
        let mut probs = vec![0.0; rows * classes];
        let nll = cross_entropy(&logits, &targets, &mut probs, rows, classes);
        assert!((nll - (classes as f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_backward_finite_diff() {
        let rows = 3;
        let classes = 5;
        let logits = rand_vec(rows * classes, 13);
        let targets = vec![1u32, 4, 0];
        let mut probs = vec![0.0; rows * classes];
        cross_entropy(&logits, &targets, &mut probs, rows, classes);
        let mut dl = vec![0.0; rows * classes];
        cross_entropy_backward(&probs, &targets, &mut dl, rows, classes);
        for i in 0..logits.len() {
            let h = 1e-2;
            let mut lp = logits.clone();
            let mut lm = logits.clone();
            lp[i] += h;
            lm[i] -= h;
            let mut tmp = vec![0.0; rows * classes];
            let fp = cross_entropy(&lp, &targets, &mut tmp, rows, classes);
            let fm = cross_entropy(&lm, &targets, &mut tmp, rows, classes);
            let fd = (fp - fm) / (2.0 * h);
            assert!((dl[i] - fd).abs() < 1e-3, "i={i}");
        }
    }
}
