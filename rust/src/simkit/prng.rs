//! Counter-based Philox-4x32-10 PRNG — the rust half of FeedSign's shared
//! randomness substrate.
//!
//! Construction is identical to the Pallas kernel in
//! `python/compile/kernels/philox.py`: key `(seed, KEY1_INIT)`, counter
//! block `(i, 0, 0, 0)`, 10 rounds, then `u32 -> (0,1)` via
//! `(x >> 8) * 2^-24 + 2^-25` and Box–Muller.  The u32 word stream matches
//! the kernel **bit-exactly** (pure integer pipeline; pinned against the
//! manifest's recorded vectors in `runtime::manifest` tests); the f32
//! normals agree to ~1e-6 (the [`crate::simkit::fastmath`] polynomial
//! transcendentals vs XLA's).
//!
//! Counter-based generation is what lets FeedSign ship a *direction in R^d*
//! as a 32-bit seed: element `i` of `z` is a pure function of `(seed, i)`
//! — **counter-space purity**, the exactness invariant every consumer in
//! this crate leans on.  Any tile of `z` can be regenerated wherever it
//! is consumed: the streaming SPSA AXPYs in [`crate::simkit::zo`], their
//! chunk-parallel split of the counter space across worker threads
//! (exact, not approximate), and the seed-history catch-up replay all
//! exploit exactly that.  The fused span consumers share one walker,
//! [`for_each_span`], which dispatches between the scalar lane loop
//! ([`for_each_span_lane`]) and the structure-of-arrays wide kernel
//! (`philox4x32xW`, W ∈ {4, 8, 16} counter lanes per iteration — see
//! [`SimdWidth`] / [`simd_width`] and the `FEEDSIGN_SIMD` escape hatch).
//! Because the wide kernel is the *same* u32 arithmetic over W counters
//! and the normal map is the *same* straight-line [`box_muller`] per
//! element, **every dispatch width emits the identical f32 stream
//! bit-for-bit** — the wide path is a throughput choice, never a
//! numerics choice (pinned by `wide_widths_match_scalar_stream_bitwise`).
//!
//! The second invariant here is the **serial-zone policy**
//! ([`serial_zone`] / [`SerialZone`]): a thread already inside a
//! parallel region (a round-engine worker, a distributed client thread)
//! marks itself serial so nested noise ops do not multiply client-level
//! and chunk-level fan-out into oversubscription.  The zone changes
//! wall-clock only — bits are identical either way.

/// Philox multiplier constants (Salmon et al., SC'11).
pub const PHILOX_M0: u32 = 0xD251_1F53;
pub const PHILOX_M1: u32 = 0xCD9E_8D57;
/// Weyl key increments.
pub const PHILOX_W0: u32 = 0x9E37_79B9;
pub const PHILOX_W1: u32 = 0xBB67_AE85;
/// Initial second key lane (matches the Pallas kernel).
pub const KEY1_INIT: u32 = 0xCAFE_F00D;

use crate::simkit::fastmath;

#[inline(always)]
fn mulhilo(a: u32, b: u32) -> (u32, u32) {
    let p = (a as u64) * (b as u64);
    ((p >> 32) as u32, p as u32)
}

/// One Philox-4x32-10 block: 4 random u32 words for counter index `ctr`.
#[inline]
pub fn philox4x32(seed: u32, ctr: u32) -> [u32; 4] {
    let (mut c0, mut c1, mut c2, mut c3) = (ctr, 0u32, 0u32, 0u32);
    let mut k0 = seed;
    let mut k1 = KEY1_INIT;
    for _ in 0..10 {
        let (hi0, lo0) = mulhilo(PHILOX_M0, c0);
        let (hi1, lo1) = mulhilo(PHILOX_M1, c2);
        (c0, c1, c2, c3) = (hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ k1, lo0);
        k0 = k0.wrapping_add(PHILOX_W0);
        k1 = k1.wrapping_add(PHILOX_W1);
    }
    [c0, c1, c2, c3]
}

/// The 31-bit direction-seed domain.  `net::ChannelModel`'s bit-flip
/// impairment masks corrupted seed fields back into this space (the MSB
/// is reserved), so every seed that names a direction — round-derived,
/// client-drawn, or pool-derived — must stay below `2^31`.
pub const DIRECTION_MASK: u32 = 0x7FFF_FFFF;

/// Derive the shared per-round direction seed from a round counter,
/// masked into the 31-bit [`DIRECTION_MASK`] domain.  The naive
/// `t as u32` leaves the domain once round counters reach the MSB
/// (t >= 2^31), silently breaking the channel model's masking
/// assumption; every round→seed derivation site goes through here.
#[inline(always)]
pub fn round_direction_seed(t: u64) -> u32 {
    (t as u32) & DIRECTION_MASK
}

/// Map a u32 to the log-safe interval (0, 1] — same bit recipe as the
/// Pallas kernel, so uniform streams match exactly.  (The top of the
/// range rounds to exactly 1.0f32, which is harmless: Box-Muller only
/// needs u1 > 0.)
#[inline(always)]
pub fn u32_to_unit(x: u32) -> f32 {
    (x >> 8) as f32 * (1.0 / (1 << 24) as f32) + 1.0 / (1 << 25) as f32
}

/// Box–Muller: two uniforms in (0,1] -> two standard normals.
///
/// Transcendentals come from [`fastmath`], not libm: the branch-free
/// polynomial kernels auto-vectorize inside the wide walker's per-lane
/// loops, and — because this *same* straight-line function is the only
/// normal map in the crate — the scalar and wide paths produce
/// bit-identical f32 streams by construction.  `sqrt` is IEEE-exact and
/// a single instruction on every target.
#[inline(always)]
pub fn box_muller(u1: f32, u2: f32) -> (f32, f32) {
    let r = (-2.0 * fastmath::ln_pos(u1)).sqrt();
    let (s, c) = fastmath::sincos_2pi(u2);
    (r * c, r * s)
}

/// The 4 standard normals of counter lane `ctr`: elements
/// `z[4*ctr .. 4*ctr+4]` of the direction `z(seed)`.
#[inline]
pub fn normals4(seed: u32, ctr: u32) -> [f32; 4] {
    let [x0, x1, x2, x3] = philox4x32(seed, ctr);
    let (za, zb) = box_muller(u32_to_unit(x0), u32_to_unit(x1));
    let (zc, zd) = box_muller(u32_to_unit(x2), u32_to_unit(x3));
    [za, zb, zc, zd]
}

/// Walk the counter lanes covering elements `[start, start + len)` of the
/// direction `z(seed)`, calling `f(i, z)` with the span-relative element
/// offset `i` and the lane normals for elements `i .. i + z.len()`.
///
/// This is **the one** head/body/tail walker behind every fused
/// counter-space consumer — [`normals_into_span`],
/// [`crate::simkit::zo::perturb_span`] and
/// [`crate::simkit::zo::axpy_span`] are thin per-lane closures over it
/// (they used to be three hand-fused copies of this loop).  `start` may
/// be **any** element offset, not just a lane boundary: the partial head
/// lane is regenerated in full and sliced, which is what lets the
/// chunk-parallel drivers cut the counter space anywhere and still
/// reproduce the sequential stream bit-exactly (counter-space purity:
/// element `i` of `z(seed)` is a pure function of `(seed, i)`).
/// `#[inline(always)]` + closure specialization keep the full-lane body
/// as tight as the hand-fused originals (the Philox block dominates
/// either way; `perf_hotpath`'s PRNG-throughput shape check pins it).
#[inline(always)]
pub fn for_each_span_lane<F: FnMut(usize, &[f32])>(seed: u32, start: usize, len: usize, mut f: F) {
    if len == 0 {
        return;
    }
    let mut i = 0usize;
    let mut ctr = (start / 4) as u32;
    let phase = start % 4;
    if phase != 0 {
        let z = normals4(seed, ctr);
        let take = (4 - phase).min(len);
        f(0, &z[phase..phase + take]);
        i = take;
        ctr += 1;
    }
    while i + 4 <= len {
        let z = normals4(seed, ctr);
        f(i, &z);
        i += 4;
        ctr += 1;
    }
    if i < len {
        let z = normals4(seed, ctr);
        f(i, &z[..len - i]);
    }
}

// ---------------------------------------------------------------------------
// Wide lanes: structure-of-arrays philox4x32xW and the dispatching walker
// ---------------------------------------------------------------------------

/// Widest supported SoA kernel (lanes); one wide block covers
/// `4 * MAX_LANES` stream elements.
pub const MAX_LANES: usize = 16;

/// `philox4x32xW` + Box–Muller over `W` consecutive counter lanes
/// `ctr .. ctr + W`, writing the `4 * W` stream elements into `out`.
///
/// Structure of arrays: the four counter words live in `[u32; W]` arrays
/// so each Philox round is W independent identical u32 operations — LLVM
/// turns the inner `for j in 0..W` loops into packed integer SIMD.  The
/// normal map then calls the scalar [`box_muller`] per lane; its body is
/// branch-free polynomial arithmetic ([`fastmath`]), so that loop
/// vectorizes too *and* every element goes through the exact expression
/// tree the scalar walker uses — identical bits by construction, not by
/// tolerance.
#[inline(always)]
fn normals_soa<const W: usize>(seed: u32, ctr: u32, out: &mut [f32]) {
    debug_assert_eq!(out.len(), 4 * W);
    let mut c0 = [0u32; W];
    let mut c1 = [0u32; W];
    let mut c2 = [0u32; W];
    let mut c3 = [0u32; W];
    for j in 0..W {
        c0[j] = ctr.wrapping_add(j as u32);
    }
    let mut k0 = seed;
    let mut k1 = KEY1_INIT;
    for _ in 0..10 {
        for j in 0..W {
            let (hi0, lo0) = mulhilo(PHILOX_M0, c0[j]);
            let (hi1, lo1) = mulhilo(PHILOX_M1, c2[j]);
            (c0[j], c1[j], c2[j], c3[j]) = (hi1 ^ c1[j] ^ k0, lo1, hi0 ^ c3[j] ^ k1, lo0);
        }
        k0 = k0.wrapping_add(PHILOX_W0);
        k1 = k1.wrapping_add(PHILOX_W1);
    }
    for j in 0..W {
        let (za, zb) = box_muller(u32_to_unit(c0[j]), u32_to_unit(c1[j]));
        let (zc, zd) = box_muller(u32_to_unit(c2[j]), u32_to_unit(c3[j]));
        out[4 * j] = za;
        out[4 * j + 1] = zb;
        out[4 * j + 2] = zc;
        out[4 * j + 3] = zd;
    }
}

/// Runtime-selected lane count for the span walkers.  `Scalar` is the
/// one-lane [`for_each_span_lane`] loop; the wide variants run
/// [`normals_soa`] blocks of `4 * W` elements with scalar head/tail.
/// All widths emit bit-identical streams — this knob trades nothing but
/// throughput.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdWidth {
    /// One counter lane per iteration (the fallback / escape hatch).
    Scalar,
    /// 4 lanes (16 elements) per iteration — 128-bit registers.
    W4,
    /// 8 lanes (32 elements) per iteration — 256-bit registers (default).
    W8,
    /// 16 lanes (64 elements) per iteration — 512-bit registers.
    W16,
}

impl SimdWidth {
    /// Every width, scalar first — the parity tests sweep this.
    pub const ALL: [SimdWidth; 4] =
        [SimdWidth::Scalar, SimdWidth::W4, SimdWidth::W8, SimdWidth::W16];

    /// Counter lanes processed per wide iteration (1 for `Scalar`).
    pub fn lanes(self) -> usize {
        match self {
            SimdWidth::Scalar => 1,
            SimdWidth::W4 => 4,
            SimdWidth::W8 => 8,
            SimdWidth::W16 => 16,
        }
    }

    /// Parse a `FEEDSIGN_SIMD` value.  `off`/`scalar`/`0`/`1` force the
    /// scalar walker; `4`/`8`/`16` pick a lane count; `on`/`wide` mean
    /// the default wide width.  Unknown strings return `None` (the
    /// dispatcher then falls back to the default rather than panicking
    /// mid-run).
    pub fn parse(s: &str) -> Option<SimdWidth> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "scalar" | "0" | "1" => Some(SimdWidth::Scalar),
            "4" => Some(SimdWidth::W4),
            "8" | "on" | "wide" => Some(SimdWidth::W8),
            "16" => Some(SimdWidth::W16),
            _ => None,
        }
    }
}

/// The process-wide dispatch width: `FEEDSIGN_SIMD` if set and valid
/// (see [`SimdWidth::parse`]), else [`SimdWidth::W8`] — 8 lanes keeps
/// the SoA state in 256-bit registers on AVX2 and splits cleanly into
/// two 128-bit halves on baseline SSE2/NEON.  Read once and cached:
/// the hot loops must not re-parse an env var per span.
pub fn simd_width() -> SimdWidth {
    static WIDTH: std::sync::OnceLock<SimdWidth> = std::sync::OnceLock::new();
    *WIDTH.get_or_init(|| {
        std::env::var("FEEDSIGN_SIMD")
            .ok()
            .and_then(|v| SimdWidth::parse(&v))
            .unwrap_or(SimdWidth::W8)
    })
}

/// Default tile length (elements) for the fused commit+probe sweep in
/// [`crate::simkit::zo::fused_commit_probe`]: 32768 f32 elements =
/// 128 KiB — the canonical tile plus a couple of staged view tiles stay
/// resident in a typical 512 KiB–1 MiB L2 while every pass consumes
/// them.
pub const DEFAULT_TILE_ELEMS: usize = 1 << 15;

/// Parse a `FEEDSIGN_TILE` value: a positive element count picks that
/// tile length, `0`/`auto`/`default` (and unset/invalid) mean
/// [`DEFAULT_TILE_ELEMS`].
pub fn parse_tile(s: &str) -> Option<usize> {
    match s.trim().to_ascii_lowercase().as_str() {
        "0" | "auto" | "default" => Some(DEFAULT_TILE_ELEMS),
        v => v.parse::<usize>().ok().filter(|&t| t >= 1),
    }
}

/// The process-wide tile length for the fused sweep: `FEEDSIGN_TILE` if
/// set and valid (see [`parse_tile`]), else [`DEFAULT_TILE_ELEMS`].
/// Read once and cached, like [`simd_width`] — the hot loops must not
/// re-parse an env var per sweep.  Tiling is bit-invisible (counter-
/// space purity: any tile of `z(seed)` regenerates identically), so
/// this knob trades nothing but locality.
pub fn tile_elems() -> usize {
    static TILE: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *TILE.get_or_init(|| {
        std::env::var("FEEDSIGN_TILE")
            .ok()
            .and_then(|v| parse_tile(&v))
            .unwrap_or(DEFAULT_TILE_ELEMS)
    })
}

/// [`for_each_span_lane`] with `W`-lane wide blocks: scalar head up to
/// the next lane boundary, [`normals_soa`] body, scalar ragged tail.
/// Spans shorter than one wide block take the scalar walker whole.
#[inline(always)]
fn for_each_span_wide<const W: usize, F: FnMut(usize, &[f32])>(
    seed: u32,
    start: usize,
    len: usize,
    mut f: F,
) {
    let block = 4 * W;
    if len < block {
        for_each_span_lane(seed, start, len, f);
        return;
    }
    let phase = start % 4;
    let head = if phase == 0 { 0 } else { 4 - phase };
    if head != 0 {
        for_each_span_lane(seed, start, head, &mut f);
    }
    let mut i = head;
    let mut ctr = ((start + head) / 4) as u32;
    let mut buf = [0.0f32; 4 * MAX_LANES];
    while i + block <= len {
        normals_soa::<W>(seed, ctr, &mut buf[..block]);
        f(i, &buf[..block]);
        i += block;
        ctr = ctr.wrapping_add(W as u32);
    }
    if i < len {
        for_each_span_lane(seed, start + i, len - i, |off, z| f(i + off, z));
    }
}

/// The dispatching span walker every fused counter-space consumer calls:
/// [`for_each_span_w`] at the process-wide [`simd_width`].  Contract and
/// bit-exactness guarantees are those of [`for_each_span_lane`] — the
/// width changes throughput only.
#[inline]
pub fn for_each_span<F: FnMut(usize, &[f32])>(seed: u32, start: usize, len: usize, f: F) {
    for_each_span_w(seed, start, len, simd_width(), f)
}

/// [`for_each_span`] at an explicit width — the parity tests and benches
/// sweep widths side by side without touching the process environment.
#[inline]
pub fn for_each_span_w<F: FnMut(usize, &[f32])>(
    seed: u32,
    start: usize,
    len: usize,
    width: SimdWidth,
    f: F,
) {
    match width {
        SimdWidth::Scalar => for_each_span_lane(seed, start, len, f),
        SimdWidth::W4 => for_each_span_wide::<4, F>(seed, start, len, f),
        SimdWidth::W8 => for_each_span_wide::<8, F>(seed, start, len, f),
        SimdWidth::W16 => for_each_span_wide::<16, F>(seed, start, len, f),
    }
}

/// Fill `out` with elements `z[start .. start + out.len()]` of the
/// direction `z(seed)` — the copy instance of [`for_each_span`],
/// and the primitive the chunk-parallel noise ops hand to each worker
/// thread.
pub fn normals_into_span(seed: u32, start: usize, out: &mut [f32]) {
    normals_into_span_w(seed, start, out, simd_width());
}

/// [`normals_into_span`] at an explicit dispatch width.
pub fn normals_into_span_w(seed: u32, start: usize, out: &mut [f32], width: SimdWidth) {
    for_each_span_w(seed, start, out.len(), width, |i, z| {
        out[i..i + z.len()].copy_from_slice(z);
    });
}

/// Fill `out` with the leading `out.len()` elements of `z(seed)`,
/// fanning the counter space out over worker threads for large vectors
/// (bit-identical to the sequential fill for every thread count).
pub fn normals_into(seed: u32, out: &mut [f32]) {
    let threads = noise_threads(out.len());
    if threads <= 1 {
        normals_into_span(seed, 0, out);
        return;
    }
    let chunk = chunk_size(out.len(), threads);
    scoped_spawn(out.chunks_mut(chunk), |i, c| normals_into_span(seed, i * chunk, c));
}

// ---------------------------------------------------------------------------
// Chunk-parallelism policy (shared by this module and `simkit::zo`)
// ---------------------------------------------------------------------------

/// Minimum element count before chunk-parallel noise generation pays for
/// its thread spawns (scoped threads cost ~10us each; a Philox lane is
/// ~10ns, so below this the sequential loop always wins).
pub const PAR_MIN_ELEMS: usize = 1 << 16;

thread_local! {
    static SERIAL_ZONE: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// RAII guard marking the current thread as already inside a parallel
/// region: nested noise ops stay sequential while it lives, so the round
/// engine's per-client fan-out does not multiply with the per-chunk
/// fan-out into thread oversubscription.
pub struct SerialZone {
    prev: bool,
}

/// Enter a serial zone on this thread (see [`SerialZone`]).
pub fn serial_zone() -> SerialZone {
    let prev = SERIAL_ZONE.with(|c| c.replace(true));
    SerialZone { prev }
}

impl Drop for SerialZone {
    fn drop(&mut self) {
        let prev = self.prev;
        SERIAL_ZONE.with(|c| c.set(prev));
    }
}

/// Worker threads a chunk-parallel driver may use on this thread: 1
/// inside a [`serial_zone`], else the `FEEDSIGN_ZO_THREADS` override or
/// the machine's available parallelism.  Callers that have a workload
/// size should prefer [`noise_threads`], which also applies the
/// [`PAR_MIN_ELEMS`] threshold.
pub fn worker_threads() -> usize {
    if SERIAL_ZONE.with(|c| c.get()) {
        return 1;
    }
    std::env::var("FEEDSIGN_ZO_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |p| p.get()))
}

/// Worker threads for a chunk-parallel noise op over `n` elements: 1 when
/// inside a [`serial_zone`] or below [`PAR_MIN_ELEMS`], else
/// [`worker_threads`].
pub fn noise_threads(n: usize) -> usize {
    if n < PAR_MIN_ELEMS {
        return 1;
    }
    worker_threads()
}

/// The one scoped chunked-spawn driver behind every chunk-parallel
/// fan-out in the crate: the noise fill ([`normals_into`]), the SPSA
/// perturb/AXPY drivers in [`crate::simkit::zo`], and the net
/// simulator's per-link draw loop (`net`).  Spawns one scoped worker per
/// item — callers pre-chunk their workload into the desired worker count
/// — and joins in spawn order; `f` receives `(item_index, item)`.
///
/// The driver adds no policy of its own: exactness comes from the
/// *items* being independent pure functions of their index (counter-
/// space purity for the noise ops, keyed draws for the net simulator),
/// so any chunking reproduces the sequential walk bit-identically.
/// These three used to be copy-shaped `thread::scope` loops; the
/// ROADMAP flagged the dedup for when a fourth user appeared.
pub fn scoped_spawn<I, F>(items: I, f: F)
where
    I: IntoIterator,
    I::Item: Send,
    F: Fn(usize, I::Item) + Sync,
{
    std::thread::scope(|s| {
        for (i, item) in items.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || f(i, item));
        }
    });
}

/// Per-worker chunk length for an even split of `n` over `threads`,
/// rounded up to a whole Philox lane so only the final chunk can end
/// mid-lane.
pub fn chunk_size(n: usize, threads: usize) -> usize {
    let per = n.div_ceil(threads.max(1));
    (per.div_ceil(4) * 4).max(4)
}

/// Allocate-and-fill convenience for [`normals_into`].
pub fn normals_vec(seed: u32, n: usize) -> Vec<f32> {
    let mut v = vec![0.0; n];
    normals_into(seed, &mut v);
    v
}

/// A stateful convenience RNG over the Philox stream, for everything that
/// is *not* the shared direction (data generation, client seed sampling,
/// Dirichlet partitioning, attack noise).  Each call consumes counter
/// lanes from a private, very high counter region (bit 31 set) so it can
/// never collide with direction streams, which use counters < 2^31.
#[derive(Debug, Clone)]
pub struct Rng {
    seed: u32,
    ctr: u32,
    /// buffered words from the last philox block
    buf: [u32; 4],
    buf_used: usize,
}

impl Rng {
    /// Create a stream from `(seed, stream)`; different streams are
    /// statistically independent (they perturb the key).
    pub fn new(seed: u32, stream: u32) -> Self {
        Rng {
            seed: seed ^ stream.wrapping_mul(PHILOX_W1),
            ctr: 0x8000_0000,
            buf: [0; 4],
            buf_used: 4,
        }
    }

    /// Next raw u32 word.
    pub fn next_u32(&mut self) -> u32 {
        if self.buf_used == 4 {
            self.buf = philox4x32(self.seed, self.ctr);
            self.ctr = self.ctr.wrapping_add(1);
            self.buf_used = 0;
        }
        let w = self.buf[self.buf_used];
        self.buf_used += 1;
        w
    }

    /// Uniform f32 in (0, 1).
    pub fn uniform(&mut self) -> f32 {
        u32_to_unit(self.next_u32())
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f32 {
        let (u1, u2) = (self.uniform(), self.uniform());
        box_muller(u1, u2).0
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang (alpha > 0), used by the
    /// Dirichlet partitioner.
    pub fn gamma(&mut self, alpha: f32) -> f32 {
        if alpha < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(alpha + 1.0);
            let u = self.uniform();
            return g * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x * x * x * x
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Dirichlet(alpha * 1_k) sample of length `k`.
    pub fn dirichlet(&mut self, alpha: f32, k: usize) -> Vec<f32> {
        let mut g: Vec<f32> = (0..k).map(|_| self.gamma(alpha).max(1e-30)).collect();
        let s: f32 = g.iter().sum();
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

/// Build the initial flat parameter vector from manifest-style segment
/// descriptions, matching `python compile.model.init_params`: weights are
/// `std * z(seed*65536 + segment_index)`, layernorm gains are 1, biases 0.
pub fn init_flat_params(
    segments: &[(String, Vec<usize>, f32)],
    padded_size: usize,
    seed: u32,
) -> Vec<f32> {
    let mut w = Vec::with_capacity(padded_size);
    for (idx, (_, shape, std)) in segments.iter().enumerate() {
        let n: usize = shape.iter().product();
        if *std == 1.0 && shape.len() == 1 {
            w.extend(std::iter::repeat(1.0f32).take(n));
        } else if *std == 0.0 {
            w.extend(std::iter::repeat(0.0f32).take(n));
        } else {
            // fill the segment in place: the span walker regenerates any
            // ragged tail lane itself, so no lane-padded scratch vector
            // is needed, and scaling in place keeps the exact
            // `z * std` bits of the old copy-out
            let at = w.len();
            w.resize(at + n, 0.0);
            normals_into(seed.wrapping_mul(65536).wrapping_add(idx as u32), &mut w[at..]);
            for v in &mut w[at..] {
                *v *= std;
            }
        }
    }
    w.resize(padded_size, 0.0);
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn philox_known_structure() {
        // distinct counters give distinct words
        let a = philox4x32(0, 0);
        let b = philox4x32(0, 1);
        assert_ne!(a, b);
        // distinct seeds give distinct words
        let c = philox4x32(1, 0);
        assert_ne!(a, c);
    }

    #[test]
    fn philox_deterministic() {
        assert_eq!(philox4x32(42, 7), philox4x32(42, 7));
    }

    #[test]
    fn tile_parse_accepts_counts_and_aliases() {
        assert_eq!(parse_tile("4096"), Some(4096));
        assert_eq!(parse_tile(" 1 "), Some(1));
        assert_eq!(parse_tile("0"), Some(DEFAULT_TILE_ELEMS));
        assert_eq!(parse_tile("auto"), Some(DEFAULT_TILE_ELEMS));
        assert_eq!(parse_tile("default"), Some(DEFAULT_TILE_ELEMS));
        assert_eq!(parse_tile("nope"), None);
        assert_eq!(parse_tile("-3"), None);
    }

    #[test]
    fn round_seed_stays_in_the_31_bit_direction_space() {
        // below the MSB the masked derivation is the identity — the
        // bugfix is a no-op for every realistic round count
        for t in [0u64, 1, 1000, (1 << 31) - 1] {
            assert_eq!(round_direction_seed(t), t as u32);
        }
        // at and past the boundary the MSB is cleared, never set
        for t in [1u64 << 31, (1 << 31) + 5, u32::MAX as u64, (1 << 40) + 3] {
            let s = round_direction_seed(t);
            assert_eq!(s & !DIRECTION_MASK, 0, "MSB leaked for t={t}");
            assert_eq!(s, (t as u32) & DIRECTION_MASK);
        }
    }

    #[test]
    fn unit_interval_log_safe() {
        assert!(u32_to_unit(0) > 0.0);
        assert!(u32_to_unit(u32::MAX) <= 1.0);
        // never zero anywhere in the low range either
        for x in [1u32, 255, 256, 1 << 20] {
            assert!(u32_to_unit(x) > 0.0);
        }
    }

    #[test]
    fn normals_moments() {
        let z = normals_vec(123, 1 << 16);
        let mean: f32 = z.iter().sum::<f32>() / z.len() as f32;
        let var: f32 = z.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / z.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn normals_into_matches_normals4_tiling() {
        let v = normals_vec(9, 10); // non-multiple-of-4 tail
        let head = normals4(9, 0);
        let mid = normals4(9, 1);
        let tail = normals4(9, 2);
        assert_eq!(&v[..4], &head);
        assert_eq!(&v[4..8], &mid);
        assert_eq!(&v[8..10], &tail[..2]);
    }

    #[test]
    fn rng_streams_independent() {
        let mut a = Rng::new(1, 0);
        let mut b = Rng::new(1, 1);
        let xa: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let xb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn rng_below_bounds() {
        let mut r = Rng::new(3, 0);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn gamma_positive_and_mean() {
        let mut r = Rng::new(5, 0);
        let n = 20_000;
        let alpha = 2.5f32;
        let mut sum = 0.0;
        for _ in 0..n {
            let g = r.gamma(alpha);
            assert!(g > 0.0);
            sum += g;
        }
        let mean = sum / n as f32;
        assert!((mean - alpha).abs() < 0.1, "gamma mean {mean}");
    }

    #[test]
    fn gamma_small_alpha() {
        let mut r = Rng::new(6, 0);
        for _ in 0..1000 {
            let g = r.gamma(0.3);
            assert!(g.is_finite() && g >= 0.0);
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(7, 0);
        for &alpha in &[0.1f32, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 8);
            let s: f32 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(d.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_behaviour() {
        // small alpha -> spiky; large alpha -> uniform-ish
        let mut r = Rng::new(8, 0);
        let spiky = r.dirichlet(0.05, 10);
        let flat = r.dirichlet(100.0, 10);
        let max_spiky = spiky.iter().cloned().fold(0.0, f32::max);
        let max_flat = flat.iter().cloned().fold(0.0, f32::max);
        assert!(max_spiky > 0.5, "spiky {max_spiky}");
        assert!(max_flat < 0.2, "flat {max_flat}");
    }

    #[test]
    fn init_flat_params_layout() {
        let segs = vec![
            ("w".to_string(), vec![4, 8], 0.02f32),
            ("gain".to_string(), vec![8], 1.0),
            ("bias".to_string(), vec![8], 0.0),
        ];
        let w = init_flat_params(&segs, 64, 0);
        assert_eq!(w.len(), 64);
        assert!(w[..32].iter().any(|&v| v != 0.0));
        assert!(w[32..40].iter().all(|&v| v == 1.0));
        assert!(w[40..48].iter().all(|&v| v == 0.0));
        assert!(w[48..].iter().all(|&v| v == 0.0)); // pad tail
    }

    #[test]
    fn init_flat_params_fills_segments_in_place_bitwise() {
        // regression for the lane-padded scratch allocation: ragged
        // (n % 4 != 0) weight segments must hold exactly std * z bits,
        // with no padding spill into the next segment
        let segs = vec![
            ("w0".to_string(), vec![3, 3], 0.02f32), // n = 9, ragged
            ("gain".to_string(), vec![5], 1.0),
            ("w1".to_string(), vec![7], 0.5), // ragged again, odd offset
            ("bias".to_string(), vec![4], 0.0),
        ];
        let w = init_flat_params(&segs, 32, 3);
        assert_eq!(w.len(), 32);
        let z0 = normals_vec(3u32.wrapping_mul(65536), 9);
        for (a, b) in w[..9].iter().zip(&z0) {
            assert_eq!(a.to_bits(), (b * 0.02f32).to_bits());
        }
        assert!(w[9..14].iter().all(|&v| v == 1.0));
        let z2 = normals_vec(3u32.wrapping_mul(65536).wrapping_add(2), 7);
        for (a, b) in w[14..21].iter().zip(&z2) {
            assert_eq!(a.to_bits(), (b * 0.5f32).to_bits());
        }
        assert!(w[21..].iter().all(|&v| v == 0.0), "bias + pad tail");
    }

    #[test]
    fn simd_width_parse_table() {
        for s in ["off", "scalar", "0", "1", " OFF "] {
            assert_eq!(SimdWidth::parse(s), Some(SimdWidth::Scalar), "{s:?}");
        }
        assert_eq!(SimdWidth::parse("4"), Some(SimdWidth::W4));
        for s in ["8", "on", "wide", "ON"] {
            assert_eq!(SimdWidth::parse(s), Some(SimdWidth::W8), "{s:?}");
        }
        assert_eq!(SimdWidth::parse("16"), Some(SimdWidth::W16));
        assert_eq!(SimdWidth::parse("512"), None);
        assert_eq!(SimdWidth::parse(""), None);
        for w in SimdWidth::ALL {
            assert!(w.lanes() <= MAX_LANES);
        }
        assert!(SimdWidth::ALL.contains(&simd_width()));
    }

    #[test]
    fn wide_widths_match_scalar_stream_bitwise() {
        // the tentpole invariant: every dispatch width emits the same
        // f32 stream bit-for-bit at arbitrary offsets and ragged tails
        crate::util::proptest_lite::check("wide vs scalar normal stream", |g| {
            let seed = g.u32() & 0x7FFF_FFFF;
            let start = g.usize_in(0, 200);
            let len = g.usize_in(1, 300);
            let mut scalar = vec![0.0f32; len];
            normals_into_span_w(seed, start, &mut scalar, SimdWidth::Scalar);
            for width in [SimdWidth::W4, SimdWidth::W8, SimdWidth::W16] {
                let mut wide = vec![0.0f32; len];
                normals_into_span_w(seed, start, &mut wide, width);
                for (i, (a, b)) in scalar.iter().zip(&wide).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{width:?} diverged at {i} (seed {seed}, start {start}, len {len})"
                    );
                }
            }
        });
    }

    #[test]
    fn wide_walker_covers_every_element_exactly_once() {
        // the wide walker's head/body/tail must tile the span: offsets
        // chosen to exercise mid-lane heads, whole-block bodies and
        // every ragged tail length around a block boundary
        for width in SimdWidth::ALL {
            let block = 4 * width.lanes();
            for start in [0usize, 1, 2, 3, 5] {
                for len in [1usize, 3, block - 1, block, block + 1, 3 * block + 2] {
                    let mut hits = vec![0u8; len];
                    for_each_span_w(77, start, len, width, |i, z| {
                        for j in 0..z.len() {
                            hits[i + j] += 1;
                        }
                    });
                    assert!(
                        hits.iter().all(|&h| h == 1),
                        "{width:?}: start {start} len {len} coverage {hits:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn span_fill_matches_full_stream_at_any_offset() {
        let full = normals_vec(21, 64);
        for start in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 30, 61] {
            let len = 64 - start;
            let mut span = vec![0.0f32; len];
            normals_into_span(21, start, &mut span);
            assert_eq!(&span, &full[start..], "offset {start}");
        }
    }

    #[test]
    fn parallel_fill_bit_identical_to_sequential() {
        let n = PAR_MIN_ELEMS + 37; // crosses the parallel threshold, ragged tail
        let mut seq = vec![0.0f32; n];
        normals_into_span(33, 0, &mut seq);
        let mut par = vec![0.0f32; n];
        normals_into(33, &mut par);
        assert_eq!(seq, par);
    }

    #[test]
    fn scoped_spawn_joins_all_items_in_index_order() {
        let mut out = vec![0usize; 9];
        scoped_spawn(out.chunks_mut(2), |i, c| {
            for (j, v) in c.iter_mut().enumerate() {
                *v = i * 2 + j + 1;
            }
        });
        assert_eq!(out, (1..=9).collect::<Vec<_>>());
        // empty workloads are a no-op
        scoped_spawn(std::iter::empty::<&mut [usize]>(), |_, _| {});
    }

    #[test]
    fn serial_zone_forces_single_thread() {
        let _guard = serial_zone();
        assert_eq!(noise_threads(PAR_MIN_ELEMS * 4), 1);
        drop(_guard);
        assert!(noise_threads(4) == 1, "tiny fills stay sequential");
    }

    #[test]
    fn chunk_size_lane_aligned_and_covers() {
        for (n, t) in [(100usize, 3usize), (1 << 20, 7), (17, 16), (4, 1)] {
            let c = chunk_size(n, t);
            assert_eq!(c % 4, 0, "chunk must end on a lane boundary");
            assert!(c * t >= n, "chunks must cover the vector");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11, 0);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
