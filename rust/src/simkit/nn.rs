//! Native model substrate: a decoder-only transformer LM and a linear-probe
//! classifier, both over **flat parameter vectors**, with hand-written
//! backprop for the first-order FedSGD baseline.
//!
//! The transformer mirrors `python/compile/model.py` exactly — same segment
//! layout (the manifest's `segments` list round-trips through
//! [`ModelCfg::segments`]), same layernorm/GeLU/attention formulation — so
//! checkpoints and orbits are interchangeable between the PJRT engine and
//! this substrate at the semantic level.  The linear probe is the paper's
//! "ViT last-layer FFT" analogue (Table 3/9, Figs 2–4): a frozen featurizer
//! lives in [`crate::data::vision`], only the classifier head trains.

use super::ops;
use crate::data::Batch;

/// Architecture hyperparameters, mirroring `compile.model.ModelConfig`.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelCfg {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
}

pub const PAD_MULTIPLE: usize = 1024;

impl ModelCfg {
    pub fn new(vocab: usize, d_model: usize, n_layers: usize, n_heads: usize, seq_len: usize) -> Self {
        assert!(d_model % n_heads == 0, "heads must divide d_model");
        ModelCfg { vocab, d_model, n_layers, n_heads, seq_len }
    }

    /// A very small config for tests and fast benches.
    pub fn test_tiny() -> Self {
        ModelCfg::new(32, 16, 2, 2, 8)
    }

    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// `(name, shape, init_std)` per parameter segment, in flat order —
    /// byte-for-byte the layout `compile.model.ModelConfig.segments` emits.
    pub fn segments(&self) -> Vec<(String, Vec<usize>, f32)> {
        let (d, f, v, t) = (self.d_model, self.d_ff(), self.vocab, self.seq_len);
        let w_std = 0.02f32;
        let mut segs: Vec<(String, Vec<usize>, f32)> = vec![
            ("embed".into(), vec![v, d], w_std),
            ("pos".into(), vec![t, d], w_std),
        ];
        for l in 0..self.n_layers {
            let p = format!("layer{l}.");
            segs.extend([
                (format!("{p}ln1_gain"), vec![d], 1.0),
                (format!("{p}ln1_bias"), vec![d], 0.0),
                (format!("{p}w_qkv"), vec![d, 3 * d], w_std),
                (format!("{p}b_qkv"), vec![3 * d], 0.0),
                (format!("{p}w_attn_out"), vec![d, d], w_std),
                (format!("{p}b_attn_out"), vec![d], 0.0),
                (format!("{p}ln2_gain"), vec![d], 1.0),
                (format!("{p}ln2_bias"), vec![d], 0.0),
                (format!("{p}w_mlp_in"), vec![d, f], w_std),
                (format!("{p}b_mlp_in"), vec![f], 0.0),
                (format!("{p}w_mlp_out"), vec![f, d], w_std),
                (format!("{p}b_mlp_out"), vec![d], 0.0),
            ]);
        }
        segs.push(("lnf_gain".into(), vec![d], 1.0));
        segs.push(("lnf_bias".into(), vec![d], 0.0));
        segs
    }

    pub fn n_params(&self) -> usize {
        self.segments().iter().map(|(_, s, _)| s.iter().product::<usize>()).sum()
    }

    pub fn padded_size(&self) -> usize {
        (self.n_params() + PAD_MULTIPLE - 1) / PAD_MULTIPLE * PAD_MULTIPLE
    }
}

/// Byte offsets of each segment inside the flat vector.
#[derive(Debug, Clone)]
pub struct Layout {
    pub offsets: Vec<(String, usize, usize)>, // (name, offset, len)
}

impl Layout {
    pub fn of(cfg: &ModelCfg) -> Self {
        let mut offsets = Vec::new();
        let mut off = 0usize;
        for (name, shape, _) in cfg.segments() {
            let n: usize = shape.iter().product();
            offsets.push((name, off, n));
            off += n;
        }
        Layout { offsets }
    }

    pub fn get<'w>(&self, w: &'w [f32], name: &str) -> &'w [f32] {
        let (_, off, len) = self
            .offsets
            .iter()
            .find(|(n, _, _)| n == name)
            .unwrap_or_else(|| panic!("unknown segment {name}"));
        &w[*off..off + len]
    }

    pub fn range(&self, name: &str) -> std::ops::Range<usize> {
        let (_, off, len) = self
            .offsets
            .iter()
            .find(|(n, _, _)| n == name)
            .unwrap_or_else(|| panic!("unknown segment {name}"));
        *off..*off + *len
    }
}

/// Trainable model interface shared by the transformer and linear probe;
/// [`crate::engine::NativeEngine`] adapts it to the federated `Engine`.
pub trait Model: Send {
    /// Flat (padded) parameter vector length.
    fn n_params(&self) -> usize;
    /// Mean loss on a batch.
    fn loss(&mut self, w: &[f32], batch: &Batch) -> f32;
    /// `(mean loss, #correct)` on an eval batch.
    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32);
    /// Loss and full gradient (accumulated into `grad`, which is zeroed here).
    fn loss_and_grad(&mut self, w: &[f32], batch: &Batch, grad: &mut [f32]) -> f32;
    /// Fresh initial parameter vector.
    fn init(&self, seed: u32) -> Vec<f32>;
}

// ---------------------------------------------------------------------------
// Linear probe (vision last-layer FFT analogue)
// ---------------------------------------------------------------------------

/// `logits = x @ W^T + b` over frozen features — the trainable part of the
/// paper's ViT/ResNet last-layer fine-tuning experiments.
pub struct LinearProbe {
    pub dim: usize,
    pub classes: usize,
    probs: Vec<f32>,
}

impl LinearProbe {
    pub fn new(dim: usize, classes: usize) -> Self {
        LinearProbe { dim, classes, probs: Vec::new() }
    }

    pub fn raw_params(&self) -> usize {
        self.classes * self.dim + self.classes
    }

    fn logits(&self, w: &[f32], x: &[f32], rows: usize, out: &mut Vec<f32>) {
        let (c, f) = (self.classes, self.dim);
        out.resize(rows * c, 0.0);
        out.fill(0.0);
        // W stored [C, F] row-major, then bias [C]
        ops::matmul_bt_acc(x, &w[..c * f], out, rows, f, c);
        let bias = &w[c * f..c * f + c];
        for r in 0..rows {
            for (v, &b) in out[r * c..(r + 1) * c].iter_mut().zip(bias) {
                *v += b;
            }
        }
    }
}

impl Model for LinearProbe {
    fn n_params(&self) -> usize {
        (self.raw_params() + PAD_MULTIPLE - 1) / PAD_MULTIPLE * PAD_MULTIPLE
    }

    fn loss(&mut self, w: &[f32], batch: &Batch) -> f32 {
        let Batch::Features { x, y, rows, dim } = batch else {
            panic!("LinearProbe expects feature batches");
        };
        debug_assert_eq!(*dim, self.dim);
        let mut logits = Vec::new();
        self.logits(w, x, *rows, &mut logits);
        self.probs.resize(*rows * self.classes, 0.0);
        ops::cross_entropy(&logits, y, &mut self.probs, *rows, self.classes)
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32) {
        let Batch::Features { x, y, rows, .. } = batch else {
            panic!("LinearProbe expects feature batches");
        };
        let mut logits = Vec::new();
        self.logits(w, x, *rows, &mut logits);
        self.probs.resize(*rows * self.classes, 0.0);
        let loss = ops::cross_entropy(&logits, y, &mut self.probs, *rows, self.classes);
        let mut correct = 0u32;
        for r in 0..*rows {
            let row = &logits[r * self.classes..(r + 1) * self.classes];
            // total under NaN logits: an impaired channel (`net`) can
            // legitimately drive a replica non-finite, and eval must
            // still return a (chance-level) accuracy rather than panic
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .unwrap()
                .0;
            if argmax as u32 == y[r] {
                correct += 1;
            }
        }
        (loss, correct)
    }

    fn loss_and_grad(&mut self, w: &[f32], batch: &Batch, grad: &mut [f32]) -> f32 {
        let Batch::Features { x, y, rows, .. } = batch else {
            panic!("LinearProbe expects feature batches");
        };
        let (c, f) = (self.classes, self.dim);
        let loss = self.loss(w, batch);
        grad.fill(0.0);
        let mut dlogits = vec![0.0; *rows * c];
        ops::cross_entropy_backward(&self.probs, y, &mut dlogits, *rows, c);
        // dW[C,F] = dlogits^T @ x ; db = column sums
        ops::matmul_at_acc(&dlogits, x, &mut grad[..c * f], *rows, c, f);
        for r in 0..*rows {
            for j in 0..c {
                grad[c * f + j] += dlogits[r * c + j];
            }
        }
        loss
    }

    fn init(&self, seed: u32) -> Vec<f32> {
        let mut w = crate::simkit::prng::normals_vec(seed, self.n_params());
        for v in w.iter_mut() {
            *v *= 0.02;
        }
        for v in w[self.raw_params()..].iter_mut() {
            *v = 0.0;
        }
        // zero bias
        let (c, f) = (self.classes, self.dim);
        for v in w[c * f..c * f + c].iter_mut() {
            *v = 0.0;
        }
        w
    }
}

// ---------------------------------------------------------------------------
// Transformer LM
// ---------------------------------------------------------------------------

/// Per-layer activation cache for the backward pass.
#[derive(Default, Clone)]
struct LayerActs {
    x_in: Vec<f32>,      // [bt, d] residual stream entering the layer
    ln1: Vec<f32>,       // [bt, d]
    ln1_stats: Vec<(f32, f32)>,
    qkv: Vec<f32>,       // [bt, 3d]
    attn: Vec<f32>,      // [b, h, t, t] softmax weights
    attn_merged: Vec<f32>, // [bt, d] pre-projection
    x_mid: Vec<f32>,     // [bt, d] residual after attention
    ln2: Vec<f32>,       // [bt, d]
    ln2_stats: Vec<(f32, f32)>,
    mlp_pre: Vec<f32>,   // [bt, f] pre-GeLU
    mlp_h: Vec<f32>,     // [bt, f] post-GeLU
}

/// Decoder-only transformer LM over a flat parameter vector, with cached
/// activations and hand-written backprop.  Scratch buffers are reused
/// across calls so the federated round loop is allocation-free after
/// warmup.
pub struct TransformerSim {
    pub cfg: ModelCfg,
    layout: Layout,
    acts: Vec<LayerActs>,
    xf: Vec<f32>,     // final-LN output [bt, d]
    xf_stats: Vec<(f32, f32)>,
    x_last: Vec<f32>, // pre-final-LN residual
    logits: Vec<f32>, // [bt, v]
    probs: Vec<f32>,
}

impl TransformerSim {
    pub fn new(cfg: ModelCfg) -> Self {
        let layout = Layout::of(&cfg);
        TransformerSim {
            acts: vec![LayerActs::default(); cfg.n_layers],
            layout,
            cfg,
            xf: Vec::new(),
            xf_stats: Vec::new(),
            x_last: Vec::new(),
            logits: Vec::new(),
            probs: Vec::new(),
        }
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Bytes of live activation scratch after the last forward/backward —
    /// the measured basis of the Table 10 memory comparison (inference vs
    /// backprop).  The SPSA probe path needs only these inference
    /// activations; `loss_and_grad` additionally materialises per-layer
    /// gradient buffers of comparable size plus the full dense gradient.
    pub fn activation_bytes(&self) -> usize {
        let f32s = |v: &Vec<f32>| v.capacity() * std::mem::size_of::<f32>();
        let mut total = f32s(&self.xf)
            + f32s(&self.x_last)
            + f32s(&self.logits)
            + f32s(&self.probs)
            + self.xf_stats.capacity() * std::mem::size_of::<(f32, f32)>();
        for a in &self.acts {
            total += f32s(&a.x_in)
                + f32s(&a.ln1)
                + f32s(&a.qkv)
                + f32s(&a.attn)
                + f32s(&a.attn_merged)
                + f32s(&a.x_mid)
                + f32s(&a.ln2)
                + f32s(&a.mlp_pre)
                + f32s(&a.mlp_h)
                + (a.ln1_stats.capacity() + a.ln2_stats.capacity())
                    * std::mem::size_of::<(f32, f32)>();
        }
        total
    }

    fn tokens_of<'b>(&self, batch: &'b Batch) -> (&'b [u32], usize, usize) {
        let Batch::Tokens { data, rows, cols } = batch else {
            panic!("TransformerSim expects token batches");
        };
        assert_eq!(*cols, self.cfg.seq_len + 1, "batch cols must be seq_len+1");
        (data, *rows, *cols)
    }

    /// Forward pass, caching activations; fills `self.logits` ([b*t, v]).
    fn forward(&mut self, w: &[f32], tokens: &[u32], b: usize) {
        let cfg = self.cfg.clone();
        let (d, t, v, f, h) = (cfg.d_model, cfg.seq_len, cfg.vocab, cfg.d_ff(), cfg.n_heads);
        let hd = cfg.head_dim();
        let bt = b * t;
        let embed = self.layout.range("embed");
        let pos = self.layout.range("pos");

        // embedding + positional
        let mut x = vec![0.0f32; bt * d];
        {
            let e = &w[embed.clone()];
            let p = &w[pos.clone()];
            for row in 0..bt {
                let tok = tokens[(row / t) * (t + 1) + row % t] as usize;
                let tpos = row % t;
                for j in 0..d {
                    x[row * d + j] = e[tok * d + j] + p[tpos * d + j];
                }
            }
        }

        let scale = 1.0 / (hd as f32).sqrt();
        for l in 0..cfg.n_layers {
            let pre = format!("layer{l}.");
            let a = &mut self.acts[l];
            a.x_in.clone_from(&x);

            // LN1
            a.ln1.resize(bt * d, 0.0);
            a.ln1_stats.resize(bt, (0.0, 0.0));
            let g1 = self.layout.get(w, &format!("{pre}ln1_gain"));
            let b1 = self.layout.get(w, &format!("{pre}ln1_bias"));
            for r in 0..bt {
                a.ln1_stats[r] = ops::layernorm_row(
                    &a.x_in[r * d..(r + 1) * d],
                    g1,
                    b1,
                    &mut a.ln1[r * d..(r + 1) * d],
                    1e-5,
                );
            }

            // QKV
            a.qkv.resize(bt * 3 * d, 0.0);
            let wqkv = self.layout.get(w, &format!("{pre}w_qkv"));
            let bqkv = self.layout.get(w, &format!("{pre}b_qkv"));
            ops::matmul(&a.ln1, wqkv, &mut a.qkv, bt, d, 3 * d);
            for r in 0..bt {
                for (vv, &bb) in a.qkv[r * 3 * d..(r + 1) * 3 * d].iter_mut().zip(bqkv) {
                    *vv += bb;
                }
            }

            // attention per batch-row and head
            a.attn.resize(b * h * t * t, 0.0);
            a.attn_merged.resize(bt * d, 0.0);
            a.attn_merged.fill(0.0);
            for bi in 0..b {
                for hi in 0..h {
                    let att = &mut a.attn[(bi * h + hi) * t * t..(bi * h + hi + 1) * t * t];
                    // scores (causal)
                    for ti in 0..t {
                        let q = &a.qkv[((bi * t + ti) * 3 * d + hi * hd)..];
                        for tj in 0..t {
                            att[ti * t + tj] = if tj <= ti {
                                let k =
                                    &a.qkv[((bi * t + tj) * 3 * d + d + hi * hd)..];
                                let mut s = 0.0;
                                for u in 0..hd {
                                    s += q[u] * k[u];
                                }
                                s * scale
                            } else {
                                f32::NEG_INFINITY
                            };
                        }
                    }
                    ops::softmax_rows(att, t, t);
                    // out = attn @ V
                    for ti in 0..t {
                        let orow = &mut a.attn_merged
                            [(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + (hi + 1) * hd];
                        for tj in 0..=ti {
                            let aw = att[ti * t + tj];
                            if aw == 0.0 {
                                continue;
                            }
                            let vrow =
                                &a.qkv[((bi * t + tj) * 3 * d + 2 * d + hi * hd)..];
                            for u in 0..hd {
                                orow[u] += aw * vrow[u];
                            }
                        }
                    }
                }
            }

            // output projection + residual
            a.x_mid.resize(bt * d, 0.0);
            let wo = self.layout.get(w, &format!("{pre}w_attn_out"));
            let bo = self.layout.get(w, &format!("{pre}b_attn_out"));
            ops::matmul(&a.attn_merged, wo, &mut a.x_mid, bt, d, d);
            for r in 0..bt {
                for j in 0..d {
                    a.x_mid[r * d + j] += bo[j] + a.x_in[r * d + j];
                }
            }

            // LN2 + MLP + residual
            a.ln2.resize(bt * d, 0.0);
            a.ln2_stats.resize(bt, (0.0, 0.0));
            let g2 = self.layout.get(w, &format!("{pre}ln2_gain"));
            let b2 = self.layout.get(w, &format!("{pre}ln2_bias"));
            for r in 0..bt {
                a.ln2_stats[r] = ops::layernorm_row(
                    &a.x_mid[r * d..(r + 1) * d],
                    g2,
                    b2,
                    &mut a.ln2[r * d..(r + 1) * d],
                    1e-5,
                );
            }
            a.mlp_pre.resize(bt * f, 0.0);
            let wi = self.layout.get(w, &format!("{pre}w_mlp_in"));
            let bi_ = self.layout.get(w, &format!("{pre}b_mlp_in"));
            ops::matmul(&a.ln2, wi, &mut a.mlp_pre, bt, d, f);
            for r in 0..bt {
                for (vv, &bb) in a.mlp_pre[r * f..(r + 1) * f].iter_mut().zip(bi_) {
                    *vv += bb;
                }
            }
            a.mlp_h.resize(bt * f, 0.0);
            for (hh, &p) in a.mlp_h.iter_mut().zip(a.mlp_pre.iter()) {
                *hh = ops::gelu(p);
            }
            let wo2 = self.layout.get(w, &format!("{pre}w_mlp_out"));
            let bo2 = self.layout.get(w, &format!("{pre}b_mlp_out"));
            x.clone_from(&a.x_mid);
            ops::matmul_acc(&a.mlp_h, wo2, &mut x, bt, f, d);
            for r in 0..bt {
                for j in 0..d {
                    x[r * d + j] += bo2[j];
                }
            }
        }

        // final LN + tied head
        self.x_last.clone_from(&x);
        self.xf.resize(bt * d, 0.0);
        self.xf_stats.resize(bt, (0.0, 0.0));
        let gf = self.layout.get(w, "lnf_gain");
        let bf = self.layout.get(w, "lnf_bias");
        for r in 0..bt {
            self.xf_stats[r] = ops::layernorm_row(
                &x[r * d..(r + 1) * d],
                gf,
                bf,
                &mut self.xf[r * d..(r + 1) * d],
                1e-5,
            );
        }
        self.logits.resize(bt * v, 0.0);
        self.logits.fill(0.0);
        let e = &w[embed];
        ops::matmul_bt_acc(&self.xf, e, &mut self.logits, bt, d, v);
    }

    fn targets(tokens: &[u32], b: usize, t: usize) -> Vec<u32> {
        let mut tg = Vec::with_capacity(b * t);
        for bi in 0..b {
            for ti in 0..t {
                tg.push(tokens[bi * (t + 1) + ti + 1]);
            }
        }
        tg
    }
}

impl Model for TransformerSim {
    fn n_params(&self) -> usize {
        self.cfg.padded_size()
    }

    fn loss(&mut self, w: &[f32], batch: &Batch) -> f32 {
        let (tokens, b, _) = self.tokens_of(batch);
        let tokens = tokens.to_vec();
        let t = self.cfg.seq_len;
        self.forward(w, &tokens, b);
        let targets = Self::targets(&tokens, b, t);
        self.probs.resize(b * t * self.cfg.vocab, 0.0);
        ops::cross_entropy(&self.logits, &targets, &mut self.probs, b * t, self.cfg.vocab)
    }

    fn eval(&mut self, w: &[f32], batch: &Batch) -> (f32, u32) {
        let (tokens, b, _) = self.tokens_of(batch);
        let tokens = tokens.to_vec();
        let t = self.cfg.seq_len;
        let v = self.cfg.vocab;
        let loss = self.loss(w, batch);
        // last-position accuracy (classification tasks put the label there)
        let mut correct = 0u32;
        for bi in 0..b {
            let row = &self.logits[(bi * t + t - 1) * v..(bi * t + t) * v];
            // total under NaN logits (see LinearProbe::eval)
            let argmax = row
                .iter()
                .enumerate()
                .max_by(|a, c| a.1.partial_cmp(c.1).unwrap_or(std::cmp::Ordering::Equal))
                .unwrap()
                .0 as u32;
            if argmax == tokens[bi * (t + 1) + t] {
                correct += 1;
            }
        }
        (loss, correct)
    }

    fn loss_and_grad(&mut self, w: &[f32], batch: &Batch, grad: &mut [f32]) -> f32 {
        let (tokens, b, _) = self.tokens_of(batch);
        let tokens = tokens.to_vec();
        let cfg = self.cfg.clone();
        let (d, t, v, f, h) = (cfg.d_model, cfg.seq_len, cfg.vocab, cfg.d_ff(), cfg.n_heads);
        let hd = cfg.head_dim();
        let bt = b * t;
        let scale = 1.0 / (hd as f32).sqrt();

        self.forward(w, &tokens, b);
        let targets = Self::targets(&tokens, b, t);
        self.probs.resize(bt * v, 0.0);
        let loss =
            ops::cross_entropy(&self.logits, &targets, &mut self.probs, bt, v);

        grad.fill(0.0);
        let mut dlogits = vec![0.0f32; bt * v];
        ops::cross_entropy_backward(&self.probs, &targets, &mut dlogits, bt, v);

        // tied head: logits = xf @ E^T
        let embed_r = self.layout.range("embed");
        let mut dxf = vec![0.0f32; bt * d];
        ops::matmul_acc(&dlogits, &w[embed_r.clone()], &mut dxf, bt, v, d);
        ops::matmul_at_acc(&dlogits, &self.xf, &mut grad[embed_r.clone()], bt, v, d);

        // final LN backward
        let mut dx = vec![0.0f32; bt * d];
        {
            let gf = self.layout.get(w, "lnf_gain").to_vec();
            let gr = self.layout.range("lnf_gain");
            let br = self.layout.range("lnf_bias");
            let (gslice, rest) = grad[gr.start..br.end].split_at_mut(gr.len());
            for r in 0..bt {
                let (mean, rstd) = self.xf_stats[r];
                ops::layernorm_row_backward(
                    &self.x_last[r * d..(r + 1) * d],
                    &gf,
                    &dxf[r * d..(r + 1) * d],
                    mean,
                    rstd,
                    &mut dx[r * d..(r + 1) * d],
                    gslice,
                    rest,
                );
            }
        }

        // layers in reverse
        for l in (0..cfg.n_layers).rev() {
            let pre = format!("layer{l}.");
            let a = &self.acts[l];

            // ---- MLP backward: x = x_mid + (gelu(ln2@Wi+bi))@Wo + bo
            let mut dmlp_h = vec![0.0f32; bt * f];
            {
                let wo2 = self.layout.get(w, &format!("{pre}w_mlp_out")).to_vec();
                ops::matmul_bt_acc(&dx, &wo2, &mut dmlp_h, bt, d, f);
                let wr = self.layout.range(format!("{pre}w_mlp_out").as_str());
                ops::matmul_at_acc(&a.mlp_h, &dx, &mut grad[wr], bt, f, d);
                let br = self.layout.range(format!("{pre}b_mlp_out").as_str());
                for r in 0..bt {
                    for j in 0..d {
                        grad[br.start + j] += dx[r * d + j];
                    }
                }
            }
            let mut dmlp_pre = vec![0.0f32; bt * f];
            for i in 0..bt * f {
                dmlp_pre[i] = dmlp_h[i] * ops::gelu_grad(a.mlp_pre[i]);
            }
            let mut dln2 = vec![0.0f32; bt * d];
            {
                let wi = self.layout.get(w, &format!("{pre}w_mlp_in")).to_vec();
                ops::matmul_bt_acc(&dmlp_pre, &wi, &mut dln2, bt, f, d);
                let wr = self.layout.range(format!("{pre}w_mlp_in").as_str());
                ops::matmul_at_acc(&a.ln2, &dmlp_pre, &mut grad[wr], bt, d, f);
                let br = self.layout.range(format!("{pre}b_mlp_in").as_str());
                for r in 0..bt {
                    for j in 0..f {
                        grad[br.start + j] += dmlp_pre[r * f + j];
                    }
                }
            }
            // LN2 backward -> dx_mid ; plus the residual path dx
            let mut dx_mid = dx.clone(); // residual branch
            {
                let g2 = self.layout.get(w, &format!("{pre}ln2_gain")).to_vec();
                let gr = self.layout.range(format!("{pre}ln2_gain").as_str());
                let br = self.layout.range(format!("{pre}ln2_bias").as_str());
                let (gslice, bslice) = grad[gr.start..br.end].split_at_mut(gr.len());
                for r in 0..bt {
                    let (mean, rstd) = a.ln2_stats[r];
                    ops::layernorm_row_backward(
                        &a.x_mid[r * d..(r + 1) * d],
                        &g2,
                        &dln2[r * d..(r + 1) * d],
                        mean,
                        rstd,
                        &mut dx_mid[r * d..(r + 1) * d],
                        gslice,
                        bslice,
                    );
                }
            }

            // ---- attention backward: x_mid = x_in + merged@Wo + bo
            let mut dmerged = vec![0.0f32; bt * d];
            {
                let wo = self.layout.get(w, &format!("{pre}w_attn_out")).to_vec();
                ops::matmul_bt_acc(&dx_mid, &wo, &mut dmerged, bt, d, d);
                let wr = self.layout.range(format!("{pre}w_attn_out").as_str());
                ops::matmul_at_acc(&a.attn_merged, &dx_mid, &mut grad[wr], bt, d, d);
                let br = self.layout.range(format!("{pre}b_attn_out").as_str());
                for r in 0..bt {
                    for j in 0..d {
                        grad[br.start + j] += dx_mid[r * d + j];
                    }
                }
            }

            let mut dqkv = vec![0.0f32; bt * 3 * d];
            for bi in 0..b {
                for hi in 0..h {
                    let att = &a.attn[(bi * h + hi) * t * t..(bi * h + hi + 1) * t * t];
                    // datt[ti,tj] = dmerged[ti] . v[tj]; dv[tj] += att[ti,tj]*dmerged[ti]
                    let mut datt = vec![0.0f32; t * t];
                    for ti in 0..t {
                        let dm = &dmerged
                            [(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + (hi + 1) * hd];
                        for tj in 0..=ti {
                            let vrow =
                                &a.qkv[((bi * t + tj) * 3 * d + 2 * d + hi * hd)..];
                            let mut s = 0.0;
                            for u in 0..hd {
                                s += dm[u] * vrow[u];
                            }
                            datt[ti * t + tj] = s;
                            let aw = att[ti * t + tj];
                            let dvrow = &mut dqkv
                                [((bi * t + tj) * 3 * d + 2 * d + hi * hd)..];
                            for u in 0..hd {
                                dvrow[u] += aw * dm[u];
                            }
                        }
                    }
                    // softmax backward: ds = att * (datt - sum(datt*att))
                    for ti in 0..t {
                        let arow = &att[ti * t..(ti + 1) * t];
                        let drow = &mut datt[ti * t..(ti + 1) * t];
                        let sum: f32 =
                            arow.iter().zip(drow.iter()).map(|(&aa, &dd)| aa * dd).sum();
                        for (dd, &aa) in drow.iter_mut().zip(arow) {
                            *dd = aa * (*dd - sum);
                        }
                    }
                    // dq[ti] += ds[ti,tj]*k[tj]*scale ; dk[tj] += ds[ti,tj]*q[ti]*scale
                    for ti in 0..t {
                        for tj in 0..=ti {
                            let ds = datt[ti * t + tj] * scale;
                            if ds == 0.0 {
                                continue;
                            }
                            for u in 0..hd {
                                let qv = a.qkv[(bi * t + ti) * 3 * d + hi * hd + u];
                                let kv = a.qkv[(bi * t + tj) * 3 * d + d + hi * hd + u];
                                dqkv[(bi * t + ti) * 3 * d + hi * hd + u] += ds * kv;
                                dqkv[(bi * t + tj) * 3 * d + d + hi * hd + u] += ds * qv;
                            }
                        }
                    }
                }
            }

            // qkv = ln1 @ Wqkv + bqkv
            let mut dln1 = vec![0.0f32; bt * d];
            {
                let wqkv = self.layout.get(w, &format!("{pre}w_qkv")).to_vec();
                ops::matmul_bt_acc(&dqkv, &wqkv, &mut dln1, bt, 3 * d, d);
                let wr = self.layout.range(format!("{pre}w_qkv").as_str());
                ops::matmul_at_acc(&a.ln1, &dqkv, &mut grad[wr], bt, d, 3 * d);
                let br = self.layout.range(format!("{pre}b_qkv").as_str());
                for r in 0..bt {
                    for j in 0..3 * d {
                        grad[br.start + j] += dqkv[r * 3 * d + j];
                    }
                }
            }
            // LN1 backward -> dx_in (plus residual dx_mid)
            let mut dx_in = dx_mid.clone();
            {
                let g1 = self.layout.get(w, &format!("{pre}ln1_gain")).to_vec();
                let gr = self.layout.range(format!("{pre}ln1_gain").as_str());
                let br = self.layout.range(format!("{pre}ln1_bias").as_str());
                let (gslice, bslice) = grad[gr.start..br.end].split_at_mut(gr.len());
                for r in 0..bt {
                    let (mean, rstd) = a.ln1_stats[r];
                    ops::layernorm_row_backward(
                        &a.x_in[r * d..(r + 1) * d],
                        &g1,
                        &dln1[r * d..(r + 1) * d],
                        mean,
                        rstd,
                        &mut dx_in[r * d..(r + 1) * d],
                        gslice,
                        bslice,
                    );
                }
            }
            dx = dx_in;
        }

        // embedding + positional gradients
        {
            let er = self.layout.range("embed");
            let pr = self.layout.range("pos");
            for row in 0..bt {
                let tok = tokens[(row / t) * (t + 1) + row % t] as usize;
                let tpos = row % t;
                for j in 0..d {
                    grad[er.start + tok * d + j] += dx[row * d + j];
                    grad[pr.start + tpos * d + j] += dx[row * d + j];
                }
            }
        }
        loss
    }

    fn init(&self, seed: u32) -> Vec<f32> {
        crate::simkit::prng::init_flat_params(
            &self.cfg.segments(),
            self.cfg.padded_size(),
            seed,
        )
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;
    use crate::simkit::prng::Rng;

    fn token_batch(cfg: &ModelCfg, b: usize, seed: u32) -> Batch {
        let mut rng = Rng::new(seed, 0);
        let cols = cfg.seq_len + 1;
        let data: Vec<u32> = (0..b * cols).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch::Tokens { data, rows: b, cols }
    }

    #[test]
    fn segment_layout_matches_param_count() {
        let cfg = ModelCfg::test_tiny();
        let layout = Layout::of(&cfg);
        let (name, off, len) = layout.offsets.last().unwrap().clone();
        assert_eq!(name, "lnf_bias");
        assert_eq!(off + len, cfg.n_params());
    }

    #[test]
    fn initial_loss_near_uniform() {
        let cfg = ModelCfg::test_tiny();
        let mut m = TransformerSim::new(cfg.clone());
        let w = m.init(0);
        let batch = token_batch(&cfg, 4, 1);
        let loss = m.loss(&w, &batch);
        assert!((loss - (cfg.vocab as f32).ln()).abs() < 0.5, "loss {loss}");
    }

    #[test]
    fn loss_deterministic() {
        let cfg = ModelCfg::test_tiny();
        let mut m = TransformerSim::new(cfg.clone());
        let w = m.init(0);
        let batch = token_batch(&cfg, 2, 2);
        assert_eq!(m.loss(&w, &batch), m.loss(&w, &batch));
    }

    #[test]
    fn transformer_grad_matches_finite_diff() {
        let cfg = ModelCfg::new(16, 8, 1, 2, 4);
        let mut m = TransformerSim::new(cfg.clone());
        let w = m.init(0);
        let batch = token_batch(&cfg, 2, 3);
        let mut grad = vec![0.0; w.len()];
        m.loss_and_grad(&w, &batch, &mut grad);
        // probe a spread of parameter indices across segments
        let idxs: Vec<usize> = (0..cfg.n_params()).step_by(cfg.n_params() / 23).collect();
        let mut checked = 0;
        for &i in &idxs {
            let h = 1e-2f32;
            let mut wp = w.clone();
            let mut wm = w.clone();
            wp[i] += h;
            wm[i] -= h;
            let fd = (m.loss(&wp, &batch) - m.loss(&wm, &batch)) / (2.0 * h);
            if fd.abs() < 1e-5 && grad[i].abs() < 1e-5 {
                continue;
            }
            assert!(
                (grad[i] - fd).abs() < 3e-2 * grad[i].abs().max(fd.abs()).max(0.1),
                "param {i}: grad={} fd={fd}",
                grad[i]
            );
            checked += 1;
        }
        assert!(checked > 5, "too few non-trivial finite-diff checks");
    }

    #[test]
    fn sgd_descends() {
        let cfg = ModelCfg::test_tiny();
        let mut m = TransformerSim::new(cfg.clone());
        let mut w = m.init(0);
        let batch = token_batch(&cfg, 4, 4);
        let mut grad = vec![0.0; w.len()];
        let l0 = m.loss_and_grad(&w, &batch, &mut grad);
        let mut last = l0;
        for _ in 0..10 {
            let l = m.loss_and_grad(&w, &batch, &mut grad);
            for (wi, gi) in w.iter_mut().zip(&grad) {
                *wi -= 0.5 * gi;
            }
            last = l;
        }
        assert!(last < l0, "loss did not descend: {l0} -> {last}");
    }

    #[test]
    fn grad_of_pad_region_is_zero() {
        let cfg = ModelCfg::test_tiny();
        let mut m = TransformerSim::new(cfg.clone());
        let w = m.init(0);
        let batch = token_batch(&cfg, 2, 5);
        let mut grad = vec![0.0; w.len()];
        m.loss_and_grad(&w, &batch, &mut grad);
        assert!(grad[cfg.n_params()..].iter().all(|&g| g == 0.0));
    }

    #[test]
    fn eval_counts_bounded() {
        let cfg = ModelCfg::test_tiny();
        let mut m = TransformerSim::new(cfg.clone());
        let w = m.init(0);
        let batch = token_batch(&cfg, 8, 6);
        let (loss, correct) = m.eval(&w, &batch);
        assert!(loss > 0.0);
        assert!(correct <= 8);
    }

    #[test]
    fn linear_probe_grad_matches_finite_diff() {
        let probe_dim = 12;
        let classes = 5;
        let mut m = LinearProbe::new(probe_dim, classes);
        let w = m.init(1);
        let mut rng = Rng::new(7, 0);
        let rows = 6;
        let x: Vec<f32> = (0..rows * probe_dim).map(|_| rng.normal()).collect();
        let y: Vec<u32> = (0..rows).map(|_| rng.below(classes) as u32).collect();
        let batch = Batch::Features { x, y, rows, dim: probe_dim };
        let mut grad = vec![0.0; w.len()];
        m.loss_and_grad(&w, &batch, &mut grad);
        for i in (0..m.raw_params()).step_by(7) {
            let h = 1e-2f32;
            let mut wp = w.clone();
            let mut wm = w.clone();
            wp[i] += h;
            wm[i] -= h;
            let fd = (m.loss(&wp, &batch) - m.loss(&wm, &batch)) / (2.0 * h);
            assert!((grad[i] - fd).abs() < 1e-2, "i={i} {} vs {fd}", grad[i]);
        }
    }

    #[test]
    fn linear_probe_learns_separable_data() {
        let dim = 8;
        let classes = 3;
        let mut m = LinearProbe::new(dim, classes);
        let mut w = m.init(0);
        let mut rng = Rng::new(9, 0);
        let rows = 64;
        let mut x = vec![0.0f32; rows * dim];
        let mut y = vec![0u32; rows];
        for r in 0..rows {
            let c = rng.below(classes);
            y[r] = c as u32;
            for j in 0..dim {
                x[r * dim + j] = rng.normal() * 0.3 + if j == c { 3.0 } else { 0.0 };
            }
        }
        let batch = Batch::Features { x, y, rows, dim };
        let mut grad = vec![0.0; w.len()];
        for _ in 0..60 {
            m.loss_and_grad(&w, &batch, &mut grad);
            for (wi, gi) in w.iter_mut().zip(&grad) {
                *wi -= 0.5 * gi;
            }
        }
        let (_, correct) = m.eval(&w, &batch);
        assert!(correct as usize > rows * 9 / 10, "correct={correct}/{rows}");
    }
}
