//! Branch-free `f32` transcendentals for the Philox→normal hot loop.
//!
//! `perf_hotpath` showed the Box–Muller stage — not the integer Philox
//! rounds — dominating normal generation: libm `ln`/`sin`/`cos` are
//! scalar calls the compiler cannot vectorize across counter lanes.
//! This module replaces them with polynomial kernels whose entire body
//! is straight-line IEEE-754 arithmetic (compares compile to selects),
//! so LLVM auto-vectorizes the per-lane loops in
//! [`crate::simkit::prng`]'s wide walker — and, critically, the *same*
//! scalar functions run on the scalar fallback path, which is what makes
//! the f32 normal stream **bit-identical across dispatch widths by
//! construction** (Rust float arithmetic is strict IEEE with no
//! reassociation; evaluating the identical expression tree per element
//! yields identical bits whether the loop runs 1 or W lanes at a time).
//!
//! Domain contracts are narrow on purpose — inputs come from
//! [`crate::simkit::prng::u32_to_unit`], which lands in `[2^-25, 1]`:
//! positive, normal, finite.  No NaN/inf/denormal handling exists or is
//! needed.  Accuracy (validated against double precision over the full
//! u32 uniform domain): `ln_pos` ≤ 1e-6 absolute, `sincos_2pi` ≤ 1.1e-7,
//! full Box–Muller pipeline ≤ 7.3e-7 — well inside the 1e-5 band the
//! manifest pins rust-vs-XLA normals to.
//!
//! `ln_pos` is the musl `logf` algorithm (bit-trick range reduction to
//! `[√2/2, √2)` + a degree-4 rational remainder).  `sincos_2pi`
//! evaluates `sin/cos(2πu)` directly from the *unit* argument: the
//! quadrant index comes from `4u` (a power-of-two multiply, exact), and
//! the residual `f = 4u - j` is exact by the Sterbenz lemma, so the
//! quadrant identity is applied with zero range-reduction rounding —
//! the classic weakness of `sin(2π·u)` at large multiples of π/2 never
//! arises.  The in-quadrant polynomials are the cephes `sinf`/`cosf`
//! minimax fits on `|t| ≤ π/4`.

/// musl `logf` constants: `log(2)` split hi/lo and the remainder
/// polynomial coefficients (`Lg1..Lg4`).
const LN2_HI: f32 = f32::from_bits(0x3F31_7180); // 0.69313812256
const LN2_LO: f32 = f32::from_bits(0x3717_F7D1); // 9.0580006e-6
const LG1: f32 = f32::from_bits(0x3F2A_AAAA); // 0xaaaaaa·2^-24 ≈ 0.66666663
const LG2: f32 = f32::from_bits(0x3ECC_CE13); // 0xccce13·2^-25 ≈ 0.40000972
const LG3: f32 = f32::from_bits(0x3E91_E9EE); // 0x91e9ee·2^-25 ≈ 0.28498787
const LG4: f32 = f32::from_bits(0x3E78_9E26); // 0xf89e26·2^-26 ≈ 0.24279079

/// cephes `sinf`/`cosf` minimax coefficients on `|t| ≤ π/4`.
const S1: f32 = f32::from_bits(0xBE2A_AAA3); // -1.6666655e-1
const S2: f32 = f32::from_bits(0x3C08_839E); // 8.3321609e-3
const S3: f32 = f32::from_bits(0xB94C_A1F9); // -1.9515296e-4
const C1: f32 = f32::from_bits(0x3D2A_AAA5); // 4.1666646e-2
const C2: f32 = f32::from_bits(0xBAB6_061A); // -1.3887316e-3
const C3: f32 = f32::from_bits(0x37CC_F5CE); // 2.4433157e-5

/// Natural log of a **positive normal finite** `x` — the musl `logf`
/// core without the special-case branches (the uniform stream can never
/// produce zero, negatives, denormals, inf or NaN).  Exact at
/// `x = 1.0` (returns `0.0`), which keeps `box_muller(1.0, ·)` finite.
#[inline(always)]
pub fn ln_pos(x: f32) -> f32 {
    // reduce: x = 2^k · m with m ∈ [√2/2, √2); 0x3f3504f3 is √2/2's
    // bit pattern, so adding (1.0 - √2/2) in bit space re-centres the
    // mantissa band before extracting the exponent
    let ix = x.to_bits().wrapping_add(0x3F80_0000 - 0x3F35_04F3);
    let k = (ix >> 23) as i32 - 0x7F;
    let ix = (ix & 0x007F_FFFF) + 0x3F35_04F3;
    let m = f32::from_bits(ix);
    let f = m - 1.0;
    let s = f / (2.0 + f);
    let z = s * s;
    let w = z * z;
    let t1 = w * (LG2 + w * LG4);
    let t2 = z * (LG1 + w * LG3);
    let r = t2 + t1;
    let hfsq = 0.5 * f * f;
    let dk = k as f32;
    // association order is musl's (left-to-right): changing it changes
    // the emitted bits, and the bit-across-widths invariant pins them
    s * (hfsq + r) + dk * LN2_LO - hfsq + f + dk * LN2_HI
}

/// `(sin(2πu), cos(2πu))` for `u ∈ [0, 1]`.
///
/// Quadrant reduction is exact: `x4 = 4·u` multiplies by a power of two
/// (no rounding), the truncating cast picks the nearest quadrant index
/// `j` (truncation equals floor for the non-negative `x4 + 0.5`, and it
/// vectorizes on baseline x86-64 where `f32::floor` does not), and
/// `f = x4 - j` is exact by Sterbenz.  The residual `|f| ≤ 0.5` maps to
/// `|t| ≤ π/4` for the cephes polynomials; the quadrant selects below
/// compile to flag-free conditional moves.
#[inline(always)]
pub fn sincos_2pi(u: f32) -> (f32, f32) {
    let x4 = 4.0 * u;
    let j = (x4 + 0.5) as i32;
    let fq = x4 - j as f32;
    let t = fq * std::f32::consts::FRAC_PI_2;
    let z = t * t;
    let sin_t = t + t * z * (S1 + z * (S2 + z * S3));
    let cos_t = (1.0 - 0.5 * z) + z * z * (C1 + z * (C2 + z * C3));
    // sin(π(j+f)/2), cos(π(j+f)/2) by quadrant: odd j swaps the pair,
    // bit 1 of j (resp. j+1) negates the sine (resp. cosine)
    let swap = (j & 1) != 0;
    let s = if swap { cos_t } else { sin_t };
    let c = if swap { sin_t } else { cos_t };
    let s = if (j & 2) != 0 { -s } else { s };
    let c = if ((j + 1) & 2) != 0 { -c } else { c };
    (s, c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_pos_tracks_libm_over_the_uniform_domain() {
        // sweep the whole (0, 1] uniform range plus magnitudes above 1
        // (Rng::gamma feeds ln through the same uniform map)
        let mut worst = 0.0f64;
        for i in 0..20_000u32 {
            let x = (i + 1) as f32 / 20_000.0;
            let err = (ln_pos(x) as f64 - (x as f64).ln()).abs();
            worst = worst.max(err);
        }
        for x in [2.0f32.powi(-25), 2.0f32.powi(-24), 0.9999999, 1.0] {
            let err = (ln_pos(x) as f64 - (x as f64).ln()).abs();
            worst = worst.max(err);
        }
        assert!(worst < 2e-6, "ln_pos worst abs error {worst}");
    }

    #[test]
    fn ln_pos_exact_at_one() {
        assert_eq!(ln_pos(1.0).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn sincos_2pi_tracks_libm() {
        let mut worst = 0.0f64;
        for i in 0..=20_000u32 {
            let u = i as f32 / 20_000.0;
            let (s, c) = sincos_2pi(u);
            let th = 2.0 * std::f64::consts::PI * u as f64;
            worst = worst.max((s as f64 - th.sin()).abs());
            worst = worst.max((c as f64 - th.cos()).abs());
        }
        assert!(worst < 5e-7, "sincos_2pi worst abs error {worst}");
    }

    #[test]
    fn sincos_2pi_exact_at_quadrant_boundaries() {
        // the exact reduction makes whole quadrants land exactly where
        // a naive sin(2π·u) accumulates π-rounding error
        assert_eq!(sincos_2pi(0.0), (0.0, 1.0));
        assert_eq!(sincos_2pi(0.25), (1.0, 0.0));
        assert_eq!(sincos_2pi(0.5), (0.0, -1.0));
        assert_eq!(sincos_2pi(0.75), (-1.0, 0.0));
        assert_eq!(sincos_2pi(1.0), (0.0, 1.0));
    }

    #[test]
    fn sincos_2pi_pythagorean_identity() {
        for i in 0..4_096u32 {
            let u = i as f32 / 4_096.0;
            let (s, c) = sincos_2pi(u);
            let norm = (s as f64).mul_add(s as f64, (c as f64) * c as f64);
            assert!((norm - 1.0).abs() < 1e-6, "u={u}: s²+c²={norm}");
        }
    }
}
