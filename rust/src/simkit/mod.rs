//! Pure-rust NN simulation substrate.
//!
//! The PJRT engine proves the three-layer AOT architecture; this module
//! exists because the paper's evaluation needs 10^4–10^5 federated steps ×
//! K clients × 5 repeats, which per-call PJRT dispatch cannot sustain on
//! this testbed.  It provides bit-compatible shared randomness
//! ([`prng`], pinned to the Pallas kernel), dense kernels ([`ops`]),
//! models with hand-written backprop ([`nn`]) and the chunk-parallel SPSA
//! AXPYs ([`zo`]).  `coordinator` code is engine-agnostic: the same
//! session runs on either backend through [`crate::engine::Engine`].

pub mod fastmath;
pub mod nn;
pub mod ops;
pub mod prng;
pub mod zo;
