//! `feedsign` — the launcher CLI for the FeedSign federated runtime.
//!
//! Subcommands:
//! * `run --config exp.toml [--csv out.csv] [--orbit out.orbit]`
//! * `quickstart [--rounds N]` — built-in 5-client FeedSign demo
//! * `init-config` — print a starter TOML
//! * `theory [--eta X] [--p-max P]` — Theorem 3.11 rate/floor table
//! * `replay --input orbit.bin --n-params D`
//! * `list-tasks`
//! * `dp-tradeoff [--clients K]`
//! * `pjrt-info [--variant tiny]` — load an AOT variant, smoke one probe

mod cli;

use anyhow::{Context, Result};
use cli::Args;
use feedsign::config::{self, ExperimentConfig};
use feedsign::coordinator::Algorithm;
use feedsign::data::tasks;
use feedsign::{dp, metrics, orbit, runtime, theory};
use std::path::{Path, PathBuf};

const USAGE: &str = "\
feedsign — FeedSign federated fine-tuning runtime

USAGE: feedsign <command> [options]

COMMANDS:
  run          --config exp.toml [--csv curve.csv] [--orbit run.orbit]
               [--threads N] [--participation full|fraction:F|bernoulli:P]
               [--catchup off|replay|rebroadcast|pool]
               [--seed-pool K] [--channel ideal|ber:P|drop:P]
               [--link mobile|wifi|iot|mixed]
               [--deadline T] [--channel-seed S] [--replica-cache N]
               [--shards N] [--tile ELEMS] [--tile-budget BYTES]
               [--trace-out trace.json|trace.jsonl]
               [--metrics-out metrics.prom] [--quiet]
  quickstart   [--rounds 2000] [--threads N] [--participation SPEC]
               [--catchup SPEC] [--seed-pool K] [--channel SPEC]
               [--link SPEC]
               [--deadline T] [--channel-seed S] [--replica-cache N]
               [--shards N] [--tile ELEMS] [--tile-budget BYTES]
               [--trace-out PATH] [--metrics-out PATH]
               [--quiet]
  init-config
  theory       [--eta 1e-3] [--p-max 0.1]
  replay       --input run.orbit --n-params D
  list-tasks
  dp-tradeoff  [--clients 5]
  pjrt-info    [--variant tiny]
";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    init_logging(&args);
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "quickstart" => cmd_quickstart(&args),
        "init-config" => {
            print!("{}", config::quickstart().to_toml());
            Ok(())
        }
        "theory" => cmd_theory(&args),
        "replay" => cmd_replay(&args),
        "list-tasks" => cmd_list_tasks(),
        "dp-tradeoff" => cmd_dp_tradeoff(&args),
        "pjrt-info" => cmd_pjrt_info(&args),
        "" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprint!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Resolve the CLI logging policy: `--quiet` pins errors-only; otherwise
/// an explicit `FEEDSIGN_LOG` wins, and the interactive default is `info`
/// so progress lines stay visible.
fn init_logging(args: &Args) {
    use feedsign::obs::log::{set_level, Level};
    if args.has_flag("quiet") {
        set_level(Level::Error);
    } else if std::env::var("FEEDSIGN_LOG")
        .ok()
        .and_then(|v| Level::parse(&v))
        .is_none()
    {
        set_level(Level::Info);
    }
}

/// Whether any `--trace-out` / `--metrics-out` observability export was
/// requested (both require tracing enabled before the run starts).
fn wants_observability(args: &Args) -> bool {
    args.str("trace-out").is_some() || args.str("metrics-out").is_some()
}

/// Write the requested observability artifacts for a finished run: the
/// Chrome-trace/JSONL span file and/or the Prometheus text metrics built
/// from the run result plus the trace-derived rollups.
fn write_observability(
    args: &Args,
    session: &feedsign::coordinator::Session,
    result: &metrics::RunResult,
) -> Result<()> {
    if let Some(path) = args.str("trace-out") {
        feedsign::obs::export::write_trace(Path::new(path), session.tracer.events())
            .with_context(|| format!("writing {path}"))?;
        feedsign::log_info!(
            "trace written to {path} ({} events)",
            session.tracer.events().len()
        );
    }
    if let Some(path) = args.str("metrics-out") {
        let mut reg = feedsign::obs::Registry::default();
        reg.absorb_result(result);
        reg.absorb_events(session.tracer.events());
        std::fs::write(path, reg.to_prometheus()).with_context(|| format!("writing {path}"))?;
        feedsign::log_info!("metrics written to {path}");
    }
    Ok(())
}

/// Apply the round-engine CLI overrides (`--threads`, `--participation`,
/// `--catchup`, `--seed-pool`, `--channel`, `--link`, `--deadline`,
/// `--channel-seed`, `--replica-cache`, `--shards`, `--tile`,
/// `--tile-budget`) on top of a loaded config, re-validating afterwards.
fn apply_engine_overrides(cfg: &mut ExperimentConfig, args: &Args) -> Result<()> {
    if let Some(t) = args.str("threads") {
        cfg.threads = t.parse().context("parsing --threads")?;
    }
    if let Some(p) = args.str("participation") {
        cfg.participation = p.to_string();
    }
    if let Some(c) = args.str("catchup") {
        cfg.catchup = c.to_string();
    }
    if let Some(k) = args.str("seed-pool") {
        cfg.seed_pool = k.parse().context("parsing --seed-pool")?;
    }
    if let Some(c) = args.str("channel") {
        cfg.channel = c.to_string();
    }
    if let Some(l) = args.str("link") {
        cfg.link = l.to_string();
    }
    if let Some(d) = args.str("deadline") {
        cfg.deadline = d.parse().context("parsing --deadline")?;
    }
    if let Some(s) = args.str("channel-seed") {
        cfg.channel_seed = s.parse().context("parsing --channel-seed")?;
    }
    if let Some(r) = args.str("replica-cache") {
        cfg.replica_cache = r.parse().context("parsing --replica-cache")?;
    }
    if let Some(n) = args.str("shards") {
        cfg.shards = n.parse().context("parsing --shards")?;
    }
    if let Some(t) = args.str("tile") {
        cfg.tile = t.parse().context("parsing --tile")?;
    }
    if let Some(b) = args.str("tile-budget") {
        cfg.tile_budget = b.parse().context("parsing --tile-budget")?;
    }
    cfg.validate()
}

fn cmd_run(args: &Args) -> Result<()> {
    let mut cfg = ExperimentConfig::load(&PathBuf::from(args.req("config")?))?;
    apply_engine_overrides(&mut cfg, args)?;
    feedsign::log_info!("experiment: {}", cfg.name);
    let mut session = cfg.build_session()?;
    if wants_observability(args) {
        session.enable_tracing();
    }
    let result = session.run();
    print_result(&result);
    if let Some(path) = args.str("csv") {
        std::fs::write(path, result.to_csv()).with_context(|| format!("writing {path}"))?;
        feedsign::log_info!("curve written to {path}");
    }
    if let Some(path) = args.str("orbit") {
        let bytes = orbit::encode(&session.orbit);
        std::fs::write(path, &bytes).with_context(|| format!("writing {path}"))?;
        feedsign::log_info!(
            "orbit written to {path} ({} bytes for {} steps)",
            bytes.len(),
            session.orbit.len()
        );
    }
    write_observability(args, &session, &result)?;
    Ok(())
}

fn cmd_quickstart(args: &Args) -> Result<()> {
    let mut cfg = config::quickstart();
    cfg.rounds = args.u64_or("rounds", 2000)?;
    apply_engine_overrides(&mut cfg, args)?;
    let mut session = cfg.build_session()?;
    if wants_observability(args) {
        session.enable_tracing();
    }
    let result = session.run();
    print_result(&result);
    write_observability(args, &session, &result)?;
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let eta = args.f32_or("eta", 1e-3)?;
    let p_max = args.f32_or("p-max", 0.1)?;
    let c = theory::Constants::example();
    println!("constants: {c:?}\n");
    let rows = [
        ("fedsgd", theory::fedsgd(&c, eta)),
        ("zo-fedsgd", theory::zo_fedsgd(&c, eta)),
        ("feedsign", theory::feedsign(&c, eta, p_max)),
        ("fs-pool-4k", theory::feedsign_pool(&c, eta, p_max, 4096)),
    ];
    println!("{:>10} | {:>12} | {:>12} | {:>12}", "method", "rate A", "floor C", "C/A");
    for (name, rf) in rows {
        println!(
            "{name:>10} | {:>12.3e} | {:>12.3e} | {:>12.3e}",
            rf.a,
            rf.c,
            rf.error_floor()
        );
    }
    println!("\nzeta (Eq. 14) = {:.2}", theory::zeta(&c));
    Ok(())
}

fn cmd_replay(args: &Args) -> Result<()> {
    let bytes = std::fs::read(args.req("input")?)?;
    let n_params: usize = args.req("n-params")?.parse()?;
    let orb = orbit::decode(&bytes)?;
    println!(
        "orbit: algorithm={} steps={} eta={} init_seed={}",
        orb.algorithm,
        orb.len(),
        orb.eta,
        orb.init_seed
    );
    let report = orbit::storage_report(&orb, n_params);
    println!(
        "storage: {} bytes vs {} byte checkpoint ({}x smaller)",
        report.orbit_bytes, report.checkpoint_bytes, report.ratio as u64
    );
    let mut w = vec![0.0f32; n_params];
    orb.replay(&mut w);
    let checksum: f64 = w.iter().map(|v| *v as f64).sum();
    println!("replayed delta checksum: {checksum:.6}");
    Ok(())
}

fn cmd_list_tasks() -> Result<()> {
    println!("LM tasks (Table 2/4/5 columns):");
    for t in tasks::OPT_TASKS {
        println!("  {:16} classes={} signal_rate={:.2}", t.name, t.n_classes, t.signal_rate);
    }
    println!("few-shot tasks (Table 7/13 columns):");
    for t in tasks::ROBERTA_TASKS {
        println!("  {:16} classes={} signal_rate={:.2}", t.name, t.n_classes, t.signal_rate);
    }
    println!("vision tasks (Table 3/9): synth-cifar10, synth-cifar100");
    Ok(())
}

fn cmd_dp_tradeoff(args: &Args) -> Result<()> {
    let clients = args.usize_or("clients", 5)?;
    let eps = [0.0f32, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0];
    println!("{:>8} | {:>12} | {:>12}", "epsilon", "P(sign err)", "rate factor");
    for p in dp::tradeoff_curve(clients, &eps) {
        println!("{:>8.1} | {:>12.4} | {:>12.4}", p.epsilon, p.sign_error, p.rate_factor);
    }
    Ok(())
}

fn cmd_pjrt_info(args: &Args) -> Result<()> {
    let variant = args.str("variant").unwrap_or("tiny");
    let dir = runtime::artifacts_dir();
    feedsign::log_info!("loading variant {variant:?} from {}", dir.display());
    let model = runtime::PjrtModel::load(&dir, variant)?;
    println!(
        "platform: {} | params: {} (padded {})",
        model.platform(),
        model.entry.n_params,
        model.entry.padded_size
    );
    let w = model.init_params(0);
    let cols = model.entry.seq_len + 1;
    let rows = model.entry.batch_probe;
    let data: Vec<u32> = (0..rows * cols).map(|i| (i % model.entry.vocab) as u32).collect();
    let batch = feedsign::data::Batch::Tokens { data, rows, cols };
    let t0 = std::time::Instant::now();
    let p = model.spsa_probe(&w, &batch, 0, 1e-3)?;
    println!("spsa_probe(seed=0) = {p:.6} in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    Ok(())
}

fn print_result(result: &metrics::RunResult) {
    feedsign::log_info!(
        "\n{}: {} rounds in {:.1}s",
        result.algorithm,
        result.rounds,
        result.wall_s
    );
    feedsign::log_info!(
        "final: loss {:.4}, accuracy {:.1}% (best {:.1}%)",
        result.final_loss,
        result.final_acc * 100.0,
        result.best_acc() * 100.0
    );
    feedsign::log_info!(
        "communication: {} bits up, {} bits down ({} msgs)",
        result.ledger.uplink_bits,
        result.ledger.downlink_bits,
        result.ledger.uplink_msgs + result.ledger.downlink_msgs
    );
    if result.replica.clients > 0 {
        feedsign::log_info!(
            "replica plane: peak {} B for K={} (dense layout: {} B), \
             {} owned, {} canonical commits",
            result.replica.peak_bytes,
            result.replica.clients,
            result.replica.dense_bytes,
            result.replica.owned_clients,
            result.replica.canonical_commits
        );
    }
    if result.probe.probes > 0 {
        feedsign::log_info!(
            "probe batching: {} probes in {} canonical passes \
             (unbatched: {}; {} engine fallbacks)",
            result.probe.probes,
            result.probe.canonical_passes,
            result.probe.unbatched_passes(),
            result.probe.fallback_probes
        );
    }
    if result.shard.shards > 0 {
        feedsign::log_info!(
            "sharded coordinator: {} shards, {} vote merges ({} bits, \
             coordinator-internal), {} rounds planned ahead of stragglers",
            result.shard.shards,
            result.shard.merges,
            result.shard.merge_bits,
            result.shard.rounds_overlapped
        );
    }
    if result.net != feedsign::net::NetStats::default() {
        feedsign::log_info!(
            "channel: {} dropped, {} corrupted ({} bits flipped), \
             {} straggler exclusions, {:.1}s virtual wall-clock",
            result.net.dropped_msgs,
            result.net.corrupted_msgs,
            result.net.flipped_bits,
            result.net.stragglers,
            result.net.virtual_s
        );
    }
    let algo = Algorithm::parse(&result.algorithm);
    if matches!(algo, Some(Algorithm::FeedSign | Algorithm::DpFeedSign { .. })) {
        let lm = feedsign::comm::LinkModel::mobile();
        feedsign::log_info!(
            "projected comm time on a mobile link: {:.3}s total",
            lm.seconds(&result.ledger)
        );
    }
}
