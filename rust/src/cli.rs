//! Tiny argv parser (the offline environment has no clap): positional
//! subcommand + `--flag value` / `--flag` options.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  An option is `--name value`; a bare `--name`
    /// followed by another option or the end is a boolean flag.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with("--") {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument {arg:?}");
            };
            match it.peek() {
                Some(next) if !next.starts_with("--") => {
                    out.options.insert(name.to_string(), it.next().unwrap().clone());
                }
                _ => out.flags.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.str(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.str(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.str(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.str(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parse_command_and_options() {
        let a = Args::parse(&argv("run --config exp.toml --rounds 100 --verbose")).unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.str("config"), Some("exp.toml"));
        assert_eq!(a.u64_or("rounds", 0).unwrap(), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&argv("run")).unwrap();
        assert!(a.req("config").is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&argv("quickstart")).unwrap();
        assert_eq!(a.u64_or("rounds", 2000).unwrap(), 2000);
        assert_eq!(a.f32_or("eta", 1e-3).unwrap(), 1e-3);
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(&argv("run stray")).is_err());
    }
}
