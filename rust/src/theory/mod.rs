//! Convergence-theory calculators: the Theorem 3.11 rate/floor constants
//! for FedSGD, ZO-FedSGD and FeedSign, Proposition D.5's Byzantine
//! sign-reversing composition, Lemma 3.9's low-effective-rank factor zeta
//! (Eq. 14) and Proposition E.2's p_{t,e} bound.
//!
//! These let tests and benches confront measured convergence curves with
//! the paper's predictions (same rate *shape*, error-floor ordering under
//! heterogeneity) and power the `feedsign theory` CLI subcommand.

/// Problem constants shared by the three bounds.
#[derive(Debug, Clone, Copy)]
pub struct Constants {
    /// L-smoothness (Assumption 3.4)
    pub l_smooth: f32,
    /// PL constant delta (Assumption 3.7)
    pub delta: f32,
    /// local effective rank r (Assumption 3.5)
    pub r_eff: f32,
    /// model dimension d
    pub dim: f32,
    /// SPSA samples n (paper uses 1)
    pub n_spsa: f32,
    /// batch-noise factor c_g and sigma_g (Assumption 3.6)
    pub c_g: f32,
    pub sigma_g: f32,
    /// heterogeneity factors c_h and sigma_h (Assumption 3.6)
    pub c_h: f32,
    pub sigma_h: f32,
    /// gradient-variance/optimality-gap coupling alpha (Eq. 11)
    pub alpha: f32,
    /// clients K, batch size B
    pub k: f32,
    pub b: f32,
}

impl Constants {
    /// A plausible fine-tuning regime for sanity tests.
    pub fn example() -> Self {
        Constants {
            l_smooth: 10.0,
            delta: 0.5,
            r_eff: 20.0,
            dim: 1e6,
            n_spsa: 1.0,
            c_g: 1.2,
            sigma_g: 1.0,
            c_h: 0.2,
            sigma_h: 0.5,
            alpha: 1.0,
            k: 5.0,
            b: 16.0,
        }
    }
}

/// Eq. 14: zeta = (d r + d - 2) / (n (d + 2)) + 1 — the dimension-free
/// variance inflation of SPSA under low effective rank.
pub fn zeta(c: &Constants) -> f32 {
    (c.dim * c.r_eff + c.dim - 2.0) / (c.n_spsa * (c.dim + 2.0)) + 1.0
}

/// Per-step contraction rate A and floor constant C; the error floor is
/// `C / A` and the loss gap shrinks as `(1 - A)^t` (Theorem 3.11).
#[derive(Debug, Clone, Copy)]
pub struct RateFloor {
    pub a: f32,
    pub c: f32,
}

impl RateFloor {
    pub fn error_floor(&self) -> f32 {
        if self.a <= 0.0 {
            f32::INFINITY
        } else {
            self.c / self.a
        }
    }

    /// Steps to bring the gap within `eps` of the floor from `gap0`
    /// (Eq. 15 solved for t).
    pub fn steps_to(&self, gap0: f32, eps: f32) -> f32 {
        if self.a <= 0.0 || self.a >= 1.0 {
            return f32::INFINITY;
        }
        ((gap0 - self.error_floor()).max(eps) / eps).ln() / -(1.0f32 - self.a).ln()
    }

    pub fn converges(&self) -> bool {
        self.a > 0.0 && self.a < 1.0
    }
}

/// Eq. 16 — FedSGD (first-order).
pub fn fedsgd(c: &Constants, eta: f32) -> RateFloor {
    let a = 2.0 * c.delta * eta
        - c.l_smooth * c.delta * eta * eta * c.c_g * (1.0 + c.c_h)
        - c.l_smooth * c.alpha * c.sigma_g * c.sigma_g * eta * eta / (c.k * c.b);
    let cc = c.l_smooth * c.c_g * c.sigma_h * c.sigma_h * eta * eta / 2.0;
    RateFloor { a, c: cc }
}

/// Eq. 17 — ZO-FedSGD: FedSGD with every quadratic term inflated by zeta.
pub fn zo_fedsgd(c: &Constants, eta: f32) -> RateFloor {
    let z = zeta(c);
    let a = 2.0 * c.delta * eta
        - c.l_smooth * z * c.delta * eta * eta * c.c_g * (1.0 + c.c_h)
        - c.l_smooth * z * c.alpha * c.sigma_g * c.sigma_g * eta * eta / (c.k * c.b);
    let cc = c.l_smooth * z * c.c_g * c.sigma_h * c.sigma_h * eta * eta / 2.0;
    RateFloor { a, c: cc }
}

/// Eq. 18 — FeedSign: rate scales with (1 - 2 p_t); the floor `L r eta²/2`
/// is **independent of the heterogeneity constants** (Remark 3.13).
pub fn feedsign(c: &Constants, eta: f32, p_max: f32) -> RateFloor {
    let a = 2.0 * (2.0 / std::f32::consts::PI).sqrt() * c.delta * eta * eta
        * (1.0 - 2.0 * p_max);
    let cc = c.l_smooth * c.r_eff * eta * eta / 2.0;
    RateFloor { a, c: cc }
}

/// FeedSign over a restricted seed space of K pooled directions
/// (FedKSeed-style, `seed_pool` mode).  Restricting the per-round
/// direction to a size-K candidate set leaves the rate shape intact but
/// raises the error floor by the pool's approximation penalty: the best
/// direction available in a finite pool misaligns with the true gradient
/// by an extra factor that shrinks as the pool grows relative to the
/// loss landscape's effective rank.  We model the floor as
/// `feedsign floor x (1 + r_eff / K)` — exact FeedSign as `K -> inf`,
/// and a pool much smaller than the effective rank pays roughly the
/// rank-to-pool ratio.  (FedKSeed's Theorem 1 gives the same qualitative
/// picture: convergence is retained for any finite K, with a constant
/// that decays in K.)
pub fn feedsign_pool(c: &Constants, eta: f32, p_max: f32, pool_k: usize) -> RateFloor {
    assert!(pool_k >= 2, "a seed pool needs at least 2 candidates");
    let base = feedsign(c, eta, p_max);
    RateFloor { a: base.a, c: base.c * (1.0 + c.r_eff / pool_k as f32) }
}

/// Proposition D.5: overall sign-reversing probability under Byzantine
/// fraction `p_b` and inherent batch error `p_e`.
pub fn byzantine_sign_error(p_e: f32, p_b: f32) -> f32 {
    p_e + p_b - p_e * p_b
}

/// Proposition E.2 / Assumption 3.8: for a symmetric batch-projection
/// distribution, the inherent sign-reversing probability is `F(0) <= 1/2`.
/// Model the projection as N(true_proj, noise²) and return p_{t,e}.
pub fn inherent_sign_error(true_projection: f32, batch_noise: f32) -> f32 {
    if batch_noise <= 0.0 {
        return if true_projection == 0.0 { 0.5 } else { 0.0 };
    }
    // P(sign flip) = P(p_hat has opposite sign) = Phi(-|mu|/sigma)
    let zscore = true_projection.abs() / batch_noise;
    0.5 * erfc_approx(zscore / std::f32::consts::SQRT_2)
}

/// Abramowitz–Stegun complementary error function (max err ~1.5e-7).
fn erfc_approx(x: f32) -> f32 {
    let t = 1.0 / (1.0 + 0.327_591_1 * x.abs());
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    let e = poly * (-x * x).exp();
    if x >= 0.0 {
        e
    } else {
        2.0 - e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeta_tracks_effective_rank_not_dim() {
        // Lemma 3.9's point: zeta ~ O(r), not O(d)
        let mut c = Constants::example();
        c.dim = 1e6;
        c.r_eff = 20.0;
        let z1 = zeta(&c);
        c.dim = 1e9;
        let z2 = zeta(&c);
        assert!((z1 - z2).abs() / z1 < 0.01, "zeta should be ~dim-free");
        assert!(z1 > c.r_eff * 0.9 && z1 < c.r_eff * 1.3, "zeta ~ r: {z1}");
    }

    #[test]
    fn fedsgd_converges_small_eta() {
        let c = Constants::example();
        let rf = fedsgd(&c, 1e-3);
        assert!(rf.converges(), "A = {}", rf.a);
        // floor = C/A shrinks linearly with eta for FO
        assert!(rf.error_floor() < fedsgd(&c, 1e-2).error_floor());
        assert!(rf.error_floor() < 0.01);
    }

    #[test]
    fn zo_needs_smaller_eta_than_fo() {
        // with zeta >> 1, the eta window for A > 0 shrinks by ~zeta
        let c = Constants::example();
        let eta = 0.05;
        let fo = fedsgd(&c, eta);
        let zo = zo_fedsgd(&c, eta);
        assert!(fo.a > 0.0);
        assert!(zo.a < 0.0, "ZO should diverge at FO's eta (zeta inflation)");
        assert!(zo_fedsgd(&c, eta / zeta(&c)).a > 0.0);
    }

    #[test]
    fn feedsign_floor_heterogeneity_independent() {
        // Remark 3.13: crank sigma_h/c_g — ZO-FedSGD floor grows, FeedSign floor fixed
        let mut c = Constants::example();
        let eta = 1e-3;
        let fs1 = feedsign(&c, eta, 0.2);
        let zo1 = zo_fedsgd(&c, eta);
        c.sigma_h = 10.0;
        c.c_g = 3.0;
        let fs2 = feedsign(&c, eta, 0.2);
        let zo2 = zo_fedsgd(&c, eta);
        assert_eq!(fs1.c, fs2.c, "FeedSign floor must ignore heterogeneity");
        assert!(zo2.c > zo1.c * 10.0, "ZO floor must grow with heterogeneity");
    }

    #[test]
    fn feedsign_rate_dies_at_p_half()
    {
        let c = Constants::example();
        assert!(feedsign(&c, 1e-3, 0.5).a.abs() < 1e-12);
        assert!(feedsign(&c, 1e-3, 0.2).a > 0.0);
        assert!(feedsign(&c, 1e-3, 0.6).a < 0.0, "adversarial majority diverges");
    }

    #[test]
    fn pool_floor_decays_monotonically_to_feedsign() {
        let c = Constants::example();
        let eta = 1e-3;
        let unrestricted = feedsign(&c, eta, 0.1);
        let mut last = f32::INFINITY;
        for k in [2usize, 16, 256, 4096, 1 << 20] {
            let rf = feedsign_pool(&c, eta, 0.1, k);
            assert_eq!(rf.a, unrestricted.a, "restricting seeds must not change the rate");
            assert!(rf.c > unrestricted.c, "a finite pool pays an approximation penalty");
            assert!(rf.c < last, "the penalty must shrink as K grows");
            last = rf.c;
        }
        // asymptote: a huge pool is within 1% of unrestricted FeedSign
        let big = feedsign_pool(&c, eta, 0.1, 1 << 20);
        assert!((big.c - unrestricted.c) / unrestricted.c < 0.01);
        // a pool far below the effective rank pays at least ~2x
        let tiny = feedsign_pool(&c, eta, 0.1, 2);
        assert!(tiny.c > unrestricted.c * 2.0);
    }

    #[test]
    #[should_panic(expected = "at least 2")]
    fn pool_theory_rejects_degenerate_pool() {
        feedsign_pool(&Constants::example(), 1e-3, 0.1, 1);
    }

    #[test]
    fn byzantine_composition_props() {
        // no byzantine: p = p_e; all byzantine: p = 1 - ... monotone in both
        assert_eq!(byzantine_sign_error(0.3, 0.0), 0.3);
        assert_eq!(byzantine_sign_error(0.0, 0.2), 0.2);
        let p1 = byzantine_sign_error(0.3, 0.2);
        assert!(p1 > 0.3 && p1 < 0.5);
        // exceeding 1/2 once p_b crosses the honest margin
        assert!(byzantine_sign_error(0.3, 0.4) > 0.5);
    }

    #[test]
    fn inherent_error_bounded_half() {
        for &(p, s) in &[(0.5f32, 1.0f32), (0.1, 2.0), (3.0, 0.5), (0.0, 1.0)] {
            let e = inherent_sign_error(p, s);
            assert!((0.0..=0.5 + 1e-6).contains(&e), "p_e = {e}");
        }
        // strong signal: near 0; no signal: exactly 1/2
        assert!(inherent_sign_error(5.0, 0.1) < 1e-6);
        assert!((inherent_sign_error(0.0, 1.0) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn steps_to_epsilon_decreasing_in_rate() {
        let c = Constants::example();
        let fast = fedsgd(&c, 2e-3);
        let slow = fedsgd(&c, 5e-4);
        assert!(fast.steps_to(1.0, 1e-2) < slow.steps_to(1.0, 1e-2));
        // FeedSign: per Eq. 18 both A and C scale with eta^2, so the floor
        // is eta-independent but the *rate* still improves with eta
        assert!(feedsign(&c, 2e-3, 0.1).a > feedsign(&c, 1e-3, 0.1).a);
    }

    #[test]
    fn erfc_sane() {
        assert!((erfc_approx(0.0) - 1.0).abs() < 1e-5);
        assert!(erfc_approx(3.0) < 1e-4);
        assert!((erfc_approx(-3.0) - 2.0).abs() < 1e-4);
    }
}
