//! Client data sharding: iid and Dirichlet(beta) label-skew partitioning
//! (Vahidian et al., the scheme Table 4 / Figure 2 use).
//!
//! For each class `c`, a proportion vector `p ~ Dirichlet(beta * 1_K)`
//! splits that class's samples across the K clients; small `beta` gives
//! each client a spiky class marginal (high heterogeneity, large sigma_h in
//! Assumption 3.6), large `beta` approaches iid.

use super::{Dataset, Shard};
use crate::simkit::prng::Rng;

/// How client shards are drawn from the training set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Uniform random split.
    Iid,
    /// Dirichlet label-skew with concentration `beta`.
    Dirichlet { beta: f32 },
}

/// Split `data` into `k` shards.  Every sample is assigned to exactly one
/// client; empty shards are repaired by stealing one sample from the
/// largest shard (a K-client round needs K non-empty shards).
pub fn split(data: &Dataset, k: usize, how: Partition, seed: u32) -> Vec<Shard> {
    assert!(k >= 1);
    let n = data.len();
    assert!(n >= k, "fewer samples than clients");
    let mut rng = Rng::new(seed, 0xD1E7);
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); k];

    match how {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut idx);
            for (i, s) in idx.into_iter().enumerate() {
                buckets[i % k].push(s);
            }
        }
        Partition::Dirichlet { beta } => {
            assert!(beta > 0.0, "beta must be positive");
            let n_classes = data.n_classes().max(1);
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
            for i in 0..n {
                by_class[data.label(i) as usize].push(i);
            }
            for class_samples in by_class.iter_mut() {
                if class_samples.is_empty() {
                    continue;
                }
                rng.shuffle(class_samples);
                let props = rng.dirichlet(beta, k);
                // cumulative allocation preserving total count
                let m = class_samples.len();
                let mut cuts = vec![0usize; k + 1];
                let mut acc = 0.0f32;
                for (j, p) in props.iter().enumerate() {
                    acc += p;
                    cuts[j + 1] = ((acc * m as f32).round() as usize).min(m);
                }
                cuts[k] = m;
                for j in 0..k {
                    buckets[j].extend_from_slice(&class_samples[cuts[j]..cuts[j + 1]]);
                }
            }
        }
    }

    // repair empties
    loop {
        let Some(empty) = buckets.iter().position(|b| b.is_empty()) else { break };
        let largest = (0..k)
            .max_by_key(|&j| buckets[j].len())
            .expect("k >= 1");
        assert!(buckets[largest].len() > 1, "cannot repair empty shard");
        let moved = buckets[largest].pop().unwrap();
        buckets[empty].push(moved);
    }

    buckets.into_iter().map(Shard::new).collect()
}

/// Heterogeneity diagnostic: mean total-variation distance between each
/// client's class marginal and the global marginal (0 = iid, ->1 = fully
/// skewed).  Reported alongside Table 4 / Fig 2 results.
pub fn label_skew(data: &Dataset, shards: &[Shard]) -> f32 {
    let n_classes = data.n_classes().max(1);
    let mut global = vec![0.0f32; n_classes];
    for i in 0..data.len() {
        global[data.label(i) as usize] += 1.0;
    }
    let total = data.len() as f32;
    for g in &mut global {
        *g /= total;
    }
    let mut tv_sum = 0.0;
    for shard in shards {
        let mut local = vec![0.0f32; n_classes];
        for &i in &shard.indices {
            local[data.label(i) as usize] += 1.0;
        }
        let m = shard.len().max(1) as f32;
        let tv: f32 = local
            .iter()
            .zip(&global)
            .map(|(l, g)| (l / m - g).abs())
            .sum::<f32>()
            / 2.0;
        tv_sum += tv;
    }
    tv_sum / shards.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vision::{generate, SYNTH_CIFAR10};

    fn dataset() -> Dataset {
        generate(&SYNTH_CIFAR10, 600, 0)
    }

    fn assert_is_partition(n: usize, shards: &[Shard]) {
        let mut seen = vec![false; n];
        for s in shards {
            for &i in &s.indices {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "some sample unassigned");
    }

    #[test]
    fn iid_is_partition_and_balanced() {
        let d = dataset();
        let shards = split(&d, 5, Partition::Iid, 1);
        assert_is_partition(600, &shards);
        for s in &shards {
            assert_eq!(s.len(), 120);
        }
    }

    #[test]
    fn dirichlet_is_partition() {
        let d = dataset();
        for &beta in &[0.1f32, 1.0, 10.0] {
            let shards = split(&d, 7, Partition::Dirichlet { beta }, 2);
            assert_is_partition(600, &shards);
            assert!(shards.iter().all(|s| !s.is_empty()));
        }
    }

    #[test]
    fn beta_controls_skew() {
        let d = dataset();
        let skew_small = label_skew(&d, &split(&d, 5, Partition::Dirichlet { beta: 0.1 }, 3));
        let skew_big = label_skew(&d, &split(&d, 5, Partition::Dirichlet { beta: 100.0 }, 3));
        let skew_iid = label_skew(&d, &split(&d, 5, Partition::Iid, 3));
        assert!(skew_small > skew_big + 0.1, "{skew_small} vs {skew_big}");
        assert!(skew_iid < 0.15, "iid skew {skew_iid}");
    }

    #[test]
    fn deterministic_split() {
        let d = dataset();
        let a = split(&d, 5, Partition::Dirichlet { beta: 0.5 }, 9);
        let b = split(&d, 5, Partition::Dirichlet { beta: 0.5 }, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn many_clients_few_samples() {
        let d = generate(&SYNTH_CIFAR10, 30, 5);
        let shards = split(&d, 25, Partition::Dirichlet { beta: 0.2 }, 4);
        assert_is_partition(30, &shards);
        assert!(shards.iter().all(|s| !s.is_empty()));
    }
}
