//! Template-grammar tiny corpus — the pretraining substrate.
//!
//! Every fine-tuning experiment in the paper starts from a *pretrained*
//! checkpoint (Assumption 3.5's low effective rank is a property of
//! pretrained models).  We manufacture that here: a token corpus generated
//! by a small probabilistic grammar (SUBJ VERB OBJ [ADV] .) with Zipf-ish
//! token reuse, giving the LM real bigram/trigram structure to learn during
//! the FO pretraining stage of `examples/e2e_train.rs` and the bench
//! harnesses.

use super::Dataset;
use crate::simkit::prng::Rng;

/// Sizes of the grammar's word classes (token id ranges are carved out of
/// the model vocabulary in order: PAD, STOP, subjects, verbs, objects,
/// adverbs; everything above is free for task tokens).
#[derive(Debug, Clone)]
pub struct GrammarSpec {
    pub n_subjects: usize,
    pub n_verbs: usize,
    pub n_objects: usize,
    pub n_adverbs: usize,
}

impl Default for GrammarSpec {
    fn default() -> Self {
        GrammarSpec { n_subjects: 12, n_verbs: 10, n_objects: 14, n_adverbs: 6 }
    }
}

pub const TOK_PAD: u32 = 0;
pub const TOK_STOP: u32 = 1;

impl GrammarSpec {
    pub fn n_grammar_tokens(&self) -> usize {
        2 + self.n_subjects + self.n_verbs + self.n_objects + self.n_adverbs
    }

    fn subj(&self, i: usize) -> u32 {
        2 + i as u32
    }
    fn verb(&self, i: usize) -> u32 {
        (2 + self.n_subjects + i) as u32
    }
    fn obj(&self, i: usize) -> u32 {
        (2 + self.n_subjects + self.n_verbs + i) as u32
    }
    fn adv(&self, i: usize) -> u32 {
        (2 + self.n_subjects + self.n_verbs + self.n_objects + i) as u32
    }

    /// Zipf-ish index: favors small indices, giving frequent/rare tokens.
    fn zipf(&self, rng: &mut Rng, n: usize) -> usize {
        let u = rng.uniform();
        ((u * u * n as f32) as usize).min(n - 1)
    }

    /// Emit one sentence.  Verb choice correlates with subject (v = s mod
    /// n_verbs with prob 0.6) so there is predictable structure beyond
    /// unigram frequency.
    fn sentence(&self, rng: &mut Rng, out: &mut Vec<u32>) {
        let s = self.zipf(rng, self.n_subjects);
        out.push(self.subj(s));
        let v = if rng.uniform() < 0.6 {
            s % self.n_verbs
        } else {
            self.zipf(rng, self.n_verbs)
        };
        out.push(self.verb(v));
        let o = if rng.uniform() < 0.5 {
            (s + v) % self.n_objects
        } else {
            self.zipf(rng, self.n_objects)
        };
        out.push(self.obj(o));
        if rng.uniform() < 0.3 {
            out.push(self.adv(self.zipf(rng, self.n_adverbs)));
        }
        out.push(TOK_STOP);
    }
}

/// Generate a pretraining dataset of `n` rows of `seq_len + 1` tokens
/// (contiguous windows over a generated token stream).
pub fn generate(spec: &GrammarSpec, vocab: usize, seq_len: usize, n: usize, seed: u32) -> Dataset {
    assert!(vocab >= spec.n_grammar_tokens(), "vocab too small for grammar");
    let cols = seq_len + 1;
    let mut rng = Rng::new(seed, 0xC0FF_EE);
    let mut stream = Vec::with_capacity(n * cols + 64);
    while stream.len() < n * cols + 1 {
        spec.sentence(&mut rng, &mut stream);
    }
    let mut data = Vec::with_capacity(n * cols);
    for i in 0..n {
        // overlapping windows with stride seq_len keep every transition
        let start = i * seq_len % (stream.len() - cols);
        data.extend_from_slice(&stream[start..start + cols]);
    }
    Dataset::Tokens { data, cols, labels: vec![0; n] }
}

/// Theoretical floor of the next-token loss under this grammar is well
/// below uniform; pretraining success is "loss < `loss_target(vocab)`".
pub fn loss_target(vocab: usize) -> f32 {
    // uniform is ln(V); the grammar is learnable to ~ln(8) on average
    (vocab as f32).ln() * 0.55
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_within_grammar_range() {
        let spec = GrammarSpec::default();
        let d = generate(&spec, 256, 32, 100, 0);
        let Dataset::Tokens { data, .. } = &d else { panic!() };
        assert!(data.iter().all(|&t| (t as usize) < spec.n_grammar_tokens()));
    }

    #[test]
    fn deterministic() {
        let spec = GrammarSpec::default();
        let a = generate(&spec, 256, 16, 50, 1);
        let b = generate(&spec, 256, 16, 50, 1);
        let (Dataset::Tokens { data: da, .. }, Dataset::Tokens { data: db, .. }) = (&a, &b)
        else {
            panic!()
        };
        assert_eq!(da, db);
    }

    #[test]
    fn grammar_structure_present() {
        // after each subject token, a verb token must follow (always)
        let spec = GrammarSpec::default();
        let d = generate(&spec, 256, 64, 200, 2);
        let Dataset::Tokens { data, cols, .. } = &d else { panic!() };
        let subj_end = 2 + spec.n_subjects as u32;
        let verb_end = subj_end + spec.n_verbs as u32;
        let mut checked = 0;
        for row in data.chunks(*cols) {
            for w in row.windows(2) {
                if w[0] >= 2 && w[0] < subj_end {
                    assert!(w[1] >= subj_end && w[1] < verb_end, "subject not followed by verb");
                    checked += 1;
                }
            }
        }
        assert!(checked > 100);
    }

    #[test]
    fn zipf_prefers_small_indices() {
        let spec = GrammarSpec::default();
        let mut rng = Rng::new(3, 0);
        let mut counts = vec![0usize; 10];
        for _ in 0..10_000 {
            counts[spec.zipf(&mut rng, 10)] += 1;
        }
        assert!(counts[0] > counts[9] * 2, "{counts:?}");
    }
}
