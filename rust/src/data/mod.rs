//! Data substrates: synthetic task suite, tiny-corpus generator, frozen
//! vision featurizer and the Dirichlet heterogeneity partitioner.
//!
//! The paper evaluates on GLUE/SuperGLUE tasks (OPT/RoBERTa) and
//! CIFAR-10/100 (ViT/ResNet) — resources this reproduction substitutes with
//! synthetic equivalents that exercise identical code paths (DESIGN.md §4):
//!
//! * [`tasks`] — planted-pattern sequence-classification tasks of graded
//!   difficulty, one per paper task column (`synth-sst2`, `synth-rte`, …);
//! * [`corpus`] — a template-grammar token corpus for LM pretraining (the
//!   "pre-trained checkpoint" every fine-tuning experiment starts from);
//! * [`vision`] — Gaussian-mixture classes behind a frozen random
//!   featurizer (the ViT/ResNet last-layer-FFT analogue);
//! * [`partition`] — Dirichlet(beta) label-skew sharding (Table 4, Fig 2).

pub mod corpus;
pub mod partition;
pub mod tasks;
pub mod vision;

/// A minibatch, engine-agnostic.
#[derive(Debug, Clone)]
pub enum Batch {
    /// LM batch: `rows` sequences of `cols = seq_len + 1` token ids
    /// (inputs ++ next-token targets; the label token sits in the last
    /// column for classification-style tasks).
    Tokens { data: Vec<u32>, rows: usize, cols: usize },
    /// Vision batch: `rows` frozen feature vectors of width `dim` + labels.
    Features { x: Vec<f32>, y: Vec<u32>, rows: usize, dim: usize },
}

impl Batch {
    pub fn rows(&self) -> usize {
        match self {
            Batch::Tokens { rows, .. } | Batch::Features { rows, .. } => *rows,
        }
    }
}

/// An in-memory labelled dataset from which client shards and batches are
/// drawn.  `label_of` powers the Dirichlet partitioner.
#[derive(Debug, Clone)]
pub enum Dataset {
    Tokens {
        /// each sample is one row of `seq_len + 1` token ids
        data: Vec<u32>,
        cols: usize,
        labels: Vec<u32>,
    },
    Features {
        x: Vec<f32>,
        dim: usize,
        labels: Vec<u32>,
    },
}

impl Dataset {
    pub fn len(&self) -> usize {
        match self {
            Dataset::Tokens { labels, .. } | Dataset::Features { labels, .. } => labels.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn label(&self, i: usize) -> u32 {
        match self {
            Dataset::Tokens { labels, .. } | Dataset::Features { labels, .. } => labels[i],
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Dataset::Tokens { labels, .. } | Dataset::Features { labels, .. } => {
                labels.iter().copied().max().map_or(0, |m| m as usize + 1)
            }
        }
    }

    /// Assemble a batch from sample indices.
    pub fn gather(&self, idx: &[usize]) -> Batch {
        match self {
            Dataset::Tokens { data, cols, .. } => {
                let mut out = Vec::with_capacity(idx.len() * cols);
                for &i in idx {
                    out.extend_from_slice(&data[i * cols..(i + 1) * cols]);
                }
                Batch::Tokens { data: out, rows: idx.len(), cols: *cols }
            }
            Dataset::Features { x, dim, labels } => {
                let mut xs = Vec::with_capacity(idx.len() * dim);
                let mut ys = Vec::with_capacity(idx.len());
                for &i in idx {
                    xs.extend_from_slice(&x[i * dim..(i + 1) * dim]);
                    ys.push(labels[i]);
                }
                Batch::Features { x: xs, y: ys, rows: idx.len(), dim: *dim }
            }
        }
    }
}

/// A client's view of its shard: cycles minibatches with a private RNG.
#[derive(Debug, Clone)]
pub struct Shard {
    pub indices: Vec<usize>,
    cursor: usize,
}

impl Shard {
    pub fn new(indices: Vec<usize>) -> Self {
        Shard { indices, cursor: 0 }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next minibatch of `size` samples (wraps around; reshuffles each
    /// epoch with the supplied RNG).
    pub fn next_batch(
        &mut self,
        data: &Dataset,
        size: usize,
        rng: &mut crate::simkit::prng::Rng,
    ) -> Batch {
        assert!(!self.indices.is_empty(), "empty shard");
        let mut pick = Vec::with_capacity(size);
        for _ in 0..size {
            if self.cursor == 0 {
                rng.shuffle(&mut self.indices);
            }
            pick.push(self.indices[self.cursor]);
            self.cursor = (self.cursor + 1) % self.indices.len();
        }
        data.gather(&pick)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::prng::Rng;

    fn toy_dataset() -> Dataset {
        Dataset::Features {
            x: (0..40).map(|i| i as f32).collect(),
            dim: 4,
            labels: vec![0, 1, 0, 1, 2, 2, 0, 1, 2, 0],
        }
    }

    #[test]
    fn dataset_basics() {
        let d = toy_dataset();
        assert_eq!(d.len(), 10);
        assert_eq!(d.n_classes(), 3);
        assert_eq!(d.label(4), 2);
    }

    #[test]
    fn gather_features() {
        let d = toy_dataset();
        let b = d.gather(&[1, 3]);
        let Batch::Features { x, y, rows, dim } = b else { panic!() };
        assert_eq!((rows, dim), (2, 4));
        assert_eq!(x, vec![4.0, 5.0, 6.0, 7.0, 12.0, 13.0, 14.0, 15.0]);
        assert_eq!(y, vec![1, 1]);
    }

    #[test]
    fn gather_tokens() {
        let d = Dataset::Tokens {
            data: (0..12).collect(),
            cols: 4,
            labels: vec![0, 1, 0],
        };
        let b = d.gather(&[2, 0]);
        let Batch::Tokens { data, rows, cols } = b else { panic!() };
        assert_eq!((rows, cols), (2, 4));
        assert_eq!(data, vec![8, 9, 10, 11, 0, 1, 2, 3]);
    }

    #[test]
    fn shard_cycles_all_samples() {
        let d = toy_dataset();
        let mut shard = Shard::new((0..10).collect());
        let mut rng = Rng::new(1, 0);
        let mut seen = std::collections::HashSet::new();
        // one epoch = 10 samples
        for _ in 0..5 {
            let b = shard.next_batch(&d, 2, &mut rng);
            let Batch::Features { x, .. } = b else { panic!() };
            for chunk in x.chunks(4) {
                seen.insert(chunk[0] as usize / 4);
            }
        }
        assert_eq!(seen.len(), 10, "every sample visited exactly once per epoch");
    }

    #[test]
    fn batch_rows_accessor() {
        let d = toy_dataset();
        assert_eq!(d.gather(&[0, 1, 2]).rows(), 3);
    }
}
