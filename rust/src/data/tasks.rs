//! Synthetic sequence-classification task suite — the stand-in for the
//! paper's GLUE/SuperGLUE benchmark columns.
//!
//! Each task plants a class-conditional token signal inside otherwise
//! uniform sequences: class `c` draws a fraction `signal_rate` of its
//! tokens from a class-specific signal set, and the final position carries
//! the label token (`vocab - n_classes + c`), so next-token LM loss and
//! last-position accuracy measure exactly what the paper's prompted
//! classification measures.  Difficulty is graded per task via
//! `signal_rate` (lower = harder) and `n_classes`, chosen so the
//! zero-shot → FO → ZO metric ordering in Tables 2/4/5 has room to show.

use super::Dataset;
use crate::simkit::prng::Rng;

/// Generator parameters for one synthetic task.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Task name (mirrors the paper's task columns, `synth-` prefixed).
    pub name: &'static str,
    pub n_classes: usize,
    /// Fraction of positions carrying class-signal tokens.
    pub signal_rate: f32,
    /// Tokens per class-signal set.
    pub signal_width: usize,
}

impl TaskSpec {
    pub const fn new(name: &'static str, n_classes: usize, signal_rate: f32, signal_width: usize) -> Self {
        TaskSpec { name, n_classes, signal_rate, signal_width }
    }
}

/// The 11 OPT task columns of Table 2/5 (graded difficulty) …
pub const OPT_TASKS: &[TaskSpec] = &[
    TaskSpec::new("synth-sst2", 2, 0.45, 6),
    TaskSpec::new("synth-rte", 2, 0.18, 4),
    TaskSpec::new("synth-cb", 3, 0.30, 4),
    TaskSpec::new("synth-boolq", 2, 0.25, 5),
    TaskSpec::new("synth-wsc", 2, 0.15, 3),
    TaskSpec::new("synth-wic", 2, 0.16, 4),
    TaskSpec::new("synth-multirc", 2, 0.20, 5),
    TaskSpec::new("synth-copa", 2, 0.40, 6),
    TaskSpec::new("synth-record", 4, 0.35, 5),
    TaskSpec::new("synth-squad", 8, 0.40, 4),
    TaskSpec::new("synth-drop", 8, 0.22, 4),
];

/// … and the 6 RoBERTa few-shot columns of Table 7/13.
pub const ROBERTA_TASKS: &[TaskSpec] = &[
    TaskSpec::new("synth-sst2", 2, 0.45, 6),
    TaskSpec::new("synth-sst5", 5, 0.28, 4),
    TaskSpec::new("synth-snli", 3, 0.35, 5),
    TaskSpec::new("synth-mnli", 3, 0.25, 5),
    TaskSpec::new("synth-rte", 2, 0.18, 4),
    TaskSpec::new("synth-trec", 6, 0.40, 5),
];

pub fn find_task(name: &str) -> Option<&'static TaskSpec> {
    OPT_TASKS
        .iter()
        .chain(ROBERTA_TASKS.iter())
        .find(|t| t.name == name)
}

/// Generate `n` samples of a task for a given model shape.
///
/// Layout per sample (`seq_len + 1` ids): `[tok_0 .. tok_{T-2}, SEP, label]`
/// where SEP = `vocab - n_classes - 1` and label tokens occupy the top of
/// the vocabulary.  Signal sets are derived deterministically from
/// `(task, class)` so train/test splits share them.
pub fn generate(
    spec: &TaskSpec,
    vocab: usize,
    seq_len: usize,
    n: usize,
    seed: u32,
) -> Dataset {
    assert!(vocab > spec.n_classes + 8, "vocab too small for task");
    let cols = seq_len + 1;
    let sep = (vocab - spec.n_classes - 1) as u32;
    let label_base = (vocab - spec.n_classes) as u32;
    // content tokens exclude SEP and labels
    let content = vocab - spec.n_classes - 1;

    // deterministic per-class signal token sets
    let mut sig_rng = Rng::new(hash_name(spec.name), 17);
    let signal_sets: Vec<Vec<u32>> = (0..spec.n_classes)
        .map(|_| {
            (0..spec.signal_width)
                .map(|_| sig_rng.below(content) as u32)
                .collect()
        })
        .collect();

    let mut rng = Rng::new(seed, hash_name(spec.name));
    let mut data = Vec::with_capacity(n * cols);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(spec.n_classes);
        labels.push(c as u32);
        for pos in 0..cols {
            if pos == cols - 2 {
                data.push(sep);
            } else if pos == cols - 1 {
                data.push(label_base + c as u32);
            } else if rng.uniform() < spec.signal_rate {
                let set = &signal_sets[c];
                data.push(set[rng.below(set.len())]);
            } else {
                data.push(rng.below(content) as u32);
            }
        }
    }
    Dataset::Tokens { data, cols, labels }
}

fn hash_name(name: &str) -> u32 {
    // FNV-1a, stable across runs
    let mut h = 0x811C_9DC5u32;
    for b in name.as_bytes() {
        h ^= *b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Batch;

    #[test]
    fn task_lookup() {
        assert!(find_task("synth-sst2").is_some());
        assert!(find_task("synth-mnli").is_some());
        assert!(find_task("nope").is_none());
    }

    #[test]
    fn generate_shapes_and_labels() {
        let spec = &OPT_TASKS[0];
        let d = generate(spec, 64, 16, 100, 0);
        assert_eq!(d.len(), 100);
        assert_eq!(d.n_classes(), spec.n_classes);
        let Dataset::Tokens { data, cols, labels } = &d else { panic!() };
        assert_eq!(*cols, 17);
        for (i, &lab) in labels.iter().enumerate() {
            // last column is the label token
            assert_eq!(data[i * cols + cols - 1], 64 - spec.n_classes as u32 + lab);
            // second-to-last is SEP
            assert_eq!(data[i * cols + cols - 2], 64 - spec.n_classes as u32 - 1);
            // content tokens stay below SEP
            for p in 0..cols - 2 {
                assert!(data[i * cols + p] < 64 - spec.n_classes as u32 - 1);
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        let spec = &OPT_TASKS[1];
        let a = generate(spec, 64, 12, 50, 3);
        let b = generate(spec, 64, 12, 50, 3);
        let (Dataset::Tokens { data: da, .. }, Dataset::Tokens { data: db, .. }) = (&a, &b)
        else {
            panic!()
        };
        assert_eq!(da, db);
    }

    #[test]
    fn different_seeds_differ() {
        let spec = &OPT_TASKS[0];
        let a = generate(spec, 64, 12, 50, 1);
        let b = generate(spec, 64, 12, 50, 2);
        let (Dataset::Tokens { data: da, .. }, Dataset::Tokens { data: db, .. }) = (&a, &b)
        else {
            panic!()
        };
        assert_ne!(da, db);
    }

    #[test]
    fn signal_is_learnable_statistic() {
        // class-0 samples must contain class-0 signal tokens far more often
        // than class-1 samples do
        let spec = TaskSpec::new("probe", 2, 0.5, 4);
        let d = generate(&spec, 64, 32, 400, 7);
        let Dataset::Tokens { data, cols, labels } = &d else { panic!() };
        let mut sig_rng = Rng::new(hash_name("probe"), 17);
        let set0: Vec<u32> = (0..4).map(|_| sig_rng.below(64 - 3) as u32).collect();
        let mut hits = [0usize; 2];
        let mut counts = [0usize; 2];
        for i in 0..400 {
            let c = labels[i] as usize;
            counts[c] += cols - 2;
            for p in 0..cols - 2 {
                if set0.contains(&data[i * cols + p]) {
                    hits[c] += 1;
                }
            }
        }
        let r0 = hits[0] as f32 / counts[0] as f32;
        let r1 = hits[1] as f32 / counts[1] as f32;
        assert!(r0 > 2.0 * r1, "signal not planted: {r0} vs {r1}");
    }

    #[test]
    fn all_specs_generate_under_model_vocabs() {
        for spec in OPT_TASKS.iter().chain(ROBERTA_TASKS) {
            let d = generate(spec, 256, 16, 20, 0);
            assert_eq!(d.len(), 20);
            let b = d.gather(&[0, 1]);
            assert!(matches!(b, Batch::Tokens { .. }));
        }
    }
}
