//! Synthetic vision tasks: Gaussian-mixture classes behind a frozen random
//! featurizer — the CIFAR-10/100 + pretrained-ViT/ResNet analogue.
//!
//! Table 3/9 and Figures 2–4 fine-tune only the classifier layer of a
//! pretrained vision model; the frozen backbone is, functionally, a fixed
//! feature map.  We reproduce that regime with `feat = relu(W_frozen @ x)`
//! where `x` are Gaussian-mixture "images": the trainable surface (a
//! linear head), the class geometry (clusters of graded separation) and
//! the heterogeneity structure (labels for Dirichlet sharding) are all
//! preserved.

use super::Dataset;
use crate::simkit::prng::Rng;

/// Generator parameters for one synthetic vision dataset.
#[derive(Debug, Clone)]
pub struct VisionSpec {
    pub name: &'static str,
    pub n_classes: usize,
    /// raw "image" dimensionality before the frozen featurizer
    pub raw_dim: usize,
    /// frozen feature width (the probe's input dim)
    pub feat_dim: usize,
    /// cluster separation in raw space (higher = easier)
    pub separation: f32,
    /// within-class noise
    pub noise: f32,
}

/// CIFAR-10 analogue (easy: 10 well-separated clusters).
pub const SYNTH_CIFAR10: VisionSpec = VisionSpec {
    name: "synth-cifar10",
    n_classes: 10,
    raw_dim: 64,
    feat_dim: 128,
    separation: 0.45,
    noise: 1.0,
};

/// CIFAR-100 analogue (hard: 100 closer clusters).
pub const SYNTH_CIFAR100: VisionSpec = VisionSpec {
    name: "synth-cifar100",
    n_classes: 100,
    raw_dim: 64,
    feat_dim: 128,
    separation: 0.30,
    noise: 1.0,
};

/// The frozen backbone: a fixed random projection + ReLU, deterministic in
/// the dataset seed (every client regenerates the identical featurizer —
/// the "download the pretrained checkpoint" step of the paper).
pub struct Featurizer {
    pub raw_dim: usize,
    pub feat_dim: usize,
    w: Vec<f32>, // [feat_dim, raw_dim]
}

impl Featurizer {
    pub fn new(raw_dim: usize, feat_dim: usize, seed: u32) -> Self {
        let mut w = crate::simkit::prng::normals_vec(seed ^ 0x5EED_F00D, feat_dim * raw_dim);
        let scale = 1.0 / (raw_dim as f32).sqrt();
        for v in &mut w {
            *v *= scale;
        }
        Featurizer { raw_dim, feat_dim, w }
    }

    pub fn apply(&self, x_raw: &[f32]) -> Vec<f32> {
        assert_eq!(x_raw.len() % self.raw_dim, 0);
        let rows = x_raw.len() / self.raw_dim;
        let mut out = vec![0.0f32; rows * self.feat_dim];
        crate::simkit::ops::matmul_bt_acc(x_raw, &self.w, &mut out, rows, self.raw_dim, self.feat_dim);
        for v in &mut out {
            *v = v.max(0.0);
        }
        out
    }
}

/// Generate `n` featurized samples of a vision task.
pub fn generate(spec: &VisionSpec, n: usize, seed: u32) -> Dataset {
    let mut rng = Rng::new(seed, 0x1000 + spec.n_classes as u32);
    // class means are deterministic in the *task*, not the sample seed, so
    // train/test splits share geometry
    let mut mean_rng = Rng::new(0xFACE ^ spec.n_classes as u32, 1);
    let means: Vec<f32> = (0..spec.n_classes * spec.raw_dim)
        .map(|_| mean_rng.normal() * spec.separation)
        .collect();
    let featurizer = Featurizer::new(spec.raw_dim, spec.feat_dim, 0xFACE ^ spec.n_classes as u32);

    let mut raw = vec![0.0f32; n * spec.raw_dim];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(spec.n_classes);
        labels.push(c as u32);
        for j in 0..spec.raw_dim {
            raw[i * spec.raw_dim + j] =
                means[c * spec.raw_dim + j] + rng.normal() * spec.noise;
        }
    }
    let x = featurizer.apply(&raw);
    Dataset::Features { x, dim: spec.feat_dim, labels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let d = generate(&SYNTH_CIFAR10, 200, 0);
        assert_eq!(d.len(), 200);
        assert_eq!(d.n_classes(), 10);
        let Dataset::Features { x, dim, .. } = &d else { panic!() };
        assert_eq!(*dim, 128);
        assert_eq!(x.len(), 200 * 128);
    }

    #[test]
    fn features_nonnegative_relu() {
        let d = generate(&SYNTH_CIFAR10, 50, 1);
        let Dataset::Features { x, .. } = &d else { panic!() };
        assert!(x.iter().all(|&v| v >= 0.0));
        assert!(x.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn train_test_share_geometry() {
        // a nearest-class-mean classifier fit on split A must transfer to
        // split B — guarantees the task is a real generalization problem
        let train = generate(&SYNTH_CIFAR10, 500, 10);
        let test = generate(&SYNTH_CIFAR10, 200, 11);
        let (Dataset::Features { x: xa, labels: la, dim, .. },
             Dataset::Features { x: xb, labels: lb, .. }) = (&train, &test)
        else {
            panic!()
        };
        let d = *dim;
        let mut means = vec![0.0f64; 10 * d];
        let mut counts = vec![0usize; 10];
        for i in 0..500 {
            counts[la[i] as usize] += 1;
            for j in 0..d {
                means[la[i] as usize * d + j] += xa[i * d + j] as f64;
            }
        }
        for c in 0..10 {
            for j in 0..d {
                means[c * d + j] /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..200 {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..10 {
                let dist: f64 = (0..d)
                    .map(|j| {
                        let diff = xb[i * d + j] as f64 - means[c * d + j];
                        diff * diff
                    })
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 as u32 == lb[i] {
                correct += 1;
            }
        }
        assert!(correct > 120, "transfer accuracy too low: {correct}/200");
    }

    #[test]
    fn cifar100_harder_than_cifar10() {
        // same nearest-mean probe: accuracy on 100-way must be lower
        fn nm_accuracy(spec: &VisionSpec) -> f32 {
            let train = generate(spec, 1000, 20);
            let test = generate(spec, 300, 21);
            let (Dataset::Features { x: xa, labels: la, dim, .. },
                 Dataset::Features { x: xb, labels: lb, .. }) = (&train, &test)
            else {
                panic!()
            };
            let d = *dim;
            let c_n = spec.n_classes;
            let mut means = vec![0.0f64; c_n * d];
            let mut counts = vec![0usize; c_n];
            for i in 0..1000 {
                counts[la[i] as usize] += 1;
                for j in 0..d {
                    means[la[i] as usize * d + j] += xa[i * d + j] as f64;
                }
            }
            for c in 0..c_n {
                for j in 0..d {
                    means[c * d + j] /= counts[c].max(1) as f64;
                }
            }
            let mut correct = 0;
            for i in 0..300 {
                let mut best = (f64::INFINITY, 0usize);
                for c in 0..c_n {
                    let dist: f64 = (0..d)
                        .map(|j| {
                            let diff = xb[i * d + j] as f64 - means[c * d + j];
                            diff * diff
                        })
                        .sum();
                    if dist < best.0 {
                        best = (dist, c);
                    }
                }
                if best.1 as u32 == lb[i] {
                    correct += 1;
                }
            }
            correct as f32 / 300.0
        }
        let a10 = nm_accuracy(&SYNTH_CIFAR10);
        let a100 = nm_accuracy(&SYNTH_CIFAR100);
        assert!(a10 > a100, "cifar10 {a10} should beat cifar100 {a100}");
    }

    #[test]
    fn featurizer_deterministic() {
        let f1 = Featurizer::new(8, 16, 5);
        let f2 = Featurizer::new(8, 16, 5);
        let x = vec![1.0f32; 8];
        assert_eq!(f1.apply(&x), f2.apply(&x));
    }
}
