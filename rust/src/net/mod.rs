//! Deterministic impaired-channel simulator — the executable counterpart
//! of the analytic [`crate::comm::LinkModel`].
//!
//! The paper's headline is not just "1 bit per step" but that the 1-bit
//! design is *robust*: a corrupted sign is bounded-impact by the same
//! argument that bounds a Byzantine client (§ Byzantine robustness),
//! which is exactly the property you want over unreliable links — the
//! regime of the wireless ZO-FL follow-ups.  This module makes that
//! claim executable: it sits between the coordinator and the clients and
//! impairs [`crate::comm::Message`]s **semantically** —
//!
//! * a flipped [`Message::SignVote`] becomes the opposite sign;
//! * a flipped bit in a [`Message::Projection`] / [`Message::Gradient`]
//!   corrupts that field (seed bits pick a different-but-valid Philox
//!   direction; f32 bits can blow a projection or gradient entry up by
//!   orders of magnitude — the fragility the dense baselines pay);
//! * a dropped uplink makes the PS treat the client as **absent** that
//!   round, feeding the existing participation / catch-up machinery;
//! * heterogeneous per-client [`LinkProfile`]s plus a virtual event
//!   clock turn ledger bits into per-round wall-clock, and a round
//!   `deadline` excludes stragglers at plan time — they resync later
//!   via [`crate::coordinator::catchup`].
//!
//! ## Determinism contract
//!
//! Every impairment draw comes from the crate's Philox PRNG keyed by
//! `(channel_seed, round, client, direction)` — a *fresh* stream per
//! message, never shared state — so the impairment trace is a pure
//! function of the key: identical across worker-thread counts, across
//! the synchronous session and the threaded distributed topology, and
//! across reruns.  The `ideal` channel takes the exact code paths of a
//! run without the simulator (zero draws), pinned bit-identical by
//! `rust/tests/net_parity.rs`.  Because the key carries the *client* id
//! and never a shard id, the trace is also **shard-count-invariant**:
//! a `--shards N` coordinator ([`crate::coordinator::shard`]) observes
//! the same flips, drops and straggler cuts for every N (pinned by
//! `rust/tests/shard_parity.rs`).  Only [`NetSim::admit`]'s virtual
//! clock accumulates sequentially, which is why admission stays in the
//! global plan phase rather than moving into the shards.
//!
//! Scope note: the coordinator applies channel impairment to the
//! **uplink** (client → PS), where the protocol's 1-bit votes travel
//! uncoded.  The PS → client broadcast and the catch-up bulk transfers
//! are modeled reliable-in-round (a deployment protects them with ARQ /
//! repetition — they are the cheap direction), while *missing* the
//! downlink is expressed through absence: drops and deadline stragglers
//! leave a client stale, and the seed history brings it back.
//!
//! [`Message::SignVote`]: crate::comm::Message::SignVote
//! [`Message::Projection`]: crate::comm::Message::Projection
//! [`Message::Gradient`]: crate::comm::Message::Gradient

use crate::comm::Message;
use crate::simkit::prng::{self, Rng};

/// Impairment draw keying: which way the message travels.
pub const DIR_UP: u32 = 0;
/// Downlink direction key (used by [`NetSim::deliver`] for PS → client
/// messages; the coordinator wiring keeps the downlink reliable).
pub const DIR_DOWN: u32 = 1;
/// Latency/jitter draw key (one draw per `(round, client)`).
pub const DIR_LATENCY: u32 = 2;

/// Participant count above which the per-link latency draw loop fans out
/// over scoped workers (the fourth user of [`prng::scoped_spawn`]); below
/// it the serial loop always wins.
pub const PAR_MIN_LINKS: usize = 64;

/// Display names of the three link archetypes, indexed by
/// [`LinkProfile::class_index`] — the straggler-attribution label space
/// the observability plane ([`crate::obs`]) rolls round-gating up by.
pub const LINK_CLASS_NAMES: [&str; 3] = ["mobile", "wifi", "iot"];

/// How the channel treats payload bits in flight.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChannelModel {
    /// Every bit arrives as sent — pinned bit-identical to a run without
    /// the simulator.
    Ideal,
    /// Binary-symmetric channel: each payload bit flips independently
    /// with probability `ber`.
    BitFlip { ber: f64 },
    /// Erasure channel: the whole message is lost with probability `p`.
    Erasure { p: f64 },
}

impl ChannelModel {
    /// Parse a config/CLI spec: `ideal`, `ber:1e-3`, `drop:0.05`.
    pub fn parse(s: &str) -> Option<ChannelModel> {
        let s = s.trim().to_ascii_lowercase();
        if s == "ideal" {
            return Some(ChannelModel::Ideal);
        }
        if let Some(v) = s.strip_prefix("ber:") {
            let ber: f64 = v.parse().ok()?;
            if (0.0..=1.0).contains(&ber) {
                return Some(ChannelModel::BitFlip { ber });
            }
            return None;
        }
        if let Some(v) = s.strip_prefix("drop:") {
            let p: f64 = v.parse().ok()?;
            if (0.0..=1.0).contains(&p) {
                return Some(ChannelModel::Erasure { p });
            }
            return None;
        }
        None
    }

    /// Render back to the config-string form [`ChannelModel::parse`]
    /// accepts.
    pub fn render(&self) -> String {
        match self {
            ChannelModel::Ideal => "ideal".to_string(),
            ChannelModel::BitFlip { ber } => format!("ber:{ber}"),
            ChannelModel::Erasure { p } => format!("drop:{p}"),
        }
    }

    pub fn is_ideal(&self) -> bool {
        matches!(self, ChannelModel::Ideal)
    }
}

/// One client's physical link: bandwidth, fixed latency and jitter —
/// the per-client generalization of the single global
/// [`crate::comm::LinkModel`] the analytic projections use.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    /// uplink bandwidth, bits/s
    pub up_bps: f64,
    /// downlink bandwidth, bits/s
    pub down_bps: f64,
    /// fixed per-round latency, seconds
    pub rtt_s: f64,
    /// extra uniform per-round delay in `[0, jitter_s)`
    pub jitter_s: f64,
}

impl LinkProfile {
    /// A conservative mobile uplink: 20 Mbps up / 100 Mbps down / 30 ms
    /// RTT (the [`crate::comm::LinkModel::mobile`] numbers, plus jitter).
    pub fn mobile() -> Self {
        LinkProfile { up_bps: 20e6, down_bps: 100e6, rtt_s: 0.03, jitter_s: 0.02 }
    }

    /// A wired/WLAN client: fast and tight.
    pub fn wifi() -> Self {
        LinkProfile { up_bps: 100e6, down_bps: 400e6, rtt_s: 0.005, jitter_s: 0.005 }
    }

    /// A LoRa-class constrained device: slow, high-latency, jittery —
    /// the straggler archetype a round deadline cuts.
    pub fn iot() -> Self {
        LinkProfile { up_bps: 50e3, down_bps: 250e3, rtt_s: 0.4, jitter_s: 0.6 }
    }

    /// Seconds one round costs this link for the given payload, with the
    /// jitter draw already resolved.  Bandwidth terms go through the
    /// guarded [`crate::comm::transfer_seconds`]: a zero/negative/NaN
    /// bandwidth projects an unreachable link (`inf`), never a NaN that
    /// would poison the virtual clock's `max()` straggler comparison.
    pub fn round_seconds(&self, up_bits: u64, down_bits: u64, jitter_s: f64) -> f64 {
        self.rtt_s
            + jitter_s
            + crate::comm::transfer_seconds(up_bits, self.up_bps)
            + crate::comm::transfer_seconds(down_bits, self.down_bps)
    }

    /// Which [`LINK_CLASS_NAMES`] archetype this profile belongs to —
    /// exact matches on the canonical constructors first, then a
    /// bandwidth-tier fallback for hand-built profiles (sub-Mbps uplinks
    /// read as iot-class, sub-50-Mbps as mobile, the rest as wifi).
    /// Attribution metadata only: no engine branch reads it.
    pub fn class_index(&self) -> usize {
        if *self == LinkProfile::mobile() {
            return 0;
        }
        if *self == LinkProfile::wifi() {
            return 1;
        }
        if *self == LinkProfile::iot() {
            return 2;
        }
        if !(self.up_bps >= 1e6) {
            2
        } else if self.up_bps < 50e6 {
            0
        } else {
            1
        }
    }

    /// Relative *compute*-cost weight of the device class behind this
    /// link — the execute fan-out's size-aware bin-packing signal
    /// (`coordinator::session`): in the paper's deployment archetypes a
    /// slow uplink correlates with weak hardware, so a worker that draws
    /// the iot-class client should not also draw three wifi clients.
    /// Log-scaled on uplink bandwidth (wifi 1, mobile 4, iot 12);
    /// deterministic, and only ever a scheduling hint — the committed
    /// bits are assignment-independent.  A degenerate (zero/negative/NaN)
    /// uplink gets the bounded worst-class weight instead of the
    /// `inf -> u64::MAX` saturation that would overflow bin sums.
    pub fn device_cost_weight(&self) -> u64 {
        if !(self.up_bps > 0.0) || !self.up_bps.is_finite() {
            return 64;
        }
        let ratio = (2e8 / self.up_bps).max(1.0);
        (ratio.log2().ceil() as u64).max(1).min(64)
    }
}

/// How link profiles map onto the client pool.
#[derive(Debug, Clone, PartialEq)]
pub enum LinkAssignment {
    /// Every client shares one profile (the pre-`net` assumption).
    Uniform(LinkProfile),
    /// Client `id` gets `profiles[id % len]` — a deterministic
    /// heterogeneous pool.
    Cycle(Vec<LinkProfile>),
}

impl LinkAssignment {
    /// Parse a config/CLI spec: `mobile`, `wifi`, `iot`, or `mixed`
    /// (a wifi/mobile/iot cycle).
    pub fn parse(s: &str) -> Option<LinkAssignment> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mobile" => Some(LinkAssignment::Uniform(LinkProfile::mobile())),
            "wifi" => Some(LinkAssignment::Uniform(LinkProfile::wifi())),
            "iot" => Some(LinkAssignment::Uniform(LinkProfile::iot())),
            "mixed" => Some(LinkAssignment::Cycle(vec![
                LinkProfile::wifi(),
                LinkProfile::mobile(),
                LinkProfile::iot(),
            ])),
            _ => None,
        }
    }

    /// The profile client `id` is attached to.
    pub fn profile(&self, id: usize) -> LinkProfile {
        match self {
            LinkAssignment::Uniform(p) => *p,
            LinkAssignment::Cycle(ps) => ps[id % ps.len()],
        }
    }

    /// Whether this is the pre-`net` assumption — one global mobile link
    /// (the analytic [`crate::comm::LinkModel::mobile`] numbers).
    /// Anything else asks for per-client link simulation and activates
    /// the virtual event clock.
    pub fn is_default(&self) -> bool {
        matches!(self, LinkAssignment::Uniform(p) if *p == LinkProfile::mobile())
    }
}

/// Full network-simulation configuration, threaded through
/// `SessionCfg` / the experiment TOML / the CLI (`--channel`, `--link`,
/// `--deadline`).
#[derive(Debug, Clone, PartialEq)]
pub struct NetCfg {
    pub channel: ChannelModel,
    pub links: LinkAssignment,
    /// Round deadline in virtual seconds; a planned participant whose
    /// round latency exceeds it is excluded before any compute runs
    /// (`0` disables the cut).
    pub deadline_s: f64,
    /// Seed of the impairment draw streams (keyed with
    /// `(round, client, direction)`).
    pub channel_seed: u32,
}

impl NetCfg {
    /// The do-nothing configuration: ideal channel, no deadline.
    pub fn ideal() -> Self {
        NetCfg {
            channel: ChannelModel::Ideal,
            links: LinkAssignment::Uniform(LinkProfile::mobile()),
            deadline_s: 0.0,
            channel_seed: 0,
        }
    }

    /// Whether the simulator engages at all.  When false, the session
    /// takes exactly the pre-`net` code paths (zero draws, zero stats).
    /// A non-default link assignment engages the virtual clock even over
    /// an ideal channel — asking for `--link mixed` must never be
    /// silently ignored — but an ideal channel still delivers every
    /// message untouched, so replicas and ledgers stay bit-identical to
    /// the no-`net` baseline (only the clock stats tick).
    pub fn is_active(&self) -> bool {
        !self.channel.is_ideal() || self.deadline_s > 0.0 || !self.links.is_default()
    }

    /// Whether the simulated network can leave a planned participant
    /// without this round's update — the channel half of the session's
    /// snapshot-cache admission check.  An [`ChannelModel::Erasure`]
    /// channel drops uplink votes outright and a positive deadline cuts
    /// stragglers from the plan, so both can create stale replica
    /// readers; [`ChannelModel::BitFlip`] corrupts payload bits but
    /// still *delivers* every message, and an ideal channel delivers
    /// everything untouched.
    pub fn can_strand_clients(&self) -> bool {
        matches!(self.channel, ChannelModel::Erasure { .. }) || self.deadline_s > 0.0
    }
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg::ideal()
    }
}

/// Per-run impairment counters — the observable summary of the
/// impairment trace (identical across worker-thread counts and
/// topologies for the same `(channel_seed, cfg)`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetStats {
    /// rounds the virtual clock observed
    pub rounds: u64,
    /// virtual wall-clock elapsed over those rounds, seconds
    pub virtual_s: f64,
    /// planned participants excluded by the round deadline
    pub stragglers: u64,
    /// uplink messages lost to the erasure channel
    pub dropped_msgs: u64,
    /// uplink messages delivered with at least one flipped bit
    pub corrupted_msgs: u64,
    /// total payload bits flipped in delivered messages
    pub flipped_bits: u64,
}

/// One [`NetSim::admit`] call's attribution record — who gated the
/// round, on what link class, and what it cost the virtual clock.  Pure
/// bookkeeping for the observability plane ([`crate::obs`]): the engine
/// never reads it back, and it lives outside the [`NetStats`] struct the
/// parity suites compare.  Every field is a deterministic function of
/// `(channel_seed, round, plan)`, so the log itself is identical across
/// worker-thread counts and topologies.
#[derive(Debug, Clone, Copy)]
pub struct AdmitSummary {
    /// round the admission gated
    pub round: u64,
    /// planned participants entering the deadline check
    pub planned: u32,
    /// participants admitted on time
    pub kept: u32,
    /// participants cut as stragglers
    pub cut: u32,
    /// slowest *admitted* client — the one whose link the round waited
    /// for (`-1` when the deadline cut everyone)
    pub gating_client: i64,
    /// [`LINK_CLASS_NAMES`] index of the gating client's link class
    pub gating_class: u32,
    /// the round's virtual duration, microseconds
    pub virtual_us: u64,
}

/// The simulator: configuration + accumulated stats.  One lives in the
/// synchronous [`crate::coordinator::session::Session`] and one on the
/// PS side of the threaded [`crate::coordinator::distributed`] topology;
/// because draws are keyed, both observe the same trace.
pub struct NetSim {
    pub cfg: NetCfg,
    pub stats: NetStats,
    /// Whether [`NetSim::admit`] records [`AdmitSummary`] rows (the
    /// session flips this on when tracing is enabled; off by default so
    /// untraced runs allocate nothing).
    pub log_admissions: bool,
    admit_log: Vec<AdmitSummary>,
}

/// Positions of Bernoulli(`ber`) successes over `n_bits` trials, via
/// geometric inter-arrival sampling — O(flips) draws, not O(bits), so a
/// dense-gradient payload at a low BER stays cheap.
fn flipped_bit_positions(n_bits: u64, ber: f64, rng: &mut Rng) -> Vec<u64> {
    let mut out = Vec::new();
    if ber <= 0.0 || n_bits == 0 {
        return out;
    }
    if ber >= 1.0 {
        return (0..n_bits).collect();
    }
    let ln_q = (1.0 - ber).ln();
    let mut pos = 0u64;
    loop {
        // uniform() is in (0, 1]: ln(u) <= 0 and ln_q < 0, so the skip is
        // a non-negative geometric draw
        let skip = ((rng.uniform() as f64).ln() / ln_q) as u64;
        pos = pos.saturating_add(skip);
        if pos >= n_bits {
            return out;
        }
        out.push(pos);
        pos += 1;
    }
}

/// XOR a flip mask over a 32-bit field given positions within it.
fn flip_u32(x: u32, flips: &[u64], base: u64) -> u32 {
    let mut out = x;
    for &b in flips {
        if (base..base + 32).contains(&b) {
            out ^= 1u32 << (b - base) as u32;
        }
    }
    out
}

/// Apply flip positions to an f32 array (bit `b` lands in element
/// `b / 32`).
fn flip_f32s(g: &mut [f32], flips: &[u64]) {
    for &b in flips {
        let (i, bit) = ((b / 32) as usize, (b % 32) as u32);
        g[i] = f32::from_bits(g[i].to_bits() ^ (1u32 << bit));
    }
}

/// Corrupt one 64-bit seed-projection pair at bit offset `base` of the
/// flip mask: seed bits first, then the f32 coefficient, with the seed's
/// reserved MSB masked back into the 31-bit direction space.
fn corrupt_pair(seed: u32, p: f32, flips: &[u64], base: u64) -> (u32, f32) {
    let seed = flip_u32(seed, flips, base) & 0x7FFF_FFFF;
    let p = f32::from_bits(flip_u32(p.to_bits(), flips, base + 32));
    (seed, p)
}

impl NetSim {
    pub fn new(cfg: NetCfg) -> Self {
        NetSim { cfg, stats: NetStats::default(), log_admissions: false, admit_log: Vec::new() }
    }

    /// Drain the accumulated [`AdmitSummary`] rows (empty unless
    /// [`NetSim::log_admissions`] is set).  The session drains this after
    /// every plan so lookahead admissions for round `t+1` drawn during
    /// round `t` still land on their own round number.
    pub fn take_admit_log(&mut self) -> Vec<AdmitSummary> {
        std::mem::take(&mut self.admit_log)
    }

    /// See [`NetCfg::is_active`].
    pub fn is_active(&self) -> bool {
        self.cfg.is_active()
    }

    /// The fresh draw stream for one `(round, client, direction)` key —
    /// avalanched so nearby keys land in unrelated Philox streams.
    fn draw_stream(&self, round: u64, client: usize, dir: u32) -> Rng {
        let mut h = (round as u32).wrapping_mul(0x9E37_79B9);
        h ^= (client as u32).wrapping_mul(0x85EB_CA6B).rotate_left(13);
        h ^= dir.wrapping_mul(0xC2B2_AE35).rotate_left(27);
        Rng::new(self.cfg.channel_seed ^ h, h ^ 0x0C0F_FEE0)
    }

    /// One `n_bits`-payload message crossing the channel: `None` = lost
    /// to erasure, `Some(flips)` = delivered with the given payload-bit
    /// positions flipped (empty on a clean arrival).
    fn transmit(&mut self, round: u64, client: usize, dir: u32, n_bits: u64) -> Option<Vec<u64>> {
        match self.cfg.channel {
            ChannelModel::Ideal => Some(Vec::new()),
            ChannelModel::Erasure { p } => {
                // p >= 1 drops deterministically: uniform() can land on
                // exactly 1.0, which would otherwise leak ~1-in-2^24
                // deliveries through a `drop:1` channel
                let lost =
                    p >= 1.0 || (self.draw_stream(round, client, dir).uniform() as f64) < p;
                if lost {
                    self.stats.dropped_msgs += 1;
                    None
                } else {
                    Some(Vec::new())
                }
            }
            ChannelModel::BitFlip { ber } => {
                let mut rng = self.draw_stream(round, client, dir);
                let flips = flipped_bit_positions(n_bits, ber, &mut rng);
                if !flips.is_empty() {
                    self.stats.corrupted_msgs += 1;
                    self.stats.flipped_bits += flips.len() as u64;
                }
                Some(flips)
            }
        }
    }

    /// Dir-parametric core of [`NetSim::deliver_sign`]: a flip is the
    /// opposite sign.
    fn sign_through(&mut self, round: u64, client: usize, dir: u32, sign: i8) -> Option<i8> {
        if self.cfg.channel.is_ideal() {
            return Some(sign);
        }
        let flips = self.transmit(round, client, dir, 1)?;
        Some(if flips.is_empty() { sign } else { -sign })
    }

    /// Dir-parametric core of [`NetSim::deliver_pair`].  Flipped seed
    /// bits select a different-but-valid Philox direction (the seed space
    /// is the 31-bit counter region, so the reserved MSB is masked on
    /// receive); flipped projection bits corrupt the f32 coefficient.
    fn pair_through(
        &mut self,
        round: u64,
        client: usize,
        dir: u32,
        seed: u32,
        p: f32,
    ) -> Option<(u32, f32)> {
        if self.cfg.channel.is_ideal() {
            return Some((seed, p));
        }
        let flips = self.transmit(round, client, dir, 64)?;
        Some(corrupt_pair(seed, p, &flips, 0))
    }

    /// Dir-parametric core of [`NetSim::deliver_gradient`], corrupting
    /// `g` in place; `false` = the whole message was lost.
    fn f32s_through(&mut self, round: u64, client: usize, dir: u32, g: &mut [f32]) -> bool {
        if self.cfg.channel.is_ideal() {
            return true;
        }
        match self.transmit(round, client, dir, 32 * g.len() as u64) {
            None => false,
            Some(flips) => {
                flip_f32s(g, &flips);
                true
            }
        }
    }

    /// A 1-bit sign vote crossing the uplink: `None` = the PS treats the
    /// voter as absent this round; a flip is the opposite sign.
    pub fn deliver_sign(&mut self, round: u64, client: usize, sign: i8) -> Option<i8> {
        self.sign_through(round, client, DIR_UP, sign)
    }

    /// A 64-bit seed-projection pair crossing the uplink (see
    /// [`NetSim::pair_through`] for the corruption semantics).
    pub fn deliver_pair(
        &mut self,
        round: u64,
        client: usize,
        seed: u32,
        p: f32,
    ) -> Option<(u32, f32)> {
        self.pair_through(round, client, DIR_UP, seed, p)
    }

    /// A dense `32·d`-bit gradient crossing the uplink, corrupted in
    /// place; `false` = the whole message was lost.
    pub fn deliver_gradient(&mut self, round: u64, client: usize, g: &mut [f32]) -> bool {
        self.f32s_through(round, client, DIR_UP, g)
    }

    /// Generic semantic impairment of a protocol message (`dir` keys the
    /// draw stream): `None` = lost.  Delegates to the same cores the
    /// coordinator's typed paths use, so the two APIs cannot drift.
    /// Zero-payload and bulk-transfer messages (`RoundStart`,
    /// `ReplayHistory`, `Rebroadcast`) pass through unimpaired — they
    /// model ARQ-protected control/bulk traffic.
    pub fn deliver(
        &mut self,
        round: u64,
        client: usize,
        dir: u32,
        msg: Message,
    ) -> Option<Message> {
        match msg {
            Message::SignVote { sign } => {
                let sign = self.sign_through(round, client, dir, sign)?;
                Some(Message::SignVote { sign })
            }
            Message::GlobalSign { sign } => {
                let sign = self.sign_through(round, client, dir, sign)?;
                Some(Message::GlobalSign { sign })
            }
            Message::Projection { seed, p } => {
                let (seed, p) = self.pair_through(round, client, dir, seed, p)?;
                Some(Message::Projection { seed, p })
            }
            Message::Gradient { mut g } => {
                self.f32s_through(round, client, dir, &mut g).then_some(Message::Gradient { g })
            }
            Message::GlobalGradient { mut g } => {
                self.f32s_through(round, client, dir, &mut g)
                    .then_some(Message::GlobalGradient { g })
            }
            Message::GlobalProjections { pairs } => {
                if self.cfg.channel.is_ideal() {
                    return Some(Message::GlobalProjections { pairs });
                }
                let flips = self.transmit(round, client, dir, 64 * pairs.len() as u64)?;
                let pairs = pairs
                    .iter()
                    .enumerate()
                    .map(|(i, &(seed, p))| corrupt_pair(seed, p, &flips, 64 * i as u64))
                    .collect();
                Some(Message::GlobalProjections { pairs })
            }
            passthrough => Some(passthrough),
        }
    }

    /// Round latency for one client at the given payload (jitter draw
    /// resolved from the `(round, client)` latency stream).
    pub fn link_latency(&self, round: u64, client: usize, up_bits: u64, down_bits: u64) -> f64 {
        let prof = self.cfg.links.profile(client);
        let jitter = if prof.jitter_s > 0.0 {
            let mut rng = self.draw_stream(round, client, DIR_LATENCY);
            rng.uniform() as f64 * prof.jitter_s
        } else {
            0.0
        };
        prof.round_seconds(up_bits, down_bits, jitter)
    }

    /// Per-link latency draws for a participant set — independent pure
    /// functions of `(channel_seed, round, client)`, so the loop
    /// chunk-parallelizes over [`prng::scoped_spawn`] for large pools
    /// (the fourth chunked-spawn user the ROADMAP anticipated) and stays
    /// bit-identical to the serial walk.
    fn fill_latencies(
        &self,
        round: u64,
        ids: &[usize],
        up_bits: u64,
        down_bits: u64,
        out: &mut [f64],
    ) {
        debug_assert_eq!(ids.len(), out.len());
        let threads = if ids.len() < PAR_MIN_LINKS { 1 } else { prng::worker_threads() };
        if threads <= 1 {
            for (o, &id) in out.iter_mut().zip(ids) {
                *o = self.link_latency(round, id, up_bits, down_bits);
            }
            return;
        }
        let chunk = ids.len().div_ceil(threads);
        prng::scoped_spawn(out.chunks_mut(chunk).zip(ids.chunks(chunk)), |_, (oc, idc)| {
            for (o, &id) in oc.iter_mut().zip(idc) {
                *o = self.link_latency(round, id, up_bits, down_bits);
            }
        });
    }

    /// Plan-phase admission: advance the virtual clock and apply the
    /// round deadline.  Returns the on-time participants (id order
    /// preserved); excluded stragglers never probe this round and resync
    /// later through the catch-up machinery.  The round's virtual
    /// duration is the slowest admitted client's latency — or the full
    /// deadline when the PS had to wait it out to conclude a straggler
    /// missed the cut.
    pub fn admit(
        &mut self,
        round: u64,
        participants: Vec<usize>,
        up_bits: u64,
        down_bits: u64,
    ) -> Vec<usize> {
        self.stats.rounds += 1;
        if participants.is_empty() {
            return participants;
        }
        let mut latencies = vec![0.0f64; participants.len()];
        self.fill_latencies(round, &participants, up_bits, down_bits, &mut latencies);
        let deadline = self.cfg.deadline_s;
        let mut kept = Vec::with_capacity(participants.len());
        let mut round_s = 0.0f64;
        let mut cut = 0u32;
        let mut gating: i64 = -1;
        for (&id, &lat) in participants.iter().zip(&latencies) {
            if deadline > 0.0 && lat > deadline {
                cut += 1;
                self.stats.stragglers += 1;
            } else {
                // strict `>` keeps the first argmax — a deterministic
                // tie-break in participant (client-id) order
                if lat > round_s || gating < 0 {
                    round_s = round_s.max(lat);
                    gating = id as i64;
                }
                kept.push(id);
            }
        }
        if cut > 0 {
            round_s = deadline;
        }
        self.stats.virtual_s += round_s;
        if self.log_admissions {
            let gating_class = if gating < 0 {
                0
            } else {
                self.cfg.links.profile(gating as usize).class_index() as u32
            };
            self.admit_log.push(AdmitSummary {
                round,
                planned: participants.len() as u32,
                kept: kept.len() as u32,
                cut,
                gating_client: gating,
                gating_class,
                virtual_us: (round_s * 1e6) as u64,
            });
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_cost_weight_orders_the_link_classes() {
        let (wifi, mobile, iot) = (
            LinkProfile::wifi().device_cost_weight(),
            LinkProfile::mobile().device_cost_weight(),
            LinkProfile::iot().device_cost_weight(),
        );
        assert!(wifi < mobile && mobile < iot, "{wifi} < {mobile} < {iot}");
        assert!(wifi >= 1, "weights are positive bin-packing costs");
    }

    #[test]
    fn degenerate_links_never_produce_nan_times_or_saturated_weights() {
        // a zero-bandwidth link is unreachable (inf), not 0/0 = NaN —
        // NaN would poison admit()'s max() straggler comparison; and its
        // cost weight stays a bounded bin-packing cost, not u64::MAX
        let dead = LinkProfile { up_bps: 0.0, down_bps: 0.0, rtt_s: 0.01, jitter_s: 0.0 };
        assert!(dead.round_seconds(1, 1, 0.0).is_infinite());
        assert!(!dead.round_seconds(1, 1, 0.0).is_nan());
        assert_eq!(dead.round_seconds(0, 0, 0.0), 0.01, "empty payload costs only rtt");
        assert_eq!(dead.device_cost_weight(), 64);
        let nan = LinkProfile { up_bps: f64::NAN, down_bps: -1.0, rtt_s: 0.0, jitter_s: 0.0 };
        assert!(!nan.round_seconds(8, 8, 0.0).is_nan());
        assert_eq!(nan.device_cost_weight(), 64);
        // healthy profiles are untouched by the guard
        let m = LinkProfile::mobile();
        assert!((m.round_seconds(20e6 as u64, 0, 0.0) - (0.03 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn stranding_capability_by_channel_and_deadline() {
        let mut cfg = NetCfg::ideal();
        assert!(!cfg.can_strand_clients(), "ideal channel delivers everything");
        cfg.channel = ChannelModel::BitFlip { ber: 0.5 };
        assert!(!cfg.can_strand_clients(), "bit-flips corrupt but still deliver");
        cfg.channel = ChannelModel::Erasure { p: 0.01 };
        assert!(cfg.can_strand_clients(), "erasures drop whole votes");
        cfg.channel = ChannelModel::Ideal;
        cfg.deadline_s = 0.2;
        assert!(cfg.can_strand_clients(), "a deadline cuts stragglers from the plan");
    }

    fn sim(channel: &str, deadline_s: f64) -> NetSim {
        NetSim::new(NetCfg {
            channel: ChannelModel::parse(channel).unwrap(),
            links: LinkAssignment::parse("mixed").unwrap(),
            deadline_s,
            channel_seed: 42,
        })
    }

    #[test]
    fn channel_spec_parse_render_roundtrip() {
        for s in ["ideal", "ber:0.001", "drop:0.05", "ber:0", "drop:1"] {
            let c = ChannelModel::parse(s).unwrap();
            assert_eq!(ChannelModel::parse(&c.render()), Some(c), "{s}");
        }
        assert_eq!(ChannelModel::parse("ber:1e-3"), Some(ChannelModel::BitFlip { ber: 1e-3 }));
        assert_eq!(ChannelModel::parse("IDEAL"), Some(ChannelModel::Ideal));
        assert!(ChannelModel::parse("ber:1.5").is_none());
        assert!(ChannelModel::parse("drop:-0.1").is_none());
        assert!(ChannelModel::parse("lossy").is_none());
    }

    #[test]
    fn link_spec_parses_and_cycles() {
        for s in ["mobile", "wifi", "iot", "mixed"] {
            assert!(LinkAssignment::parse(s).is_some(), "{s}");
        }
        assert!(LinkAssignment::parse("carrier-pigeon").is_none());
        let mixed = LinkAssignment::parse("mixed").unwrap();
        assert_eq!(mixed.profile(0), LinkProfile::wifi());
        assert_eq!(mixed.profile(1), LinkProfile::mobile());
        assert_eq!(mixed.profile(2), LinkProfile::iot());
        assert_eq!(mixed.profile(3), LinkProfile::wifi(), "cycles by id");
        let uni = LinkAssignment::parse("mobile").unwrap();
        assert_eq!(uni.profile(7), LinkProfile::mobile());
    }

    #[test]
    fn ideal_cfg_is_inactive_and_draw_free() {
        let cfg = NetCfg::ideal();
        assert!(!cfg.is_active());
        let mut sim = NetSim::new(cfg);
        assert_eq!(sim.deliver_sign(3, 1, -1), Some(-1));
        assert_eq!(sim.deliver_pair(3, 1, 7, 0.5), Some((7, 0.5)));
        let mut g = vec![1.0f32, -2.0];
        assert!(sim.deliver_gradient(3, 1, &mut g));
        assert_eq!(g, vec![1.0, -2.0]);
        assert_eq!(sim.stats, NetStats::default());
    }

    #[test]
    fn deadline_alone_activates_the_simulator() {
        let mut cfg = NetCfg::ideal();
        cfg.deadline_s = 0.5;
        assert!(cfg.is_active());
    }

    #[test]
    fn non_default_link_alone_activates_the_clock() {
        // asking for --link wifi/mixed must never be silently ignored:
        // the virtual clock engages even over an ideal channel
        let mut cfg = NetCfg::ideal();
        cfg.links = LinkAssignment::parse("mixed").unwrap();
        assert!(cfg.is_active());
        cfg.links = LinkAssignment::Uniform(LinkProfile::mobile());
        assert!(!cfg.is_active(), "the default mobile link is the pre-net assumption");
        assert!(LinkAssignment::parse("mobile").unwrap().is_default());
        assert!(!LinkAssignment::parse("iot").unwrap().is_default());
    }

    #[test]
    fn sign_flip_is_the_opposite_sign() {
        // at ber = 1 every bit flips: the vote always inverts but is
        // never lost
        let mut s = sim("ber:1", 0.0);
        for round in 0..8u64 {
            assert_eq!(s.deliver_sign(round, 0, 1), Some(-1));
            assert_eq!(s.deliver_sign(round, 1, -1), Some(1));
        }
        assert_eq!(s.stats.flipped_bits, 16);
        assert_eq!(s.stats.corrupted_msgs, 16);
        assert_eq!(s.stats.dropped_msgs, 0);
    }

    #[test]
    fn drop_one_loses_every_message() {
        let mut s = sim("drop:1", 0.0);
        for round in 0..64u64 {
            assert!(s.deliver_sign(round, 0, 1).is_none(), "round {round} leaked through");
        }
        assert_eq!(s.stats.dropped_msgs, 64);
    }

    #[test]
    fn erasure_drops_at_the_configured_rate() {
        let mut s = sim("drop:0.3", 0.0);
        let n = 4000u64;
        let mut lost = 0u64;
        for round in 0..n {
            if s.deliver_sign(round, 0, 1).is_none() {
                lost += 1;
            }
        }
        let rate = lost as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.03, "drop rate {rate}");
        assert_eq!(s.stats.dropped_msgs, lost);
        assert_eq!(s.stats.flipped_bits, 0, "erasure never corrupts");
    }

    #[test]
    fn bit_flips_land_at_the_configured_rate() {
        let mut s = sim("ber:0.01", 0.0);
        let mut g = vec![0.0f32; 1000]; // 32_000 bits per message
        let mut total = 0u64;
        for round in 0..50u64 {
            g.fill(0.0);
            assert!(s.deliver_gradient(round, 2, &mut g));
            total += g.iter().map(|v| v.to_bits().count_ones() as u64).sum::<u64>();
        }
        // 50 rounds x 32_000 bits x 0.01 = 16_000 expected flips
        let expect = 16_000.0;
        assert!((total as f64 - expect).abs() < 0.1 * expect, "flips {total}");
        assert_eq!(s.stats.flipped_bits, total, "stats count the applied mask");
    }

    #[test]
    fn pair_corruption_masks_the_reserved_seed_msb() {
        let mut s = sim("ber:1", 0.0);
        // every one of the 64 bits flips: seed = !seed masked to 31 bits,
        // p = bitwise-not p
        let (seed, p) = s.deliver_pair(0, 0, 0, 1.0f32).unwrap();
        assert_eq!(seed, 0x7FFF_FFFF, "MSB stays out of the direction-seed space");
        assert_eq!(p.to_bits(), !1.0f32.to_bits());
    }

    #[test]
    fn draws_are_keyed_not_sequenced() {
        // the impairment of (round, client) must not depend on what was
        // transmitted before it — the property that makes the trace
        // identical across worker-thread counts and topologies
        let mut a = sim("drop:0.5", 0.0);
        let mut b = sim("drop:0.5", 0.0);
        let direct = a.deliver_sign(9, 3, 1);
        for round in 0..9u64 {
            for client in 0..4usize {
                let _ = b.deliver_sign(round, client, 1);
            }
        }
        assert_eq!(b.deliver_sign(9, 3, 1), direct);
    }

    #[test]
    fn different_channel_seeds_give_different_traces() {
        let mut a = sim("drop:0.5", 0.0);
        let mut b = sim("drop:0.5", 0.0);
        b.cfg.channel_seed = 43;
        let pat_a: Vec<bool> =
            (0..64u64).map(|r| a.deliver_sign(r, 0, 1).is_some()).collect();
        let pat_b: Vec<bool> =
            (0..64u64).map(|r| b.deliver_sign(r, 0, 1).is_some()).collect();
        assert_ne!(pat_a, pat_b);
    }

    #[test]
    fn message_level_impairment_matches_typed_paths() {
        let mut typed = sim("ber:0.4", 0.0);
        let mut msg = sim("ber:0.4", 0.0);
        for round in 0..32u64 {
            let t = typed.deliver_pair(round, 5, 1234, -0.75);
            let m = msg.deliver(round, 5, DIR_UP, Message::Projection { seed: 1234, p: -0.75 });
            match (t, m) {
                (Some((seed, p)), Some(Message::Projection { seed: s2, p: p2 })) => {
                    assert_eq!(seed, s2);
                    assert_eq!(p.to_bits(), p2.to_bits());
                }
                (None, None) => {}
                other => panic!("typed and message paths diverged: {other:?}"),
            }
        }
        // control/bulk messages pass through
        let m = msg.deliver(0, 0, DIR_DOWN, Message::RoundStart { round: 0 });
        assert_eq!(m, Some(Message::RoundStart { round: 0 }));
    }

    #[test]
    fn deadline_cuts_slow_links_and_charges_the_wait() {
        // mixed cycle: id 2 is iot (rtt 0.4 > deadline), ids 0/1 are fast
        let mut s = sim("ideal", 0.1);
        let kept = s.admit(0, vec![0, 1, 2], 1, 1);
        assert_eq!(kept, vec![0, 1]);
        assert_eq!(s.stats.stragglers, 1);
        // the PS waited out the full deadline to conclude the cut
        assert!((s.stats.virtual_s - 0.1).abs() < 1e-12);
        // without a cut the round costs the slowest admitted latency
        let before = s.stats.virtual_s;
        let kept = s.admit(1, vec![0, 1], 1, 1);
        assert_eq!(kept, vec![0, 1]);
        let dt = s.stats.virtual_s - before;
        assert!(dt > 0.0 && dt < 0.1, "round time {dt}");
    }

    #[test]
    fn admit_without_deadline_just_tracks_the_clock() {
        let mut s = sim("ideal", 0.0);
        let kept = s.admit(0, vec![0, 1, 2, 3], 64, 640);
        assert_eq!(kept, vec![0, 1, 2, 3]);
        assert_eq!(s.stats.stragglers, 0);
        // slowest link is iot (id 2): rtt 0.4 + jitter [0, 0.6)
        assert!(s.stats.virtual_s >= 0.4 && s.stats.virtual_s < 1.1);
    }

    #[test]
    fn latency_fill_chunk_parallel_matches_serial() {
        let s = sim("ideal", 0.0);
        let ids: Vec<usize> = (0..PAR_MIN_LINKS * 2 + 7).collect();
        let mut serial = vec![0.0f64; ids.len()];
        for (o, &id) in serial.iter_mut().zip(&ids) {
            *o = s.link_latency(11, id, 64, 64);
        }
        let mut par = vec![0.0f64; ids.len()];
        s.fill_latencies(11, &ids, 64, 64, &mut par);
        assert_eq!(serial, par, "per-link draws are keyed, so splits are exact");
    }

    #[test]
    fn geometric_flip_positions_edge_cases() {
        let mut rng = Rng::new(1, 1);
        assert!(flipped_bit_positions(0, 0.5, &mut rng).is_empty());
        assert!(flipped_bit_positions(100, 0.0, &mut rng).is_empty());
        assert_eq!(flipped_bit_positions(5, 1.0, &mut rng), vec![0, 1, 2, 3, 4]);
        let flips = flipped_bit_positions(1000, 0.05, &mut rng);
        assert!(flips.windows(2).all(|w| w[0] < w[1]), "strictly increasing");
        assert!(flips.iter().all(|&b| b < 1000));
    }
}
