//! # FeedSign — robust full-parameter federated fine-tuning with 1-bit communication
//!
//! Reproduction of Cai, Chen & Zhu, *"FeedSign: Robust Full-parameter
//! Federated Fine-tuning of Large Models with Extremely Low Communication
//! Overhead of One Bit"* (2025), as a three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 1** (build-time Python): Pallas kernels for the shared-PRNG
//!   substrate — counter-based Philox noise generation fused with the SPSA
//!   AXPY — plus a tiled `linear_gelu` for the transformer MLP hot-spot.
//! * **Layer 2** (build-time Python): a decoder-only transformer LM over a
//!   flat parameter vector; the SPSA probe / update / eval / FO-baseline
//!   step graphs are AOT-lowered to HLO text (`make artifacts`).
//! * **Layer 3** (this crate): the federated runtime — parameter server,
//!   client pool, 1-bit vote aggregation, Byzantine attack models, data
//!   heterogeneity, orbit storage, differential privacy — with Python never
//!   on the request path.
//!
//! Two interchangeable [`engine::Engine`] backends drive client compute:
//! [`runtime::SharedPjrtEngine`] executes the AOT artifacts through the
//! PJRT C API, and [`simkit`] is a pure-rust NN substrate (own Philox
//! PRNG, bit-compatible with the Pallas kernel at the u32 level) that
//! makes the paper's 10^4–10^5-step sweeps tractable on this testbed.
//!
//! The coordinator runs a **parallel round engine**: each round is
//! planned (participant sampling via
//! [`coordinator::participation::ParticipationCfg`] — full,
//! fixed-fraction, or Bernoulli availability), executed (per-client SPSA
//! probes fan out over scoped threads; `Engine: Send` and the chunk-
//! parallel Philox AXPYs in [`simkit::zo`] exist for this), and committed
//! **in client-id order**, so every run is bit-identical for every worker
//! thread count — the determinism contract pinned by
//! `rust/tests/parallel_parity.rs`.
//!
//! Partial participation no longer assumes broadcast-to-everyone: the
//! PS keeps a FedKSeed-style [`comm::SeedHistory`] of every committed
//! `(round, seed, sign, lr_scale)` record, and a client that missed
//! rounds replays the span on rejoin ([`coordinator::catchup`], the
//! `catchup = "replay" | "rebroadcast" | "off"` knob) — bit-identically
//! to an always-on client, as pinned by `rust/tests/catchup_parity.rs`.
//!
//! Client memory is flat in the pool size: [`coordinator::replica`] is a
//! copy-on-write shared parameter store — one canonical buffer at the
//! committed head round, per-client `Shared`/`Owned` logical replicas,
//! and a single canonical AXPY per committed round — so a pool of
//! hundreds of clients costs the coordinator `O(d)` instead of `K·d`
//! (pinned against a dense K-replica mirror by
//! `rust/tests/replica_parity.rs`).
//!
//! The protocol's robustness story has an executable surface in [`net`]:
//! a deterministic impaired-channel simulator (bit-flip / erasure
//! channels, heterogeneous per-client link profiles, a virtual event
//! clock and a round deadline) sits between the coordinator and the
//! clients, keyed off the same Philox substrate so every impairment
//! trace is reproducible — and `--channel ideal` stays bit-identical to
//! a run without it (`rust/tests/net_parity.rs`).
//!
//! Entry points: [`coordinator::session::Session`] for programmatic use,
//! the `feedsign` binary for the CLI, `examples/` for runnable scenarios
//! and `benches/` for the per-table/figure reproduction harnesses.  The
//! round engine itself is documented end to end in
//! `docs/ARCHITECTURE.md`.

pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dp;
pub mod engine;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod orbit;
pub mod runtime;
pub mod simkit;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
