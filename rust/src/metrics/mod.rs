//! Run metrics: per-round records, CSV/JSON export and mean/std summaries
//! over repeated runs (the paper reports 5-seed means with std brackets).

use crate::comm::Ledger;

/// One evaluation point along a run.  Ledger/engine counters are
/// cumulative snapshots at the eval round, so the CSV reads as a time
/// series of everything the run pays, not just what it scores.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u64,
    pub eval_loss: f32,
    pub eval_acc: f32,
    pub uplink_bits: u64,
    pub downlink_bits: u64,
    /// wall-clock seconds elapsed since the run started
    pub wall_s: f64,
    /// canonical replica commits so far ([`crate::coordinator::ReplicaStats`])
    pub canonical_commits: u64,
    /// canonical-buffer passes the probe batcher saved so far
    pub probe_passes_saved: u64,
    /// coordinator-internal shard vote-merge traffic so far, bits
    pub shard_merge_bits: u64,
    /// uplink messages the impaired channel dropped so far
    pub net_dropped: u64,
    /// payload bits the impaired channel flipped so far
    pub net_flipped: u64,
}

/// The outcome of one federated run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub algorithm: String,
    pub records: Vec<RoundRecord>,
    pub ledger: Ledger,
    pub final_loss: f32,
    pub final_acc: f32,
    pub rounds: u64,
    pub wall_s: f64,
    /// Impaired-channel counters ([`crate::net`]); all zero on a run
    /// without an active simulation.
    pub net: crate::net::NetStats,
    /// Replica-plane accounting ([`crate::coordinator::replica`]):
    /// peak coordinator replica bytes (O(d) on the all-synced path vs
    /// the dense layout's K·d), owned-replica count, and the
    /// one-canonical-AXPY-per-round commit counter.
    pub replica: crate::coordinator::ReplicaStats,
    /// Execute-phase batching counters ([`crate::engine::probe_batch`]):
    /// canonical-buffer passes actually streamed vs the two-per-probe an
    /// unbatched engine would have paid.
    pub probe: crate::engine::ProbeBatchStats,
    /// Sharded-coordinator counters ([`crate::coordinator::shard`]):
    /// shard count, hierarchical vote-merge traffic (coordinator-internal
    /// — never part of the client-facing [`Ledger`]), and rounds whose
    /// next plan was drawn while a straggler shard was still executing.
    /// All zero on the unsharded legacy path.
    pub shard: crate::coordinator::ShardStats,
}

impl RunResult {
    /// Best (max) eval accuracy along the run — the paper reports best
    /// checkpoint metrics.
    pub fn best_acc(&self) -> f32 {
        self.records.iter().map(|r| r.eval_acc).fold(self.final_acc, f32::max)
    }

    /// Best (min) eval loss along the run.
    pub fn best_loss(&self) -> f32 {
        self.records.iter().map(|r| r.eval_loss).fold(self.final_loss, f32::min)
    }

    /// CSV dump, one row per eval point; every counter column is the
    /// cumulative value at that round.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,eval_loss,eval_acc,uplink_bits,downlink_bits,wall_s,\
             canonical_commits,probe_passes_saved,shard_merge_bits,net_dropped,net_flipped\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{},{:.3},{},{},{},{},{}\n",
                r.round,
                r.eval_loss,
                r.eval_acc,
                r.uplink_bits,
                r.downlink_bits,
                r.wall_s,
                r.canonical_commits,
                r.probe_passes_saved,
                r.shard_merge_bits,
                r.net_dropped,
                r.net_flipped
            ));
        }
        s
    }
}

/// mean ± std over repeated runs (population std, like numpy default).
#[derive(Debug, Clone, Copy)]
pub struct MeanStd {
    pub mean: f32,
    pub std: f32,
}

impl std::fmt::Display for MeanStd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.1} ({:.1})", self.mean, self.std)
    }
}

pub fn mean_std(values: &[f32]) -> MeanStd {
    let n = values.len().max(1) as f32;
    let mean = values.iter().sum::<f32>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    MeanStd { mean, std: var.sqrt() }
}

/// Aggregate the best-accuracy metric (in percent) over repeats.
pub fn best_acc_pct(runs: &[RunResult]) -> MeanStd {
    let accs: Vec<f32> = runs.iter().map(|r| r.best_acc() * 100.0).collect();
    mean_std(&accs)
}

/// Pretty-print a metrics table row set: header + one row per method.
pub fn render_table(title: &str, columns: &[&str], rows: &[(String, Vec<String>)]) -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} ==\n"));
    let width = rows.iter().map(|(n, _)| n.len()).max().unwrap_or(8).max(10);
    out.push_str(&format!("{:width$}", "method"));
    for c in columns {
        out.push_str(&format!(" | {c:>12}"));
    }
    out.push('\n');
    out.push_str(&"-".repeat(width + columns.len() * 15));
    out.push('\n');
    for (name, cells) in rows {
        out.push_str(&format!("{name:width$}"));
        for c in cells {
            out.push_str(&format!(" | {c:>12}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(accs: &[f32]) -> RunResult {
        RunResult {
            algorithm: "feedsign".into(),
            records: accs
                .iter()
                .enumerate()
                .map(|(i, &a)| RoundRecord {
                    round: i as u64,
                    eval_loss: 1.0 - a,
                    eval_acc: a,
                    uplink_bits: i as u64,
                    downlink_bits: i as u64,
                    wall_s: i as f64 * 0.5,
                    canonical_commits: i as u64,
                    probe_passes_saved: 2 * i as u64,
                    shard_merge_bits: 0,
                    net_dropped: 0,
                    net_flipped: 0,
                })
                .collect(),
            ledger: Ledger::default(),
            final_loss: 1.0 - accs.last().copied().unwrap_or(0.0),
            final_acc: accs.last().copied().unwrap_or(0.0),
            rounds: accs.len() as u64,
            wall_s: 0.0,
            net: Default::default(),
            replica: Default::default(),
            probe: Default::default(),
            shard: Default::default(),
        }
    }

    #[test]
    fn best_metrics() {
        let r = run(&[0.1, 0.5, 0.3]);
        assert_eq!(r.best_acc(), 0.5);
        assert!((r.best_loss() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn mean_std_basic() {
        let ms = mean_std(&[1.0, 2.0, 3.0]);
        assert!((ms.mean - 2.0).abs() < 1e-6);
        assert!((ms.std - (2.0f32 / 3.0).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn mean_std_display_matches_paper_format() {
        let ms = MeanStd { mean: 87.3, std: 0.5 };
        assert_eq!(format!("{ms}"), "87.3 (0.5)");
    }

    #[test]
    fn csv_has_header_and_rows() {
        let csv = run(&[0.1, 0.2]).to_csv();
        assert!(csv.starts_with("round,"));
        assert_eq!(csv.lines().count(), 3);
        let header = csv.lines().next().unwrap();
        for col in
            ["wall_s", "canonical_commits", "probe_passes_saved", "shard_merge_bits", "net_dropped", "net_flipped"]
        {
            assert!(header.contains(col), "missing column {col}");
        }
        let row = csv.lines().nth(2).unwrap();
        assert_eq!(row.split(',').count(), header.split(',').count());
        assert!(row.contains("0.500"), "wall_s snapshot rendered: {row}");
    }

    #[test]
    fn table_render_contains_cells() {
        let t = render_table(
            "Table X",
            &["acc"],
            &[("feedsign".into(), vec!["87.3 (0.5)".into()])],
        );
        assert!(t.contains("Table X"));
        assert!(t.contains("feedsign"));
        assert!(t.contains("87.3"));
    }
}
