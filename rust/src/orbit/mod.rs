//! Orbit storage: a fine-tuned model as the sequence of aggregated
//! seed-direction steps from a checkpoint (§5 / Appendix D.1, Figs 5–6).
//!
//! A FeedSign orbit entry is a single bit (the seed is the round index);
//! a ZO-FedSGD orbit entry is K seed-projection pairs.  Replaying the
//! orbit over the shared PRNG reconstructs the fine-tuned parameters
//! **bit-exactly** (f32 addition of regenerated terms is deterministic),
//! which is the paper's "OPT-13B fine-tune in < 200 bytes" claim — the
//! `fig5_orbit_storage` bench regenerates the storage-ledger comparison.

use crate::comm::{index_bits_for, SeedPool};
use crate::simkit::{prng, zo};

/// One aggregated global step.
#[derive(Debug, Clone, PartialEq)]
pub enum OrbitEntry {
    /// FeedSign: the 1-bit global vote; seed is the step index.
    Sign(i8),
    /// ZO-FedSGD / MeZO: aggregated seed-projection pairs applied that
    /// step (MeZO has one pair; ZO-FedSGD one per client).
    Pairs(Vec<(u32, f32)>),
    /// Restricted seed space (`seed_pool` mode, FedKSeed): the round's
    /// direction named by a `ceil(log2 K)`-bit index into the pool the
    /// orbit's metadata derives, plus the 1-bit vote.  A 0-sign index
    /// entry replays as a no-op, like [`OrbitEntry::Sign`].
    IndexSign { index: u32, sign: i8 },
}

/// A complete fine-tuning orbit.
#[derive(Debug, Clone)]
pub struct Orbit {
    /// Algorithm tag (matches `Algorithm::name()`).
    pub algorithm: String,
    /// Shared checkpoint the orbit starts from.
    pub init_seed: u32,
    /// Learning rate folded into replay.
    pub eta: f32,
    /// Restricted-seed-pool metadata (`seed_pool` mode): the pool seed
    /// and candidate count [`OrbitEntry::IndexSign`] indices resolve
    /// through.  `pool_k == 0` means no pool — the pre-pool encoding
    /// (version 1) is byte-identical for such orbits.
    pub pool_seed: u32,
    pub pool_k: u32,
    pub entries: Vec<OrbitEntry>,
}

/// Serialized-size magic + versions: version 1 is the pre-pool format;
/// version 2 adds the pool metadata header and index entries, and is
/// only emitted when the orbit actually uses them.
const MAGIC: u32 = 0xFEED_5160;
const VERSION: u8 = 1;
const VERSION_POOL: u8 = 2;

impl Orbit {
    pub fn new(algorithm: &str, init_seed: u32, eta: f32) -> Self {
        Orbit {
            algorithm: algorithm.to_string(),
            init_seed,
            eta,
            pool_seed: 0,
            pool_k: 0,
            entries: Vec::new(),
        }
    }

    /// Attach restricted-seed-pool metadata (`seed_pool` mode) so
    /// [`OrbitEntry::IndexSign`] entries can resolve their directions.
    pub fn set_pool(&mut self, pool_seed: u32, k: usize) {
        assert!(k >= 2, "a seed pool needs at least 2 candidates");
        self.pool_seed = pool_seed;
        self.pool_k = k as u32;
    }

    pub fn push_sign(&mut self, sign: i8) {
        self.entries.push(OrbitEntry::Sign(sign));
    }

    pub fn push_pairs(&mut self, pairs: Vec<(u32, f32)>) {
        self.entries.push(OrbitEntry::Pairs(pairs));
    }

    /// Push a restricted-pool step (requires [`Orbit::set_pool`]).
    pub fn push_index(&mut self, index: u32, sign: i8) {
        debug_assert!(self.pool_k >= 2, "push_index requires pool metadata");
        debug_assert!(index < self.pool_k);
        self.entries.push(OrbitEntry::IndexSign { index, sign });
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Replay the orbit onto a parameter vector (which must be the
    /// checkpoint the orbit started from).  FeedSign steps use
    /// `seed = step index`, exactly the protocol's seed schedule; 0-sign
    /// entries (zero-participant no-op rounds) replay as no-ops while
    /// keeping the seed schedule dense.
    pub fn replay(&self, w: &mut [f32]) {
        self.replay_prefix(w, self.entries.len());
    }

    /// Replay only the first `rounds` entries — the parameters *as of*
    /// round `rounds`.  Entry index equals round index (no-op rounds
    /// store explicit entries), so this reconstructs any historical
    /// replica bit-exactly; the coordinator's replica plane uses it to
    /// materialize a stale logical replica that fell out of the snapshot
    /// cache ([`crate::coordinator::replica`]).
    pub fn replay_prefix(&self, w: &mut [f32], rounds: usize) {
        let pool = (self.pool_k >= 2).then(|| SeedPool::derive(self.pool_seed, self.pool_k as usize));
        for (t, entry) in self.entries.iter().take(rounds).enumerate() {
            match entry {
                OrbitEntry::Sign(s) => {
                    // masked round->seed derivation: the same 31-bit
                    // direction domain every other derivation site uses
                    zo::apply_update(w, prng::round_direction_seed(t as u64), *s as f32 * self.eta);
                }
                OrbitEntry::Pairs(pairs) => {
                    let k = pairs.len().max(1) as f32;
                    for &(seed, p) in pairs {
                        zo::apply_update(w, seed, self.eta * p / k);
                    }
                }
                OrbitEntry::IndexSign { index, sign } => {
                    let pool =
                        pool.as_ref().expect("index orbit entries require pool metadata (set_pool)");
                    zo::apply_update(w, pool.seed_at(*index), *sign as f32 * self.eta);
                }
            }
        }
    }
}

/// Compact binary encoding (separate from serde so the storage ledger
/// reflects true wire size, not JSON overhead).
pub fn encode(orbit: &Orbit) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    // version 2 only when the pool machinery is actually in play, so
    // every pre-pool orbit stays byte-identical to the version-1 format
    let v2 = orbit.pool_k != 0
        || orbit.entries.iter().any(|e| matches!(e, OrbitEntry::IndexSign { .. }));
    out.push(if v2 { VERSION_POOL } else { VERSION });
    let algo = orbit.algorithm.as_bytes();
    out.push(algo.len() as u8);
    out.extend_from_slice(algo);
    out.extend_from_slice(&orbit.init_seed.to_le_bytes());
    out.extend_from_slice(&orbit.eta.to_le_bytes());
    if v2 {
        out.extend_from_slice(&orbit.pool_seed.to_le_bytes());
        out.extend_from_slice(&orbit.pool_k.to_le_bytes());
    }
    out.extend_from_slice(&(orbit.entries.len() as u64).to_le_bytes());

    // homogeneous fast paths: all non-zero Sign entries -> 1 bit each;
    // all non-zero IndexSign entries -> ceil(log2 K) + 1 bits each.
    // Sign(0) / IndexSign{sign: 0} (zero-participant no-op rounds) have
    // no packed encoding, so orbits containing one fall back to the
    // tagged form.
    let all_signs = orbit.entries.iter().all(|e| matches!(e, OrbitEntry::Sign(s) if *s != 0));
    let all_index = v2
        && orbit.pool_k >= 2
        && orbit
            .entries
            .iter()
            .all(|e| matches!(e, OrbitEntry::IndexSign { sign, .. } if *sign != 0));
    let mode: u8 = if all_signs {
        1
    } else if all_index {
        2
    } else {
        0
    };
    out.push(mode);
    match mode {
        1 => {
            let mut byte = 0u8;
            for (i, e) in orbit.entries.iter().enumerate() {
                let OrbitEntry::Sign(s) = e else { unreachable!() };
                if *s > 0 {
                    byte |= 1 << (i % 8);
                }
                if i % 8 == 7 {
                    out.push(byte);
                    byte = 0;
                }
            }
            if orbit.entries.len() % 8 != 0 {
                out.push(byte);
            }
        }
        2 => {
            // LSB-first bit stream of (sign bit, then index bits) per
            // entry — the same ceil(log2 K) + 1 bits the ledger prices
            let ib = index_bits_for(orbit.pool_k as usize) as u32;
            let mut acc = 0u64;
            let mut nbits = 0u32;
            for e in &orbit.entries {
                let OrbitEntry::IndexSign { index, sign } = e else { unreachable!() };
                let val = ((*index as u64) << 1) | (*sign > 0) as u64;
                acc |= val << nbits;
                nbits += ib + 1;
                while nbits >= 8 {
                    out.push(acc as u8);
                    acc >>= 8;
                    nbits -= 8;
                }
            }
            if nbits > 0 {
                out.push(acc as u8);
            }
        }
        _ => {
            for e in &orbit.entries {
                match e {
                    OrbitEntry::Sign(s) => {
                        out.push(0u8);
                        out.push(*s as u8);
                    }
                    OrbitEntry::Pairs(pairs) => {
                        out.push(1u8);
                        out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
                        for (seed, p) in pairs {
                            out.extend_from_slice(&seed.to_le_bytes());
                            out.extend_from_slice(&p.to_le_bytes());
                        }
                    }
                    OrbitEntry::IndexSign { index, sign } => {
                        out.push(2u8);
                        out.extend_from_slice(&index.to_le_bytes());
                        out.push(*sign as u8);
                    }
                }
            }
        }
    }
    out
}

/// Decode [`encode`]'s output.
pub fn decode(bytes: &[u8]) -> anyhow::Result<Orbit> {
    use anyhow::{bail, Context};
    let mut pos = 0usize;
    let mut take = |n: usize| -> anyhow::Result<&[u8]> {
        if pos + n > bytes.len() {
            bail!("orbit truncated at offset {pos}");
        }
        let s = &bytes[pos..pos + n];
        pos += n;
        Ok(s)
    };
    let magic = u32::from_le_bytes(take(4)?.try_into().unwrap());
    if magic != MAGIC {
        bail!("bad orbit magic {magic:#x}");
    }
    let version = take(1)?[0];
    if version != VERSION && version != VERSION_POOL {
        bail!("unsupported orbit version {version}");
    }
    let alen = take(1)?[0] as usize;
    let algorithm = String::from_utf8(take(alen)?.to_vec()).context("algorithm name")?;
    let init_seed = u32::from_le_bytes(take(4)?.try_into().unwrap());
    let eta = f32::from_le_bytes(take(4)?.try_into().unwrap());
    let (pool_seed, pool_k) = if version == VERSION_POOL {
        let ps = u32::from_le_bytes(take(4)?.try_into().unwrap());
        let pk = u32::from_le_bytes(take(4)?.try_into().unwrap());
        (ps, pk)
    } else {
        (0, 0)
    };
    let count = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
    let mode = take(1)?[0];

    let mut entries = Vec::with_capacity(count);
    match mode {
        1 => {
            let nbytes = (count + 7) / 8;
            let packed = take(nbytes)?.to_vec();
            for i in 0..count {
                let bit = (packed[i / 8] >> (i % 8)) & 1;
                entries.push(OrbitEntry::Sign(if bit == 1 { 1 } else { -1 }));
            }
        }
        2 => {
            if version != VERSION_POOL || pool_k < 2 {
                bail!("packed-index orbit without pool metadata");
            }
            let ib = index_bits_for(pool_k as usize) as usize;
            let per = ib + 1;
            let nbytes = (count * per + 7) / 8;
            let packed = take(nbytes)?.to_vec();
            let mut bitpos = 0usize;
            for _ in 0..count {
                let mut val = 0u64;
                for b in 0..per {
                    let p = bitpos + b;
                    if (packed[p / 8] >> (p % 8)) & 1 == 1 {
                        val |= 1 << b;
                    }
                }
                bitpos += per;
                let sign = if val & 1 == 1 { 1i8 } else { -1 };
                let index = (val >> 1) as u32;
                if index >= pool_k {
                    bail!("orbit index {index} outside pool of {pool_k}");
                }
                entries.push(OrbitEntry::IndexSign { index, sign });
            }
        }
        0 => {
            for _ in 0..count {
                let tag = take(1)?[0];
                match tag {
                    0 => entries.push(OrbitEntry::Sign(take(1)?[0] as i8)),
                    1 => {
                        let n = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
                        let mut pairs = Vec::with_capacity(n);
                        for _ in 0..n {
                            let seed = u32::from_le_bytes(take(4)?.try_into().unwrap());
                            let p = f32::from_le_bytes(take(4)?.try_into().unwrap());
                            pairs.push((seed, p));
                        }
                        entries.push(OrbitEntry::Pairs(pairs));
                    }
                    2 if version == VERSION_POOL => {
                        let index = u32::from_le_bytes(take(4)?.try_into().unwrap());
                        let sign = take(1)?[0] as i8;
                        entries.push(OrbitEntry::IndexSign { index, sign });
                    }
                    t => bail!("bad entry tag {t}"),
                }
            }
        }
        m => bail!("bad orbit entry mode {m}"),
    }
    Ok(Orbit { algorithm, init_seed, eta, pool_seed, pool_k, entries })
}

/// Storage ledger entry for the Fig 5/6 comparison.
#[derive(Debug, Clone)]
pub struct StorageReport {
    pub steps: usize,
    pub orbit_bytes: usize,
    pub checkpoint_bytes: usize,
    pub ratio: f64,
}

/// Compare orbit size against a dense f32 checkpoint of `n_params`.
pub fn storage_report(orbit: &Orbit, n_params: usize) -> StorageReport {
    let orbit_bytes = encode(orbit).len();
    let checkpoint_bytes = n_params * 4;
    StorageReport {
        steps: orbit.len(),
        orbit_bytes,
        checkpoint_bytes,
        ratio: checkpoint_bytes as f64 / orbit_bytes.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::prng::normals_vec;

    fn sign_orbit(t: usize) -> Orbit {
        let mut o = Orbit::new("feedsign", 0, 1e-3);
        for i in 0..t {
            o.push_sign(if i % 3 == 0 { -1 } else { 1 });
        }
        o
    }

    #[test]
    fn encode_decode_roundtrip_signs() {
        let o = sign_orbit(1000);
        let bytes = encode(&o);
        let back = decode(&bytes).unwrap();
        assert_eq!(o.entries, back.entries);
        assert_eq!(o.eta, back.eta);
        assert_eq!(o.algorithm, back.algorithm);
    }

    #[test]
    fn encode_decode_roundtrip_pairs() {
        let mut o = Orbit::new("zo-fedsgd", 3, 1e-4);
        o.push_pairs(vec![(1, 0.5), (2, -0.25)]);
        o.push_sign(1); // mixed orbit
        o.push_pairs(vec![(9, 1.25)]);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
    }

    #[test]
    fn feedsign_orbit_is_one_bit_per_step() {
        let o = sign_orbit(10_000);
        let bytes = encode(&o).len();
        // header is ~30 bytes; payload must be 1250 bytes for 10k steps
        assert!(bytes <= 10_000 / 8 + 64, "orbit too large: {bytes}");
    }

    #[test]
    fn paper_claim_200_bytes_at_paper_scale() {
        // §D.1: "10,000 fine-tune steps ... less than 200 bytes" — the paper
        // counts the *information content* (10k bits = 1250 bytes packed, or
        // ~200 bytes after entropy coding of a biased stream).  Our packed
        // format achieves 1 bit/step exactly; verify the OPT-13B comparison
        // direction: 24 GB checkpoint vs ~1.3 KB orbit.
        let o = sign_orbit(10_000);
        let rep = storage_report(&o, 13_000_000_000 / 4 * 4);
        assert!(rep.orbit_bytes < 1400);
        assert!(rep.ratio > 1e6);
    }

    #[test]
    fn replay_reconstructs_bit_exactly() {
        let mut w = normals_vec(42, 512);
        let w0 = w.clone();
        let mut o = Orbit::new("feedsign", 42, 0.01);
        // simulate training: apply updates while recording
        for t in 0..100u32 {
            let s = if t % 2 == 0 { 1i8 } else { -1 };
            crate::simkit::zo::apply_update(&mut w, t, s as f32 * 0.01);
            o.push_sign(s);
        }
        // replay from the checkpoint
        let mut w_replay = w0;
        o.replay(&mut w_replay);
        assert_eq!(w, w_replay, "replay must be bit-exact");
    }

    #[test]
    fn replay_pairs_matches_direct() {
        let mut w = normals_vec(7, 256);
        let w0 = w.clone();
        let mut o = Orbit::new("zo-fedsgd", 7, 0.05);
        for t in 0..20u32 {
            let pairs = vec![(t * 2, 0.3f32), (t * 2 + 1, -0.7f32)];
            for &(s, p) in &pairs {
                crate::simkit::zo::apply_update(&mut w, s, 0.05 * p / 2.0);
            }
            o.push_pairs(pairs);
        }
        let mut w_replay = w0;
        o.replay(&mut w_replay);
        assert_eq!(w, w_replay);
    }

    #[test]
    fn zero_sign_noop_entries_roundtrip_and_replay() {
        // Sign(0) has no bit-packed form; the encoder must take the
        // tagged path and the entry must replay as a no-op
        let mut o = Orbit::new("feedsign", 0, 0.01);
        o.push_sign(1);
        o.push_sign(0);
        o.push_sign(-1);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
        let mut w = normals_vec(5, 128);
        let mut expect = w.clone();
        crate::simkit::zo::apply_update(&mut expect, 0, 0.01);
        crate::simkit::zo::apply_update(&mut expect, 2, -0.01);
        o.replay(&mut w);
        assert_eq!(w, expect, "0-sign entry must not move parameters or shift seeds");
    }

    #[test]
    fn replay_prefix_reconstructs_intermediate_replicas() {
        let init = normals_vec(11, 256);
        let mut w = init.clone();
        let mut o = Orbit::new("feedsign", 11, 0.01);
        let mut snapshots = Vec::new();
        for t in 0..30u32 {
            snapshots.push(w.clone()); // parameters as of round t
            let s = if t % 3 == 0 { -1i8 } else { 1 };
            crate::simkit::zo::apply_update(&mut w, t, s as f32 * 0.01);
            o.push_sign(s);
        }
        for (t, expect) in snapshots.iter().enumerate() {
            let mut wp = init.clone();
            o.replay_prefix(&mut wp, t);
            assert_eq!(&wp, expect, "prefix {t} must be bit-exact");
        }
        // full-length prefix == replay
        let mut wp = init.clone();
        o.replay_prefix(&mut wp, 30);
        assert_eq!(wp, w);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[1, 2, 3]).is_err());
        let mut bytes = encode(&sign_orbit(8));
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn decode_rejects_truncation() {
        let bytes = encode(&sign_orbit(100));
        assert!(decode(&bytes[..bytes.len() - 5]).is_err());
    }

    fn index_orbit(t: usize, pool_seed: u32, k: usize) -> Orbit {
        let mut o = Orbit::new("feedsign", 0, 1e-3);
        o.set_pool(pool_seed, k);
        for i in 0..t {
            let index = ((i * 37) % k) as u32;
            o.push_index(index, if i % 3 == 0 { -1 } else { 1 });
        }
        o
    }

    #[test]
    fn packed_index_orbit_roundtrips() {
        let o = index_orbit(1000, 99, 4096);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
        assert_eq!(back.pool_seed, 99);
        assert_eq!(back.pool_k, 4096);
    }

    #[test]
    fn mixed_index_orbit_with_noop_takes_tagged_path() {
        let mut o = index_orbit(10, 7, 256);
        o.entries.push(OrbitEntry::IndexSign { index: 3, sign: 0 });
        o.push_index(200, 1);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
        assert_eq!(back.pool_k, 256);
    }

    #[test]
    fn index_replay_matches_direct_application() {
        let pool = SeedPool::derive(21, 64);
        let mut w = normals_vec(21, 256);
        let w0 = w.clone();
        let mut o = Orbit::new("feedsign", 21, 0.02);
        o.set_pool(21, 64);
        for t in 0..40usize {
            let index = ((t * 11) % 64) as u32;
            let s = if t % 4 == 0 { -1i8 } else { 1 };
            crate::simkit::zo::apply_update(&mut w, pool.seed_at(index), s as f32 * 0.02);
            o.push_index(index, s);
        }
        let mut w_replay = w0;
        o.replay(&mut w_replay);
        assert_eq!(w, w_replay, "index replay must be bit-exact");
    }

    #[test]
    fn packed_index_orbit_is_log2k_plus_one_bits_per_step() {
        // at K = 4096 an index step packs to 13 bits vs the 64-bit dense
        // (seed, projection) pair — the >= 4x storage win the restricted
        // seed space buys the ledger
        let steps = 10_000;
        let o = index_orbit(steps, 5, 4096);
        let index_bytes = encode(&o).len();
        let mut dense = Orbit::new("zo-fedsgd", 5, 1e-3);
        for i in 0..steps {
            dense.push_pairs(vec![(i as u32, 1.0)]);
        }
        let dense_bytes = encode(&dense).len();
        let per_step_bits = (index_bytes as f64 - 64.0) * 8.0 / steps as f64;
        assert!(per_step_bits <= 13.1, "expected ~13 bits/step, got {per_step_bits}");
        assert!(
            dense_bytes as f64 / index_bytes as f64 >= 4.0,
            "index orbit must be >= 4x smaller than dense pairs ({dense_bytes} vs {index_bytes})"
        );
        let rep = storage_report(&o, 1 << 20);
        assert!(rep.orbit_bytes == index_bytes && rep.steps == steps);
    }

    #[test]
    fn packed_index_roundtrips_at_edge_pool_sizes_including_the_one_bit_floor() {
        // K = 2 exercises the `index_bits_for` 1-bit floor (2 bits/step);
        // K = 3 the first non-power-of-two (3 bits/step); K = 4096 the
        // table-scale pool (13 bits/step).  257 steps: odd length, so the
        // packed stream straddles byte boundaries in every case.
        for (k, per_bits) in [(2usize, 2usize), (3, 3), (4096, 13)] {
            assert_eq!(index_bits_for(k) as usize + 1, per_bits);
            let o = index_orbit(257, 17, k);
            let bytes = encode(&o);
            assert_eq!(bytes[4], VERSION_POOL, "K={k}");
            let header = 4 + 1 + 1 + o.algorithm.len() + 4 + 4 + 4 + 4 + 8 + 1;
            assert_eq!(
                bytes.len(),
                header + (257 * per_bits).div_ceil(8),
                "K={k} must pack ceil(log2 K)+1 = {per_bits} bits/step"
            );
            let back = decode(&bytes).unwrap();
            assert_eq!(back.entries, o.entries, "K={k}");
            assert_eq!(back.pool_seed, 17);
            assert_eq!(back.pool_k, k as u32);
        }
    }

    #[test]
    fn packed_index_roundtrips_at_the_two_power_31_boundary() {
        // the Philox direction domain is 31-bit, so 2^31 candidates is
        // the largest meaningful pool; its indices pack at 31 + 1 = 32
        // bits/step and the top index must survive the bit stream
        let k = 1usize << 31;
        assert_eq!(index_bits_for(k), 31);
        let top = (1u32 << 31) - 1;
        let mut o = Orbit::new("feedsign", 0, 1e-3);
        o.set_pool(13, k);
        for (index, sign) in [(0u32, 1i8), (1, -1), (top - 1, -1), (top, 1)] {
            o.push_index(index, sign);
        }
        let bytes = encode(&o);
        let header = 4 + 1 + 1 + o.algorithm.len() + 4 + 4 + 4 + 4 + 8 + 1;
        assert_eq!(bytes.len(), header + 4 * 32 / 8, "4 steps at 32 bits each");
        let back = decode(&bytes).unwrap();
        assert_eq!(back.entries, o.entries);
        assert_eq!(back.pool_k, 1u32 << 31);
        // a 0-sign no-op at the boundary index has no packed form: the
        // orbit must fall back to the tagged encoding and still roundtrip
        o.entries.push(OrbitEntry::IndexSign { index: top, sign: 0 });
        let tagged = decode(&encode(&o)).unwrap();
        assert_eq!(tagged.entries, o.entries);
    }

    #[test]
    fn decode_rejects_out_of_pool_packed_index() {
        // mode-2 streams validate indices against the pool bound
        let o = index_orbit(16, 3, 8);
        let mut bytes = encode(&o);
        // pool_k lives right after magic+version+alen+name+seed+eta;
        // 5 still needs 3 index bits, so the stream parses at the same
        // width but the orbit's index 7 now lies outside the pool
        let pool_k_at = 4 + 1 + 1 + o.algorithm.len() + 4 + 4 + 4;
        bytes[pool_k_at..pool_k_at + 4].copy_from_slice(&5u32.to_le_bytes());
        assert!(decode(&bytes).is_err(), "indices >= K must be rejected");
    }

    #[test]
    fn plain_sign_orbits_still_encode_as_version_one() {
        // pool-free orbits must stay byte-identical to the pre-pool format
        let bytes = encode(&sign_orbit(64));
        assert_eq!(bytes[4], VERSION);
    }
}
