//! Orbit storage: a fine-tuned model as the sequence of aggregated
//! seed-direction steps from a checkpoint (§5 / Appendix D.1, Figs 5–6).
//!
//! A FeedSign orbit entry is a single bit (the seed is the round index);
//! a ZO-FedSGD orbit entry is K seed-projection pairs.  Replaying the
//! orbit over the shared PRNG reconstructs the fine-tuned parameters
//! **bit-exactly** (f32 addition of regenerated terms is deterministic),
//! which is the paper's "OPT-13B fine-tune in < 200 bytes" claim — the
//! `fig5_orbit_storage` bench regenerates the storage-ledger comparison.

use crate::simkit::zo;

/// One aggregated global step.
#[derive(Debug, Clone, PartialEq)]
pub enum OrbitEntry {
    /// FeedSign: the 1-bit global vote; seed is the step index.
    Sign(i8),
    /// ZO-FedSGD / MeZO: aggregated seed-projection pairs applied that
    /// step (MeZO has one pair; ZO-FedSGD one per client).
    Pairs(Vec<(u32, f32)>),
}

/// A complete fine-tuning orbit.
#[derive(Debug, Clone)]
pub struct Orbit {
    /// Algorithm tag (matches `Algorithm::name()`).
    pub algorithm: String,
    /// Shared checkpoint the orbit starts from.
    pub init_seed: u32,
    /// Learning rate folded into replay.
    pub eta: f32,
    pub entries: Vec<OrbitEntry>,
}

/// Serialized-size magic + version.
const MAGIC: u32 = 0xFEED_5160;
const VERSION: u8 = 1;

impl Orbit {
    pub fn new(algorithm: &str, init_seed: u32, eta: f32) -> Self {
        Orbit { algorithm: algorithm.to_string(), init_seed, eta, entries: Vec::new() }
    }

    pub fn push_sign(&mut self, sign: i8) {
        self.entries.push(OrbitEntry::Sign(sign));
    }

    pub fn push_pairs(&mut self, pairs: Vec<(u32, f32)>) {
        self.entries.push(OrbitEntry::Pairs(pairs));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Replay the orbit onto a parameter vector (which must be the
    /// checkpoint the orbit started from).  FeedSign steps use
    /// `seed = step index`, exactly the protocol's seed schedule; 0-sign
    /// entries (zero-participant no-op rounds) replay as no-ops while
    /// keeping the seed schedule dense.
    pub fn replay(&self, w: &mut [f32]) {
        self.replay_prefix(w, self.entries.len());
    }

    /// Replay only the first `rounds` entries — the parameters *as of*
    /// round `rounds`.  Entry index equals round index (no-op rounds
    /// store explicit entries), so this reconstructs any historical
    /// replica bit-exactly; the coordinator's replica plane uses it to
    /// materialize a stale logical replica that fell out of the snapshot
    /// cache ([`crate::coordinator::replica`]).
    pub fn replay_prefix(&self, w: &mut [f32], rounds: usize) {
        for (t, entry) in self.entries.iter().take(rounds).enumerate() {
            match entry {
                OrbitEntry::Sign(s) => {
                    zo::apply_update(w, t as u32, *s as f32 * self.eta);
                }
                OrbitEntry::Pairs(pairs) => {
                    let k = pairs.len().max(1) as f32;
                    for &(seed, p) in pairs {
                        zo::apply_update(w, seed, self.eta * p / k);
                    }
                }
            }
        }
    }
}

/// Compact binary encoding (separate from serde so the storage ledger
/// reflects true wire size, not JSON overhead).
pub fn encode(orbit: &Orbit) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    let algo = orbit.algorithm.as_bytes();
    out.push(algo.len() as u8);
    out.extend_from_slice(algo);
    out.extend_from_slice(&orbit.init_seed.to_le_bytes());
    out.extend_from_slice(&orbit.eta.to_le_bytes());
    out.extend_from_slice(&(orbit.entries.len() as u64).to_le_bytes());

    // homogeneous fast path: all non-zero Sign entries -> bit-packed.
    // Sign(0) (a zero-participant no-op round) has no bit encoding, so
    // orbits containing one fall back to the tagged form.
    let all_signs = orbit.entries.iter().all(|e| matches!(e, OrbitEntry::Sign(s) if *s != 0));
    out.push(all_signs as u8);
    if all_signs {
        let mut byte = 0u8;
        for (i, e) in orbit.entries.iter().enumerate() {
            let OrbitEntry::Sign(s) = e else { unreachable!() };
            if *s > 0 {
                byte |= 1 << (i % 8);
            }
            if i % 8 == 7 {
                out.push(byte);
                byte = 0;
            }
        }
        if orbit.entries.len() % 8 != 0 {
            out.push(byte);
        }
    } else {
        for e in &orbit.entries {
            match e {
                OrbitEntry::Sign(s) => {
                    out.push(0u8);
                    out.push(*s as u8);
                }
                OrbitEntry::Pairs(pairs) => {
                    out.push(1u8);
                    out.extend_from_slice(&(pairs.len() as u32).to_le_bytes());
                    for (seed, p) in pairs {
                        out.extend_from_slice(&seed.to_le_bytes());
                        out.extend_from_slice(&p.to_le_bytes());
                    }
                }
            }
        }
    }
    out
}

/// Decode [`encode`]'s output.
pub fn decode(bytes: &[u8]) -> anyhow::Result<Orbit> {
    use anyhow::{bail, Context};
    let mut pos = 0usize;
    let mut take = |n: usize| -> anyhow::Result<&[u8]> {
        if pos + n > bytes.len() {
            bail!("orbit truncated at offset {pos}");
        }
        let s = &bytes[pos..pos + n];
        pos += n;
        Ok(s)
    };
    let magic = u32::from_le_bytes(take(4)?.try_into().unwrap());
    if magic != MAGIC {
        bail!("bad orbit magic {magic:#x}");
    }
    let version = take(1)?[0];
    if version != VERSION {
        bail!("unsupported orbit version {version}");
    }
    let alen = take(1)?[0] as usize;
    let algorithm = String::from_utf8(take(alen)?.to_vec()).context("algorithm name")?;
    let init_seed = u32::from_le_bytes(take(4)?.try_into().unwrap());
    let eta = f32::from_le_bytes(take(4)?.try_into().unwrap());
    let count = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
    let all_signs = take(1)?[0] == 1;

    let mut entries = Vec::with_capacity(count);
    if all_signs {
        let nbytes = (count + 7) / 8;
        let packed = take(nbytes)?.to_vec();
        for i in 0..count {
            let bit = (packed[i / 8] >> (i % 8)) & 1;
            entries.push(OrbitEntry::Sign(if bit == 1 { 1 } else { -1 }));
        }
    } else {
        for _ in 0..count {
            let tag = take(1)?[0];
            match tag {
                0 => entries.push(OrbitEntry::Sign(take(1)?[0] as i8)),
                1 => {
                    let n = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
                    let mut pairs = Vec::with_capacity(n);
                    for _ in 0..n {
                        let seed = u32::from_le_bytes(take(4)?.try_into().unwrap());
                        let p = f32::from_le_bytes(take(4)?.try_into().unwrap());
                        pairs.push((seed, p));
                    }
                    entries.push(OrbitEntry::Pairs(pairs));
                }
                t => bail!("bad entry tag {t}"),
            }
        }
    }
    Ok(Orbit { algorithm, init_seed, eta, entries })
}

/// Storage ledger entry for the Fig 5/6 comparison.
#[derive(Debug, Clone)]
pub struct StorageReport {
    pub steps: usize,
    pub orbit_bytes: usize,
    pub checkpoint_bytes: usize,
    pub ratio: f64,
}

/// Compare orbit size against a dense f32 checkpoint of `n_params`.
pub fn storage_report(orbit: &Orbit, n_params: usize) -> StorageReport {
    let orbit_bytes = encode(orbit).len();
    let checkpoint_bytes = n_params * 4;
    StorageReport {
        steps: orbit.len(),
        orbit_bytes,
        checkpoint_bytes,
        ratio: checkpoint_bytes as f64 / orbit_bytes.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simkit::prng::normals_vec;

    fn sign_orbit(t: usize) -> Orbit {
        let mut o = Orbit::new("feedsign", 0, 1e-3);
        for i in 0..t {
            o.push_sign(if i % 3 == 0 { -1 } else { 1 });
        }
        o
    }

    #[test]
    fn encode_decode_roundtrip_signs() {
        let o = sign_orbit(1000);
        let bytes = encode(&o);
        let back = decode(&bytes).unwrap();
        assert_eq!(o.entries, back.entries);
        assert_eq!(o.eta, back.eta);
        assert_eq!(o.algorithm, back.algorithm);
    }

    #[test]
    fn encode_decode_roundtrip_pairs() {
        let mut o = Orbit::new("zo-fedsgd", 3, 1e-4);
        o.push_pairs(vec![(1, 0.5), (2, -0.25)]);
        o.push_sign(1); // mixed orbit
        o.push_pairs(vec![(9, 1.25)]);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
    }

    #[test]
    fn feedsign_orbit_is_one_bit_per_step() {
        let o = sign_orbit(10_000);
        let bytes = encode(&o).len();
        // header is ~30 bytes; payload must be 1250 bytes for 10k steps
        assert!(bytes <= 10_000 / 8 + 64, "orbit too large: {bytes}");
    }

    #[test]
    fn paper_claim_200_bytes_at_paper_scale() {
        // §D.1: "10,000 fine-tune steps ... less than 200 bytes" — the paper
        // counts the *information content* (10k bits = 1250 bytes packed, or
        // ~200 bytes after entropy coding of a biased stream).  Our packed
        // format achieves 1 bit/step exactly; verify the OPT-13B comparison
        // direction: 24 GB checkpoint vs ~1.3 KB orbit.
        let o = sign_orbit(10_000);
        let rep = storage_report(&o, 13_000_000_000 / 4 * 4);
        assert!(rep.orbit_bytes < 1400);
        assert!(rep.ratio > 1e6);
    }

    #[test]
    fn replay_reconstructs_bit_exactly() {
        let mut w = normals_vec(42, 512);
        let w0 = w.clone();
        let mut o = Orbit::new("feedsign", 42, 0.01);
        // simulate training: apply updates while recording
        for t in 0..100u32 {
            let s = if t % 2 == 0 { 1i8 } else { -1 };
            crate::simkit::zo::apply_update(&mut w, t, s as f32 * 0.01);
            o.push_sign(s);
        }
        // replay from the checkpoint
        let mut w_replay = w0;
        o.replay(&mut w_replay);
        assert_eq!(w, w_replay, "replay must be bit-exact");
    }

    #[test]
    fn replay_pairs_matches_direct() {
        let mut w = normals_vec(7, 256);
        let w0 = w.clone();
        let mut o = Orbit::new("zo-fedsgd", 7, 0.05);
        for t in 0..20u32 {
            let pairs = vec![(t * 2, 0.3f32), (t * 2 + 1, -0.7f32)];
            for &(s, p) in &pairs {
                crate::simkit::zo::apply_update(&mut w, s, 0.05 * p / 2.0);
            }
            o.push_pairs(pairs);
        }
        let mut w_replay = w0;
        o.replay(&mut w_replay);
        assert_eq!(w, w_replay);
    }

    #[test]
    fn zero_sign_noop_entries_roundtrip_and_replay() {
        // Sign(0) has no bit-packed form; the encoder must take the
        // tagged path and the entry must replay as a no-op
        let mut o = Orbit::new("feedsign", 0, 0.01);
        o.push_sign(1);
        o.push_sign(0);
        o.push_sign(-1);
        let back = decode(&encode(&o)).unwrap();
        assert_eq!(o.entries, back.entries);
        let mut w = normals_vec(5, 128);
        let mut expect = w.clone();
        crate::simkit::zo::apply_update(&mut expect, 0, 0.01);
        crate::simkit::zo::apply_update(&mut expect, 2, -0.01);
        o.replay(&mut w);
        assert_eq!(w, expect, "0-sign entry must not move parameters or shift seeds");
    }

    #[test]
    fn replay_prefix_reconstructs_intermediate_replicas() {
        let init = normals_vec(11, 256);
        let mut w = init.clone();
        let mut o = Orbit::new("feedsign", 11, 0.01);
        let mut snapshots = Vec::new();
        for t in 0..30u32 {
            snapshots.push(w.clone()); // parameters as of round t
            let s = if t % 3 == 0 { -1i8 } else { 1 };
            crate::simkit::zo::apply_update(&mut w, t, s as f32 * 0.01);
            o.push_sign(s);
        }
        for (t, expect) in snapshots.iter().enumerate() {
            let mut wp = init.clone();
            o.replay_prefix(&mut wp, t);
            assert_eq!(&wp, expect, "prefix {t} must be bit-exact");
        }
        // full-length prefix == replay
        let mut wp = init.clone();
        o.replay_prefix(&mut wp, 30);
        assert_eq!(wp, w);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(decode(&[1, 2, 3]).is_err());
        let mut bytes = encode(&sign_orbit(8));
        bytes[0] ^= 0xFF;
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn decode_rejects_truncation() {
        let bytes = encode(&sign_orbit(100));
        assert!(decode(&bytes[..bytes.len() - 5]).is_err());
    }
}
