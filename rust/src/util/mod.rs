//! In-tree replacements for crates the offline build environment lacks:
//! a JSON parser/writer ([`json`]) for the artifacts manifest and metric
//! dumps, a TOML-subset parser ([`toml_lite`]) for experiment configs, and
//! a randomized property-testing harness ([`proptest_lite`]) built on the
//! crate's own Philox RNG.

pub mod bench;
pub mod json;
pub mod proptest_lite;
pub mod toml_lite;
