//! Randomized property testing on the crate's own Philox RNG (the offline
//! environment has no proptest).  Each property runs `CASES` randomized
//! cases; failures report the case seed so the exact input reproduces with
//! `Gen::new(seed)`.

use crate::simkit::prng::Rng;

pub const CASES: u32 = 64;

/// A deterministic random input generator for one test case.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u32,
}

impl Gen {
    pub fn new(case_seed: u32) -> Self {
        Gen { rng: Rng::new(case_seed, 0x9E57), case_seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.rng.below(hi - lo)
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.uniform()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal()).collect()
    }

    pub fn signs(&mut self, len: usize) -> Vec<i8> {
        (0..len)
            .map(|_| if self.rng.uniform() < 0.5 { 1 } else { -1 })
            .collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }
}

/// Run `property` for [`CASES`] deterministic cases; panics with the case
/// seed on the first failure.
pub fn check(name: &str, mut property: impl FnMut(&mut Gen)) {
    for case in 0..CASES {
        let seed = 0xABCD_0000 ^ case;
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(payload) = result {
            crate::log_warn!("property {name:?} failed at case {case} (Gen seed {seed:#x})");
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(7);
        let mut b = Gen::new(7);
        assert_eq!(a.u32(), b.u32());
        assert_eq!(a.vec_f32(5, 0.0, 1.0), b.vec_f32(5, 0.0, 1.0));
    }

    #[test]
    fn usize_in_bounds() {
        check("usize_in bounds", |g| {
            let v = g.usize_in(3, 10);
            assert!((3..10).contains(&v));
        });
    }

    #[test]
    #[should_panic]
    fn failures_propagate() {
        check("always fails", |_| panic!("boom"));
    }

    #[test]
    fn signs_are_pm_one() {
        check("signs", |g| {
            let s = g.signs(16);
            assert!(s.iter().all(|&v| v == 1 || v == -1));
        });
    }
}
