//! A TOML subset for experiment configs: `[section]` headers and
//! `key = value` pairs with string / integer / float / boolean values.
//! Dotted keys inside sections are not needed — the config schema is flat
//! per section (see `configs/*.toml`).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A scalar config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `sections[""]` holds top-level keys.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: unterminated section header", lineno + 1);
                };
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected `key = value`", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let value = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}", lineno + 1))?;
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            doc.sections.entry(section.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn str(&self, section: &str, key: &str) -> Option<String> {
        self.get(section, key).and_then(|v| v.as_str()).map(str::to_string)
    }

    pub fn int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key).and_then(Value::as_i64)
    }

    pub fn float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(Value::as_f64)
    }

    pub fn bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(Value::as_bool)
    }

    /// Serialize back to text (stable ordering).
    pub fn render(&self) -> String {
        let mut out = String::new();
        if let Some(top) = self.sections.get("") {
            for (k, v) in top {
                out.push_str(&format!("{k} = {}\n", render_value(v)));
            }
        }
        for (name, sec) in &self.sections {
            if name.is_empty() {
                continue;
            }
            out.push_str(&format!("\n[{name}]\n"));
            for (k, v) in sec {
                out.push_str(&format!("{k} = {}\n", render_value(v)));
            }
        }
        out
    }

    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    if let Some(rest) = text.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            bail!("unterminated string: {text:?}");
        };
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if !text.contains(['.', 'e', 'E']) {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {text:?}")
}

fn render_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "exp1"   # a comment
rounds = 2000
eta = 2e-3
verbose = true

[model]
kind = "linear-probe"
dim = 128
"#;

    #[test]
    fn parse_sample() {
        let d = Doc::parse(SAMPLE).unwrap();
        assert_eq!(d.str("", "name").as_deref(), Some("exp1"));
        assert_eq!(d.int("", "rounds"), Some(2000));
        assert!((d.float("", "eta").unwrap() - 2e-3).abs() < 1e-12);
        assert_eq!(d.bool("", "verbose"), Some(true));
        assert_eq!(d.str("model", "kind").as_deref(), Some("linear-probe"));
        assert_eq!(d.int("model", "dim"), Some(128));
    }

    #[test]
    fn comments_respect_strings() {
        let d = Doc::parse(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(d.str("", "s").as_deref(), Some("a#b"));
    }

    #[test]
    fn roundtrip() {
        let d = Doc::parse(SAMPLE).unwrap();
        let text = d.render();
        let d2 = Doc::parse(&text).unwrap();
        assert_eq!(d.str("model", "kind"), d2.str("model", "kind"));
        assert_eq!(d.int("", "rounds"), d2.int("", "rounds"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[open").is_err());
        assert!(Doc::parse("novalue").is_err());
        assert!(Doc::parse("k = \"open").is_err());
        assert!(Doc::parse("k = what").is_err());
    }

    #[test]
    fn int_vs_float() {
        let d = Doc::parse("a = 3\nb = 3.0\n").unwrap();
        assert_eq!(d.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(d.get("", "b"), Some(&Value::Float(3.0)));
        // floats readable as f64 from ints too
        assert_eq!(d.float("", "a"), Some(3.0));
    }
}
