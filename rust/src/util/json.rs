//! Minimal JSON: a recursive-descent parser (full RFC 8259 value grammar,
//! enough for `artifacts/manifest.json`) and a writer for metric dumps.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|n| n as i64 as u32)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", b as char, self.pos)
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let mut end = start + 1;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..end])?);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => bail!("expected , or ] at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => bail!("expected , or }} at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = Json::parse(r#""A\t\\ü""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\ü"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"k":[1,2.5,"s",true,null],"z":{"n":-3}}"#;
        let v = Json::parse(text).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn large_u32_survives() {
        let v = Json::parse("3405705229").unwrap();
        assert_eq!(v.as_u32(), Some(3_405_705_229));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = std::path::Path::new("artifacts/manifest.json");
        if !path.exists() {
            return;
        }
        let text = std::fs::read_to_string(path).unwrap();
        let v = Json::parse(&text).unwrap();
        assert!(v.get("philox").is_some());
        assert!(v.get("models").unwrap().as_obj().unwrap().len() >= 1);
    }
}
