//! Bench-baseline bookkeeping shared by the `harness = false` bench
//! binaries and the test suite.
//!
//! The perf benches write `BENCH_<name>.json` files that double as the
//! committed regression baselines (`benches/common/mod.rs`).  The
//! hard no-regression gate must only arm when **both** sides of the
//! comparison are trustworthy: the committed baseline was written by a
//! full-scale run (`calibrated: true`) *and* the current run is itself
//! full-scale (`FEEDSIGN_BENCH_SCALE >= 1`).  That conjunction used to
//! live inline in `benches/perf_hotpath.rs`, where no `cargo test` could
//! reach it — a smoke-scale baseline (or a baseline missing the
//! `calibrated` flag entirely) must soft-log, never fail the build.
//! Keeping the predicate here makes the uncalibrated path unit-testable.

use crate::util::json::Json;

/// Whether a committed baseline's numbers came from a full-scale run.
/// A missing or non-boolean `calibrated` key means the file predates the
/// flag or was hand-seeded: treat it as uncalibrated.
pub fn baseline_calibrated(base: &Json) -> bool {
    matches!(base.get("calibrated"), Some(Json::Bool(true)))
}

/// Whether the hard regression gate should arm for this run: the
/// baseline is calibrated AND the current run's round-budget scale is
/// full (`>= 1.0`).  NaN or sub-unit scales (smoke runs) never arm.
pub fn regression_gate_armed(base: &Json, scale: f64) -> bool {
    baseline_calibrated(base) && scale >= 1.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn baseline(calibrated: Option<Json>) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::Str("perf_hotpath".to_string()));
        if let Some(c) = calibrated {
            m.insert("calibrated".to_string(), c);
        }
        Json::Obj(m)
    }

    #[test]
    fn uncalibrated_baseline_never_arms_the_gate() {
        // explicit smoke-run baseline
        let smoke = baseline(Some(Json::Bool(false)));
        assert!(!baseline_calibrated(&smoke));
        assert!(!regression_gate_armed(&smoke, 1.0));
        assert!(!regression_gate_armed(&smoke, 8.0));
        // pre-flag baseline file: no `calibrated` key at all
        let legacy = baseline(None);
        assert!(!baseline_calibrated(&legacy));
        assert!(!regression_gate_armed(&legacy, 1.0));
        // corrupt flag types are uncalibrated, not armed
        let corrupt = baseline(Some(Json::Num(1.0)));
        assert!(!baseline_calibrated(&corrupt));
        assert!(!regression_gate_armed(&corrupt, 1.0));
    }

    #[test]
    fn calibrated_baseline_arms_only_at_full_scale() {
        let cal = baseline(Some(Json::Bool(true)));
        assert!(baseline_calibrated(&cal));
        assert!(regression_gate_armed(&cal, 1.0));
        assert!(regression_gate_armed(&cal, 4.0));
        // current run is a smoke run: soft-log, don't gate
        assert!(!regression_gate_armed(&cal, 0.1));
        assert!(!regression_gate_armed(&cal, 0.999));
        assert!(!regression_gate_armed(&cal, f64::NAN));
    }
}
