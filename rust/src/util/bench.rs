//! Bench-baseline bookkeeping shared by the `harness = false` bench
//! binaries and the test suite.
//!
//! The perf benches write `BENCH_<name>.json` files that double as the
//! committed regression baselines (`benches/common/mod.rs`).  The
//! hard no-regression gate must only arm when **both** sides of the
//! comparison are trustworthy: the committed baseline was written by a
//! full-scale run (`calibrated: true`) *and* the current run is itself
//! full-scale (`FEEDSIGN_BENCH_SCALE >= 1`).  That conjunction used to
//! live inline in `benches/perf_hotpath.rs`, where no `cargo test` could
//! reach it — a smoke-scale baseline (or a baseline missing the
//! `calibrated` flag entirely) must soft-log, never fail the build.
//! Keeping the predicate here makes the uncalibrated path unit-testable.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// Run-attribution metadata stamped into every `BENCH_<name>.json`: the
/// git revision the numbers were measured at, the host's thread count,
/// the effective SIMD dispatch width, and the coordinator shard count in
/// force.  Keys are stable (`git_rev`, `threads`, `simd_lanes`,
/// `shards`) so the bench trajectory stays attributable across PRs even
/// when the writing host changes.
pub fn run_metadata() -> BTreeMap<String, Json> {
    let mut m = BTreeMap::new();
    m.insert("git_rev".to_string(), Json::Str(git_rev()));
    m.insert(
        "threads".to_string(),
        Json::Num(std::thread::available_parallelism().map_or(1, |p| p.get()) as f64),
    );
    m.insert(
        "simd_lanes".to_string(),
        Json::Num(crate::simkit::prng::simd_width().lanes() as f64),
    );
    m.insert("shards".to_string(), Json::Num(env_shards() as f64));
    m
}

/// The coordinator shard count the environment pins (`FEEDSIGN_SHARDS`),
/// defaulting to 1 — the same resolution the session/distributed configs
/// use when TOML/CLI leave shards unset.
fn env_shards() -> u64 {
    std::env::var("FEEDSIGN_SHARDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// Short git revision of the working tree, `"unknown"` when git (or the
/// repo) is unavailable — bench artifacts must still write offline.
fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Whether a committed baseline's numbers came from a full-scale run.
/// A missing or non-boolean `calibrated` key means the file predates the
/// flag or was hand-seeded: treat it as uncalibrated.
pub fn baseline_calibrated(base: &Json) -> bool {
    matches!(base.get("calibrated"), Some(Json::Bool(true)))
}

/// Whether the committed baseline was measured in the same environment
/// this run executes in: the `meta` object's `threads`, `simd_lanes`
/// and `shards` must all equal the current [`run_metadata`] values.
/// `git_rev` is deliberately excluded — a committed baseline *should*
/// predate the PR measured against it.  A baseline with no `meta`
/// object (a pre-metadata file) or with any of the three keys missing
/// never matches: numbers measured under an unknown SIMD width or
/// thread count cannot back a hard gate (a W16 baseline would fail
/// every honest W4 run, and vice versa).
pub fn baseline_environment_matches(base: &Json) -> bool {
    let Some(meta) = base.get("meta") else {
        return false;
    };
    let current = run_metadata();
    ["threads", "simd_lanes", "shards"].iter().all(|&k| {
        match (meta.get(k).and_then(Json::as_f64), current.get(k).and_then(Json::as_f64)) {
            (Some(b), Some(c)) => b == c,
            _ => false,
        }
    })
}

/// Whether the hard regression gate should arm for this run: the
/// baseline is calibrated AND the current run's round-budget scale is
/// full (`>= 1.0`) AND the baseline's recorded environment (threads /
/// SIMD lanes / shards — never `git_rev`) matches the current one
/// ([`baseline_environment_matches`]).  NaN or sub-unit scales (smoke
/// runs) and cross-environment comparisons soft-log, never fail the
/// build.
pub fn regression_gate_armed(base: &Json, scale: f64) -> bool {
    baseline_calibrated(base) && scale >= 1.0 && baseline_environment_matches(base)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline(calibrated: Option<Json>) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::Str("perf_hotpath".to_string()));
        if let Some(c) = calibrated {
            m.insert("calibrated".to_string(), c);
        }
        // stamp the current environment (with a divergent git_rev, which
        // must never matter) so environment matching is not the variable
        // under test here
        let mut meta = run_metadata();
        meta.insert("git_rev".to_string(), Json::Str("baseline-rev".to_string()));
        m.insert("meta".to_string(), Json::Obj(meta));
        Json::Obj(m)
    }

    #[test]
    fn uncalibrated_baseline_never_arms_the_gate() {
        // explicit smoke-run baseline
        let smoke = baseline(Some(Json::Bool(false)));
        assert!(!baseline_calibrated(&smoke));
        assert!(!regression_gate_armed(&smoke, 1.0));
        assert!(!regression_gate_armed(&smoke, 8.0));
        // pre-flag baseline file: no `calibrated` key at all
        let legacy = baseline(None);
        assert!(!baseline_calibrated(&legacy));
        assert!(!regression_gate_armed(&legacy, 1.0));
        // corrupt flag types are uncalibrated, not armed
        let corrupt = baseline(Some(Json::Num(1.0)));
        assert!(!baseline_calibrated(&corrupt));
        assert!(!regression_gate_armed(&corrupt, 1.0));
    }

    #[test]
    fn calibrated_baseline_arms_only_at_full_scale() {
        let cal = baseline(Some(Json::Bool(true)));
        assert!(baseline_calibrated(&cal));
        assert!(regression_gate_armed(&cal, 1.0));
        assert!(regression_gate_armed(&cal, 4.0));
        // current run is a smoke run: soft-log, don't gate
        assert!(!regression_gate_armed(&cal, 0.1));
        assert!(!regression_gate_armed(&cal, 0.999));
        assert!(!regression_gate_armed(&cal, f64::NAN));
    }

    #[test]
    fn cross_environment_baseline_never_arms_the_gate() {
        // a calibrated baseline from a *different* environment must
        // soft-log, never gate: perturb each matched key in turn
        for key in ["threads", "simd_lanes", "shards"] {
            let mut base = baseline(Some(Json::Bool(true)));
            if let Json::Obj(m) = &mut base {
                if let Some(Json::Obj(meta)) = m.get_mut("meta") {
                    let cur = meta[key].as_f64().unwrap();
                    meta.insert(key.to_string(), Json::Num(cur + 1.0));
                }
            }
            assert!(!baseline_environment_matches(&base), "perturbed {key} matched");
            assert!(!regression_gate_armed(&base, 1.0), "perturbed {key} armed");
        }
        // a pre-metadata baseline (no `meta` object) never matches
        let mut legacy = baseline(Some(Json::Bool(true)));
        if let Json::Obj(m) = &mut legacy {
            m.remove("meta");
        }
        assert!(!baseline_environment_matches(&legacy));
        assert!(!regression_gate_armed(&legacy, 1.0));
        // but a divergent git_rev alone still arms — baselines are
        // supposed to predate the PR measured against them
        let cal = baseline(Some(Json::Bool(true)));
        assert!(baseline_environment_matches(&cal));
        assert!(regression_gate_armed(&cal, 1.0));
    }

    #[test]
    fn run_metadata_has_stable_keys_and_types() {
        let m = run_metadata();
        for key in ["git_rev", "threads", "simd_lanes", "shards"] {
            assert!(m.contains_key(key), "missing {key}");
        }
        assert!(matches!(m["git_rev"], Json::Str(_)));
        let threads = m["threads"].as_f64().unwrap();
        assert!(threads >= 1.0);
        let lanes = m["simd_lanes"].as_f64().unwrap();
        assert!([1.0, 4.0, 8.0, 16.0].contains(&lanes), "lanes {lanes}");
        assert!(m["shards"].as_f64().unwrap() >= 1.0);
        // git_rev is a short hex hash or the offline fallback
        if let Json::Str(rev) = &m["git_rev"] {
            assert!(
                rev == "unknown" || rev.chars().all(|c| c.is_ascii_hexdigit()),
                "unexpected rev {rev:?}"
            );
        }
    }
}
